#!/usr/bin/env bash
# Perf smoke: the Figure-1 throughput bench on the tiny config, covering
# BOTH executions of the flat/group clipping modes (bk vs twopass), plus
# the serving-engine bench (slot-pool continuous batching vs the
# dispatch-per-token loop). --smoke ASSERTS the acceptance bars: the
# engine wins at 4 slots, AND the paged KV data plane serves strictly
# more concurrent slots than per-slot contiguous caches at the same
# cache-byte budget (the fixed-budget sweep in BENCH_serve.json).
# Writes benchmarks/BENCH_throughput.json + BENCH_serve.json and
# refreshes the cross-PR aggregate benchmarks/BENCH_summary.json.
# bench_startup --smoke additionally ASSERTS that a warm start through the
# persistent compile cache beats the cold start for BOTH the train and
# serve entry points (BENCH_startup.json records the margin).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m benchmarks.bench_throughput
python -m benchmarks.bench_serve --smoke
python -m benchmarks.bench_startup --smoke
python -m benchmarks.run --aggregate-only
