#!/usr/bin/env bash
# Perf smoke: the Figure-1 throughput bench on the tiny config, covering
# BOTH executions of the flat/group clipping modes (bk vs twopass).
# Writes benchmarks/BENCH_throughput.json and refreshes the cross-PR
# aggregate benchmarks/BENCH_summary.json.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m benchmarks.bench_throughput
python -m benchmarks.run --aggregate-only
