#!/usr/bin/env bash
# CI: tier-1 tests + the perf smoke + the 8-virtual-device sharded stage.
set -euo pipefail
cd "$(dirname "$0")"
# the sharded-engine subprocess test is covered by the explicit 8-device
# stage below — deselect it here so CI pays the ~4 min suite once (the
# bare tier-1 command `scripts/test.sh` still runs everything)
./test.sh --deselect \
    tests/test_sharded.py::test_sharded_engine_checks_subprocess
./bench_smoke.sh

# ---- sharded stage: the multi-device engine on 8 virtual CPU devices ----
# Runs the full sharded check suite (parity + the zero-model-axis-norm-
# collectives HLO assertion) with the forced device count, then a quick
# bench_sharded smoke (which subprocesses its own device sets).
cd ..
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python tests/sharded_checks.py
python -m benchmarks.bench_sharded --smoke
python -m benchmarks.run --aggregate-only
