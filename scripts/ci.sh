#!/usr/bin/env bash
# CI: tier-1 tests + the perf smoke in one command.
set -euo pipefail
cd "$(dirname "$0")"
./test.sh
./bench_smoke.sh
