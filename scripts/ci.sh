#!/usr/bin/env bash
# CI: tier-1 tests + the perf smoke + the 8-virtual-device sharded stage.
set -euo pipefail
cd "$(dirname "$0")"
# the sharded-engine subprocess test is covered by the explicit 8-device
# stage below — deselect it here so CI pays the ~4 min suite once (the
# bare tier-1 command `scripts/test.sh` still runs everything)
./test.sh --deselect \
    tests/test_sharded.py::test_sharded_engine_checks_subprocess
./bench_smoke.sh

# ---- serving-engine smoke: ragged request set served through the slot
# pool on CPU, with fewer slots than requests so admission happens
# MID-FLIGHT into recycled slots (parity vs the oracle is asserted by
# tests/test_engine.py in the tier-1 stage above; this exercises the CLI).
cd ..
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --arch tiny --mode engine --batch 4 \
        --slots 3 --prompt-len 12 --min-prompt-len 3 --gen 16

# ---- paged data-plane smoke: the same ragged traffic through the block
# pool with a common system prompt (its full pages are shared
# physically), then a deliberately starved pool (--num-pages below the
# working set) so admission has to evict registered prefixes through the
# host spill tier and re-admit them. Token parity for all of this is
# asserted by tests/test_paged.py; these runs exercise the CLI wiring
# end to end.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --arch tiny --mode engine --batch 4 \
        --slots 3 --prompt-len 12 --min-prompt-len 3 --gen 16 \
        --paging on --page-len 8 --shared-prefix 16
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --arch tiny --mode engine --batch 4 \
        --slots 2 --prompt-len 24 --min-prompt-len 24 --gen 16 \
        --paging on --page-len 8 --num-pages 12
cd scripts

# ---- sharded stage: the multi-device engine on 8 virtual CPU devices ----
# Runs the full sharded check suite (parity + the zero-model-axis-norm-
# collectives HLO assertion) with the forced device count, then a quick
# bench_sharded smoke (which subprocesses its own device sets).
cd ..
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python tests/sharded_checks.py
python -m benchmarks.bench_sharded --smoke
python -m benchmarks.run --aggregate-only
