#!/usr/bin/env bash
# CI: tier-1 tests + the perf smoke + the 8-virtual-device sharded stage.
set -euo pipefail
cd "$(dirname "$0")"
# the sharded-engine subprocess test is covered by the explicit 8-device
# stage below — deselect it here so CI pays the ~4 min suite once (the
# bare tier-1 command `scripts/test.sh` still runs everything)
./test.sh --deselect \
    tests/test_sharded.py::test_sharded_engine_checks_subprocess
./bench_smoke.sh

# ---- serving-engine smoke: ragged request set served through the slot
# pool on CPU, with fewer slots than requests so admission happens
# MID-FLIGHT into recycled slots (parity vs the oracle is asserted by
# tests/test_engine.py in the tier-1 stage above; this exercises the CLI).
cd ..
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --arch tiny --mode engine --batch 4 \
        --slots 3 --prompt-len 12 --min-prompt-len 3 --gen 16

# ---- paged data-plane smoke: the same ragged traffic through the block
# pool with a common system prompt (its full pages are shared
# physically), then a deliberately starved pool (--num-pages below the
# working set) so admission has to evict registered prefixes through the
# host spill tier and re-admit them. Token parity for all of this is
# asserted by tests/test_paged.py; these runs exercise the CLI wiring
# end to end.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --arch tiny --mode engine --batch 4 \
        --slots 3 --prompt-len 12 --min-prompt-len 3 --gen 16 \
        --paging on --page-len 8 --shared-prefix 16
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --arch tiny --mode engine --batch 4 \
        --slots 2 --prompt-len 24 --min-prompt-len 24 --gen 16 \
        --paging on --page-len 8 --num-pages 12

# ---- multi-tenant smoke: two tenants on one engine through the CLI (no
# adapter dirs -> both serve the base model; mixed-pool parity, hot-swap
# bitwise verification, and zero-recompile asserts run in tier-1 via
# tests/test_engine.py / tests/test_swap.py; the end-to-end train ->
# publish -> swap loop is examples/multi_tenant_serve.py).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --arch tiny --mode engine --batch 4 \
        --slots 3 --prompt-len 12 --min-prompt-len 3 --gen 16 \
        --tenants 2 --lora-rank 4

# ---- doc drift: CLI flags <-> docs, link targets, the generated
# engine-stats table (also part of tier-1; re-run here standalone so a
# docs-only change failing CI names this stage, not the whole suite).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q tests/test_docs.py
cd scripts

# ---- crash-safe service smoke: the REAL kill -9 variant of the fault
# matrix (tier-1 runs the same points in-process via tests/test_service.py).
# A reference service runs 8 steps uninterrupted; a second one is killed
# by --fault-at (os._exit mid-publish) and resumed. The resumed run must
# be bitwise identical to the reference — checkpoint shards, sampler
# stream, AND ledger bytes — and the replayed epsilon must be monotone.
cd ..
SVC_ROOT="$(mktemp -d /tmp/repro_svc_ci.XXXXXX)"
SVC_ARGS=(--arch tiny --steps 8 --batch 8 --seq 32 --docs 64 --sigma 0.8
          --checkpoint-every 3 --log-every 100)
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.service --service-dir "$SVC_ROOT/ref" \
        "${SVC_ARGS[@]}"
for fault in post-ledger-append:5 pre-ckpt-rename:6; do
    dir="$SVC_ROOT/fault-${fault//:/-}"
    rc=0
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.launch.service --service-dir "$dir" \
            "${SVC_ARGS[@]}" --fault-at "$fault" || rc=$?
    [ "$rc" -eq 86 ] || { echo "fault $fault: expected exit 86, got $rc"; exit 1; }
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.launch.service --service-dir "$dir" "${SVC_ARGS[@]}"
done
PYTHONPATH="src:tests${PYTHONPATH:+:$PYTHONPATH}" python - "$SVC_ROOT" <<'EOF'
import sys
import faults
from repro.core.accounting import RdpAccountant
root = sys.argv[1]
ref = faults.state_digest(f"{root}/ref")
for fault in ("post-ledger-append-5", "pre-ckpt-rename-6"):
    got = faults.state_digest(f"{root}/fault-{fault}")
    assert got == ref, f"{fault}: resumed state differs from reference"
recs = faults.ledger_records(f"{root}/ref")
acct, eps_seq = RdpAccountant(), []
for r in recs:
    acct.spend(r["q"], r["sigma"])
    eps_seq.append(acct.epsilon(1e-5))
assert eps_seq == sorted(eps_seq) and eps_seq[0] > 0, "epsilon not monotone"
print(f"service smoke OK: {len(recs)} ledgered steps, "
      f"eps={eps_seq[-1]:.4f}, kill/resume bitwise-identical")
EOF
rm -rf "$SVC_ROOT"
cd scripts

# ---- autotune round trip: the timing sweep persists its table in one
# process and a SECOND process (a fleet worker, after pre-warm) loads it
# and resolves `auto` to the measured argmin on every measured bucket.
cd ..
AT_ROOT="$(mktemp -d /tmp/repro_autotune_ci.XXXXXX)"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.kernels.autotune --sweep --cache-dir "$AT_ROOT"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - "$AT_ROOT" <<'EOF'
import sys
from repro.kernels import autotune, backend
tab = autotune.install_default(sys.argv[1])
assert tab.stale_reason is None and len(tab) > 0, tab.stale_reason
cfg = backend.EngineConfig(backend="auto")
for key, slot in tab.entries.items():
    op, t, di, do = key.split("|")
    t, di, do = (int(x[1:]) for x in (t, di, do))
    measured = {b: v["us"] for b, v in slot.items()
                if v["source"] == "measured"}
    want = min(measured, key=measured.get)
    got = backend.choose_op(op, t, di, do, cfg)
    assert got == want, (key, got, want)
print(f"autotune round-trip OK: {len(tab)} buckets, auto == measured "
      "argmin in a second process")
EOF
rm -rf "$AT_ROOT"
cd scripts

# ---- sharded stage: the multi-device engine on 8 virtual CPU devices ----
# Runs the full sharded check suite (parity + the zero-model-axis-norm-
# collectives HLO assertion) with the forced device count, then a quick
# bench_sharded smoke (which subprocesses its own device sets).
cd ..
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python tests/sharded_checks.py
python -m benchmarks.bench_sharded --smoke
python -m benchmarks.run --aggregate-only

# ---- static DP-safety audit: the full clipping x execution x mesh matrix ----
# Both analyzer passes (jaxpr taint + HLO rules) on every supported config;
# writes benchmarks/AUDIT.json, exits non-zero on any ERROR finding. The
# seeded-violation selftest first proves the auditor still has teeth.
# (the CLI forces its own 8-device count before jax loads)
python -m repro.launch.audit --selftest
python -m repro.launch.audit --matrix
