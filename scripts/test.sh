#!/usr/bin/env bash
# Tier-1 verify: one command, from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
