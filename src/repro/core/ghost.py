"""Per-example gradient norms WITHOUT materializing per-example gradients.

This is the computational core of the paper's fused per-layer clipping
(Sec. 3.1), built on the "ghost norm" identity (Goodfellow 2015;
Li et al. 2022b Sec. 4): for a linear layer y = x @ W with per-example
activations A_i in R^{T x d_in} and output cotangents G_i in R^{T x d_out},
the per-example weight gradient is A_i^T G_i and

    || A_i^T G_i ||_F^2  =  < A_i A_i^T ,  G_i G_i^T >        (gram path)
                         =  sum_{t,t'} <a_t, a_t'> <g_t, g_t'>

which costs O(T^2 (d_in + d_out)) instead of O(T d_in d_out) and never forms
the (d_in x d_out) per-example matrix. When T^2 > d_in * d_out the outer
path (materialize per-example grad, but only transiently inside the fused
op) is cheaper; `linear_norms_sq` picks automatically, mirroring the mixed
ghost-clipping dispatch of Bu et al. (2022).

These are the pure-jnp reference implementations; `repro.kernels.ops`
provides Pallas TPU kernels with identical semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

ACC_DTYPE = jnp.float32


def _as3d(x: jax.Array) -> jax.Array:
    """(B, d) -> (B, 1, d); (B, T, d) unchanged; higher ranks folded into T."""
    if x.ndim == 2:
        return x[:, None, :]
    if x.ndim == 3:
        return x
    return x.reshape(x.shape[0], -1, x.shape[-1])


def gram_path_cost(t: int, din: int, dout: int) -> int:
    return t * t * (din + dout + 1)


def outer_path_cost(t: int, din: int, dout: int) -> int:
    return t * din * dout + din * dout


# Memory guardrails for path selection (elements, not bytes).
# NOTE (§Perf): these reason about LOGICAL shapes; under model-axis sharding
# the outer path's (B, din, dout) transient is sharded on dout and the cap
# can safely be raised ~model_size x (scoped engine config — see
# repro.kernels.backend), which also avoids the gram path's un-shardable
# T² work — a large win at long sequence.
DEFAULT_OUTER_MAX_ELEMS = 1 << 22  # per-example materialized grad cap
DEFAULT_GRAM_CHUNK = 1024  # row-block size for the chunked gram path
_OUTER_MAX_ELEMS = DEFAULT_OUTER_MAX_ELEMS
_GRAM_CHUNK = DEFAULT_GRAM_CHUNK

_EPS = 1e-12


def configure(*, outer_max_elems: int | None = None,
              gram_chunk: int | None = None) -> dict:
    """Set module-global ghost-path policy (returns the previous values).

    DEPRECATED for engine users: prefer `repro.kernels.backend.scoped(...)`,
    which threads the policy through without mutating globals — jitted step
    functions then capture their policy statically at trace time. Direct
    callers of this module still honor these globals as defaults.
    """
    global _OUTER_MAX_ELEMS, _GRAM_CHUNK
    prev = {"outer_max_elems": _OUTER_MAX_ELEMS, "gram_chunk": _GRAM_CHUNK}
    if outer_max_elems is not None:
        _OUTER_MAX_ELEMS = outer_max_elems
    if gram_chunk is not None:
        _GRAM_CHUNK = gram_chunk
    return prev


def clip_factor(c: jax.Array, norms_sq: jax.Array) -> jax.Array:
    """Per-example clip factor from encoded thresholds.

    Encoding (one mechanism drives every clipping mode — see
    core.dp_layers module doc):
        c > 0     -> min(1, c / ||g_i||)   (clip to threshold)
        c == +inf -> 1                     (no clipping)
        c < 0     -> |c|                   (direct scale, two-pass modes)
    """
    # dp_clip_factor: the static auditor's anchor (repro.analysis) — norm
    # data is consumed here; what leaves is a bounded scaling factor
    with jax.named_scope("dp_clip_factor"):
        c = c.astype(jnp.float32)
        n = norms_sq.astype(jnp.float32)
        clipped = jnp.minimum(1.0, c * jax.lax.rsqrt(n + _EPS))
        factor = jnp.where(jnp.isinf(c), 1.0, clipped)
        return jnp.where(c < 0, -c, factor)


def linear_norms_sq(a: jax.Array, g: jax.Array, *,
                    force_path: str | None = None,
                    outer_max_elems: int | None = None,
                    gram_chunk: int | None = None) -> jax.Array:
    """(B,) squared Frobenius norms of per-example grads A_i^T G_i.

    a: (B, T, d_in) or (B, d_in) activations into the layer.
    g: (B, T, d_out) or (B, d_out) cotangents w.r.t. the layer output.
    force_path: 'gram' | 'gram_chunked' | 'outer' | None (auto).
    outer_max_elems / gram_chunk: explicit policy (None -> module globals).

    Auto selection minimizes flops subject to a memory cap: the outer path
    transiently materializes (B, d_in, d_out) so it is only allowed for
    small weights; the gram path materializes (B, T, T), chunked into
    (B, chunk, T) row blocks when T is large — the same blocking the Pallas
    kernel uses in VMEM.
    """
    outer_cap = (_OUTER_MAX_ELEMS if outer_max_elems is None
                 else outer_max_elems)
    chunk = _GRAM_CHUNK if gram_chunk is None else gram_chunk
    a3, g3 = _as3d(a).astype(ACC_DTYPE), _as3d(g).astype(ACC_DTYPE)
    b, t, din = a3.shape
    dout = g3.shape[-1]
    if t == 1:
        # rank-1: ||a_i g_i^T||_F^2 = ||a_i||^2 ||g_i||^2
        return (jnp.sum(a3 * a3, axis=(1, 2)) * jnp.sum(g3 * g3, axis=(1, 2)))
    path = force_path
    if path is None:
        outer_ok = din * dout <= outer_cap
        if outer_ok and outer_path_cost(t, din, dout) < gram_path_cost(t, din, dout):
            path = "outer"
        elif t > chunk:
            path = "gram_chunked"
        else:
            path = "gram"
    if path == "gram":
        gram_a = jnp.einsum("bti,bsi->bts", a3, a3)
        gram_g = jnp.einsum("bto,bso->bts", g3, g3)
        return jnp.sum(gram_a * gram_g, axis=(1, 2))
    if path == "gram_chunked":
        nb = -(-t // chunk)
        pad = nb * chunk - t
        ap = jnp.pad(a3, ((0, 0), (0, pad), (0, 0)))
        gp = jnp.pad(g3, ((0, 0), (0, pad), (0, 0)))
        ac = ap.reshape(b, nb, chunk, din)
        gc = gp.reshape(b, nb, chunk, dout)

        def body(acc, blk):
            ablk, gblk = blk  # (B, chunk, d)
            ga = jnp.einsum("bci,bti->bct", ablk, ap)
            gg = jnp.einsum("bco,bto->bct", gblk, gp)
            return acc + jnp.sum(ga * gg, axis=(1, 2)), None

        acc, _ = jax.lax.scan(
            body, jnp.zeros((b,), ACC_DTYPE),
            (jnp.moveaxis(ac, 1, 0), jnp.moveaxis(gc, 1, 0)))
        return acc
    if path == "outer":
        pg = jnp.einsum("bti,bto->bio", a3, g3)
        return jnp.sum(pg * pg, axis=(1, 2))
    raise ValueError(f"unknown path {path!r}")


def bias_norms_sq(g: jax.Array) -> jax.Array:
    """(B,) squared norms of per-example bias grads sum_t g_t."""
    g3 = _as3d(g).astype(ACC_DTYPE)
    s = jnp.sum(g3, axis=1)
    return jnp.sum(s * s, axis=-1)


def embed_norms_sq(ids: jax.Array, g: jax.Array, *,
                   gram_chunk: int | None = None) -> jax.Array:
    """(B,) squared norms of per-example embedding grads (collision-exact).

    Per-example grad of the embedding table is the scatter-add of cotangent
    rows g_t into rows ids_t; repeated tokens within an example collide, so

        ||grad_i||^2 = sum_{t,t'} 1[ids_t == ids_t'] <g_t, g_t'>
                     = < EqualityMask_i , G_i G_i^T >.
    """
    chunk = _GRAM_CHUNK if gram_chunk is None else gram_chunk
    ids2 = ids.reshape(ids.shape[0], -1)
    g3 = _as3d(g).astype(ACC_DTYPE)
    b, t, d = g3.shape
    if t <= chunk:
        eq = (ids2[:, :, None] == ids2[:, None, :]).astype(ACC_DTYPE)
        gram_g = jnp.einsum("btd,bsd->bts", g3, g3)
        return jnp.sum(eq * gram_g, axis=(1, 2))
    # chunked: row blocks against the full sequence
    nb = -(-t // chunk)
    pad = nb * chunk - t
    gp = jnp.pad(g3, ((0, 0), (0, pad), (0, 0)))
    # pad ids with -1 (padded g rows are zero, so their matches contribute 0)
    ip = jnp.pad(ids2, ((0, 0), (0, pad)), constant_values=-1)
    gc = gp.reshape(b, nb, chunk, d)
    ic = ip.reshape(b, nb, chunk)

    def body(acc, blk):
        gblk, iblk = blk
        gram = jnp.einsum("bcd,btd->bct", gblk, gp)
        eq = (iblk[:, :, None] == ip[:, None, :]).astype(ACC_DTYPE)
        return acc + jnp.sum(gram * eq, axis=(1, 2)), None

    acc, _ = jax.lax.scan(body, jnp.zeros((b,), ACC_DTYPE),
                          (jnp.moveaxis(gc, 1, 0), jnp.moveaxis(ic, 1, 0)))
    return acc


def scale_norms_sq(xhat: jax.Array, g: jax.Array) -> jax.Array:
    """(B,) squared norms for an elementwise-scale parameter y = s * xhat.

    Per-example grad ds_i = sum_t (g ⊙ xhat)_t, a (d,)-vector — cheap to
    materialize per example.
    """
    gx = _as3d(g * xhat).astype(ACC_DTYPE)
    s = jnp.sum(gx, axis=1)
    return jnp.sum(s * s, axis=-1)


def vector_norms_sq(per_example_grad: jax.Array) -> jax.Array:
    """(B,) norms² for the broadcast-trick fallback: grads already (B, ...)."""
    g = per_example_grad.astype(ACC_DTYPE)
    return jnp.sum(g * g, axis=tuple(range(1, g.ndim)))


# ---------------------------------------------------------------------------
# Blocked (per-shard) norms: norms of column/row blocks of the weight grad.
# ---------------------------------------------------------------------------


def linear_norms_sq_blocked(
    a: jax.Array, g: jax.Array, num_blocks: int, *, block_axis: str = "out"
) -> jax.Array:
    """(B, M) squared norms of per-example grads of M weight blocks.

    Used by per-shard (per-device) clipping: the weight is Megatron-sharded
    into M column blocks (block_axis='out', column parallel) or M row blocks
    (block_axis='in', row parallel); each block is its own clipping group so
    the norm reduction never crosses shards.
    """
    a3, g3 = _as3d(a).astype(ACC_DTYPE), _as3d(g).astype(ACC_DTYPE)
    b, t, din = a3.shape
    dout = g3.shape[-1]
    m = num_blocks
    if block_axis == "out":
        if dout % m:
            raise ValueError(f"dout={dout} not divisible by num_blocks={m}")
        gb = g3.reshape(b, t, m, dout // m)
        gram_a = jnp.einsum("bti,bsi->bts", a3, a3)
        gram_gb = jnp.einsum("btmo,bsmo->bmts", gb, gb)
        return jnp.einsum("bts,bmts->bm", gram_a, gram_gb)
    if block_axis == "in":
        if din % m:
            raise ValueError(f"din={din} not divisible by num_blocks={m}")
        ab = a3.reshape(b, t, m, din // m)
        gram_g = jnp.einsum("bto,bso->bts", g3, g3)
        gram_ab = jnp.einsum("btmi,bsmi->bmts", ab, ab)
        return jnp.einsum("bts,bmts->bm", gram_g, gram_ab)
    raise ValueError(f"block_axis must be 'out' or 'in', got {block_axis!r}")


# ---------------------------------------------------------------------------
# Fused clipped sums.
# ---------------------------------------------------------------------------


def clipped_sum_linear(a: jax.Array, g: jax.Array, factors: jax.Array
                       ) -> jax.Array:
    """sum_i c_i A_i^T G_i as one scaled contraction. factors: (B,).

    f32 accumulation throughout (like every clipped sum here): quantizing
    the clip factor to bf16 would let clipped contributions exceed the
    sensitivity bound, and the Pallas clip_reduce kernel computes in f32 —
    the reference must match it.
    """
    a3, g3 = _as3d(a).astype(ACC_DTYPE), _as3d(g).astype(ACC_DTYPE)
    gs = g3 * factors[:, None, None].astype(ACC_DTYPE)
    return jnp.einsum("bti,bto->io", a3, gs)


def fold_block_factors(a3: jax.Array, g3: jax.Array, factors: jax.Array,
                       block_axis: str = "out"
                       ) -> tuple[jax.Array, jax.Array]:
    """Fold per-block clip factors (B, M) into the blocked operand.

    Returns (a3, g3) in f32 with the factor absorbed into the tensor whose
    feature axis is blocked — shared by the jnp path below and the Pallas
    backend (which then runs the big contraction with unit row factors).
    The f32 fold keeps clip factors unquantized (sensitivity bound) and
    matches the kernels' accumulation dtype.
    """
    a3 = a3.astype(ACC_DTYPE)
    g3 = g3.astype(ACC_DTYPE)
    b, t, din = a3.shape
    dout = g3.shape[-1]
    m = factors.shape[-1]
    if block_axis == "out":
        g3 = (g3.reshape(b, t, m, dout // m)
              * factors[:, None, :, None].astype(ACC_DTYPE)
              ).reshape(b, t, dout)
    else:
        a3 = (a3.reshape(b, t, m, din // m)
              * factors[:, None, :, None].astype(ACC_DTYPE)
              ).reshape(b, t, din)
    return a3, g3


def clipped_sum_linear_blocked(
    a: jax.Array, g: jax.Array, factors: jax.Array, *, block_axis: str = "out"
) -> jax.Array:
    """sum_i A_i^T diag-blocked(c_i) G_i; factors: (B, M) per block."""
    a3, g3 = fold_block_factors(_as3d(a), _as3d(g), factors, block_axis)
    return jnp.einsum("bti,bto->io", a3, g3)


def clipped_sum_bias(g: jax.Array, factors: jax.Array) -> jax.Array:
    # accumulate in f32: the B*T reduction and the clip factors must not
    # quantize to bf16 or clipped contributions can exceed the sensitivity
    # bound (callers cast the result back to the param dtype)
    g3 = _as3d(g).astype(ACC_DTYPE)
    return jnp.einsum("bto,b->o", g3, factors.astype(ACC_DTYPE))


def clipped_sum_embed(ids: jax.Array, g: jax.Array, factors: jax.Array,
                      vocab: int) -> jax.Array:
    ids2 = ids.reshape(ids.shape[0], -1)
    g3 = _as3d(g).astype(ACC_DTYPE)  # f32 factors + accumulation, as above
    gs = (g3 * factors[:, None, None].astype(ACC_DTYPE)
          ).reshape(-1, g3.shape[-1])
    out = jnp.zeros((vocab, g3.shape[-1]), dtype=ACC_DTYPE)
    return out.at[ids2.reshape(-1)].add(gs)


def clipped_sum_scale(xhat: jax.Array, g: jax.Array, factors: jax.Array
                      ) -> jax.Array:
    gx = _as3d(g * xhat).astype(ACC_DTYPE)  # f32 accumulation, as bias
    return jnp.einsum("btd,b->d", gx, factors.astype(ACC_DTYPE))
