"""Per-example gradient norms WITHOUT materializing per-example gradients.

This is the computational core of the paper's fused per-layer clipping
(Sec. 3.1), built on the "ghost norm" identity (Goodfellow 2015;
Li et al. 2022b Sec. 4): for a linear layer y = x @ W with per-example
activations A_i in R^{T x d_in} and output cotangents G_i in R^{T x d_out},
the per-example weight gradient is A_i^T G_i and

    || A_i^T G_i ||_F^2  =  < A_i A_i^T ,  G_i G_i^T >        (gram path)
                         =  sum_{t,t'} <a_t, a_t'> <g_t, g_t'>

which costs O(T^2 (d_in + d_out)) instead of O(T d_in d_out) and never forms
the (d_in x d_out) per-example matrix. When T^2 > d_in * d_out the outer
path (materialize per-example grad, but only transiently inside the fused
op) is cheaper; `linear_norms_sq` picks automatically, mirroring the mixed
ghost-clipping dispatch of Bu et al. (2022).

These are the pure-jnp reference implementations; `repro.kernels.ops`
provides Pallas TPU kernels with identical semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

ACC_DTYPE = jnp.float32


def _as3d(x: jax.Array) -> jax.Array:
    """(B, d) -> (B, 1, d); (B, T, d) unchanged; higher ranks folded into T."""
    if x.ndim == 2:
        return x[:, None, :]
    if x.ndim == 3:
        return x
    return x.reshape(x.shape[0], -1, x.shape[-1])


def gram_path_cost(t: int, din: int, dout: int) -> int:
    return t * t * (din + dout + 1)


def outer_path_cost(t: int, din: int, dout: int) -> int:
    return t * din * dout + din * dout


# Memory guardrails for path selection (elements, not bytes).
# NOTE (§Perf): these reason about LOGICAL shapes; under model-axis sharding
# the outer path's (B, din, dout) transient is sharded on dout and the cap
# can safely be raised ~model_size x (configure()), which also avoids the
# gram path's un-shardable T² work — a large win at long sequence.
_OUTER_MAX_ELEMS = 1 << 22  # per-example materialized grad cap (outer path)
_GRAM_CHUNK = 1024  # row-block size for the chunked gram path


def configure(*, outer_max_elems: int | None = None,
              gram_chunk: int | None = None) -> dict:
    """Set ghost-path policy (returns the previous values)."""
    global _OUTER_MAX_ELEMS, _GRAM_CHUNK
    prev = {"outer_max_elems": _OUTER_MAX_ELEMS, "gram_chunk": _GRAM_CHUNK}
    if outer_max_elems is not None:
        _OUTER_MAX_ELEMS = outer_max_elems
    if gram_chunk is not None:
        _GRAM_CHUNK = gram_chunk
    return prev


def linear_norms_sq(a: jax.Array, g: jax.Array, *, force_path: str | None = None
                    ) -> jax.Array:
    """(B,) squared Frobenius norms of per-example grads A_i^T G_i.

    a: (B, T, d_in) or (B, d_in) activations into the layer.
    g: (B, T, d_out) or (B, d_out) cotangents w.r.t. the layer output.
    force_path: 'gram' | 'gram_chunked' | 'outer' | None (auto).

    Auto selection minimizes flops subject to a memory cap: the outer path
    transiently materializes (B, d_in, d_out) so it is only allowed for
    small weights; the gram path materializes (B, T, T), chunked into
    (B, chunk, T) row blocks when T is large — the same blocking the Pallas
    kernel uses in VMEM.
    """
    a3, g3 = _as3d(a).astype(ACC_DTYPE), _as3d(g).astype(ACC_DTYPE)
    b, t, din = a3.shape
    dout = g3.shape[-1]
    if t == 1:
        # rank-1: ||a_i g_i^T||_F^2 = ||a_i||^2 ||g_i||^2
        return (jnp.sum(a3 * a3, axis=(1, 2)) * jnp.sum(g3 * g3, axis=(1, 2)))
    path = force_path
    if path is None:
        outer_ok = din * dout <= _OUTER_MAX_ELEMS
        if outer_ok and outer_path_cost(t, din, dout) < gram_path_cost(t, din, dout):
            path = "outer"
        elif t > _GRAM_CHUNK:
            path = "gram_chunked"
        else:
            path = "gram"
    if path == "gram":
        gram_a = jnp.einsum("bti,bsi->bts", a3, a3)
        gram_g = jnp.einsum("bto,bso->bts", g3, g3)
        return jnp.sum(gram_a * gram_g, axis=(1, 2))
    if path == "gram_chunked":
        nb = -(-t // _GRAM_CHUNK)
        pad = nb * _GRAM_CHUNK - t
        ap = jnp.pad(a3, ((0, 0), (0, pad), (0, 0)))
        gp = jnp.pad(g3, ((0, 0), (0, pad), (0, 0)))
        ac = ap.reshape(b, nb, _GRAM_CHUNK, din)
        gc = gp.reshape(b, nb, _GRAM_CHUNK, dout)

        def body(acc, blk):
            ablk, gblk = blk  # (B, chunk, d)
            ga = jnp.einsum("bci,bti->bct", ablk, ap)
            gg = jnp.einsum("bco,bto->bct", gblk, gp)
            return acc + jnp.sum(ga * gg, axis=(1, 2)), None

        acc, _ = jax.lax.scan(
            body, jnp.zeros((b,), ACC_DTYPE),
            (jnp.moveaxis(ac, 1, 0), jnp.moveaxis(gc, 1, 0)))
        return acc
    if path == "outer":
        pg = jnp.einsum("bti,bto->bio", a3, g3)
        return jnp.sum(pg * pg, axis=(1, 2))
    raise ValueError(f"unknown path {path!r}")


def bias_norms_sq(g: jax.Array) -> jax.Array:
    """(B,) squared norms of per-example bias grads sum_t g_t."""
    g3 = _as3d(g).astype(ACC_DTYPE)
    s = jnp.sum(g3, axis=1)
    return jnp.sum(s * s, axis=-1)


def embed_norms_sq(ids: jax.Array, g: jax.Array) -> jax.Array:
    """(B,) squared norms of per-example embedding grads (collision-exact).

    Per-example grad of the embedding table is the scatter-add of cotangent
    rows g_t into rows ids_t; repeated tokens within an example collide, so

        ||grad_i||^2 = sum_{t,t'} 1[ids_t == ids_t'] <g_t, g_t'>
                     = < EqualityMask_i , G_i G_i^T >.
    """
    ids2 = ids.reshape(ids.shape[0], -1)
    g3 = _as3d(g).astype(ACC_DTYPE)
    b, t, d = g3.shape
    if t <= _GRAM_CHUNK:
        eq = (ids2[:, :, None] == ids2[:, None, :]).astype(ACC_DTYPE)
        gram_g = jnp.einsum("btd,bsd->bts", g3, g3)
        return jnp.sum(eq * gram_g, axis=(1, 2))
    # chunked: row blocks against the full sequence
    nb = -(-t // _GRAM_CHUNK)
    pad = nb * _GRAM_CHUNK - t
    gp = jnp.pad(g3, ((0, 0), (0, pad), (0, 0)))
    # pad ids with -1 (padded g rows are zero, so their matches contribute 0)
    ip = jnp.pad(ids2, ((0, 0), (0, pad)), constant_values=-1)
    gc = gp.reshape(b, nb, _GRAM_CHUNK, d)
    ic = ip.reshape(b, nb, _GRAM_CHUNK)

    def body(acc, blk):
        gblk, iblk = blk
        gram = jnp.einsum("bcd,btd->bct", gblk, gp)
        eq = (iblk[:, :, None] == ip[:, None, :]).astype(ACC_DTYPE)
        return acc + jnp.sum(gram * eq, axis=(1, 2)), None

    acc, _ = jax.lax.scan(body, jnp.zeros((b,), ACC_DTYPE),
                          (jnp.moveaxis(gc, 1, 0), jnp.moveaxis(ic, 1, 0)))
    return acc


def scale_norms_sq(xhat: jax.Array, g: jax.Array) -> jax.Array:
    """(B,) squared norms for an elementwise-scale parameter y = s * xhat.

    Per-example grad ds_i = sum_t (g ⊙ xhat)_t, a (d,)-vector — cheap to
    materialize per example.
    """
    gx = _as3d(g * xhat).astype(ACC_DTYPE)
    s = jnp.sum(gx, axis=1)
    return jnp.sum(s * s, axis=-1)


def vector_norms_sq(per_example_grad: jax.Array) -> jax.Array:
    """(B,) norms² for the broadcast-trick fallback: grads already (B, ...)."""
    g = per_example_grad.astype(ACC_DTYPE)
    return jnp.sum(g * g, axis=tuple(range(1, g.ndim)))


# ---------------------------------------------------------------------------
# Blocked (per-shard) norms: norms of column/row blocks of the weight grad.
# ---------------------------------------------------------------------------


def linear_norms_sq_blocked(
    a: jax.Array, g: jax.Array, num_blocks: int, *, block_axis: str = "out"
) -> jax.Array:
    """(B, M) squared norms of per-example grads of M weight blocks.

    Used by per-shard (per-device) clipping: the weight is Megatron-sharded
    into M column blocks (block_axis='out', column parallel) or M row blocks
    (block_axis='in', row parallel); each block is its own clipping group so
    the norm reduction never crosses shards.
    """
    a3, g3 = _as3d(a).astype(ACC_DTYPE), _as3d(g).astype(ACC_DTYPE)
    b, t, din = a3.shape
    dout = g3.shape[-1]
    m = num_blocks
    if block_axis == "out":
        if dout % m:
            raise ValueError(f"dout={dout} not divisible by num_blocks={m}")
        gb = g3.reshape(b, t, m, dout // m)
        gram_a = jnp.einsum("bti,bsi->bts", a3, a3)
        gram_gb = jnp.einsum("btmo,bsmo->bmts", gb, gb)
        return jnp.einsum("bts,bmts->bm", gram_a, gram_gb)
    if block_axis == "in":
        if din % m:
            raise ValueError(f"din={din} not divisible by num_blocks={m}")
        ab = a3.reshape(b, t, m, din // m)
        gram_g = jnp.einsum("bto,bso->bts", g3, g3)
        gram_ab = jnp.einsum("btmi,bsmi->bmts", ab, ab)
        return jnp.einsum("bts,bmts->bm", gram_g, gram_ab)
    raise ValueError(f"block_axis must be 'out' or 'in', got {block_axis!r}")


# ---------------------------------------------------------------------------
# Fused clipped sums.
# ---------------------------------------------------------------------------


def clipped_sum_linear(a: jax.Array, g: jax.Array, factors: jax.Array
                       ) -> jax.Array:
    """sum_i c_i A_i^T G_i as one scaled contraction. factors: (B,)."""
    a3, g3 = _as3d(a), _as3d(g)
    gs = g3 * factors[:, None, None].astype(g3.dtype)
    return jnp.einsum("bti,bto->io", a3, gs)


def clipped_sum_linear_blocked(
    a: jax.Array, g: jax.Array, factors: jax.Array, *, block_axis: str = "out"
) -> jax.Array:
    """sum_i A_i^T diag-blocked(c_i) G_i; factors: (B, M) per block."""
    a3, g3 = _as3d(a), _as3d(g)
    b, t, din = a3.shape
    dout = g3.shape[-1]
    m = factors.shape[-1]
    if block_axis == "out":
        gs = (g3.reshape(b, t, m, dout // m)
              * factors[:, None, :, None].astype(g3.dtype)).reshape(b, t, dout)
        return jnp.einsum("bti,bto->io", a3, gs)
    asb = (a3.reshape(b, t, m, din // m)
           * factors[:, None, :, None].astype(a3.dtype)).reshape(b, t, din)
    return jnp.einsum("bti,bto->io", asb, g3)


def clipped_sum_bias(g: jax.Array, factors: jax.Array) -> jax.Array:
    g3 = _as3d(g)
    return jnp.einsum("bto,b->o", g3, factors.astype(g3.dtype))


def clipped_sum_embed(ids: jax.Array, g: jax.Array, factors: jax.Array,
                      vocab: int) -> jax.Array:
    ids2 = ids.reshape(ids.shape[0], -1)
    g3 = _as3d(g)
    gs = (g3 * factors[:, None, None].astype(g3.dtype)).reshape(-1, g3.shape[-1])
    out = jnp.zeros((vocab, g3.shape[-1]), dtype=ACC_DTYPE)
    return out.at[ids2.reshape(-1)].add(gs.astype(ACC_DTYPE))


def clipped_sum_scale(xhat: jax.Array, g: jax.Array, factors: jax.Array
                      ) -> jax.Array:
    gx = _as3d(g * xhat)
    return jnp.einsum("btd,b->d", gx, factors.astype(gx.dtype))
