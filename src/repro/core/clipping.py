"""Clipping-mode drivers: one mechanism, five modes, two executions.

Every model exposes   loss_fn(params, batch, thresholds) -> (B,) per-example
losses, where `thresholds` is the GroupLayout dict of encoded per-example
threshold vectors consumed by the dp_* primitives. The drivers below turn
that into (clipped summed grads, per-example norms², clip counts):

  non_private : thresholds=+inf; one backward pass; standard summed grads.
  per_layer   : the paper's headline (Sec 3.1). ONE backward pass; each
                layer's custom bwd clips with its own C_k the moment the
                cotangent reaches it; norms² come back through the
                threshold cotangents for the quantile update.
  ghost_flat  : flat (ghost) clipping, Li et al. 2022b — the paper's honest
                efficiency baseline. Default execution is BOOK-KEEPING
                (`bk`, Bu et al. 2022 / repro.core.bk): ONE backward pass
                that reads norms² AND caches each layer's ghost residuals,
                then a scale-and-contract epilogue builds the clipped sums
                from the cache once the flat factor is known.
  per_group   : arbitrary partition of layout groups (per-device clipping —
                the paper's Sec 4 GPT-3 recipe: partition = pipeline stages
                / model shards). Same BK execution; pass-1 norms are
                segment-summed per supergroup before the epilogue.
  naive_flat  : Opacus-style oracle — materializes per-example grads with
                jacrev, clips, sums. O(B x params) memory; used as the
                correctness oracle and the Figure-1 "usual flat" baseline.

Executions for the flat/group modes (`execution=` kwarg, also reachable as
explicit `ghost_flat_twopass` / `per_group_twopass` reference modes):

  bk      : one backprop + epilogue (above). Falls back to twopass
            automatically when the layout cannot be captured (a threshold
            leaf consumed at >1 call sites, shared-site params with
            sensitivity_mult > 1 — see bk.probe_recipes).
  twopass : the historical reference — pass 1 reads norms² only (weight
            contractions dead-code-eliminated), pass 2 applies the
            per-example factor via direct-scale thresholds.

per_shard is expressed through the layout itself (blocked groups, see
core.spec / dp_linear_blocked) and then driven as per_layer — each block is
simply its own group with a local norm.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bk
from repro.core.quantile import clip_counts
from repro.core.spec import GroupLayout, P
from repro.kernels import backend

MODES = ("non_private", "per_layer", "ghost_flat", "per_group", "naive_flat",
         "ghost_flat_twopass", "per_group_twopass")
EXECUTIONS = ("bk", "twopass")


def base_mode(mode: str) -> str:
    """Strip the `_twopass` reference-execution suffix off a mode name."""
    suffix = "_twopass"
    return mode[: -len(suffix)] if mode.endswith(suffix) else mode

LossFn = Callable[[Any, Any, dict], jax.Array]  # (params, batch, thresholds) -> (B,)


class ClipResult(NamedTuple):
    grads: Any            # pytree like params: clipped summed grads
    norms_sq: jax.Array   # (K, B) per-group per-example squared norms
    loss: jax.Array       # scalar mean per-example loss (pre-clipping)


def _sum_loss(loss_fn: LossFn, params, batch, thresholds) -> jax.Array:
    return jnp.sum(loss_fn(params, batch, thresholds))


def _grads_and_norms(loss_fn, params, batch, thresholds_tree, trainable_key):
    """One backward pass: clipped grads + norms² via threshold cotangents."""
    if trainable_key is None:
        def f(p, t):
            return _sum_loss(loss_fn, p, batch, t)

        val, (gp, gt) = jax.value_and_grad(f, argnums=(0, 1))(
            params, thresholds_tree)
        return val, gp, gt

    def f(sub, t):
        return _sum_loss(loss_fn, {**params, trainable_key: sub}, batch, t)

    val, (gs, gt) = jax.value_and_grad(f, argnums=(0, 1))(
        params[trainable_key], thresholds_tree)
    return val, {trainable_key: gs}, gt


def _norms_only(loss_fn, params, batch, thresholds_tree):
    def f(t):
        return _sum_loss(loss_fn, params, batch, t)

    # norms-only pass: disable the fused norm+clip kernel so the unused
    # clipped-sum contraction stays a separate op XLA can dead-code-eliminate
    with backend.scoped(prefer_fused=False):
        return jax.value_and_grad(f)(thresholds_tree)


def _grads_only(loss_fn, params, batch, thresholds_tree, trainable_key):
    if trainable_key is None:
        def g(p):
            return _sum_loss(loss_fn, p, batch, thresholds_tree)

        return jax.value_and_grad(g)(params)

    def g(sub):
        return _sum_loss(loss_fn, {**params, trainable_key: sub}, batch,
                         thresholds_tree)

    val, gs = jax.value_and_grad(g)(params[trainable_key])
    return val, {trainable_key: gs}


def group_clip_factors(norms_sq_groups: jax.Array, c: jax.Array) -> jax.Array:
    """min(1, C_g / ||g_g^(i)||) with 0-norm safety. (G, B) from (G, B), (G,).

    The `dp_clip_factor` scope marks the factor computation for the static
    auditor (repro.analysis.jaxpr_taint): per-example norms are CONSUMED
    here and what leaves is a bounded scaling factor."""
    with jax.named_scope("dp_clip_factor"):
        norm = jnp.sqrt(norms_sq_groups + 1e-12)
        return jnp.minimum(1.0, c[:, None] / norm)


def flat_clip_factors(total_norms_sq: jax.Array,
                      c: float | jax.Array) -> jax.Array:
    """min(1, C / ||g^(i)||): the flat-clipping per-example factor, (B,).

    Single marked implementation shared by ghost_flat, naive_flat and both
    sharded drivers — the `dp_clip_factor` scope is the auditor's anchor,
    so factor math must not be re-derived inline at call sites."""
    with jax.named_scope("dp_clip_factor"):
        c = jnp.asarray(c, jnp.float32)
        return jnp.minimum(1.0, c / jnp.sqrt(total_norms_sq + 1e-12))


def _bk_capture_ok(layout: GroupLayout, trainable_key: str | None) -> bool:
    """BK's epilogue rebuilds grads by walking the layout's spec, so the
    spec must cover exactly the trainable tree (it does for both the full-
    params case and the DP-LoRA {'lora': ...} sub-spec)."""
    return trainable_key is None or set(layout._spec) == {trainable_key}


def _norms_pass(loss_fn, params, batch, layout, batch_size, inf_tree,
                trainable_key, execution):
    """The shared first stage of ghost_flat / per_group: one backward pass
    for (sum loss, (K, B) norms²), capturing BK residuals when possible.

    Returns (val, norms, cap) with cap = (residuals, recipes) under BK or
    None when running (or falling back to) the twopass reference."""
    cap = (bk.capture_clipped(loss_fn, params, batch, layout, batch_size)
           if execution == "bk" and _bk_capture_ok(layout, trainable_key)
           else None)
    if cap is not None:
        val, norms, residuals, recipes = cap
        return val, norms, (residuals, recipes)
    val, norm_tree = _norms_only(loss_fn, params, batch, inf_tree)
    return val, layout.unpack(norm_tree), None


def _naive_group_norms(layout: GroupLayout, jac: Any, batch_size: int
                       ) -> jax.Array:
    """(K, B) per-layout-group norms² from materialized per-example grads.

    Gives the naive_flat oracle the same norms surface as every other mode
    (stacked leaves contribute one row per stack element, blocked leaves
    one row per column/row block), so group-wise parity tests can compare
    against it directly."""
    norms = jnp.zeros((layout.num_groups, batch_size), jnp.float32)

    def walk(node, j, path):
        nonlocal norms
        if isinstance(node, P):
            grp = layout.group(layout._leaf_group[path])
            x = j.astype(jnp.float32)  # (B,) + node.shape
            if node.blocks > 1:
                m = node.blocks
                x = x.reshape(x.shape[:-1] + (m, x.shape[-1] // m))
                x = jnp.moveaxis(x, -2, 1 + node.stack)  # blocks after stack
            sq = jnp.sum(
                x.reshape((batch_size,) + grp.stack_shape + (-1,)) ** 2,
                axis=-1)
            rows = sq.reshape(batch_size, grp.count).T  # (count, B)
            norms = norms.at[grp.offset: grp.offset + grp.count].add(rows)
            return
        for k in node:
            walk(node[k], j[k], path + (k,))

    walk(layout._spec, jac, ())
    return norms


def dp_clipped_gradients(
    loss_fn: LossFn,
    params: Any,
    batch: Any,
    layout: GroupLayout,
    *,
    mode: str,
    batch_size: int,
    thresholds: jax.Array | None = None,   # (K,) per_layer / per_shard
    flat_threshold: float | jax.Array = 1.0,  # scalar C for flat modes
    group_assignment: jax.Array | None = None,  # (K,) ints for per_group
    group_thresholds: jax.Array | None = None,  # (G,) for per_group
    trainable_key: str | None = None,  # top-level params subtree to train
    #   (DP LoRA: params = {'base': frozen, 'lora': adapters},
    #    trainable_key='lora'; grads come back as {'lora': ...})
    execution: str = "bk",  # bk | twopass, for ghost_flat / per_group
) -> ClipResult:
    """Clipped summed gradients + norms under the requested mode."""
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    if execution not in EXECUTIONS:
        raise ValueError(f"execution {execution!r} not in {EXECUTIONS}")
    if mode.endswith("_twopass"):
        mode, execution = base_mode(mode), "twopass"
    inf_tree = layout.pack_value(jnp.inf, batch_size)

    if mode == "non_private":
        val, grads = _grads_only(loss_fn, params, batch, inf_tree,
                                 trainable_key)
        norms = jnp.zeros((layout.num_groups, batch_size), jnp.float32)
        return ClipResult(grads, norms, val / batch_size)

    if mode == "per_layer":
        if thresholds is None:
            raise ValueError("per_layer mode needs thresholds (K,)")
        th_tree = layout.pack(thresholds, batch_size)
        val, grads, norm_tree = _grads_and_norms(loss_fn, params, batch,
                                                 th_tree, trainable_key)
        norms = layout.unpack(norm_tree)
        return ClipResult(grads, norms, val / batch_size)

    if mode == "ghost_flat":
        val, norms, cap = _norms_pass(loss_fn, params, batch, layout,
                                      batch_size, inf_tree, trainable_key,
                                      execution)
        total = jnp.sum(norms, axis=0)  # (B,)
        f = flat_clip_factors(total, flat_threshold)  # (B,)
        if cap is not None:  # BK epilogue: contract the cached residuals
            residuals, recipes = cap
            f_rows = jnp.broadcast_to(f[None], (layout.num_groups,
                                                batch_size))
            grads = bk.contract_clipped(layout, recipes, residuals, f_rows)
        else:  # twopass reference (or BK fallback): second backward pass
            scale_tree = layout.pack_value(-f, batch_size)
            _, grads = _grads_only(loss_fn, params, batch, scale_tree,
                                   trainable_key)
        return ClipResult(grads, norms, val / batch_size)

    if mode == "per_group":
        if group_assignment is None or group_thresholds is None:
            raise ValueError("per_group mode needs group_assignment + group_thresholds")
        val, norms, cap = _norms_pass(loss_fn, params, batch, layout,
                                      batch_size, inf_tree, trainable_key,
                                      execution)
        num_super = group_thresholds.shape[0]
        super_norms = jax.ops.segment_sum(
            norms, group_assignment, num_segments=num_super)  # (G, B)
        f_super = group_clip_factors(super_norms, group_thresholds)  # (G, B)
        f_per_layer = f_super[group_assignment]  # (K, B)
        if cap is not None:
            residuals, recipes = cap
            grads = bk.contract_clipped(layout, recipes, residuals,
                                        f_per_layer)
        else:
            scale_tree = layout.pack_rows(-f_per_layer)
            _, grads = _grads_only(loss_fn, params, batch, scale_tree,
                                   trainable_key)
        return ClipResult(grads, norms, val / batch_size)

    # naive_flat: the Opacus-style materializing oracle.
    if trainable_key is None:
        def per_example_losses(p):
            return loss_fn(p, batch, inf_tree)

        jac = jax.jacrev(per_example_losses)(params)
    else:
        def per_example_losses_sub(sub):
            return loss_fn({**params, trainable_key: sub}, batch, inf_tree)

        jac = {trainable_key: jax.jacrev(per_example_losses_sub)(
            params[trainable_key])}

        def per_example_losses(p):
            return loss_fn(p, batch, inf_tree)
    # real per-layout-group norms² (stacked/blocked aware) so group-wise
    # parity tests can compare every mode against this oracle
    norms = _naive_group_norms(layout, jac, batch_size)
    total = jnp.sum(norms, axis=0)  # (B,)
    f = flat_clip_factors(total, flat_threshold)
    grads = jax.tree_util.tree_map(
        lambda l: jnp.tensordot(f.astype(jnp.float32),
                                l.astype(jnp.float32).reshape(batch_size, -1),
                                axes=1).reshape(l.shape[1:]).astype(l.dtype),
        jac,
    )
    loss = jnp.mean(per_example_losses(params))
    return ClipResult(grads, norms, loss)


# ---------------------------------------------------------------------------
# Sharded (shard_map) execution: per-device clipping that runs for real.
#
# Inside a `shard_map` body each device holds a LOCAL batch shard (data
# axes) and a model-axis coordinate m. `shard_assignment` maps every layout
# group to its owning model shard (launch.sharding.group_shard_assignment),
# and the driver keeps the paper's Sec-4 communication contract executable:
#
#   per_group (per-DEVICE clipping): shard m reduces norms² over ONLY the
#       groups it owns and computes its clip factor locally — zero
#       cross-model-axis collectives before scaling;
#   ghost_flat: the total per-example norm² needs every shard's partial —
#       exactly one (B_local,) psum over the model axis, named
#       `flat_norm_psum` so the HLO axis classifier can find it;
#   epilogue: each shard contracts only its owned groups' residuals (others
#       are masked to zero) and the clipped sums are joined by ONE psum over
#       (data + model) per layer, interleaved with the next layer's
#       contraction (bk.contract_clipped psum_axes) so gradient reduction
#       overlaps the book-keeping compute.
#
# The loss backward itself runs data-parallel (params replicated across the
# model axis at compute time; the launcher may still STORE them model-
# sharded per launch.sharding rules — the entry all-gather is weight
# traffic, not norm traffic, and classifies as such). What this engine
# distributes for real over the model axis is the clipping bookkeeping:
# norm reductions, clip factors, and the scale-and-contract epilogue.
# ---------------------------------------------------------------------------


class ShardedClipResult(NamedTuple):
    grads: Any           # GLOBALLY summed clipped grads (replicated)
    norms_sq: jax.Array  # (K, B_local) this data shard's examples
    loss: jax.Array      # scalar GLOBAL mean per-example loss
    counts: jax.Array    # (G,) global clip counts (replicated)


def _psum_tree(tree, axes):
    with jax.named_scope("grad_psum"):
        return jax.tree_util.tree_map(lambda l: jax.lax.psum(l, axes), tree)


def sharded_clipped_gradients(
    loss_fn: LossFn,
    params: Any,
    batch: Any,  # LOCAL batch shard
    layout: GroupLayout,
    *,
    mode: str,
    batch_size: int,       # LOCAL per-device-row batch size
    data_size: int,        # number of data-plane shards (global B = both)
    data_axes: tuple,      # mesh axis names of the data plane
    model_axis: str,       # mesh axis name of the model plane
    shard_assignment: jax.Array | None = None,  # (K,) group -> model shard
    thresholds: jax.Array | None = None,        # (K,) per_layer
    flat_threshold: float | jax.Array = 1.0,
    group_thresholds: jax.Array | None = None,  # (M,) per_group==per-device
    trainable_key: str | None = None,
    execution: str = "bk",
) -> ShardedClipResult:
    """`dp_clipped_gradients` under manual SPMD — see module comment above."""
    if mode.endswith("_twopass"):
        mode, execution = base_mode(mode), "twopass"
    all_axes = tuple(data_axes) + (model_axis,)
    inf_tree = layout.pack_value(jnp.inf, batch_size)
    global_b = batch_size * data_size

    def _mean_loss(val):
        with jax.named_scope("loss_psum"):
            return jax.lax.psum(val, tuple(data_axes)) / global_b

    if mode == "non_private":
        val, grads = _grads_only(loss_fn, params, batch, inf_tree,
                                 trainable_key)
        norms = jnp.zeros((layout.num_groups, batch_size), jnp.float32)
        return ShardedClipResult(_psum_tree(grads, tuple(data_axes)), norms,
                                 _mean_loss(val), jnp.zeros((1,)))

    if mode == "per_layer":
        if thresholds is None:
            raise ValueError("per_layer mode needs thresholds (K,)")
        th_tree = layout.pack(thresholds, batch_size)
        val, grads, norm_tree = _grads_and_norms(loss_fn, params, batch,
                                                 th_tree, trainable_key)
        norms = layout.unpack(norm_tree)
        with jax.named_scope("clip_count_psum"):
            counts = jax.lax.psum(clip_counts(norms, thresholds),
                                  tuple(data_axes))
        return ShardedClipResult(_psum_tree(grads, tuple(data_axes)), norms,
                                 _mean_loss(val), counts)

    if mode not in ("ghost_flat", "per_group"):
        raise ValueError(
            f"sharded execution supports non_private/per_layer/ghost_flat/"
            f"per_group, not {mode!r} (naive_flat is a single-device oracle)")
    if shard_assignment is None:
        raise ValueError("sharded flat/group modes need shard_assignment")

    val, norms, cap = _norms_pass(loss_fn, params, batch, layout, batch_size,
                                  inf_tree, trainable_key, execution)
    midx = jax.lax.axis_index(model_axis)
    own = (shard_assignment == midx).astype(jnp.float32)  # (K,)
    # this shard's contribution: norms² of the groups it owns only
    with jax.named_scope("shardlocal_norms"):
        partial = jnp.sum(norms * own[:, None], axis=0)  # (B_local,)

    if mode == "ghost_flat":
        c = jnp.asarray(flat_threshold, jnp.float32)
        # THE flat-clipping model-axis collective: the total per-example
        # norm² crosses every model shard before any factor exists
        with jax.named_scope("flat_norm_psum"):
            total = jax.lax.psum(partial, model_axis)  # (B_local,)
        f = flat_clip_factors(total, c)
        f_rows = f[None, :] * own[:, None]  # masked: epilogue is per-owner
        with jax.named_scope("clip_count_psum"):
            counts = jax.lax.psum(
                jnp.sum((total <= c * c).astype(jnp.float32))[None],
                tuple(data_axes))
        f_full = jnp.broadcast_to(f[None], (layout.num_groups, batch_size))
    else:  # per_group == per-DEVICE: factors close over shard-local norms
        if group_thresholds is None:
            raise ValueError("per_group mode needs group_thresholds (M,)")
        num_super = group_thresholds.shape[0]
        c_m = group_thresholds[midx]
        f_m = flat_clip_factors(partial, c_m)  # (B_local,)
        f_rows = f_m[None, :] * own[:, None]
        with jax.named_scope("clip_count_psum"):
            slot = (jnp.arange(num_super) == midx).astype(jnp.float32)
            counts = jax.lax.psum(
                slot * jnp.sum((partial <= c_m * c_m).astype(jnp.float32)),
                all_axes)
        f_full = None  # gathered below only if the twopass fallback runs

    if cap is not None:  # BK: masked, collective-overlapped epilogue
        residuals, recipes = cap
        grads = bk.contract_clipped(layout, recipes, residuals, f_rows,
                                    psum_axes=all_axes)
        return ShardedClipResult(grads, norms, _mean_loss(val), counts)

    # twopass fallback: the second backward produces every group's grads on
    # every shard (replicated over model), so it needs the FULL factor rows;
    # gathering them is factor traffic AFTER scaling factors exist, not norm
    # traffic — named accordingly.
    if f_full is None:
        with jax.named_scope("factor_gather_psum"):
            f_full = jax.lax.psum(f_rows, model_axis)
    scale_tree = layout.pack_rows(-f_full)
    _, grads = _grads_only(loss_fn, params, batch, scale_tree, trainable_key)
    return ShardedClipResult(_psum_tree(grads, tuple(data_axes)), norms,
                             _mean_loss(val), counts)
