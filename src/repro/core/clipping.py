"""Clipping-mode drivers: one mechanism, five modes, two executions.

Every model exposes   loss_fn(params, batch, thresholds) -> (B,) per-example
losses, where `thresholds` is the GroupLayout dict of encoded per-example
threshold vectors consumed by the dp_* primitives. The drivers below turn
that into (clipped summed grads, per-example norms², clip counts):

  non_private : thresholds=+inf; one backward pass; standard summed grads.
  per_layer   : the paper's headline (Sec 3.1). ONE backward pass; each
                layer's custom bwd clips with its own C_k the moment the
                cotangent reaches it; norms² come back through the
                threshold cotangents for the quantile update.
  ghost_flat  : flat (ghost) clipping, Li et al. 2022b — the paper's honest
                efficiency baseline. Default execution is BOOK-KEEPING
                (`bk`, Bu et al. 2022 / repro.core.bk): ONE backward pass
                that reads norms² AND caches each layer's ghost residuals,
                then a scale-and-contract epilogue builds the clipped sums
                from the cache once the flat factor is known.
  per_group   : arbitrary partition of layout groups (per-device clipping —
                the paper's Sec 4 GPT-3 recipe: partition = pipeline stages
                / model shards). Same BK execution; pass-1 norms are
                segment-summed per supergroup before the epilogue.
  naive_flat  : Opacus-style oracle — materializes per-example grads with
                jacrev, clips, sums. O(B x params) memory; used as the
                correctness oracle and the Figure-1 "usual flat" baseline.

Executions for the flat/group modes (`execution=` kwarg, also reachable as
explicit `ghost_flat_twopass` / `per_group_twopass` reference modes):

  bk      : one backprop + epilogue (above). Falls back to twopass
            automatically when the layout cannot be captured (a threshold
            leaf consumed at >1 call sites, shared-site params with
            sensitivity_mult > 1 — see bk.probe_recipes).
  twopass : the historical reference — pass 1 reads norms² only (weight
            contractions dead-code-eliminated), pass 2 applies the
            per-example factor via direct-scale thresholds.

per_shard is expressed through the layout itself (blocked groups, see
core.spec / dp_linear_blocked) and then driven as per_layer — each block is
simply its own group with a local norm.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bk
from repro.core.spec import GroupLayout, P
from repro.kernels import backend

MODES = ("non_private", "per_layer", "ghost_flat", "per_group", "naive_flat",
         "ghost_flat_twopass", "per_group_twopass")
EXECUTIONS = ("bk", "twopass")


def base_mode(mode: str) -> str:
    """Strip the `_twopass` reference-execution suffix off a mode name."""
    suffix = "_twopass"
    return mode[: -len(suffix)] if mode.endswith(suffix) else mode

LossFn = Callable[[Any, Any, dict], jax.Array]  # (params, batch, thresholds) -> (B,)


class ClipResult(NamedTuple):
    grads: Any            # pytree like params: clipped summed grads
    norms_sq: jax.Array   # (K, B) per-group per-example squared norms
    loss: jax.Array       # scalar mean per-example loss (pre-clipping)


def _sum_loss(loss_fn: LossFn, params, batch, thresholds) -> jax.Array:
    return jnp.sum(loss_fn(params, batch, thresholds))


def _grads_and_norms(loss_fn, params, batch, thresholds_tree, trainable_key):
    """One backward pass: clipped grads + norms² via threshold cotangents."""
    if trainable_key is None:
        def f(p, t):
            return _sum_loss(loss_fn, p, batch, t)

        val, (gp, gt) = jax.value_and_grad(f, argnums=(0, 1))(
            params, thresholds_tree)
        return val, gp, gt

    def f(sub, t):
        return _sum_loss(loss_fn, {**params, trainable_key: sub}, batch, t)

    val, (gs, gt) = jax.value_and_grad(f, argnums=(0, 1))(
        params[trainable_key], thresholds_tree)
    return val, {trainable_key: gs}, gt


def _norms_only(loss_fn, params, batch, thresholds_tree):
    def f(t):
        return _sum_loss(loss_fn, params, batch, t)

    # norms-only pass: disable the fused norm+clip kernel so the unused
    # clipped-sum contraction stays a separate op XLA can dead-code-eliminate
    with backend.scoped(prefer_fused=False):
        return jax.value_and_grad(f)(thresholds_tree)


def _grads_only(loss_fn, params, batch, thresholds_tree, trainable_key):
    if trainable_key is None:
        def g(p):
            return _sum_loss(loss_fn, p, batch, thresholds_tree)

        return jax.value_and_grad(g)(params)

    def g(sub):
        return _sum_loss(loss_fn, {**params, trainable_key: sub}, batch,
                         thresholds_tree)

    val, gs = jax.value_and_grad(g)(params[trainable_key])
    return val, {trainable_key: gs}


def group_clip_factors(norms_sq_groups: jax.Array, c: jax.Array) -> jax.Array:
    """min(1, C_g / ||g_g^(i)||) with 0-norm safety. (G, B) from (G, B), (G,)."""
    norm = jnp.sqrt(norms_sq_groups + 1e-12)
    return jnp.minimum(1.0, c[:, None] / norm)


def _bk_capture_ok(layout: GroupLayout, trainable_key: str | None) -> bool:
    """BK's epilogue rebuilds grads by walking the layout's spec, so the
    spec must cover exactly the trainable tree (it does for both the full-
    params case and the DP-LoRA {'lora': ...} sub-spec)."""
    return trainable_key is None or set(layout._spec) == {trainable_key}


def _norms_pass(loss_fn, params, batch, layout, batch_size, inf_tree,
                trainable_key, execution):
    """The shared first stage of ghost_flat / per_group: one backward pass
    for (sum loss, (K, B) norms²), capturing BK residuals when possible.

    Returns (val, norms, cap) with cap = (residuals, recipes) under BK or
    None when running (or falling back to) the twopass reference."""
    cap = (bk.capture_clipped(loss_fn, params, batch, layout, batch_size)
           if execution == "bk" and _bk_capture_ok(layout, trainable_key)
           else None)
    if cap is not None:
        val, norms, residuals, recipes = cap
        return val, norms, (residuals, recipes)
    val, norm_tree = _norms_only(loss_fn, params, batch, inf_tree)
    return val, layout.unpack(norm_tree), None


def _naive_group_norms(layout: GroupLayout, jac: Any, batch_size: int
                       ) -> jax.Array:
    """(K, B) per-layout-group norms² from materialized per-example grads.

    Gives the naive_flat oracle the same norms surface as every other mode
    (stacked leaves contribute one row per stack element, blocked leaves
    one row per column/row block), so group-wise parity tests can compare
    against it directly."""
    norms = jnp.zeros((layout.num_groups, batch_size), jnp.float32)

    def walk(node, j, path):
        nonlocal norms
        if isinstance(node, P):
            grp = layout.group(layout._leaf_group[path])
            x = j.astype(jnp.float32)  # (B,) + node.shape
            if node.blocks > 1:
                m = node.blocks
                x = x.reshape(x.shape[:-1] + (m, x.shape[-1] // m))
                x = jnp.moveaxis(x, -2, 1 + node.stack)  # blocks after stack
            sq = jnp.sum(
                x.reshape((batch_size,) + grp.stack_shape + (-1,)) ** 2,
                axis=-1)
            rows = sq.reshape(batch_size, grp.count).T  # (count, B)
            norms = norms.at[grp.offset: grp.offset + grp.count].add(rows)
            return
        for k in node:
            walk(node[k], j[k], path + (k,))

    walk(layout._spec, jac, ())
    return norms


def dp_clipped_gradients(
    loss_fn: LossFn,
    params: Any,
    batch: Any,
    layout: GroupLayout,
    *,
    mode: str,
    batch_size: int,
    thresholds: jax.Array | None = None,   # (K,) per_layer / per_shard
    flat_threshold: float | jax.Array = 1.0,  # scalar C for flat modes
    group_assignment: jax.Array | None = None,  # (K,) ints for per_group
    group_thresholds: jax.Array | None = None,  # (G,) for per_group
    trainable_key: str | None = None,  # top-level params subtree to train
    #   (DP LoRA: params = {'base': frozen, 'lora': adapters},
    #    trainable_key='lora'; grads come back as {'lora': ...})
    execution: str = "bk",  # bk | twopass, for ghost_flat / per_group
) -> ClipResult:
    """Clipped summed gradients + norms under the requested mode."""
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    if execution not in EXECUTIONS:
        raise ValueError(f"execution {execution!r} not in {EXECUTIONS}")
    if mode.endswith("_twopass"):
        mode, execution = base_mode(mode), "twopass"
    inf_tree = layout.pack_value(jnp.inf, batch_size)

    if mode == "non_private":
        val, grads = _grads_only(loss_fn, params, batch, inf_tree,
                                 trainable_key)
        norms = jnp.zeros((layout.num_groups, batch_size), jnp.float32)
        return ClipResult(grads, norms, val / batch_size)

    if mode == "per_layer":
        if thresholds is None:
            raise ValueError("per_layer mode needs thresholds (K,)")
        th_tree = layout.pack(thresholds, batch_size)
        val, grads, norm_tree = _grads_and_norms(loss_fn, params, batch,
                                                 th_tree, trainable_key)
        norms = layout.unpack(norm_tree)
        return ClipResult(grads, norms, val / batch_size)

    if mode == "ghost_flat":
        val, norms, cap = _norms_pass(loss_fn, params, batch, layout,
                                      batch_size, inf_tree, trainable_key,
                                      execution)
        total = jnp.sum(norms, axis=0)  # (B,)
        c = jnp.asarray(flat_threshold, jnp.float32)
        f = jnp.minimum(1.0, c / jnp.sqrt(total + 1e-12))  # (B,)
        if cap is not None:  # BK epilogue: contract the cached residuals
            residuals, recipes = cap
            f_rows = jnp.broadcast_to(f[None], (layout.num_groups,
                                                batch_size))
            grads = bk.contract_clipped(layout, recipes, residuals, f_rows)
        else:  # twopass reference (or BK fallback): second backward pass
            scale_tree = layout.pack_value(-f, batch_size)
            _, grads = _grads_only(loss_fn, params, batch, scale_tree,
                                   trainable_key)
        return ClipResult(grads, norms, val / batch_size)

    if mode == "per_group":
        if group_assignment is None or group_thresholds is None:
            raise ValueError("per_group mode needs group_assignment + group_thresholds")
        val, norms, cap = _norms_pass(loss_fn, params, batch, layout,
                                      batch_size, inf_tree, trainable_key,
                                      execution)
        num_super = group_thresholds.shape[0]
        super_norms = jax.ops.segment_sum(
            norms, group_assignment, num_segments=num_super)  # (G, B)
        f_super = group_clip_factors(super_norms, group_thresholds)  # (G, B)
        f_per_layer = f_super[group_assignment]  # (K, B)
        if cap is not None:
            residuals, recipes = cap
            grads = bk.contract_clipped(layout, recipes, residuals,
                                        f_per_layer)
        else:
            scale_tree = layout.pack_rows(-f_per_layer)
            _, grads = _grads_only(loss_fn, params, batch, scale_tree,
                                   trainable_key)
        return ClipResult(grads, norms, val / batch_size)

    # naive_flat: the Opacus-style materializing oracle.
    if trainable_key is None:
        def per_example_losses(p):
            return loss_fn(p, batch, inf_tree)

        jac = jax.jacrev(per_example_losses)(params)
    else:
        def per_example_losses_sub(sub):
            return loss_fn({**params, trainable_key: sub}, batch, inf_tree)

        jac = {trainable_key: jax.jacrev(per_example_losses_sub)(
            params[trainable_key])}

        def per_example_losses(p):
            return loss_fn(p, batch, inf_tree)
    # real per-layout-group norms² (stacked/blocked aware) so group-wise
    # parity tests can compare every mode against this oracle
    norms = _naive_group_norms(layout, jac, batch_size)
    total = jnp.sum(norms, axis=0)  # (B,)
    c = jnp.asarray(flat_threshold, jnp.float32)
    f = jnp.minimum(1.0, c / jnp.sqrt(total + 1e-12))
    grads = jax.tree_util.tree_map(
        lambda l: jnp.tensordot(f.astype(jnp.float32),
                                l.astype(jnp.float32).reshape(batch_size, -1),
                                axes=1).reshape(l.shape[1:]).astype(l.dtype),
        jac,
    )
    loss = jnp.mean(per_example_losses(params))
    return ClipResult(grads, norms, loss)
