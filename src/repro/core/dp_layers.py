"""DP layer primitives: clipping fused into backpropagation via custom_vjp.

This module is the JAX realization of the paper's Sec. 3.1: "gradient
clipping for any layer can be performed as soon as backpropagation reaches
that layer". Each parametric primitive carries a `jax.custom_vjp` whose
backward rule

  1. computes per-example gradient norms² WITHOUT materializing per-example
     gradients (ghost trick, `repro.core.ghost` / Pallas kernels),
  2. forms clip factors and emits the already-clipped, already-summed
     parameter gradient in one fused contraction,
  3. passes the UNCLIPPED input cotangent downstream (Algorithm 1 line 11),
  4. reports the per-example norms² through the *threshold cotangent*:
     the threshold is passed as a per-example vector c (B,), and we define
     dL/dc := norms². A single jax.grad over (params, thresholds) therefore
     yields clipped gradients AND every group's norms in one backward pass.

Threshold encoding (one mechanism drives every clipping mode):
    c > 0      : clip to threshold c        -> factor min(1, c / ||g_i||)
    c == +inf  : no clipping                -> factor 1
    c < 0      : direct scale               -> factor |c|
The direct-scale encoding is what makes two-pass (flat / per-group /
per-device) clipping reuse the same primitives: pass 1 reads norms with
c=+inf (XLA dead-code-eliminates the unused weight contractions), the driver
computes group factors f_i, and pass 2 runs with c = -f_i which yields
exactly the group-clipped sums.

Every ghost op below resolves through the backend engine
(`repro.kernels.backend.active()`) at trace time — `xla` reference paths,
`pallas` kernels, or `auto` cost-model dispatch. Select with
`backend.scoped(...)` (done by `make_dp_train_step` from `DPConfig.backend`).

Book-keeping capture (repro.core.bk): when the threshold argument arrives
as a `bk.BkChannel` (only inside `backend.scoped(capture_residuals=True)`,
driven by `bk.capture_clipped`), the backward rule emits per-example norms²
through the threshold cotangent as usual but, instead of contracting the
clipped weight gradient, stashes the ghost residuals (activations + output
cotangents) through the channel's sink cotangent. Parameter cotangents are
ZERO in that mode — the BK epilogue (`bk.contract_clipped`) owns them; the
input cotangent stays the real one so backprop continues downstream.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bk
from repro.core.ghost import clip_factor  # noqa: F401  (re-export, public API)
from repro.kernels import backend


def _int_zero_cotangent(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# dp_linear: y = x @ w (+ b); group = {w, b}.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dp_linear(w: jax.Array, b: jax.Array | None, x: jax.Array, c: jax.Array
              ) -> jax.Array:
    bk.record_linear(c, w, b, x)
    y = x @ w
    if b is not None:
        y = y + b
    return y


def _dp_linear_fwd(w, b, x, c):
    return dp_linear(w, b, x, c), (w, b, x, c)


def _dp_linear_bwd(res, gy):
    w, b, x, c = res
    has_bias = b is not None
    eng = backend.active()
    dx = gy @ w.T
    bsz = x.shape[0]
    a3 = x.reshape(bsz, -1, x.shape[-1])
    g3 = gy.reshape(bsz, -1, gy.shape[-1])
    extra = eng.bias_norms_sq(g3) if has_bias else None
    if isinstance(c, bk.BkChannel):  # BK capture: norms + residual stash
        n = eng.linear_norms_sq(a3, g3)
        if has_bias:
            n = n + extra
        dc = bk.emit(c, n, a=a3, g=g3)
        db = jnp.zeros_like(b) if has_bias else None
        return jnp.zeros_like(w), db, dx, dc
    n, f, dw = eng.linear_clip(a3, g3, c, extra)
    dw = dw.astype(w.dtype)
    db = eng.clipped_sum_bias(g3, f).astype(w.dtype) if has_bias else None
    dc = n  # norms² through the threshold side channel
    return dw, db, dx, dc


dp_linear.defvjp(_dp_linear_fwd, _dp_linear_bwd)


# ---------------------------------------------------------------------------
# dp_linear_blocked: per-shard clipping (groups = Megatron weight blocks).
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def dp_linear_blocked(w, b, x, c, block_axis: str = "out"):
    """Linear layer whose weight grad is clipped per column/row block.

    c: (B, M) encoded thresholds, one per block. This is the TPU analogue of
    the paper's per-device clipping: block m lives on model-shard m, its norm
    and clip factor are computed from shard-local data only, so no norm
    all-reduce appears in the partitioned HLO.
    """
    bk.record_linear_blocked(c, w, b, x, block_axis)
    y = x @ w
    if b is not None:
        y = y + b
    return y


def _dp_linear_blocked_fwd(w, b, x, c, block_axis):
    return dp_linear_blocked(w, b, x, c, block_axis), (w, b, x, c)


def _dp_linear_blocked_bwd(block_axis, res, gy):
    w, b, x, c = res
    has_bias = b is not None
    eng = backend.active()
    dx = gy @ w.T
    bsz = x.shape[0]
    a3 = x.reshape(bsz, -1, x.shape[-1])
    g3 = gy.reshape(bsz, -1, gy.shape[-1])
    m = bk.thresholds_of(c).shape[-1]
    n = eng.linear_norms_sq_blocked(a3, g3, m, block_axis=block_axis)
    if has_bias:
        # bias columns live with the 'out' blocks; for 'in' blocking the bias
        # is whole on every shard -> fold into block 0 to keep accounting
        # conservative and simple.
        if block_axis == "out":
            gb = g3.reshape(bsz, g3.shape[1], m, -1)
            sb = jnp.sum(gb, axis=1)
            n = n + jnp.sum(sb.astype(jnp.float32) ** 2, axis=-1)
        else:
            n = n.at[:, 0].add(eng.bias_norms_sq(g3))
    if isinstance(c, bk.BkChannel):
        dc = bk.emit(c, n, a=a3, g=g3)
        db = jnp.zeros_like(b) if has_bias else None
        return jnp.zeros_like(w), db, dx, dc
    f = clip_factor(c, n)  # (B, M)
    dw = eng.clipped_sum_linear_blocked(a3, g3, f, block_axis=block_axis
                                        ).astype(w.dtype)
    if has_bias:
        if block_axis == "out":
            gb = g3.reshape(bsz, g3.shape[1], m, -1)
            db = jnp.einsum("btmo,bm->mo", gb,
                            f.astype(g3.dtype)).reshape(-1).astype(w.dtype)
        else:
            db = eng.clipped_sum_bias(g3, f[:, 0]).astype(w.dtype)
    else:
        db = None
    return dw, db, dx, n


dp_linear_blocked.defvjp(_dp_linear_blocked_fwd, _dp_linear_blocked_bwd)


# ---------------------------------------------------------------------------
# dp_embed: y = table[ids]; collision-exact ghost norms.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dp_embed(table: jax.Array, ids: jax.Array, c: jax.Array) -> jax.Array:
    bk.record_embed(c, table, ids)
    return jnp.take(table, ids, axis=0)


def _dp_embed_fwd(table, ids, c):
    # zero-size sentinel carries (vocab, dtype) without keeping the table alive
    sentinel = jnp.zeros((table.shape[0], 0), table.dtype)
    return dp_embed(table, ids, c), (sentinel, ids, c)


def _dp_embed_bwd(res, gy):
    sentinel, ids, c = res
    vocab, dtype = sentinel.shape[0], sentinel.dtype
    eng = backend.active()
    bsz = ids.shape[0]
    ids2 = ids.reshape(bsz, -1)
    g3 = gy.reshape(bsz, -1, gy.shape[-1])
    n = eng.embed_norms_sq(ids2, g3)
    if isinstance(c, bk.BkChannel):
        # token ids ride the float sink channel (exact below 2^24)
        dc = bk.emit(c, n, g=g3, ids=ids2.astype(jnp.float32))
        return (jnp.zeros((vocab, g3.shape[-1]), dtype),
                _int_zero_cotangent(ids), dc)
    f = clip_factor(c, n)
    dtable = eng.clipped_sum_embed(ids2, g3, f, vocab).astype(dtype)
    return dtable, _int_zero_cotangent(ids), n


dp_embed.defvjp(_dp_embed_fwd, _dp_embed_bwd)


# ---------------------------------------------------------------------------
# dp_scale / dp_shift: elementwise gain / bias parameters (norm layers).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dp_scale(s: jax.Array, xhat: jax.Array, c: jax.Array) -> jax.Array:
    bk.record_scale(c, s, xhat)
    return xhat * s


def _dp_scale_fwd(s, xhat, c):
    return dp_scale(s, xhat, c), (s, xhat, c)


def _dp_scale_bwd(res, gy):
    s, xhat, c = res
    eng = backend.active()
    dxhat = gy * s
    n = eng.scale_norms_sq(xhat, gy)
    if isinstance(c, bk.BkChannel):
        # the per-example grad itself is small ((B, d)): stash it directly
        pg = jnp.sum((gy * xhat).reshape(gy.shape[0], -1, gy.shape[-1])
                     .astype(jnp.float32), axis=1)
        return jnp.zeros_like(s), dxhat, bk.emit(c, n, pg=pg)
    f = clip_factor(c, n)
    ds = eng.clipped_sum_scale(xhat, gy, f).astype(s.dtype)
    return ds, dxhat, n


dp_scale.defvjp(_dp_scale_fwd, _dp_scale_bwd)


@jax.custom_vjp
def dp_shift(b: jax.Array, x: jax.Array, c: jax.Array) -> jax.Array:
    bk.record_shift(c, x)
    return x + b


def _dp_shift_fwd(b, x, c):
    sentinel = jnp.zeros((0,), b.dtype)
    return dp_shift(b, x, c), (sentinel, c)


def _dp_shift_bwd(res, gy):
    sentinel, c = res
    dtype = sentinel.dtype
    eng = backend.active()
    bsz = gy.shape[0]
    g3 = gy.reshape(bsz, -1, gy.shape[-1])
    n = eng.bias_norms_sq(g3)
    if isinstance(c, bk.BkChannel):
        pg = jnp.sum(g3.astype(jnp.float32), axis=1)  # (B, d) per-ex grad
        return (jnp.zeros((g3.shape[-1],), dtype), gy,
                bk.emit(c, n, pg=pg))
    f = clip_factor(c, n)
    db = eng.clipped_sum_bias(g3, f).astype(dtype)
    return db, gy, n


dp_shift.defvjp(_dp_shift_fwd, _dp_shift_bwd)


# ---------------------------------------------------------------------------
# dp_broadcast: the broadcast-trick fallback for arbitrary small parameters
# (SSM decay vectors, RWKV time-mix params, ...). Returns the parameter with
# a leading batch dim; the cotangent arriving back IS the per-example grad.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dp_broadcast(p: jax.Array, c: jax.Array) -> jax.Array:
    bk.record_broadcast(c, p)
    bsz = bk.thresholds_of(c).shape[0]
    return jnp.broadcast_to(p, (bsz,) + p.shape)


def _dp_broadcast_fwd(p, c):
    sentinel = jnp.zeros((0,), p.dtype)
    return dp_broadcast(p, c), (sentinel, c)


def _dp_broadcast_bwd(res, gy):
    sentinel, c = res
    dtype = sentinel.dtype
    n = backend.active().vector_norms_sq(gy)
    if isinstance(c, bk.BkChannel):
        # the cotangent arriving here IS the (B, ...) per-example grad
        return (jnp.zeros(gy.shape[1:], dtype),
                bk.emit(c, n, pg=gy.astype(jnp.float32)))
    f = clip_factor(c, n)
    dp = jnp.tensordot(f.astype(jnp.float32),
                       gy.astype(jnp.float32), axes=1).astype(dtype)
    return dp, n


dp_broadcast.defvjp(_dp_broadcast_fwd, _dp_broadcast_bwd)


# ---------------------------------------------------------------------------
# dp_expert_linear: exact per-example clipping through MoE token dispatch.
#
# Dispatched expert buffers mix tokens from different examples, so the
# per-example norm of expert e's weight gradient needs example-masked grams:
#     n_{e,i} = sum_{slots s,s' of e with ex(s)=ex(s')=i} <x_s,x_s'> <g_s,g_s'>
# computed per expert as rowsums of (X Xᵀ ⊙ G Gᵀ ⊙ EqMask) segment-summed by
# example id. Each expert is its own clipping group (the MoE analogue of
# "a layer"), so thresholds arrive as (E, B).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dp_expert_linear(w: jax.Array, x: jax.Array, exids: jax.Array,
                     c: jax.Array) -> jax.Array:
    """w: (E, din, dout); x: (E, C, din) dispatched slots; exids: (E, C)
    example id per slot (-1 for empty slots); c: (E, B) encoded thresholds."""
    bk.record_expert(c, w, x)
    return jnp.einsum("ecd,edf->ecf", x, w)


def _dp_expert_fwd(w, x, exids, c):
    return dp_expert_linear(w, x, exids, c), (w, x, exids, c)


def _dp_expert_bwd(res, gy):
    w, x, exids, c = res
    bsz = bk.thresholds_of(c).shape[-1]
    dx = jnp.einsum("ecf,edf->ecd", gy, w)
    valid = exids >= 0
    seg = jnp.where(valid, exids, bsz)  # invalid -> overflow bucket

    def per_expert(carry, inp):
        xe, ge, se = inp  # (C, din), (C, dout), (C,)
        xf = xe.astype(jnp.float32)
        gf = ge.astype(jnp.float32)
        gram = (xf @ xf.T) * (gf @ gf.T)  # (C, C)
        eq = (se[:, None] == se[None, :]).astype(jnp.float32)
        rows = jnp.sum(gram * eq, axis=-1)  # (C,)
        n_e = jax.ops.segment_sum(rows, se, num_segments=bsz + 1)[:bsz]
        return carry, n_e

    _, n = jax.lax.scan(per_expert, 0, (x, gy, seg))  # n: (E, B)
    if isinstance(c, bk.BkChannel):
        dc = bk.emit(c, n, x=x, g=gy, seg=seg.astype(jnp.float32))
        return jnp.zeros_like(w), dx, _int_zero_cotangent(exids), dc
    f = clip_factor(c, n)  # (E, B)
    fpad = jnp.concatenate([f, jnp.zeros((f.shape[0], 1), f.dtype)], axis=-1)
    fslot = jnp.take_along_axis(fpad, seg, axis=-1)  # (E, C)
    dw = jnp.einsum("ecd,ecf->edf", x * fslot[..., None].astype(x.dtype), gy
                    ).astype(w.dtype)
    return dw, dx, _int_zero_cotangent(exids), n


dp_expert_linear.defvjp(_dp_expert_fwd, _dp_expert_bwd)


# ---------------------------------------------------------------------------
# dp_expert_linear_grouped: per-(example, expert) dispatch buffers.
#
# Beyond-paper optimization (EXPERIMENTS.md §Perf): when the dispatch buffer
# is laid out (B, E, cap_pe, d) — every example owns its slots — per-example
# norms need NO example-masked (C, C) grams: the per-(b, e) gradient block
# is Σ_s x_s g_sᵀ over that example's own slots, so the norm uses the same
# gram/outer dual as plain linears, at per-example slot counts
# (≈ T·top_k/E instead of B·T·top_k/E). Flops drop ~B× vs the masked-gram
# exact path of dp_expert_linear.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dp_expert_linear_grouped(w: jax.Array, x: jax.Array, c: jax.Array
                             ) -> jax.Array:
    """w: (E, din, dout); x: (B, E, C, din) per-example dispatch buffers
    (empty slots zero); c: (E, B) encoded thresholds."""
    bk.record_expert_grouped(c, w, x)
    return jnp.einsum("becd,edf->becf", x, w)


def _dp_expert_grouped_fwd(w, x, c):
    return dp_expert_linear_grouped(w, x, c), (w, x, c)


def _dp_expert_grouped_bwd(res, gy):
    w, x, c = res
    bsz, e, cap, din = x.shape
    dout = gy.shape[-1]
    dx = jnp.einsum("becf,edf->becd", gy, w)
    if isinstance(c, bk.BkChannel):
        gram_x = jnp.einsum("becd,beCd->becC", x.astype(jnp.float32),
                            x.astype(jnp.float32))
        gram_g = jnp.einsum("becf,beCf->becC", gy.astype(jnp.float32),
                            gy.astype(jnp.float32))
        n = jnp.sum(gram_x * gram_g, axis=(2, 3)).T  # (E, B)
        dc = bk.emit(c, n, x=x, g=gy)
        return jnp.zeros_like(w), dx, dc
    gram_cost = cap * cap * (din + dout)
    outer_cost = cap * din * dout
    use_outer = (outer_cost < gram_cost) and (din * dout <= (1 << 22))
    if use_outer:
        # VECTORIZED over B: the (B, E, din, dout) transient shards over the
        # data axis (b) AND the expert/ff model axis — a lax.scan over
        # examples here would serialize the batch and force GSPMD to gather
        # every other device's examples each iteration (measured: 80 TB/step
        # of all-reduces on granite; see EXPERIMENTS.md §Perf A1/A2).
        dw_be = jnp.einsum("becd,becf->bedf", x.astype(jnp.float32),
                           gy.astype(jnp.float32))
        n = jnp.sum(dw_be * dw_be, axis=(2, 3)).T  # (E, B)
        f = clip_factor(c, n)  # (E, B)
        dw = jnp.einsum("bedf,be->edf", dw_be, f.T).astype(w.dtype)
        return dw, dx, n
    gram_x = jnp.einsum("becd,beCd->becC", x.astype(jnp.float32),
                        x.astype(jnp.float32))
    gram_g = jnp.einsum("becf,beCf->becC", gy.astype(jnp.float32),
                        gy.astype(jnp.float32))
    n = jnp.sum(gram_x * gram_g, axis=(2, 3)).T  # (E, B)
    f = clip_factor(c, n)  # (E, B)
    gs = gy * f.T[:, :, None, None].astype(gy.dtype)
    dw = jnp.einsum("becd,becf->edf", x, gs).astype(w.dtype)
    return dw, dx, n


dp_expert_linear_grouped.defvjp(_dp_expert_grouped_fwd,
                                _dp_expert_grouped_bwd)
