"""Algorithm 2: private pipeline-parallel training with per-device clipping.

This is the paper's Sec-4 mechanism expressed in JAX-native terms:

  * the model is partitioned into S stages of consecutive blocks; stage s's
    parameters live ONLY on mesh axis 'stage' coordinate s (shard_map —
    manual SPMD, not GSPMD inference);
  * microbatches stream through the pipeline: at each of
    (n_micro + S - 1) ticks every stage processes the microbatch it holds
    and `ppermute`s activations to the next stage (LocalForward's
    activation sends, Algorithm 3 line 5). Reverse-mode AD through the
    loop yields the mirrored backward ppermutes (Algorithm 4 line 7) —
    the backward schedule is derived, not hand-written;
  * PER-DEVICE CLIPPING: each stage's parameters form one clipping group.
    The dp_* primitives inside the stage body compute stage-LOCAL
    per-example norms — by construction no norm ever crosses the stage
    axis (the paper's "no extra communication" property, now checkable in
    the HLO: zero collectives touch the per-example norm values);
  * noise: equal-budget allocation (gamma_k = C_k) drawn stage-locally —
    each stage's noise std depends only on its own threshold (paper
    Appendix C, Algorithm 2 line 6).

The reference model here is a stage-stacked MLP tower (the mechanism is
architecture-agnostic; transformer stages plug in the same way — each
stage body is any pure block function). `tests/test_pipeline.py` checks
the pipelined loss/grads against a single-device reference and the
per-stage clipping against the per_group driver oracle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro.core import dp_layers as dpl
from repro.core.spec import P, GroupLayout, init_params


# ---------------------------------------------------------------------------
# A stage-stacked MLP tower (each stage: L_per_stage [linear+tanh] blocks).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    layers_per_stage: int
    d_model: int
    d_in: int
    n_classes: int

    @property
    def total_layers(self) -> int:
        return self.n_stages * self.layers_per_stage


def pipeline_spec(cfg: PipelineConfig) -> dict:
    lps, d = cfg.layers_per_stage, cfg.d_model
    return {
        # stage-stacked: leading dim = stage (sharded over 'stage');
        # ONE clipping group per stage (per-DEVICE clipping): explicit
        # group names collapse the per-layer params of a stage together.
        "blocks": {
            "w": P((cfg.n_stages, lps, d, d), stack=1, group="stage"),
            "b": P((cfg.n_stages, lps, d), init="zeros", stack=1,
                   group="stage"),
        },
        "head": {"w": P((d, cfg.n_classes))},
        "embed": {"w": P((cfg.d_in, d))},
    }


def _stage_body(stage_params, x, c):
    """One stage: layers_per_stage DP blocks. x: (B, d). c: (B,) encoded
    thresholds for THIS stage's group."""

    def layer(h, wb):
        w, b = wb
        h = dpl.dp_linear(w, b, h[:, None, :], c)[:, 0]
        return jnp.tanh(h), None

    x, _ = jax.lax.scan(layer, x, (stage_params["w"], stage_params["b"]))
    return x


def make_pipeline_loss(cfg: PipelineConfig, mesh, *, stage_axis: str = "pod"):
    """Returns loss_fn(params, (x, y), thresholds) -> (B,) per-example
    losses, computed through the shard_map pipeline.

    thresholds: dict {'stage': (S, B) encoded}, plus 'embed', 'head' (B,)
    (embed/head live on stage 0 / S-1 conceptually; here replicated for
    simplicity — their groups clip as usual)."""
    s_count = cfg.n_stages

    def pipelined(blocks_w, blocks_b, x0, c_stage):
        """Manual-SPMD pipeline over the stage axis.

        blocks_w/b: LOCAL stage params (1, lps, d, d) per device;
        x0: (n_micro, mb, d) microbatched embedded inputs (replicated);
        c_stage: (1, B) local encoded thresholds.
        Returns (n_micro, mb, d) final activations (valid on the LAST
        stage; other stages hold garbage, masked by the caller)."""
        idx = jax.lax.axis_index(stage_axis)
        n_micro, mb, d = x0.shape
        sp = {"w": blocks_w[0], "b": blocks_b[0]}
        c = c_stage[0]
        ticks = n_micro + s_count - 1
        buf = jnp.zeros((mb, d), x0.dtype)
        outs = jnp.zeros((n_micro, mb, d), x0.dtype)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            take = jnp.clip(t, 0, n_micro - 1)
            fresh = x0[take]
            inp = jnp.where(idx == 0, fresh, buf)
            # stage s works on microbatch m = t - s; zero invalid ticks so
            # their (garbage) activations contribute nothing to gradients
            # OR to the per-example norm side channel
            m = t - idx
            valid = (m >= 0) & (m < n_micro)
            inp = jnp.where(valid, inp, jnp.zeros_like(inp))
            # threshold columns of THIS microbatch's examples
            mclip = jnp.clip(m, 0, n_micro - 1)
            c_mb = jax.lax.dynamic_slice_in_dim(c, mclip * mb, mb)
            out = _stage_body(sp, inp, c_mb)
            # last stage records its result at slot t - (S-1)
            slot = jnp.clip(t - (s_count - 1), 0, n_micro - 1)
            valid_out = (t - (s_count - 1) >= 0) & (t - (s_count - 1) < n_micro)
            outs = jax.lax.cond(
                valid_out,
                lambda o: o.at[slot].set(out),
                lambda o: o,
                outs)
            # send activations to the next stage (ring; last->first unused)
            perm = [(i, (i + 1) % s_count) for i in range(s_count)]
            buf = jax.lax.ppermute(out, stage_axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(ticks))
        # broadcast the last stage's outs to all stages (psum of masked)
        mine = jnp.where(idx == s_count - 1, 1.0, 0.0)
        outs = jax.lax.psum(outs * mine.astype(outs.dtype), stage_axis)
        return outs

    # shard_map: blocks sharded on stage, inputs/outputs replicated
    _in_specs = (PS(stage_axis), PS(stage_axis), PS(), PS(stage_axis))
    if hasattr(jax, "shard_map"):
        smapped = jax.shard_map(pipelined, mesh=mesh, in_specs=_in_specs,
                                out_specs=PS(), check_vma=False)
    else:  # jax<=0.4: experimental API, replication check is `check_rep`
        from jax.experimental.shard_map import shard_map as _shard_map
        smapped = _shard_map(pipelined, mesh=mesh, in_specs=_in_specs,
                             out_specs=PS(), check_rep=False)

    def loss_fn(params, batch, th, *, n_micro: int = 2):
        x, y = batch  # (B, d_in), (B,)
        b = x.shape[0]
        mb = b // n_micro
        h = dpl.dp_linear(params["embed"]["w"], None, x[:, None, :],
                          th["embed"])[:, 0]
        hm = h.reshape(n_micro, mb, -1)
        # per-microbatch threshold layout: the stage group's (S, B) encoded
        # thresholds; inside the pipeline each example keeps its own column
        out = smapped(params["blocks"]["w"], params["blocks"]["b"], hm,
                      th["stage"])
        out = out.reshape(b, -1)
        logits = dpl.dp_linear(params["head"]["w"], None, out[:, None, :],
                               th["head"])[:, 0]
        logp = jax.nn.log_softmax(logits)
        return -logp[jnp.arange(b), y]

    return loss_fn


def reference_loss(cfg: PipelineConfig, params, batch, th):
    """Single-device reference: same math, no pipeline."""
    x, y = batch
    h = dpl.dp_linear(params["embed"]["w"], None, x[:, None, :],
                      th["embed"])[:, 0]
    for s in range(cfg.n_stages):
        sp = {"w": params["blocks"]["w"][s], "b": params["blocks"]["b"][s]}
        h = _stage_body(sp, h, th["stage"][s])
    logits = dpl.dp_linear(params["head"]["w"], None, h[:, None, :],
                           th["head"])[:, 0]
    logp = jax.nn.log_softmax(logits)
    return -logp[jnp.arange(y.shape[0]), y]
