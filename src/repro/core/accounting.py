"""Rényi-DP accounting for the subsampled Gaussian mechanism.

Implements:
  * RDP of the Poisson-subsampled Gaussian mechanism (Mironov 2017;
    Mironov, Talwar, Zhang 2019 "RDP of the Sampled Gaussian Mechanism"),
    evaluated over a standard grid of orders.
  * Conversion RDP -> (eps, delta)  (Canonne-Kamath-Steinke-style tight
    conversion as used by TF-Privacy / Opacus).
  * Bisection calibration of the noise multiplier sigma from a target
    (eps, delta, sampling_rate, steps).
  * Proposition 3.1 of the paper: budget split between gradient noising and
    private per-layer quantile estimation,
        sigma_new = (sigma^-2 - K / (2 sigma_b)^2)^(-1/2),
    and Remark 3.1's fraction r = K sigma^2 / (4 sigma_b^2).

Pure numpy — runs identically on any host, never touches jax device state.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

# Standard order grid (matches TF-privacy defaults plus a fine low range).
DEFAULT_ORDERS: tuple[float, ...] = tuple(
    [1.0 + x / 10.0 for x in range(1, 100)]
    + list(range(11, 64))
    + [128.0, 256.0, 512.0, 1024.0]
)


def _log_add(a: float, b: float) -> float:
    """log(exp(a) + exp(b)) stably."""
    if a == -math.inf:
        return b
    if b == -math.inf:
        return a
    hi, lo = (a, b) if a > b else (b, a)
    return hi + math.log1p(math.exp(lo - hi))


def _log_sub(a: float, b: float) -> float:
    """log(exp(a) - exp(b)) stably, requires a >= b."""
    if b == -math.inf:
        return a
    if a == b:
        return -math.inf
    return a + math.log1p(-math.exp(b - a))


def _log_comb(n: int, k: int) -> float:
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def _rdp_int_order(q: float, sigma: float, alpha: int) -> float:
    """RDP at integer order alpha for the sampled Gaussian (MTZ'19, Sec 3.3)."""
    # log( sum_k C(alpha,k) (1-q)^(alpha-k) q^k exp(k(k-1)/(2 sigma^2)) )
    log_a = -math.inf
    for k in range(alpha + 1):
        term = (
            _log_comb(alpha, k)
            + k * math.log(q)
            + (alpha - k) * math.log1p(-q)
            + (k * k - k) / (2.0 * sigma * sigma)
        )
        log_a = _log_add(log_a, term)
    return log_a / (alpha - 1)


def _rdp_frac_order(q: float, sigma: float, alpha: float) -> float:
    """RDP at fractional order via the stable series of MTZ'19 (Sec 3.2).

    Mirrors tensorflow-privacy's `_compute_log_a_frac` (two-series
    decomposition around z0 with generalized binomial coefficients).
    """
    log_a0, log_a1 = -math.inf, -math.inf
    z0 = sigma * sigma * math.log(1.0 / q - 1.0) + 0.5
    sqrt2s = math.sqrt(2.0) * sigma
    i = 0
    # generalized binom(alpha, i), tracked as (sign, log|.|)
    coef_sign, log_coef = 1.0, 0.0
    while True:
        j = alpha - i
        log_t0 = log_coef + i * math.log(q) + j * math.log1p(-q)
        log_t1 = log_coef + j * math.log(q) + i * math.log1p(-q)
        log_e0 = math.log(0.5) + _log_erfc((i - z0) / sqrt2s)
        log_e1 = math.log(0.5) + _log_erfc((z0 - j) / sqrt2s)
        log_s0 = log_t0 + (i * i - i) / (2.0 * sigma * sigma) + log_e0
        log_s1 = log_t1 + (j * j - j) / (2.0 * sigma * sigma) + log_e1
        if coef_sign > 0:
            log_a0 = _log_add(log_a0, log_s0)
            log_a1 = _log_add(log_a1, log_s1)
        else:
            log_a0 = _log_sub(log_a0, log_s0)
            log_a1 = _log_sub(log_a1, log_s1)
        # next coefficient: binom(alpha, i+1) = binom(alpha, i) * (alpha-i)/(i+1)
        factor = (alpha - i) / (i + 1)
        if factor == 0.0:
            break
        if factor < 0:
            coef_sign = -coef_sign
        log_coef += math.log(abs(factor))
        i += 1
        if max(log_s0, log_s1) < -30:
            break
        if i > 1024:  # safety bound
            break
    return _log_add(log_a0, log_a1) / (alpha - 1)


def _log_erfc(x: float) -> float:
    """log(erfc(x)) stably for large positive x."""
    if x > 6.0:
        # asymptotic expansion
        return -x * x - math.log(x * math.sqrt(math.pi)) + math.log1p(-1.0 / (2 * x * x))
    return math.log(math.erfc(x))


def rdp_sampled_gaussian(
    q: float, sigma: float, steps: int, orders: Sequence[float] = DEFAULT_ORDERS
) -> np.ndarray:
    """RDP (per order) of `steps` compositions of the sampled Gaussian."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"sampling rate q must be in [0, 1], got {q}")
    if not math.isfinite(sigma) or sigma <= 0:
        raise ValueError(f"noise multiplier sigma must be positive, got {sigma}")
    if steps < 0:
        raise ValueError(f"steps must be non-negative, got {steps}")
    if len(orders) == 0:
        raise ValueError("order grid is empty")
    if any(a <= 1.0 for a in orders):
        raise ValueError("all RDP orders must be > 1")
    if q == 0:
        return np.zeros(len(orders))
    out = np.empty(len(orders))
    for idx, alpha in enumerate(orders):
        if q == 1.0:
            rdp = alpha / (2 * sigma * sigma)
        elif float(alpha).is_integer():
            rdp = _rdp_int_order(q, sigma, int(alpha))
        else:
            rdp = _rdp_frac_order(q, sigma, alpha)
        out[idx] = rdp * steps
    return out


def rdp_to_eps(
    rdp: np.ndarray, delta: float, orders: Sequence[float] = DEFAULT_ORDERS
) -> float:
    """Tight RDP -> (eps, delta) conversion (CKS'20 / TF-privacy)."""
    orders_arr = np.asarray(orders, dtype=float)
    rdp = np.asarray(rdp, dtype=float)
    if orders_arr.size == 0:
        raise ValueError("order grid is empty")
    if rdp.shape != orders_arr.shape:
        raise ValueError(
            f"rdp grid has shape {rdp.shape}, orders {orders_arr.shape}")
    if np.any(orders_arr <= 1.0):
        raise ValueError("all RDP orders must be > 1")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    with np.errstate(over="ignore", invalid="ignore"):
        eps = (
            rdp
            + np.log1p(-1.0 / orders_arr)
            - (np.log(delta) + np.log(orders_arr)) / (orders_arr - 1.0)
        )
    eps = np.where(np.isnan(eps), np.inf, eps)
    return float(max(0.0, np.min(eps)))


def compute_epsilon(
    *,
    sigma: float,
    sampling_rate: float,
    steps: int,
    delta: float,
    orders: Sequence[float] = DEFAULT_ORDERS,
) -> float:
    """(eps) spent by `steps` DP-SGD iterations at noise multiplier sigma."""
    rdp = rdp_sampled_gaussian(sampling_rate, sigma, steps, orders)
    return rdp_to_eps(rdp, delta, orders)


def calibrate_sigma(
    *,
    target_eps: float,
    sampling_rate: float,
    steps: int,
    delta: float,
    sigma_lo: float = 0.1,
    sigma_hi: float = 64.0,
    tol: float = 1e-4,
) -> float:
    """Smallest sigma achieving <= target_eps, by bisection."""
    if target_eps <= 0:
        raise ValueError("target_eps must be positive")
    if not 0.0 < sampling_rate <= 1.0:
        raise ValueError(
            f"sampling_rate must be in (0, 1], got {sampling_rate}")
    if steps <= 0:
        raise ValueError(f"steps must be positive, got {steps}")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    # grow hi until feasible
    while compute_epsilon(
        sigma=sigma_hi, sampling_rate=sampling_rate, steps=steps, delta=delta
    ) > target_eps:
        sigma_hi *= 2.0
        if sigma_hi > 1e6:
            raise RuntimeError("cannot calibrate sigma: eps target too small")
    lo, hi = sigma_lo, sigma_hi
    while hi / lo > 1.0 + tol:
        mid = math.sqrt(lo * hi)
        eps = compute_epsilon(
            sigma=mid, sampling_rate=sampling_rate, steps=steps, delta=delta
        )
        if eps > target_eps:
            lo = mid
        else:
            hi = mid
    return hi


# ---------------------------------------------------------------------------
# Proposition 3.1: budget split for private quantile estimation.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BudgetSplit:
    sigma: float        # original noise multiplier (all budget to gradients)
    sigma_new: float    # gradient noise multiplier after paying for quantiles
    sigma_b: float      # clip-count noise multiplier (sensitivity 1/2)
    num_groups: int     # K
    r: float            # fraction of (RDP) budget spent on quantile releases


def split_noise_multiplier(sigma: float, sigma_b: float, num_groups: int) -> BudgetSplit:
    """Proposition 3.1: sigma_new = (sigma^-2 - K/(2 sigma_b)^2)^(-1/2)."""
    k = num_groups
    denom = sigma ** (-2) - k / (2.0 * sigma_b) ** 2
    if denom <= 0:
        raise ValueError(
            f"quantile-estimation budget exhausts the whole budget: "
            f"sigma={sigma}, sigma_b={sigma_b}, K={k}; increase sigma_b"
        )
    sigma_new = denom ** (-0.5)
    r = (k * sigma * sigma) / (4.0 * sigma_b * sigma_b)  # Remark 3.1
    return BudgetSplit(sigma=sigma, sigma_new=sigma_new, sigma_b=sigma_b,
                       num_groups=k, r=r)


def sigma_b_for_fraction(sigma: float, num_groups: int, r: float) -> float:
    """Invert Remark 3.1: the sigma_b that spends fraction r on K quantiles."""
    if not 0.0 < r < 1.0:
        raise ValueError("r must be in (0, 1)")
    return math.sqrt(num_groups * sigma * sigma / (4.0 * r))


# ---------------------------------------------------------------------------
# Incremental accountant (ledger replay).
# ---------------------------------------------------------------------------


class RdpAccountant:
    """Incremental RDP composition over heterogeneous (q, sigma) steps.

    Backs the training service's persistent ledger (launch.service): each
    ledger record is one `spend(q, sigma)`; `epsilon(delta)` converts the
    running RDP vector, and `peek(q, sigma, delta)` prices a step WITHOUT
    committing it — the budget gate refuses the step if the projection
    exceeds the target. Replay cost is O(records) with a per-(q, sigma)
    cache of the single-step RDP vector, so restart-time replay of a long
    ledger costs one vector evaluation per distinct mechanism, not per
    record.
    """

    def __init__(self, orders: Sequence[float] = DEFAULT_ORDERS):
        if len(orders) == 0:
            raise ValueError("order grid is empty")
        self.orders = tuple(float(a) for a in orders)
        self._rdp = np.zeros(len(self.orders))
        self._steps = 0
        self._cache: dict[tuple[float, float], np.ndarray] = {}

    def _one_step(self, q: float, sigma: float) -> np.ndarray:
        key = (float(q), float(sigma))
        rdp = self._cache.get(key)
        if rdp is None:
            rdp = rdp_sampled_gaussian(q, sigma, 1, self.orders)
            self._cache[key] = rdp
        return rdp

    @property
    def steps(self) -> int:
        return self._steps

    def spend(self, q: float, sigma: float) -> None:
        """Compose one sampled-Gaussian release into the running total."""
        self._rdp = self._rdp + self._one_step(q, sigma)
        self._steps += 1

    def epsilon(self, delta: float) -> float:
        """(eps, delta) spent so far."""
        if self._steps == 0:
            return 0.0
        return rdp_to_eps(self._rdp, delta, self.orders)

    def peek(self, q: float, sigma: float, delta: float) -> float:
        """Projected epsilon if one more (q, sigma) step were spent."""
        return rdp_to_eps(self._rdp + self._one_step(q, sigma), delta,
                          self.orders)

    def rdp(self) -> np.ndarray:
        return self._rdp.copy()


def replay_ledger(
    records: Iterable[dict],
    delta: float,
    orders: Sequence[float] = DEFAULT_ORDERS,
) -> tuple[RdpAccountant, float]:
    """Replay ledger records (dicts with 'q' and 'sigma') into an accountant.

    Returns (accountant, epsilon). The service uses this on startup to
    rebuild the spent budget from the on-disk ledger before admitting any
    new step.
    """
    acct = RdpAccountant(orders)
    for rec in records:
        acct.spend(float(rec["q"]), float(rec["sigma"]))
    return acct, acct.epsilon(delta)
