"""repro.core — the paper's contribution: group-wise clipping for DP-SGD.

Public API:
  accounting     RDP accountant, sigma calibration, Prop 3.1 budget split
  quantile       private quantile tracking for adaptive thresholds
  noise          noise allocation strategies (global / equal-budget / weighted)
  ghost          per-example grad norms without per-example grads
  dp_layers      clip-in-backprop custom_vjp primitives
  clipping       mode drivers (per_layer / ghost_flat / per_group / ...)
  dp_sgd         DPConfig + train-step factory (Algorithm 1)
  lora           DP LoRA (the paper's GPT-3 recipe)
  spec           parameter/group bookkeeping (P, GroupLayout)
"""
from repro.core import accounting, clipping, dp_layers, dp_sgd, ghost, lora, noise, quantile, spec
from repro.core.clipping import MODES, ClipResult, dp_clipped_gradients
from repro.core.dp_sgd import DPConfig, DPPlan, DPState, build_plan, make_dp_train_step
from repro.core.spec import GroupLayout, P, abstract_params, init_params

__all__ = [
    "accounting", "clipping", "dp_layers", "dp_sgd", "ghost", "lora",
    "noise", "quantile", "spec", "MODES", "ClipResult",
    "dp_clipped_gradients", "DPConfig", "DPPlan", "DPState", "build_plan",
    "make_dp_train_step", "GroupLayout", "P", "abstract_params", "init_params",
]
