"""LoRA adapters with DP clipping — the paper's GPT-3-scale recipe (Sec 5.3).

The paper fine-tunes the 175B GPT-3 with DP LoRA under per-device clipping:
base weights frozen (no per-example machinery needed for them), adapters
A (d_in x r) and B (r x d_out) trained privately. Here:

  * `lora_spec` builds the adapter P-spec (each adapter pair is ONE clipping
    group — the adapter is "the layer" in group-wise terms; for per-shard
    clipping the B matrix may be blocked).
  * `dp_lora_linear` applies y = x W_frozen + (x A) B * (alpha/r) with the
    fused clip-in-backprop on the adapter pair: ghost norms for both A and B
    from one residual set.

Per-example grad norms for LoRA factorize nicely:
    dB_i = (X_i A)^T G_i           (r x d_out)   — ghost via small r
    dA_i = X_i^T (G_i B^T)         (d_in x r)
Both are computed with the standard linear ghost identity using the low-rank
intermediate, so costs stay O(T² r) / O(T r (d_in + d_out)).

Serving side (multi-tenant): one base model, many privately fine-tuned
adapters. `stacked_lora_delta` is the inference-only variant of
`dp_lora_linear`'s adapter term over a tenant-stacked buffer — adapters
for every live tenant stored along one extra axis, a per-row int32 tenant
id gathering the right pair inside the compiled program, so admitting or
hot-swapping a tenant is a buffer write, never a retrace
(launch.engine.DecodeEngine, launch.swap).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bk
from repro.core.ghost import clip_factor
from repro.core.spec import P
from repro.kernels import backend


def lora_spec(d_in: int, d_out: int, rank: int, *, stack: tuple[int, ...] = (),
              dtype=jnp.float32) -> dict:
    """Adapter spec; {a, b} share one clipping group (their parent path)."""
    s = len(stack)
    return {
        "a": P(stack + (d_in, rank), init="normal", scale=0.02, dtype=dtype,
               stack=s),
        "b": P(stack + (rank, d_out), init="zeros", dtype=dtype, stack=s),
    }


@jax.custom_vjp
def dp_lora_linear(a, b, w_frozen, x, c, alpha):
    """y = x @ w_frozen + (x @ a) @ b * (alpha / r); {a,b} one clip group."""
    bk.record_lora(c, a, b, x)
    r = a.shape[-1]
    scale = alpha / r
    return x @ w_frozen + (x @ a) @ b * scale


def _fwd(a, b, w_frozen, x, c, alpha):
    return dp_lora_linear(a, b, w_frozen, x, c, alpha), (a, b, w_frozen, x, c, alpha)


def _bwd(res, gy):
    a, b, w_frozen, x, c, alpha = res
    r = a.shape[-1]
    scale = alpha / r
    bsz = x.shape[0]
    x3 = x.reshape(bsz, -1, x.shape[-1])
    g3 = gy.reshape(bsz, -1, gy.shape[-1])
    # input cotangent (unclipped, through both paths)
    dx = gy @ w_frozen.T + ((gy * scale) @ b.T) @ a.T
    # per-example norms of the adapter pair's gradients
    eng = backend.active()
    xa = x3 @ a  # (B, T, r)
    gbt = (g3 * scale) @ b.T  # (B, T, r)
    n_b = eng.linear_norms_sq(xa, g3 * scale)  # ||dB_i||²
    n_a = eng.linear_norms_sq(x3, gbt)  # ||dA_i||²
    n = n_a + n_b
    if isinstance(c, bk.BkChannel):
        # BK capture: stash both residual pairs (dA <- (x, G B^T s);
        # dB <- (x A, G s)); the epilogue contracts each with the factors
        dc = bk.emit(c, n, a1=x3, g1=gbt, a2=xa, g2=g3 * scale)
        return (jnp.zeros_like(a), jnp.zeros_like(b),
                jnp.zeros_like(w_frozen), dx, dc,
                jnp.zeros_like(jnp.asarray(alpha, jnp.float32)))
    f = clip_factor(c, n)
    da = eng.clipped_sum_linear(x3, gbt, f).astype(a.dtype)
    db = eng.clipped_sum_linear(xa, g3 * scale, f).astype(b.dtype)
    dw = jnp.zeros_like(w_frozen)  # frozen
    return da, db, dw, dx, n, jnp.zeros_like(jnp.asarray(alpha, jnp.float32))


dp_lora_linear.defvjp(_fwd, _bwd)


def merge_lora(w, a, b, alpha: float):
    """Fold a trained adapter into the frozen weight (serving path)."""
    r = a.shape[-1]
    return w + (a @ b) * (alpha / r)


# ---------------------------------------------------------------------------
# Multi-tenant serving: tenant-stacked adapters.
# ---------------------------------------------------------------------------


def stacked_lora_delta(x, a_stack, b_stack, tenant, alpha):
    """Per-row adapter term from a tenant-stacked buffer (serving path).

    The batched multi-LoRA matmul of the multi-tenant engine: every live
    tenant's adapter pair lives in one stacked buffer, and each batch row
    gathers its own pair by int32 tenant id — the gather indices are DATA,
    so onboarding a tenant or hot-swapping its adapter never changes the
    traced program.

      x:       (B, t, d_in) activations (t = 1 at decode).
      a_stack: (T, d_in, r) — tenant axis leading.
      b_stack: (T, r, d_out).
      tenant:  (B,) int32 adapter-slot index per row.

    Returns (B, t, d_out): `(x @ A[tenant]) @ B[tenant] * (alpha / r)` —
    row-independent (each row contracts only its own adapter), which is
    what makes a mixed-tenant pool step bitwise identical to serving each
    tenant alone (tests/test_engine.py asserts it).
    """
    a = jnp.take(a_stack, tenant, axis=0)  # (B, d_in, r)
    b = jnp.take(b_stack, tenant, axis=0)  # (B, r, d_out)
    r = a_stack.shape[-1]
    h = jnp.einsum("btd,bdr->btr", x, a)
    return jnp.einsum("btr,bro->bto", h, b) * (alpha / r)


def stacked_adapter_zeros(spec_tree, num_slots: int):
    """Zero tenant-stacked buffers for an adapter P-spec tree.

    Every adapter leaf P(shape=(n, ...)) (n = layer-scan stack) becomes a
    zeros array of shape (n, T, ...) with T = `num_slots` riding just
    inside the scan axis (lax.scan consumes the leading layer axis; the
    per-layer slice handed to the attention body is then (T, ...), i.e.
    tenant-leading as `stacked_lora_delta` expects). B-adapters init to
    zeros anyway, so an empty slot serves the exact base model.
    """
    def leaf(p):
        return jnp.zeros((p.shape[0], num_slots) + tuple(p.shape[1:]),
                         p.dtype)

    return jax.tree_util.tree_map(leaf, spec_tree,
                                  is_leaf=lambda v: isinstance(v, P))


def stacked_slot_update(stacked, slot: int, adapters):
    """Install one tenant's adapter tree into slot `slot` of a stacked
    buffer (the hot-swap write: pure data, zero retrace). `adapters` leaves
    must be (n, ...) matching the buffer's (n, T, ...) minus the tenant
    axis; None writes zeros (the base model). Returns the updated buffer
    pytree."""
    if adapters is None:
        def put(buf):
            return buf.at[:, slot].set(jnp.zeros(
                buf.shape[:1] + buf.shape[2:], buf.dtype))

        return jax.tree_util.tree_map(put, stacked)

    def put(buf, leaf):
        want = buf.shape[:1] + buf.shape[2:]
        got = tuple(jnp.shape(leaf))
        if got != want:
            raise ValueError(
                f"adapter leaf shape {got} does not match the stacked "
                f"buffer's per-tenant shape {want}")
        return buf.at[:, slot].set(
            jax.device_put(jnp.asarray(leaf, buf.dtype)))

    return jax.tree_util.tree_map(put, stacked, adapters)
