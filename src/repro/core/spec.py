"""Parameter/group specification framework.

Group-wise clipping needs global bookkeeping that PyTorch gets from module
objects and JAX has to carry explicitly:

  * which parameters form a clipping group (paper: a "layer", e.g. the
    {W, b} of one linear; per-device mode: one Megatron block of W),
  * each group's size d_k (noise allocation needs it),
  * a flat enumeration k = 1..K of groups so thresholds C_k, per-example
    norms² n_k(i), clip counts b_k and quantile trackers line up,
  * the map param-leaf -> group id (noise std lookup per leaf).

Models declare their parameters as a nested dict of `P` leaves; everything
else (init, layout, packing thresholds, unpacking norms) is derived here.

Stacked layers: a spec whose shape carries leading scan dims sets
`stack=<n leading dims>`; each stack element is its own clipping group
(adaptive per-layer clipping tracks a separate C_k per depth). Blocked
weights (`blocks=M`) split one weight into M per-shard groups (per-device
clipping analogue).
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def stable_hash(name: str) -> int:
    """Process-independent 31-bit string hash.

    Python's builtin hash() is randomized per process (PYTHONHASHSEED), so
    folding it into PRNG keys makes param init and noise draws differ
    between processes — fatal for the training service's crash/resume
    bitwise-parity guarantee. Everything that derives a key from a name
    must use this instead."""
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


@dataclasses.dataclass(frozen=True)
class P:
    """Specification of one parameter tensor."""

    shape: tuple[int, ...]
    init: str = "normal"  # zeros | ones | normal | embed | uniform
    scale: float | None = None  # stddev override (normal) / range (uniform)
    dtype: Any = jnp.float32
    group: str | None = None  # explicit group path (shared / joint groups)
    blocks: int = 1  # split into M per-shard clipping groups (weights only)
    stack: int = 0  # number of leading scan/stack dims in `shape`
    fan_in_axis: int = -2  # axis used for fan-in init scaling
    sensitivity_mult: float = 1.0  # >1 for params SHARED across use sites
    #   (each site clips to C_k separately; the summed contribution of one
    #   example is bounded by n_sites * C_k, which noise calibration must use)


SpecTree = Any  # nested dict[str, P | SpecTree]


def _walk(spec: SpecTree, prefix: tuple[str, ...] = ()) -> Iterator[tuple[tuple[str, ...], P]]:
    for name in sorted(spec):
        node = spec[name]
        path = prefix + (name,)
        if isinstance(node, P):
            yield path, node
        else:
            yield from _walk(node, path)


# Canonical param leaf names that join their parent module's group
# ({w, b} of a linear, {s} of a norm, {a, b} of a LoRA adapter pair).
_PARENT_GROUP_NAMES = frozenset({"w", "b", "s", "a"})


def _group_path(path: tuple[str, ...], p: P) -> str:
    if p.group is not None:
        return p.group
    if len(path) > 1 and path[-1] in _PARENT_GROUP_NAMES:
        return "/".join(path[:-1])
    return "/".join(path)


def init_params(spec: SpecTree, key: jax.Array) -> Any:
    """Initialize a param pytree from a spec tree."""

    def build(node, key, path):
        if isinstance(node, P):
            return _init_leaf(node, key)
        out = {}
        for name in sorted(node):
            out[name] = build(node[name],
                              jax.random.fold_in(key, stable_hash(name)),
                              path + (name,))
        return out

    return build(spec, key, ())


def _init_leaf(p: P, key: jax.Array) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "normal":
        fan_in = p.shape[p.fan_in_axis] if len(p.shape) >= 2 else max(p.shape[-1], 1)
        std = p.scale if p.scale is not None else 1.0 / math.sqrt(fan_in)
        return (std * jax.random.normal(key, p.shape)).astype(p.dtype)
    if p.init == "embed":
        std = p.scale if p.scale is not None else 0.02
        return (std * jax.random.normal(key, p.shape)).astype(p.dtype)
    if p.init == "uniform":
        r = p.scale if p.scale is not None else 0.02
        return jax.random.uniform(key, p.shape, p.dtype, -r, r)
    raise ValueError(f"unknown init {p.init!r}")


def abstract_params(spec: SpecTree) -> Any:
    """ShapeDtypeStruct pytree (for dry-run lowering, no allocation)."""

    def build(node):
        if isinstance(node, P):
            return jax.ShapeDtypeStruct(node.shape, node.dtype)
        return {k: build(v) for k, v in node.items()}

    return build(spec)


@dataclasses.dataclass(frozen=True)
class Group:
    name: str
    stack_shape: tuple[int, ...]  # e.g. (L,) for scanned layers, (L, M) blocked
    dim: int  # parameters per group element (d_k)
    offset: int  # flat id of element (0,...,0)
    sensitivity_mult: float = 1.0

    @property
    def count(self) -> int:
        return int(np.prod(self.stack_shape, dtype=np.int64)) if self.stack_shape else 1


class GroupLayout:
    """Flat enumeration of clipping groups + pack/unpack helpers."""

    def __init__(self, spec: SpecTree):
        groups: dict[str, dict] = {}
        leaf_group: dict[tuple[str, ...], str] = {}
        for path, p in _walk(spec):
            gname = _group_path(path, p)
            stack_shape = tuple(p.shape[: p.stack])
            if p.blocks > 1:
                stack_shape = stack_shape + (p.blocks,)
            per_elem = int(np.prod(p.shape[p.stack:], dtype=np.int64)) // p.blocks
            if gname in groups:
                g = groups[gname]
                g["mult"] = max(g["mult"], p.sensitivity_mult)
                if g["stack_shape"] != stack_shape:
                    # bias joining a blocked weight group: allow scalar-per-
                    # element membership only when stack shapes are compatible
                    raise ValueError(
                        f"group {gname!r}: stack shape mismatch "
                        f"{g['stack_shape']} vs {stack_shape} at {path}"
                    )
                g["dim"] += per_elem
            else:
                groups[gname] = {"stack_shape": stack_shape, "dim": per_elem,
                                 "mult": p.sensitivity_mult}
            leaf_group[path] = gname
        self.groups: list[Group] = []
        self._by_name: dict[str, Group] = {}
        offset = 0
        for name in sorted(groups):
            g = groups[name]
            grp = Group(name=name, stack_shape=g["stack_shape"], dim=g["dim"],
                        offset=offset, sensitivity_mult=g["mult"])
            self.groups.append(grp)
            self._by_name[name] = grp
            offset += grp.count
        self.num_groups = offset
        self._leaf_group = leaf_group
        self._spec = spec

    # -- flat vectors -------------------------------------------------------

    def group(self, name: str) -> Group:
        return self._by_name[name]

    @property
    def dims(self) -> np.ndarray:
        """(K,) parameter count per group."""
        out = np.empty(self.num_groups, dtype=np.int64)
        for g in self.groups:
            out[g.offset: g.offset + g.count] = g.dim
        return out

    @property
    def sens_mults(self) -> np.ndarray:
        """(K,) sensitivity multipliers (shared-parameter sites)."""
        out = np.ones(self.num_groups, dtype=np.float32)
        for g in self.groups:
            out[g.offset: g.offset + g.count] = g.sensitivity_mult
        return out

    def flat_names(self) -> list[str]:
        out = []
        for g in self.groups:
            if g.count == 1:
                out.append(g.name)
            else:
                for idx in np.ndindex(g.stack_shape):
                    out.append(g.name + "[" + ",".join(map(str, idx)) + "]")
        return out

    # -- threshold packing ---------------------------------------------------

    def pack(self, flat: jax.Array, batch: int) -> dict[str, jax.Array]:
        """(K,) encoded thresholds -> {group name: stack_shape + (B,)} dict."""
        out = {}
        for g in self.groups:
            piece = jax.lax.dynamic_slice_in_dim(flat, g.offset, g.count)
            piece = piece.reshape(g.stack_shape + (1,))
            out[g.name] = jnp.broadcast_to(piece, g.stack_shape + (batch,))
        return out

    def pack_value(self, value: jax.Array | float, batch: int) -> dict[str, jax.Array]:
        """Same encoded scalar (or (B,) vector) for every group."""
        out = {}
        v = jnp.asarray(value, jnp.float32)
        for g in self.groups:
            if v.ndim == 0:
                out[g.name] = jnp.full(g.stack_shape + (batch,), v)
            else:
                out[g.name] = jnp.broadcast_to(v, g.stack_shape + (batch,))
        return out

    def pack_rows(self, rows: jax.Array) -> dict[str, jax.Array]:
        """(K, B) per-group per-example values -> thresholds dict."""
        out = {}
        batch = rows.shape[-1]
        for g in self.groups:
            piece = jax.lax.dynamic_slice_in_dim(rows, g.offset, g.count, axis=0)
            out[g.name] = piece.reshape(g.stack_shape + (batch,))
        return out

    def unpack(self, tree: dict[str, jax.Array]) -> jax.Array:
        """{group: stack_shape + (B,)} norms -> (K, B) flat matrix."""
        rows = []
        for g in self.groups:
            leaf = tree[g.name]
            rows.append(leaf.reshape(g.count, leaf.shape[-1]))
        return jnp.concatenate(rows, axis=0)

    # -- param-leaf -> group ids (noise lookup) ------------------------------

    def param_group_ids(self) -> Any:
        """Pytree parallel to params: leaves are int arrays of the leaf's
        group stack shape holding flat group ids (broadcastable against the
        param leaf for per-depth noise stds)."""

        def build(node, prefix):
            if isinstance(node, P):
                g = self._by_name[self._leaf_group[prefix]]
                ids = g.offset + np.arange(g.count, dtype=np.int64).reshape(
                    g.stack_shape or ())
                return ids
            return {k: build(v, prefix + (k,)) for k, v in node.items()}

        return build(self._spec, ())

    def zeros_thresholds(self, value: float = 1.0) -> jax.Array:
        return jnp.full((self.num_groups,), value, dtype=jnp.float32)


def subth(th: dict, prefix: str) -> dict:
    """Select the threshold-dict subtree under `prefix` (strip 'prefix/')."""
    plen = len(prefix) + 1
    return {k[plen:]: v for k, v in th.items() if k.startswith(prefix + "/")}
