"""Noise allocation for group-wise clipping (paper Sec. 3.3 "Allocating Noise").

The Gaussian mechanism is applied to the *scaled* concatenation
g_hat = (g~_1/gamma_1, ..., g~_K/gamma_K), whose L2 sensitivity is

    S = sqrt( sum_k C_k^2 / gamma_k^2 ).

Unscaling afterwards means group k receives noise with per-coordinate std

    std_k = sigma_new * S * gamma_k      (Algorithm 1, line 13).

Strategies for the scaling coefficients gamma_k:
  * global       : gamma_k = 1          -> every coordinate gets equal noise;
                                           V_G ∝ (Σ C_k²)(Σ d_k)
  * equal_budget : gamma_k = C_k        -> S = sqrt(K); each group's noise
                                           depends only on its own threshold
                                           (the per-device scheme: no
                                           cross-device communication);
                                           V_E ∝ K Σ d_k C_k²
  * weighted     : gamma_k = C_k/sqrt(d_k) -> roughly equal per-coordinate SNR
                                           (Appendix E); V ∝ (Σ d_k)(Σ C_k²)

Noise keys are folded per leaf path so draws are deterministic, order-
independent, and shard-friendly (each shard draws its own slice because
jax.random is counter-based and partitionable under jit).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.spec import stable_hash

Strategy = str  # 'global' | 'equal_budget' | 'weighted'
_STRATEGIES = ("global", "equal_budget", "weighted")


def gammas(strategy: Strategy, thresholds: jax.Array, dims: jax.Array) -> jax.Array:
    """Scaling coefficients gamma_k, shape (K,)."""
    if strategy == "global":
        return jnp.ones_like(thresholds)
    if strategy == "equal_budget":
        return thresholds
    if strategy == "weighted":
        return thresholds / jnp.sqrt(jnp.asarray(dims, jnp.float32))
    raise ValueError(f"unknown noise allocation strategy {strategy!r}; "
                     f"expected one of {_STRATEGIES}")


def sensitivity(thresholds: jax.Array, g: jax.Array) -> jax.Array:
    """S = sqrt(sum_k C_k^2 / gamma_k^2)."""
    return jnp.sqrt(jnp.sum((thresholds / g) ** 2))


def group_noise_stds(
    strategy: Strategy,
    thresholds: jax.Array,
    dims: jax.Array,
    sigma_new: jax.Array | float,
) -> jax.Array:
    """Per-group per-coordinate noise std, shape (K,): sigma_new * S * gamma_k."""
    g = gammas(strategy, thresholds, dims)
    s = sensitivity(thresholds, g)
    return jnp.asarray(sigma_new, jnp.float32) * s * g


def total_noise_sq_norm(
    strategy: Strategy,
    thresholds: jax.Array,
    dims: jax.Array,
    sigma_new: float = 1.0,
) -> jax.Array:
    """E ||z||^2 = sum_k d_k std_k^2 — used by tests against the paper's V_G/V_E."""
    stds = group_noise_stds(strategy, thresholds, dims, sigma_new)
    return jnp.sum(jnp.asarray(dims, jnp.float32) * stds**2)


def _path_names(path: tuple) -> tuple[str, ...]:
    names = []
    for entry in path:
        name = getattr(entry, "key", None)
        if name is None:
            name = getattr(entry, "idx", None)
        if name is None:
            name = getattr(entry, "name", str(entry))
        names.append(str(name))
    return tuple(names)


def _leaf_key_hash(path: tuple) -> int:
    """31-bit fold constant for one leaf path (polynomial over crc32 of
    the path segments). Exposed separately from `_leaf_key` so collision
    detection can check the HASHES without touching jax."""
    h = 0
    for name in _path_names(path):
        h = (h * 1000003 + stable_hash(name)) & 0x7FFFFFFF
    return h


def _leaf_key(base_key: jax.Array, path: tuple) -> jax.Array:
    """Deterministic per-leaf key: fold the leaf path hash into the base key."""
    return jax.random.fold_in(base_key, _leaf_key_hash(path))


def check_leaf_key_collisions(paths: list[str],
                              hash_fn: Callable[[str], int] = stable_hash
                              ) -> dict[int, str]:
    """Raise if two distinct leaf paths fold to the SAME 31-bit key hash.

    Colliding paths would receive IDENTICAL noise draws — correlated noise
    breaks the Gaussian mechanism's sensitivity bound silently (the draw
    still looks Gaussian per leaf). crc32 over ~30-40 leaf names makes a
    collision unlikely but not impossible (birthday bound ~2^15.5 names),
    so every plan build checks statically and refuses to train on one.
    Returns the (hash -> path) table for reuse/inspection."""
    seen: dict[int, str] = {}
    for path in paths:
        h = hash_fn(path)
        other = seen.get(h)
        if other is not None and other != path:
            raise ValueError(
                f"PRNG leaf-key collision: parameter paths {other!r} and "
                f"{path!r} both fold to key hash {h} — their noise draws "
                f"would be identical (correlated noise voids the DP "
                f"guarantee). Rename one of the parameters.")
        seen[h] = path
    return seen


def add_gaussian_noise(
    grads: Any,
    group_of_leaf: Callable[[tuple], int] | Any,
    stds: jax.Array,
    key: jax.Array,
) -> Any:
    """Add per-group Gaussian noise to a pytree of summed clipped gradients.

    grads:          pytree of arrays (already clipped & summed over batch).
    group_of_leaf:  either a callable (path -> group index) or a pytree with
                    the same structure as grads whose leaves are int group ids.
    stds:           (K,) per-group noise std (see group_noise_stds).
    key:            PRNG key; per-leaf keys are derived by path folding.
    """
    paths_leaves = jax.tree_util.tree_flatten_with_path(grads)[0]
    treedef = jax.tree_util.tree_structure(grads)
    if callable(group_of_leaf):
        gids = [group_of_leaf(p) for p, _ in paths_leaves]
    else:
        gids = jax.tree_util.tree_leaves(group_of_leaf)
        if len(gids) != len(paths_leaves):
            raise ValueError("group pytree structure mismatch")
    check_leaf_key_collisions(
        ["/".join(_path_names(p)) for p, _ in paths_leaves],
        hash_fn=lambda s: _leaf_key_hash_str(s))
    noised = []
    for (path, leaf), gid in zip(paths_leaves, gids):
        # dp_noise_add:<leaf> marks the draw for the static auditor
        # (repro.analysis.jaxpr_taint): '.'-joined so the leaf name stays
        # one name-stack segment
        with jax.named_scope("dp_noise_add:" + ".".join(_path_names(path))):
            k = _leaf_key(key, path)
            std = stds[gid]
            z = std * jax.random.normal(k, leaf.shape, dtype=jnp.float32)
            noised.append((leaf.astype(jnp.float32) + z).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, noised)


def _leaf_key_hash_str(path_str: str) -> int:
    """`_leaf_key_hash` over a '/'-joined rendered path string."""
    h = 0
    for name in path_str.split("/"):
        h = (h * 1000003 + stable_hash(name)) & 0x7FFFFFFF
    return h
