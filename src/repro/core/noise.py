"""Noise allocation for group-wise clipping (paper Sec. 3.3 "Allocating Noise").

The Gaussian mechanism is applied to the *scaled* concatenation
g_hat = (g~_1/gamma_1, ..., g~_K/gamma_K), whose L2 sensitivity is

    S = sqrt( sum_k C_k^2 / gamma_k^2 ).

Unscaling afterwards means group k receives noise with per-coordinate std

    std_k = sigma_new * S * gamma_k      (Algorithm 1, line 13).

Strategies for the scaling coefficients gamma_k:
  * global       : gamma_k = 1          -> every coordinate gets equal noise;
                                           V_G ∝ (Σ C_k²)(Σ d_k)
  * equal_budget : gamma_k = C_k        -> S = sqrt(K); each group's noise
                                           depends only on its own threshold
                                           (the per-device scheme: no
                                           cross-device communication);
                                           V_E ∝ K Σ d_k C_k²
  * weighted     : gamma_k = C_k/sqrt(d_k) -> roughly equal per-coordinate SNR
                                           (Appendix E); V ∝ (Σ d_k)(Σ C_k²)

Noise keys are folded per leaf path so draws are deterministic, order-
independent, and shard-friendly (each shard draws its own slice because
jax.random is counter-based and partitionable under jit).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.spec import stable_hash

Strategy = str  # 'global' | 'equal_budget' | 'weighted'
_STRATEGIES = ("global", "equal_budget", "weighted")


def gammas(strategy: Strategy, thresholds: jax.Array, dims: jax.Array) -> jax.Array:
    """Scaling coefficients gamma_k, shape (K,)."""
    if strategy == "global":
        return jnp.ones_like(thresholds)
    if strategy == "equal_budget":
        return thresholds
    if strategy == "weighted":
        return thresholds / jnp.sqrt(jnp.asarray(dims, jnp.float32))
    raise ValueError(f"unknown noise allocation strategy {strategy!r}; "
                     f"expected one of {_STRATEGIES}")


def sensitivity(thresholds: jax.Array, g: jax.Array) -> jax.Array:
    """S = sqrt(sum_k C_k^2 / gamma_k^2)."""
    return jnp.sqrt(jnp.sum((thresholds / g) ** 2))


def group_noise_stds(
    strategy: Strategy,
    thresholds: jax.Array,
    dims: jax.Array,
    sigma_new: jax.Array | float,
) -> jax.Array:
    """Per-group per-coordinate noise std, shape (K,): sigma_new * S * gamma_k."""
    g = gammas(strategy, thresholds, dims)
    s = sensitivity(thresholds, g)
    return jnp.asarray(sigma_new, jnp.float32) * s * g


def total_noise_sq_norm(
    strategy: Strategy,
    thresholds: jax.Array,
    dims: jax.Array,
    sigma_new: float = 1.0,
) -> jax.Array:
    """E ||z||^2 = sum_k d_k std_k^2 — used by tests against the paper's V_G/V_E."""
    stds = group_noise_stds(strategy, thresholds, dims, sigma_new)
    return jnp.sum(jnp.asarray(dims, jnp.float32) * stds**2)


def _leaf_key(base_key: jax.Array, path: tuple) -> jax.Array:
    """Deterministic per-leaf key: fold the leaf path hash into the base key."""
    h = 0
    for entry in path:
        name = getattr(entry, "key", None)
        if name is None:
            name = getattr(entry, "idx", None)
        if name is None:
            name = getattr(entry, "name", str(entry))
        h = (h * 1000003 + stable_hash(str(name))) & 0x7FFFFFFF
    return jax.random.fold_in(base_key, h)


def add_gaussian_noise(
    grads: Any,
    group_of_leaf: Callable[[tuple], int] | Any,
    stds: jax.Array,
    key: jax.Array,
) -> Any:
    """Add per-group Gaussian noise to a pytree of summed clipped gradients.

    grads:          pytree of arrays (already clipped & summed over batch).
    group_of_leaf:  either a callable (path -> group index) or a pytree with
                    the same structure as grads whose leaves are int group ids.
    stds:           (K,) per-group noise std (see group_noise_stds).
    key:            PRNG key; per-leaf keys are derived by path folding.
    """
    paths_leaves = jax.tree_util.tree_flatten_with_path(grads)[0]
    treedef = jax.tree_util.tree_structure(grads)
    if callable(group_of_leaf):
        gids = [group_of_leaf(p) for p, _ in paths_leaves]
    else:
        gids = jax.tree_util.tree_leaves(group_of_leaf)
        if len(gids) != len(paths_leaves):
            raise ValueError("group pytree structure mismatch")
    noised = []
    for (path, leaf), gid in zip(paths_leaves, gids):
        k = _leaf_key(key, path)
        std = stds[gid]
        z = std * jax.random.normal(k, leaf.shape, dtype=jnp.float32)
        noised.append((leaf.astype(jnp.float32) + z).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, noised)
