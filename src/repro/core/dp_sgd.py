"""DP optimization: Algorithm 1 (adaptive per-layer DP-SGD) and friends.

Wires together:  clipping driver (core.clipping)  +  private quantile
estimation (core.quantile)  +  noise allocation (core.noise)  +  RDP
accounting incl. the Prop 3.1 budget split (core.accounting)  +  any
first-order optimizer with an optax-like (init, update) interface
(repro.optim) — the paper notes the scheme applies to DP-Adam etc.

The factory precomputes all python-float accounting at build time; the
returned step function is pure and jit/pjit-friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accounting, noise as noise_lib
from repro.core.clipping import LossFn, base_mode, dp_clipped_gradients
from repro.kernels import backend as ghost_backend
from repro.core.quantile import QuantileState, clip_counts, init_quantile_state, update_thresholds
from repro.core.spec import GroupLayout, P, SpecTree, _walk, stable_hash


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Configuration of the private learning run.

    Knob groups, with defaults and units (CLI spellings in parens refer
    to `repro.launch.train` / `repro.launch.service` flags):

    * **Clipping** — `mode` (`--clipping`, default `per_layer`) picks
      the paper's clipping granularity; `execution` (`--execution`,
      default `bk`) picks how the flat/group modes compute the clipped
      sums (one backprop + BK epilogue vs the two-backward reference).
      Accounting is identical across executions — the choice is purely
      compute/memory.
    * **Privacy budget** — `epsilon` (target, calibrated over `steps`
      optimizer steps at Poisson `sampling_rate` = B/N and `delta`);
      set `sigma` (noise multiplier, units of the clipping threshold)
      to skip calibration entirely. All python floats, resolved once at
      plan-build time.
    * **Thresholds** — `adaptive=True` tracks the `target_quantile` of
      per-example norms with learning rate `quantile_lr`, spending
      `quantile_budget_fraction` of the budget on the clip-count
      release (Prop 3.1 split); `init_threshold` is C(0) in gradient-
      norm units (also the fixed C when `adaptive=False`).
    * **per_group** — `group_assignment` maps each `GroupLayout` group
      to a supergroup; `num_supergroups` pads the count (the sharded
      engine sets it to the `--mesh` model-axis size so every shard
      owns a well-defined threshold slot).
    * **Ghost-op backend** — `backend` (`--backend`, default `auto`)
      and `autotune` (`--autotune`, default on) select the kernel
      engine for norms/clipped-sums; scoped around the step so jitted
      traces capture it statically. See `repro.kernels.backend`.
    * **Scale-out** — `microbatches` (default 1) accumulates gradients
      without changing the released quantity (clipping commutes with
      accumulation); `batch_axes` names the mesh axes of the batch dim,
      required when `microbatches > 1` under pjit (pins the microbatch
      split off the data plane). The `--mesh` itself is passed to
      `make_dp_train_step(mesh=...)`, not stored here.
    """

    mode: str = "per_layer"  # non_private|per_layer|ghost_flat|per_group|
    #   naive_flat (+ ghost_flat_twopass|per_group_twopass reference modes)
    execution: str = "bk"  # bk | twopass — how the flat/group modes run:
    #   bk (book-keeping, core.bk) caches ghost residuals during the single
    #   norm backprop and contracts them in an epilogue; twopass is the
    #   historical two-backward reference. Ignored by the other modes; a
    #   `*_twopass` mode name forces twopass.
    # --- privacy budget ---
    epsilon: float | None = 8.0
    delta: float = 1e-5
    sampling_rate: float = 0.01  # rho = B / N  (Poisson subsampling)
    steps: int = 1000  # T, for accounting
    sigma: float | None = None  # direct noise-multiplier override (skips calibration)
    # --- thresholds ---
    adaptive: bool = True  # adaptive (quantile-tracked) vs fixed thresholds
    init_threshold: float = 1.0  # C_k(0) (per-layer) or C (flat)
    target_quantile: float = 0.5  # q
    quantile_lr: float = 0.3  # eta (paper uses 0.3 everywhere)
    quantile_budget_fraction: float = 0.01  # r in (0,1)
    # --- noise allocation (Sec 3.3) ---
    noise_strategy: str = "global"  # global | equal_budget | weighted
    # Appendix A.1 protocol: rescale adaptive per-layer thresholds to an
    # equivalent GLOBAL threshold C, i.e. use C_k_eff = C * C_k / ||C||_2.
    # The tracker learns the cross-layer SHAPE; total clipping budget (and
    # hence noise scale) stays comparable to flat clipping at threshold C.
    threshold_rescale: float | None = None
    # --- per_group / per-device mode ---
    group_assignment: tuple[int, ...] | None = None  # layout-group -> supergroup
    num_supergroups: int | None = None  # explicit supergroup count G (else
    #   max(assignment)+1). The sharded engine sets G = model-axis size so a
    #   shard that owns no group still has a (well-defined, idle) threshold.
    # --- ghost-op backend (repro.kernels.backend) ---
    backend: str = "auto"  # xla | pallas | auto — engine for the ghost ops;
    #   scoped around the step function so jitted traces capture it
    #   statically. auto picks the measured argmin per (op, shape bucket)
    #   when an autotune table is installed (repro.kernels.autotune) and
    #   falls back to the static cost model (xla off-TPU) on unmeasured
    #   buckets. None-like inheritance of tunables (outer_max_elems, tile
    #   sizes) comes from the enclosing backend.scoped(...) if any.
    autotune: bool = True  # False pins auto to the static model even with
    #   a table installed (--autotune off)
    # --- misc ---
    noise_dtype: Any = jnp.float32
    microbatches: int = 1  # gradient accumulation (Algorithm 2 structure):
    #   per-example clipping commutes with microbatch accumulation, so the
    #   clipped sums and norms are EXACTLY those of the monolithic batch;
    #   noise is added once per minibatch (Alg. 2 line 6).
    batch_axes: tuple[str, ...] | None = None  # mesh axes of the batch dim.
    #   Needed when microbatches > 1 under pjit: the (B,) -> (nmb, mb) split
    #   is reshard-ambiguous and GSPMD may scatter the data axis across BOTH
    #   new dims (catastrophic per-iteration collectives); this pins the
    #   microbatch dim replicated and the example dim on the data plane.

    @property
    def private(self) -> bool:
        return self.mode != "non_private"


class DPState(NamedTuple):
    qstate: QuantileState  # K (or G) adaptive thresholds
    step: jax.Array  # scalar int32


class StepMetrics(NamedTuple):
    loss: jax.Array
    clip_fraction: jax.Array  # mean over groups of fraction clipped
    mean_threshold: jax.Array
    grad_norm: jax.Array  # norm of the (noised, averaged) update direction


@dataclasses.dataclass(frozen=True)
class DPPlan:
    """Everything precomputed at build time (python floats, accounting)."""

    config: DPConfig
    num_noise_groups: int  # K for per_layer, 1 for flat, G for per_group
    sigma: float  # total-budget noise multiplier (no quantile split)
    sigma_b: float  # clip-count noise multiplier (0 if not adaptive)
    sigma_new: float  # gradient noise multiplier after the Prop 3.1 split
    group_dims: np.ndarray  # (num_noise_groups,) parameter counts
    sens_mults: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.float32))


def build_plan(cfg: DPConfig, layout: GroupLayout) -> DPPlan:
    if not cfg.private:
        return DPPlan(cfg, 0, 0.0, 0.0, 0.0, np.zeros(0, np.int64))
    mults = layout.sens_mults
    mode = base_mode(cfg.mode)  # accounting is execution-independent
    if mode in ("ghost_flat", "naive_flat"):
        num_groups = 1
        dims = np.array([int(layout.dims.sum())], np.int64)
        mults = np.ones(1, np.float32)
    elif mode == "per_group":
        if cfg.group_assignment is None:
            raise ValueError("per_group mode requires group_assignment")
        assign = np.asarray(cfg.group_assignment)
        if assign.shape != (layout.num_groups,):
            raise ValueError(
                f"group_assignment must have shape ({layout.num_groups},)")
        num_groups = (cfg.num_supergroups if cfg.num_supergroups
                      else int(assign.max()) + 1)
        if num_groups <= int(assign.max()):
            raise ValueError("num_supergroups smaller than assignment range")
        dims = np.zeros(num_groups, np.int64)
        np.add.at(dims, assign, layout.dims)
        m = np.ones(num_groups, np.float32)
        np.maximum.at(m, assign, layout.sens_mults)
        mults = m
    else:  # per_layer (incl. per-shard blocked layouts)
        num_groups = layout.num_groups
        dims = layout.dims
    if cfg.sigma is not None:
        sigma = float(cfg.sigma)
    else:
        if cfg.epsilon is None:
            raise ValueError("need epsilon or sigma")
        sigma = accounting.calibrate_sigma(
            target_eps=cfg.epsilon, sampling_rate=cfg.sampling_rate,
            steps=cfg.steps, delta=cfg.delta)
    if cfg.adaptive:
        sigma_b = accounting.sigma_b_for_fraction(
            sigma, num_groups, cfg.quantile_budget_fraction)
        split = accounting.split_noise_multiplier(sigma, sigma_b, num_groups)
        sigma_new = split.sigma_new
    else:
        sigma_b, sigma_new = 0.0, sigma
    return DPPlan(cfg, num_groups, sigma, sigma_b, sigma_new, dims, mults)


def init_dp_state(plan: DPPlan) -> DPState:
    cfg = plan.config
    k = max(plan.num_noise_groups, 1)
    qstate = init_quantile_state(
        np.full((k,), cfg.init_threshold, np.float32),
        target_quantile=cfg.target_quantile,
        lr=cfg.quantile_lr,
        sigma_b=plan.sigma_b if cfg.adaptive else 0.0,
    )
    return DPState(qstate=qstate, step=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Noise application (spec-aware: stacked and blocked leaves).
# ---------------------------------------------------------------------------


def add_noise_to_grads(
    spec: SpecTree,
    layout: GroupLayout,
    grads: Any,
    stds: jax.Array,  # (num_layout_groups,) per-LAYOUT-group std
    key: jax.Array,
    dtype=jnp.float32,
) -> Any:
    """grads + N(0, std_k²) with the right std per (possibly stacked/blocked)
    parameter leaf. `stds` is indexed by layout-group flat id."""

    def one_leaf(node, g, path):
        gname = layout._leaf_group[path]
        grp = layout.group(gname)
        piece = jax.lax.dynamic_slice_in_dim(stds, grp.offset, grp.count)
        piece = piece.reshape(grp.stack_shape or ())
        leaf_key = jax.random.fold_in(
            key, stable_hash("/".join(path)))
        z = jax.random.normal(leaf_key, g.shape, dtype)
        if node.blocks > 1:
            # std varies per column block of the last axis
            m = node.blocks
            rest = g.shape[node.stack:-1]
            std_full = piece.reshape(
                grp.stack_shape[:-1] + (1,) * len(rest) + (m, 1))
            zb = z.reshape(g.shape[:-1] + (m, g.shape[-1] // m))
            zb = zb * std_full
            z = zb.reshape(g.shape)
        else:
            std_full = piece.reshape(
                (grp.stack_shape or ()) + (1,) * (g.ndim - len(grp.stack_shape)))
            z = z * std_full
        return (g.astype(dtype) + z).astype(g.dtype)

    def walk(node, g, path):
        if isinstance(node, P):
            # dp_noise_add:<leaf> marks this leaf's (single) draw for the
            # static auditor (repro.analysis.jaxpr_taint); '.'-joined so
            # the leaf name stays one name-stack segment
            with jax.named_scope("dp_noise_add:" + ".".join(path)):
                return one_leaf(node, g, path)
        return {k2: walk(node[k2], g[k2], path + (k2,)) for k2 in node}

    return walk(spec, grads, ())


def _layout_stds(plan: DPPlan, layout: GroupLayout,
                 thresholds: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-layout-group noise stds + the per-noise-group thresholds used.

    For flat modes the single noise group covers every layout group; for
    per_group mode the supergroup std is broadcast to its members.
    """
    cfg = plan.config
    mode = base_mode(cfg.mode)
    dims = jnp.asarray(plan.group_dims, jnp.float32)
    mults = jnp.asarray(plan.sens_mults, jnp.float32)
    stds_group = noise_lib.group_noise_stds(
        cfg.noise_strategy, thresholds * mults, dims, plan.sigma_new)  # (G,)
    if mode in ("ghost_flat", "naive_flat"):
        return jnp.broadcast_to(stds_group, (layout.num_groups,)), thresholds
    if mode == "per_group":
        assign = jnp.asarray(np.asarray(cfg.group_assignment), jnp.int32)
        return stds_group[assign], thresholds
    return stds_group, thresholds


# ---------------------------------------------------------------------------
# The train-step factory.
# ---------------------------------------------------------------------------


def _effective_thresholds(cfg: DPConfig, plan: DPPlan, dp_state: DPState):
    """Tracked thresholds, with the Appendix-A.1 global rescale applied."""
    thresholds = dp_state.qstate.thresholds  # (G,)
    if cfg.threshold_rescale is not None and plan.num_noise_groups > 1:
        thresholds = (cfg.threshold_rescale * thresholds
                      / jnp.sqrt(jnp.sum(thresholds**2) + 1e-20))
    return thresholds


def _apply_update(cfg: DPConfig, plan: DPPlan, optimizer, trainable_key,
                  batch_size, params, opt_state, dp_state, noised, counts,
                  thresholds, loss, k_q):
    """Post-clipping tail shared by the single-device and sharded steps:
    gradient averaging, optimizer update, private quantile update, metrics.
    `noised` must be the (noised) SUMMED clipped grads over the full batch;
    `counts` the full-batch clip counts — both already globally reduced in
    the sharded case."""
    tgrads = noised if trainable_key is None else noised[trainable_key]
    tparams = params if trainable_key is None else params[trainable_key]
    grad_avg = jax.tree_util.tree_map(
        lambda g: (g / batch_size).astype(g.dtype), tgrads)
    updates, new_opt_state = optimizer.update(grad_avg, opt_state, tparams)
    new_tparams = jax.tree_util.tree_map(lambda p, u: p + u, tparams,
                                         updates)
    new_params = (new_tparams if trainable_key is None
                  else {**params, trainable_key: new_tparams})

    qstate = dp_state.qstate
    if cfg.private and cfg.adaptive:
        qstate = update_thresholds(qstate, counts, batch_size, k_q)
    new_dp_state = DPState(qstate=qstate, step=dp_state.step + 1)

    gn = jnp.sqrt(sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)))
        for l in jax.tree_util.tree_leaves(grad_avg)))
    metrics = StepMetrics(
        loss=loss,
        clip_fraction=1.0 - jnp.mean(counts) / batch_size,
        mean_threshold=jnp.mean(thresholds),
        grad_norm=gn,
    )
    return new_params, new_opt_state, new_dp_state, metrics


def make_dp_train_step(
    loss_fn: LossFn,
    spec: SpecTree,
    layout: GroupLayout,
    optimizer: Any,  # repro.optim optimizer (init/update)
    cfg: DPConfig,
    *,
    batch_size: int,
    trainable_key: str | None = None,
    mesh: Any = None,
) -> tuple[Callable, Callable, DPPlan]:
    """Build the jittable private training step. Returns
    (init_fn, step_fn, plan).

    init_fn(params) -> (opt_state, dp_state)
    step_fn(params, opt_state, dp_state, batch, key)
        -> (params, opt_state, dp_state, StepMetrics)

    All accounting (sigma calibration, the Prop 3.1 quantile budget
    split, group dimensioning) happens HERE, once, in python floats —
    the returned `plan` records it and step_fn is pure. Refuses at
    build time to train a spec whose leaf paths crc32-collide into the
    same noise key.

    batch_size: the GLOBAL examples-per-step B (even under `mesh`),
    used for averaging and the sampling-rate check; must divide by
    `cfg.microbatches`.

    trainable_key: restrict training to `params[trainable_key]` (e.g.
    `"lora"` for DP-LoRA fine-tunes — the rest of the tree is frozen,
    carried through untouched, and spends no privacy budget). The
    training service publishes adapter-only checkpoints exactly when
    this is `"lora"`.

    mesh: a (data[, pod], model) device mesh. When given, step_fn is built
    under `shard_map` — batch sharded over the data plane, clipping
    bookkeeping distributed over the model axis by shard ownership
    (launch.sharding.group_shard_assignment), per-device (`per_group`)
    norms and clip factors shard-local, `ghost_flat` paying its one (B,)
    model-axis norm psum, and the BK epilogue interleaving each layer's
    gradient psum with the next layer's contraction. `batch_size` stays the
    GLOBAL batch. jit the returned step_fn as usual (optionally with
    launch.sharding params_shardings as in_shardings to keep the weights
    STORED model-sharded between steps).
    """
    if cfg.private:
        # static PRNG-safety gate (see noise.check_leaf_key_collisions):
        # two leaf paths crc32-folding to the same key would draw
        # IDENTICAL noise — refuse at plan-build time, naming both
        noise_lib.check_leaf_key_collisions(
            ["/".join(p) for p, _ in _walk(spec)])
    if mesh is not None:
        return _make_sharded_step(loss_fn, spec, layout, optimizer, cfg,
                                  batch_size=batch_size,
                                  trainable_key=trainable_key, mesh=mesh)
    plan = build_plan(cfg, layout)
    assign = (jnp.asarray(np.asarray(cfg.group_assignment), jnp.int32)
              if cfg.group_assignment is not None else None)

    def init_fn(params):
        tp = params if trainable_key is None else params[trainable_key]
        return optimizer.init(tp), init_dp_state(plan)

    nmb = cfg.microbatches
    mb_size = batch_size // nmb
    if batch_size % nmb:
        raise ValueError("batch_size must divide by microbatches")

    mode = base_mode(cfg.mode)
    execution = "twopass" if cfg.mode.endswith("_twopass") else cfg.execution

    def _clip(params, batch, thresholds):
        """Clipped sums + norms, accumulated over microbatches (exact)."""
        def one(batch_mb):
            if mode == "non_private":
                return dp_clipped_gradients(
                    loss_fn, params, batch_mb, layout, mode="non_private",
                    batch_size=mb_size, trainable_key=trainable_key)
            if mode == "per_layer":
                return dp_clipped_gradients(
                    loss_fn, params, batch_mb, layout, mode="per_layer",
                    batch_size=mb_size, thresholds=thresholds,
                    trainable_key=trainable_key)
            if mode in ("ghost_flat", "naive_flat"):
                return dp_clipped_gradients(
                    loss_fn, params, batch_mb, layout, mode=mode,
                    batch_size=mb_size, flat_threshold=thresholds[0],
                    trainable_key=trainable_key, execution=execution)
            return dp_clipped_gradients(
                loss_fn, params, batch_mb, layout, mode="per_group",
                batch_size=mb_size, group_assignment=assign,
                group_thresholds=thresholds, trainable_key=trainable_key,
                execution=execution)

        if nmb == 1:
            return one(batch)

        def _split_leaf(x):
            y = x.reshape((nmb, mb_size) + x.shape[1:])
            if cfg.batch_axes is not None:
                from jax.sharding import PartitionSpec as _PS
                y = jax.lax.with_sharding_constraint(
                    y, _PS(None, cfg.batch_axes))
            return y

        split = jax.tree_util.tree_map(_split_leaf, batch)

        def body(acc, batch_mb):
            res = one(batch_mb)
            g_acc, loss_acc = acc
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, res.grads)
            return (g_acc, loss_acc + res.loss), res.norms_sq

        tp = params if trainable_key is None else {
            trainable_key: params[trainable_key]}
        g0 = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), tp)
        (g_sum, loss_sum), norms = jax.lax.scan(body, (g0, 0.0), split)
        norms = jnp.moveaxis(norms, 0, 1).reshape(layout.num_groups,
                                                  batch_size)
        from repro.core.clipping import ClipResult
        g_sum = jax.tree_util.tree_map(
            lambda a, x: a.astype(x.dtype), g_sum, tp)
        return ClipResult(g_sum, norms, loss_sum / nmb)

    def step_fn(params, opt_state, dp_state, batch, key):
        # scoped (not global) engine: the jitted trace of this function
        # captures cfg.backend statically; tunables inherit from any
        # enclosing backend.scoped(...) (e.g. the dry-run's outer cap).
        with ghost_backend.scoped(cfg.backend, autotune=cfg.autotune):
            return _step(params, opt_state, dp_state, batch, key)

    def _step(params, opt_state, dp_state, batch, key):
        k_noise, k_q = jax.random.split(jax.random.fold_in(key, dp_state.step))
        thresholds = _effective_thresholds(cfg, plan, dp_state)

        res = _clip(params, batch, thresholds)
        if mode == "non_private":
            noised = res.grads
            counts = jnp.zeros_like(thresholds)
        else:
            if mode == "per_layer":
                counts = clip_counts(res.norms_sq, thresholds)
            elif mode in ("ghost_flat", "naive_flat"):
                counts = clip_counts(jnp.sum(res.norms_sq, axis=0)[None],
                                     thresholds)
            else:  # per_group
                super_norms = jax.ops.segment_sum(
                    res.norms_sq, assign, num_segments=plan.num_noise_groups)
                counts = clip_counts(super_norms, thresholds)
            stds, _ = _layout_stds(plan, layout, thresholds)
            noised = add_noise_to_grads(spec, layout, res.grads, stds,
                                        k_noise, cfg.noise_dtype)

        return _apply_update(cfg, plan, optimizer, trainable_key, batch_size,
                             params, opt_state, dp_state, noised, counts,
                             thresholds, res.loss, k_q)

    return init_fn, step_fn, plan


# ---------------------------------------------------------------------------
# The sharded (shard_map) train-step factory.
# ---------------------------------------------------------------------------


def _make_sharded_step(loss_fn, spec, layout, optimizer, cfg: DPConfig, *,
                       batch_size: int, trainable_key: str | None, mesh):
    """`make_dp_train_step` under manual SPMD on a (data[, pod], model) mesh.

    See `repro.core.clipping.sharded_clipped_gradients` for the per-mode
    communication contract. The quantile update, noise draw and optimizer
    run replicated (identical keys on every device), so outputs are
    replicated and out_specs are fully unsharded.
    """
    # lazy: keep core -> launch imports out of module import time
    from jax.sharding import PartitionSpec as PS
    from repro.core.clipping import sharded_clipped_gradients
    from repro.launch.mesh import data_axes as _data_axes, named_shard_map
    from repro.launch.sharding import group_shard_assignment

    dax = tuple(_data_axes(mesh))
    model_ax = "model"
    d_size = int(np.prod([mesh.shape[a] for a in dax]))
    m_size = int(mesh.shape[model_ax])
    if batch_size % d_size:
        raise ValueError(f"global batch {batch_size} must divide across the "
                         f"{d_size}-way data plane")
    b_local = batch_size // d_size
    nmb = cfg.microbatches
    if b_local % nmb:
        raise ValueError("per-shard batch must divide by microbatches")
    mb_local = b_local // nmb

    mode = base_mode(cfg.mode)
    execution = "twopass" if cfg.mode.endswith("_twopass") else cfg.execution
    if mode not in ("non_private", "per_layer", "ghost_flat", "per_group"):
        raise ValueError(
            f"sharded execution supports non_private/per_layer/ghost_flat/"
            f"per_group, not {mode!r} (naive_flat is a single-device oracle)")
    own_assign = group_shard_assignment(layout, m_size)
    if mode == "per_group":
        if (cfg.group_assignment is not None
                and tuple(cfg.group_assignment) != own_assign):
            raise ValueError(
                "sharded per_group IS per-device clipping: group_assignment "
                "must equal the model-axis shard ownership (leave it unset "
                "to derive it via launch.sharding.group_shard_assignment)")
        cfg = dataclasses.replace(cfg, group_assignment=own_assign,
                                  num_supergroups=m_size)
    plan = build_plan(cfg, layout)
    shard_assign = jnp.asarray(np.asarray(own_assign), jnp.int32)

    def init_fn(params):
        tp = params if trainable_key is None else params[trainable_key]
        return optimizer.init(tp), init_dp_state(plan)

    def _one(params, batch_mb, thresholds, bsz):
        kw = dict(batch_size=bsz, data_size=d_size, data_axes=dax,
                  model_axis=model_ax, trainable_key=trainable_key)
        if mode == "non_private":
            return sharded_clipped_gradients(loss_fn, params, batch_mb,
                                             layout, mode=mode, **kw)
        if mode == "per_layer":
            return sharded_clipped_gradients(
                loss_fn, params, batch_mb, layout, mode=mode,
                thresholds=thresholds, **kw)
        if mode == "ghost_flat":
            return sharded_clipped_gradients(
                loss_fn, params, batch_mb, layout, mode=mode,
                flat_threshold=thresholds[0], shard_assignment=shard_assign,
                execution=execution, **kw)
        return sharded_clipped_gradients(
            loss_fn, params, batch_mb, layout, mode="per_group",
            group_thresholds=thresholds, shard_assignment=shard_assign,
            execution=execution, **kw)

    def _clip(params, batch, thresholds):
        if nmb == 1:
            return _one(params, batch, thresholds, b_local)
        # microbatch accumulation: the per-microbatch grads come back
        # already globally psum'd, so plain accumulation stays exact
        split = jax.tree_util.tree_map(
            lambda x: x.reshape((nmb, mb_local) + x.shape[1:]), batch)
        tp = params if trainable_key is None else {
            trainable_key: params[trainable_key]}
        g0 = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), tp)
        c0 = jnp.zeros((max(plan.num_noise_groups, 1)
                        if mode != "per_layer" else layout.num_groups,),
                       jnp.float32)

        def body(acc, batch_mb):
            res = _one(params, batch_mb, thresholds, mb_local)
            g_acc, loss_acc, cnt_acc = acc
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, res.grads)
            return ((g_acc, loss_acc + res.loss, cnt_acc + res.counts),
                    res.norms_sq)

        (g_sum, loss_sum, counts), norms = jax.lax.scan(
            body, (g0, 0.0, c0), split)
        norms = jnp.moveaxis(norms, 0, 1).reshape(layout.num_groups, b_local)
        from repro.core.clipping import ShardedClipResult
        g_sum = jax.tree_util.tree_map(
            lambda a, x: a.astype(x.dtype), g_sum, tp)
        return ShardedClipResult(g_sum, norms, loss_sum / nmb, counts)

    def _body(params, opt_state, dp_state, batch, key):
        with ghost_backend.scoped(cfg.backend, autotune=cfg.autotune):
            k_noise, k_q = jax.random.split(
                jax.random.fold_in(key, dp_state.step))
            thresholds = _effective_thresholds(cfg, plan, dp_state)

            res = _clip(params, batch, thresholds)
            if mode == "non_private":
                noised = res.grads
                counts = jnp.zeros_like(thresholds)
            else:
                counts = res.counts  # globally reduced by the clip driver
                stds, _ = _layout_stds(plan, layout, thresholds)
                noised = add_noise_to_grads(spec, layout, res.grads, stds,
                                            k_noise, cfg.noise_dtype)

            return _apply_update(cfg, plan, optimizer, trainable_key,
                                 batch_size, params, opt_state, dp_state,
                                 noised, counts, thresholds, res.loss, k_q)

    step_fn = named_shard_map(
        _body, mesh,
        in_specs=(PS(), PS(), PS(), PS(dax), PS()),
        out_specs=(PS(), PS(), PS(), PS()))
    return init_fn, step_fn, plan
