"""Book-keeping (BK) execution engine: one backprop for the two-pass modes.

Flat and per-group clipping need clip factors that depend on the TOTAL
per-example norm across groups, which is only known after backpropagation
completes — the reason `ghost_flat`/`per_group` historically ran TWO full
backward passes (norms first, clipped grads second). Bu et al.,
*Differentially Private Optimization on Large Model at Small Cost*
(arXiv:2210.00038), observe the second pass is redundant: cache each
layer's ghost residuals — the activations A_i and output cotangents G_i —
during the single norm-computing backprop, then produce every clipped
weight gradient with one lightweight scale-and-contract per layer,

    dW = Σ_i f_i · A_iᵀ G_i,

building on the fast per-example clipping of Lee & Kifer (arXiv:2009.03106).

The JAX realization here piggybacks on the encoded-threshold side channel
that already threads one leaf per clipping group through every model
(including through `lax.scan` layer stacks): a `BkChannel` pytree leaf
bundles the encoded thresholds with zero-initialized residual *sinks*.
The dp primitives' custom VJPs, when handed a BkChannel inside a
`backend.scoped(capture_residuals=True)` extent, emit their per-example
norms² through the threshold cotangent as usual AND return the (a, g)
residuals through the sink cotangent — so a single `jax.grad` over the
channel tree yields norms and residuals together, with zero extra forward
or backward work. Scanned layer stacks need no special handling: scan
slices the sink leaves per iteration and stacks their cotangents back,
exactly as it already does for thresholds and norms.

Pipeline (driven by `core.clipping.dp_clipped_gradients`):

  1. `probe_recipes`   — trace-time `jax.eval_shape` pass over the loss
                         with sink-less probe channels; each primitive
                         records its residual shapes/dtypes per group.
                         Returns None (-> two-pass fallback) for layouts
                         BK cannot capture: a group consumed more than
                         once per step (e.g. the MTP head) or shared-site
                         parameters (sensitivity_mult > 1), whose single
                         threshold leaf would sum residuals across sites.
  2. `capture_clipped` — ONE `value_and_grad` over the channel tree:
                         per-group norms² + cached residuals.
  3. driver computes the per-example clip factors from the norms.
  4. `contract_clipped`— the epilogue: per layer, one scale-and-contract
                         over the cached residuals (`scale_contract` in
                         the backend engine — Pallas kernel on TPU) builds
                         the clipped summed gradient pytree.

A capture pass returns ZERO parameter cotangents (the epilogue owns the
weight gradients), so the primitives refuse BkChannels outside the scoped
`capture_residuals` flag.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ghost
from repro.core.spec import GroupLayout, P
from repro.kernels import backend

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# The channel leaf.
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BkChannel:
    """Threshold leaf + residual sink, with the group name as static aux.

    `c` is the usual encoded-threshold array (stack_shape + (B,)); `sink`
    is a dict of zero arrays whose COTANGENTS carry the ghost residuals
    back out of the backward pass (None during the shape probe). The group
    name rides in the treedef, so a primitive receiving a (possibly
    scan-sliced) channel knows statically which clipping group it serves.
    """

    c: Any
    sink: Any = None
    group: str = ""

    def tree_flatten(self):
        return (self.c, self.sink), (self.group,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def T(self):
        """Transpose the threshold child only (models reorder thresholds
        before blocked primitives; sinks are positional, not transposed)."""
        return BkChannel(self.c.T, self.sink, self.group)


def thresholds_of(c):
    """The encoded-threshold array of a maybe-channel threshold arg."""
    return c.c if isinstance(c, BkChannel) else c


def _require_capture_scope(channel: BkChannel) -> None:
    if not backend.active().config.capture_residuals:
        raise RuntimeError(
            f"BkChannel for group {channel.group!r} reached a dp primitive "
            "outside backend.scoped(capture_residuals=True); capture passes "
            "return zero parameter cotangents and must only be driven by "
            "repro.core.bk.capture_clipped")


def emit(channel: BkChannel, norms_sq, **sink_vals) -> BkChannel:
    """Build the channel cotangent: norms² + residuals cast to sink dtypes."""
    _require_capture_scope(channel)
    sink_ct = jax.tree_util.tree_map(
        lambda s, v: v.astype(s.dtype), channel.sink, dict(sink_vals))
    return BkChannel(norms_sq.astype(jnp.float32), sink_ct, channel.group)


# ---------------------------------------------------------------------------
# Trace-time shape probe.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Recipe:
    """What one dp-primitive call site stashes for one clipping group."""

    kind: str          # linear|linear_blocked|embed|scale|shift|broadcast|
    #                    lora|expert|expert_grouped
    c_ndim: int        # rank of the PER-CALL threshold (after scan slicing)
    sinks: dict        # sink name -> ShapeDtypeStruct (per-call shapes)
    extras: dict       # kind-specific statics (has_bias, vocab, ...)
    count: int = 1     # consumptions per step; >1 -> BK unsupported


_RECORDER: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "bk_recorder", default=None)


@contextlib.contextmanager
def _recording():
    rec: dict[str, Recipe] = {}
    token = _RECORDER.set(rec)
    try:
        yield rec
    finally:
        _RECORDER.reset(token)


def _record(channel, kind, sinks, **extras):
    rec = _RECORDER.get()
    if rec is None or not isinstance(channel, BkChannel):
        return
    name = channel.group
    if name in rec:
        rec[name].count += 1
        return
    rec[name] = Recipe(kind, channel.c.ndim, sinks, extras)


def _tfold(x) -> int:
    """Rows per example after the primitives' (B, -1, d) reshape."""
    return int(np.prod(x.shape[1:-1], dtype=np.int64)) if x.ndim > 2 else 1


# -- kind-specific recorders, called from the dp primitives' primals -------


def record_linear(c, w, b, x):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    bsz, tf, din, dout = x.shape[0], _tfold(x), x.shape[-1], w.shape[-1]
    gdt = jnp.result_type(x.dtype, w.dtype)
    _record(c, "linear", {"a": SDS((bsz, tf, din), x.dtype),
                          "g": SDS((bsz, tf, dout), gdt)},
            has_bias=b is not None)


def record_linear_blocked(c, w, b, x, block_axis):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    bsz, tf, din, dout = x.shape[0], _tfold(x), x.shape[-1], w.shape[-1]
    gdt = jnp.result_type(x.dtype, w.dtype)
    _record(c, "linear_blocked", {"a": SDS((bsz, tf, din), x.dtype),
                                  "g": SDS((bsz, tf, dout), gdt)},
            has_bias=b is not None, block_axis=block_axis,
            m=thresholds_of(c).shape[-1])


def record_embed(c, table, ids):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    bsz = ids.shape[0]
    tf = int(np.prod(ids.shape[1:], dtype=np.int64)) if ids.ndim > 1 else 1
    _record(c, "embed", {"g": SDS((bsz, tf, table.shape[-1]), table.dtype),
                         # token ids ride the float cotangent channel;
                         # exact for vocab < 2^24
                         "ids": SDS((bsz, tf), jnp.float32)},
            vocab=table.shape[0])


def record_scale(c, s, xhat):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    _record(c, "scale",
            {"pg": SDS((xhat.shape[0], xhat.shape[-1]), jnp.float32)})


def record_shift(c, x):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    _record(c, "shift",
            {"pg": SDS((x.shape[0], x.shape[-1]), jnp.float32)})


def record_broadcast(c, p):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    bsz = thresholds_of(c).shape[0]
    _record(c, "broadcast", {"pg": SDS((bsz,) + tuple(p.shape), jnp.float32)})


def record_lora(c, a, b, x):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    bsz, tf = x.shape[0], _tfold(x)
    din, r, dout = a.shape[-2], a.shape[-1], b.shape[-1]
    gdt = jnp.result_type(x.dtype, b.dtype)
    _record(c, "lora", {"a1": SDS((bsz, tf, din), x.dtype),
                        "g1": SDS((bsz, tf, r), gdt),
                        "a2": SDS((bsz, tf, r), x.dtype),
                        "g2": SDS((bsz, tf, dout), gdt)})


def record_expert(c, w, x):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    e, cap, din = x.shape
    gdt = jnp.result_type(x.dtype, w.dtype)
    _record(c, "expert", {"x": SDS((e, cap, din), x.dtype),
                          "g": SDS((e, cap, w.shape[-1]), gdt),
                          "seg": SDS((e, cap), jnp.float32)})


def record_expert_grouped(c, w, x):
    if _RECORDER.get() is None or not isinstance(c, BkChannel):
        return
    bsz, e, cap, din = x.shape
    gdt = jnp.result_type(x.dtype, w.dtype)
    _record(c, "expert_grouped", {"x": SDS((bsz, e, cap, din), x.dtype),
                                  "g": SDS((bsz, e, cap, w.shape[-1]), gdt)})


def probe_recipes(loss_fn, params, batch, layout: GroupLayout,
                  batch_size: int) -> dict | None:
    """Discover per-group residual shapes; None when BK cannot apply."""
    if any(g.sensitivity_mult > 1 for g in layout.groups):
        # shared-site params (e.g. Zamba2's shared attention block): one
        # threshold leaf is consumed at several runtime sites inside a scan,
        # so sink cotangents would SUM residuals across sites — invalid.
        return None
    inf_tree = layout.pack_value(jnp.inf, batch_size)
    probe = {k: BkChannel(v, None, k) for k, v in inf_tree.items()}
    try:
        with _recording() as rec:
            jax.eval_shape(lambda p, b, t: jnp.sum(loss_fn(p, b, t)),
                           params, batch, probe)
    except Exception as e:  # noqa: BLE001 — probe failure -> twopass, but
        # LOUDLY: a loss that cannot trace with channel leaves is either a
        # model manipulating thresholds as raw arrays (legitimately not
        # BK-able) or a bug in a record_* recorder; silent fallback would
        # double the step time with nothing to distinguish the two.
        warnings.warn(
            f"BK shape probe failed ({type(e).__name__}: {e}); falling "
            "back to the twopass execution for this clipping driver",
            stacklevel=2)
        return None
    if any(r.count > 1 for r in rec.values()):
        return None  # one leaf, several call sites (e.g. MTP reuses head)
    return rec


# ---------------------------------------------------------------------------
# Capture: one backward pass -> norms + residuals.
# ---------------------------------------------------------------------------


def build_channels(layout: GroupLayout, recipes: dict, batch_size: int):
    """Threshold tree with +inf thresholds and zero residual sinks.

    The sink prefix (scan/stack dims the model slices off before the
    primitive sees the leaf) is inferred from rank: leaf rank minus the
    recorded per-call threshold rank.
    """
    inf_tree = layout.pack_value(jnp.inf, batch_size)
    out = {}
    for g in layout.groups:
        leaf = inf_tree[g.name]
        r = recipes.get(g.name)
        if r is None:  # group never consumed by the loss: plain leaf,
            out[g.name] = leaf  # zero norms and zero grads fall out
            continue
        prefix = leaf.shape[:leaf.ndim - r.c_ndim]
        sink = {k: jnp.zeros(prefix + tuple(s.shape), s.dtype)
                for k, s in r.sinks.items()}
        out[g.name] = BkChannel(leaf, sink, g.name)
    return out


def capture_clipped(loss_fn, params, batch, layout: GroupLayout,
                    batch_size: int):
    """One backprop: (sum loss, (K, B) norms², residuals, recipes) or None."""
    recipes = probe_recipes(loss_fn, params, batch, layout, batch_size)
    if recipes is None:
        return None
    channels = build_channels(layout, recipes, batch_size)

    def f(t):
        return jnp.sum(loss_fn(params, batch, t))

    # prefer_fused off: the capture backward consumes norms + residuals
    # only; the composed ops keep the (unused) clipped-sum contraction a
    # separate op XLA dead-code-eliminates.
    with backend.scoped(prefer_fused=False, capture_residuals=True):
        val, grads = jax.value_and_grad(f)(channels)
    norm_tree = {k: (v.c if isinstance(v, BkChannel) else v)
                 for k, v in grads.items()}
    norms = layout.unpack(norm_tree)
    residuals = {k: v.sink for k, v in grads.items()
                 if isinstance(v, BkChannel)}
    return val, norms, residuals, recipes


# ---------------------------------------------------------------------------
# Epilogue: scale-and-contract the cached residuals into clipped grads.
# ---------------------------------------------------------------------------


def _fold(x, per_call_ndim: int):
    """Collapse the stack prefix into one leading axis of size S (>= 1)."""
    prefix = x.shape[:x.ndim - per_call_ndim]
    s = int(np.prod(prefix, dtype=np.int64)) if prefix else 1
    return x.reshape((s,) + x.shape[x.ndim - per_call_ndim:]), prefix


def _leaf_grad(layout, recipes, residuals, f_rows, node: P, path, eng):
    gname = layout._leaf_group[path]
    grp = layout.group(gname)
    r = recipes.get(gname)
    if r is None:
        return jnp.zeros(node.shape, node.dtype)
    sink = residuals[gname]
    bsz = f_rows.shape[-1]
    f = jax.lax.dynamic_slice_in_dim(f_rows, grp.offset, grp.count, axis=0)
    f = f.reshape(grp.stack_shape + (bsz,)).astype(jnp.float32)
    per_elem = len(node.shape) - node.stack  # leaf rank below the stack dims
    kind = r.kind

    if kind in ("linear", "lora"):
        if kind == "lora":
            # adapter pair: leaf 'a' <- (x, g·scale @ Bᵀ); 'b' <- (x·A, g·scale)
            a_s, g_s = (("a1", "g1") if path[-1] == "a" else ("a2", "g2"))
            a, g = sink[a_s], sink[g_s]
        else:
            a, g = sink["a"], sink["g"]
        a4, _ = _fold(a, 3)
        g4, _ = _fold(g, 3)
        f2, _ = _fold(f, 1)
        if kind == "lora" or per_elem == 2:  # weight (or adapter factor)
            dw = eng.scale_contract(a4, g4, f2)
            return dw.reshape(node.shape).astype(node.dtype)
        db = jnp.einsum("sbto,sb->so", g4.astype(jnp.float32), f2)
        return db.reshape(node.shape).astype(node.dtype)

    if kind == "linear_blocked":
        m, ax = r.extras["m"], r.extras["block_axis"]
        a4, _ = _fold(sink["a"], 3)
        g4, _ = _fold(sink["g"], 3)
        f3 = f.reshape(-1, m, bsz)  # (S, M, B): stack_shape ends in (M,)
        if per_elem == 2:
            def per_el(a3, g3, fmb):
                aa, gg = ghost.fold_block_factors(a3, g3, fmb.T, ax)
                return jnp.einsum("bti,bto->io", aa, gg)

            dw = jax.vmap(per_el)(a4, g4, f3)
            return dw.reshape(node.shape).astype(node.dtype)
        if ax == "out":  # bias columns live with the 'out' blocks
            s_, b_, t_, dout = g4.shape
            gb = g4.reshape(s_, b_, t_, m, dout // m).astype(jnp.float32)
            db = jnp.einsum("sbtmo,smb->smo", gb, f3)
        else:  # 'in': whole bias folded into block 0 (see dp_linear_blocked)
            db = jnp.einsum("sbto,sb->so", g4.astype(jnp.float32), f3[:, 0])
        return db.reshape(node.shape).astype(node.dtype)

    if kind == "embed":
        vocab = r.extras["vocab"]
        g4, _ = _fold(sink["g"], 3)
        ids4, _ = _fold(jnp.round(sink["ids"]).astype(jnp.int32), 2)
        f2, _ = _fold(f, 1)
        dt = jax.vmap(
            lambda i2, g3, fb: ghost.clipped_sum_embed(i2, g3, fb, vocab)
        )(ids4, g4, f2)
        return dt.reshape(node.shape).astype(node.dtype)

    if kind in ("scale", "shift", "broadcast"):
        pg = sink["pg"]  # prefix + (B,) + per-call param shape
        lead = pg.ndim - (1 + per_elem)
        s_ = (int(np.prod(pg.shape[:lead], dtype=np.int64)) if lead else 1)
        pg2 = pg.reshape(s_, bsz, -1).astype(jnp.float32)
        out = jnp.einsum("sbr,sb->sr", pg2, f.reshape(s_, bsz))
        return out.reshape(node.shape).astype(node.dtype)

    if kind == "expert":
        # sinks carry prefix + (E, C, d): the expert axis is part of the
        # per-call shape, and the group stack_shape ends in (E,) — so
        # folding everything down to per-expert slices aligns with factors
        x4, _ = _fold(sink["x"], 3)  # (S, E, C, din), S = prod(scan prefix)
        g4, _ = _fold(sink["g"], 3)
        seg4, _ = _fold(jnp.round(sink["seg"]).astype(jnp.int32), 2)
        f3 = f.reshape(-1, bsz)  # (S·E, B): stack_shape ends in (E,)

        def per_el(xe, ge, se, fe):  # (C, din), (C, dout), (C,), (B,)
            fpad = jnp.concatenate([fe, jnp.zeros((1,), fe.dtype)])
            fslot = fpad[se]
            return jnp.einsum("cd,cf->df",
                              xe.astype(jnp.float32) * fslot[:, None],
                              ge.astype(jnp.float32))

        dw = jax.vmap(per_el)(x4.reshape((-1,) + x4.shape[-2:]),
                              g4.reshape((-1,) + g4.shape[-2:]),
                              seg4.reshape((-1,) + seg4.shape[-1:]), f3)
        return dw.reshape(node.shape).astype(node.dtype)

    if kind == "expert_grouped":
        x5, _ = _fold(sink["x"], 4)  # (S, B, E, C, din)
        g5, _ = _fold(sink["g"], 4)
        f3 = f.reshape(x5.shape[0], -1, bsz)  # (S, E, B)
        dw = jnp.einsum("sbecd,sbecf,seb->sedf", x5.astype(jnp.float32),
                        g5.astype(jnp.float32), f3)
        return dw.reshape(node.shape).astype(node.dtype)

    raise ValueError(f"unknown BK recipe kind {kind!r}")


def contract_clipped(layout: GroupLayout, recipes: dict, residuals: dict,
                     f_rows, *, eng=None, psum_axes=None):
    """Clipped summed grads from cached residuals + (K, B) clip factors.

    Returns a pytree matching the layout's spec (== the trainable params
    tree the two-pass drivers produce), in the spec leaf dtypes.

    psum_axes: when set (sharded execution, inside `shard_map`), every
    leaf's contraction is followed by a `lax.psum` over those mesh axes —
    and the epilogue is emitted INTERLEAVED: leaf i's contraction is issued
    before leaf i-1's psum, so the latency-hiding scheduler overlaps each
    layer's gradient reduction with the next layer's `scale_contract`
    instead of serializing one big tree-reduce after all the compute.
    """
    eng = eng or backend.active()

    def build(node, path):
        if isinstance(node, P):
            return _leaf_grad(layout, recipes, residuals, f_rows, node,
                              path, eng)
        return {k: build(v, path + (k,)) for k, v in node.items()}

    if psum_axes is None:
        return build(layout._spec, ())

    leaves: list[tuple[tuple, P]] = []

    def collect(node, path):
        if isinstance(node, P):
            leaves.append((path, node))
            return
        for k in node:
            collect(node[k], path + (k,))

    collect(layout._spec, ())
    reduced: dict[tuple, Any] = {}
    prev = None  # (path, unreduced contraction)
    for path, node in leaves:
        with jax.named_scope("bk_epilogue_contract"):
            cur = _leaf_grad(layout, recipes, residuals, f_rows, node,
                             path, eng)
        if prev is not None:
            with jax.named_scope("bk_epilogue_grad_psum"):
                reduced[prev[0]] = jax.lax.psum(prev[1], psum_axes)
        prev = (path, cur)
    if prev is not None:
        with jax.named_scope("bk_epilogue_grad_psum"):
            reduced[prev[0]] = jax.lax.psum(prev[1], psum_axes)

    def rebuild(node, path):
        if isinstance(node, P):
            return reduced[path]
        return {k: rebuild(v, path + (k,)) for k, v in node.items()}

    return rebuild(layout._spec, ())
