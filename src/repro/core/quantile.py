"""Private per-group quantile estimation for adaptive clipping thresholds.

Implements the geometric-update quantile tracker of Andrew et al. (2019),
"Differentially Private Learning with Adaptive Clipping", adapted to the
per-layer / per-group setting of the paper (Algorithm 1, lines 15-17):

    b_k      = #(examples in batch whose group-k grad norm <= C_k)
    b~_k     = (b_k + N(0, sigma_b^2)) / B          (privatized fraction)
    C_k     <- C_k * exp(-eta * (b~_k - q))         (geometric update)

The clip-count b_k has sensitivity 1/2 after symmetrization (b - 1/2 per
example), which is what Proposition 3.1's budget split assumes.

Everything is jnp and jit-safe; the tracker state is a small pytree carried
through the training step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class QuantileState(NamedTuple):
    """State of K independent quantile trackers (one per clipping group)."""

    thresholds: jax.Array  # (K,) current clipping thresholds C_k  (>0)
    target_quantile: jax.Array  # scalar q in [0, 1]
    lr: jax.Array  # scalar eta (paper uses 0.3 everywhere)
    sigma_b: jax.Array  # scalar noise multiplier for the count release


def init_quantile_state(
    init_thresholds,
    *,
    target_quantile: float = 0.5,
    lr: float = 0.3,
    sigma_b: float = 10.0,
) -> QuantileState:
    thresholds = jnp.asarray(init_thresholds, dtype=jnp.float32)
    if thresholds.ndim == 0:
        thresholds = thresholds[None]
    return QuantileState(
        thresholds=thresholds,
        target_quantile=jnp.float32(target_quantile),
        lr=jnp.float32(lr),
        sigma_b=jnp.float32(sigma_b),
    )


def export_state(state: QuantileState) -> dict:
    """Plain-python snapshot of the tracker (msgpack/JSON-safe).

    The authoritative copy of the thresholds lives in the checkpointed
    DPState pytree; this export rides in the checkpoint manifest's `meta`
    so ops tooling (and the training service's resume validation) can read
    thresholds without deserializing the full tree."""
    return {
        "thresholds": [float(t) for t in np.asarray(state.thresholds)],
        "target_quantile": float(state.target_quantile),
        "lr": float(state.lr),
        "sigma_b": float(state.sigma_b),
    }


def restore_state(snapshot: dict) -> QuantileState:
    """Inverse of `export_state` (float32 round-trip is exact)."""
    return QuantileState(
        thresholds=jnp.asarray(snapshot["thresholds"], jnp.float32),
        target_quantile=jnp.float32(snapshot["target_quantile"]),
        lr=jnp.float32(snapshot["lr"]),
        sigma_b=jnp.float32(snapshot["sigma_b"]),
    )


def clip_counts(norms_sq: jax.Array, thresholds: jax.Array) -> jax.Array:
    """b_k = sum_i 1[ ||g_k^(i)|| <= C_k ].

    norms_sq: (K, B) per-group per-example squared gradient norms.
    thresholds: (K,) current thresholds.
    Returns (K,) float counts.
    """
    return jnp.sum(
        (norms_sq <= (thresholds[:, None] ** 2)).astype(jnp.float32), axis=-1
    )


def update_thresholds(
    state: QuantileState,
    counts: jax.Array,
    batch_size: jax.Array | int,
    key: jax.Array,
    *,
    counts_axes=None,
) -> QuantileState:
    """One private geometric update of all K thresholds (Alg. 1 l.15-17).

    Sharded-execution contract: there is exactly ONE geometric update per
    step, fed by the GLOBAL clip counts over the full batch and divided by
    the GLOBAL batch size. A caller inside `shard_map` that still holds
    shard-local counts must pass the data-plane mesh axes as
    `counts_axes` — they are psum'd here before the update — so every
    shard applies the identical threshold move (the noise draw already
    agrees across shards because the key is replicated). Callers that
    hand over pre-reduced counts (core.clipping's sharded drivers do, see
    their `clip_count_psum` scopes) leave it None.
    tests/sharded_checks.py asserts this parity against the single-device
    tracker.
    """
    if counts_axes is not None:
        with jax.named_scope("clip_count_psum"):
            counts = jax.lax.psum(counts, counts_axes)
    noise = state.sigma_b * jax.random.normal(
        key, state.thresholds.shape, dtype=jnp.float32
    )
    frac = (counts + noise) / jnp.asarray(batch_size, jnp.float32)
    new_thresholds = state.thresholds * jnp.exp(
        -state.lr * (frac - state.target_quantile)
    )
    # Keep thresholds strictly positive and finite.
    new_thresholds = jnp.clip(new_thresholds, 1e-10, 1e10)
    return state._replace(thresholds=new_thresholds)
