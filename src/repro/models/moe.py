"""Mixture-of-Experts: token-choice top-k routing with capacity dispatch.

Design constraints that shaped this implementation:
  * NO (tokens, E, capacity) one-hot dispatch tensors (GShard-style combine
    einsums explode at 256 experts x 32k tokens) — dispatch is scatter/gather
    with per-expert slot indices computed by a cumsum over the routing
    one-hot (int32, tokens x E, the only O(T·E) object).
  * Expert weights are STACKED (E, d, f) so the expert axis shards over the
    `model` mesh axis (expert parallelism); the dispatched activation buffer
    (E, C, d) shards the same way.
  * DP: every expert is its own clipping group (the MoE reading of
    "per-layer"); `dp_expert_linear` computes exact per-example norms
    through the token mixing (see core.dp_layers). The router is a plain
    dp_linear.
  * Dropped tokens (capacity overflow) contribute zero — standard dropping
    MoE semantics; the load-balance auxiliary loss (Switch style) keeps the
    router near-uniform. Aux losses are returned per example (DP needs
    per-example attribution end to end).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dp_layers as dpl
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.core.spec import P, subth


def moe_spec(cfg: ModelConfig, *, stack: tuple[int, ...] = ()) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    s = len(stack)
    out = {
        "router": L.linear_spec(d, e, stack=stack, dtype=cfg.dtype),
        # gate+up fused per expert; each expert = one clipping group
        "w_gu": P(stack + (e, d, 2 * f), dtype=cfg.dtype, stack=s + 1,
                  group=None),
        "w_down": P(stack + (e, f, d), dtype=cfg.dtype, stack=s + 1,
                    group=None),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        out["shared"] = L.swiglu_spec(d, fs, stack=stack, dtype=cfg.dtype)
    return out


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(tokens * cfg.num_experts_per_tok * cfg.capacity_factor
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor 8


def moe_block(cfg: ModelConfig, params, x, th, *, th_prefix: str = ""):
    """x: (B, T, D) -> (y (B, T, D), aux_loss (B,)).

    th keys: 'router', 'w_gu', 'w_down' (stacked (E, B) thresholds), and
    'shared/*' when shared experts are configured.
    """
    b, t, d = x.shape
    e, k, f = cfg.num_experts, cfg.num_experts_per_tok, cfg.moe_d_ff
    n = b * t
    cap = capacity(cfg, n)

    logits = L.linear(params["router"], x, th["router"])  # (B, T, E)
    logits = logits.reshape(n, e).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (n, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)  # renormalize top-k

    # ---- slot assignment: position of token within its expert's buffer ----
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (n, k, E)
    flatoh = onehot.reshape(n * k, e)
    pos_in_expert = jnp.cumsum(flatoh, axis=0) - flatoh  # (n*k, E)
    slot = jnp.sum(pos_in_expert * flatoh, axis=-1).reshape(n, k)  # (n, k)
    expert = gate_idx  # (n, k)
    keep = slot < cap
    slot = jnp.where(keep, slot, cap)  # overflow -> scratch slot

    # ---- dispatch: scatter tokens into (E, cap+1, d) ----
    xf = x.reshape(n, d)
    buf = jnp.zeros((e, cap + 1, d), xf.dtype)
    exp_flat = expert.reshape(-1)
    slot_flat = slot.reshape(-1)
    tok_rep = jnp.repeat(jnp.arange(n), k)
    buf = buf.at[exp_flat, slot_flat].set(xf[tok_rep], mode="drop")
    buf = buf[:, :cap]  # drop scratch

    # example id per dispatched slot (for exact per-example DP norms)
    ex_of_token = jnp.repeat(jnp.arange(b), t)  # (n,)
    exid_buf = jnp.full((e, cap + 1), -1, jnp.int32)
    exid_buf = exid_buf.at[exp_flat, slot_flat].set(
        ex_of_token[tok_rep], mode="drop")
    exid_buf = exid_buf[:, :cap]

    # ---- expert computation (each expert its own DP group) ----
    h = dpl.dp_expert_linear(params["w_gu"], buf, exid_buf, th["w_gu"])
    gate_h, up_h = h[..., :f], h[..., f:]
    act = jax.nn.silu(gate_h.astype(jnp.float32)).astype(h.dtype) * up_h
    out_buf = dpl.dp_expert_linear(params["w_down"], act, exid_buf,
                                   th["w_down"])  # (E, cap, d)

    # ---- combine: gather back and weight by gates ----
    gathered = out_buf[exp_flat, jnp.minimum(slot_flat, cap - 1)]  # (n*k, d)
    gathered = gathered * (keep.reshape(-1)[:, None]
                           * gate_vals.reshape(-1)[:, None]).astype(gathered.dtype)
    y = jax.ops.segment_sum(gathered, tok_rep, num_segments=n)
    y = y.reshape(b, t, d).astype(x.dtype)

    if cfg.num_shared_experts:
        y = y + L.swiglu(params["shared"], x, subth(th, "shared"),
                         f=f * cfg.num_shared_experts)

    # ---- Switch-style load-balance aux loss, per example ----
    pe = probs.reshape(b, t, e)
    frac_prob = jnp.mean(pe, axis=1)  # (B, E)
    top1 = jax.nn.one_hot(gate_idx[:, 0].reshape(b, t), e, dtype=jnp.float32)
    frac_tok = jnp.mean(top1, axis=1)  # (B, E)
    aux = cfg.router_aux_coef * e * jnp.sum(frac_prob * frac_tok, axis=-1)
    return y, aux


def capacity_per_example(cfg: ModelConfig, tokens_per_example: int) -> int:
    c = int(tokens_per_example * cfg.num_experts_per_tok
            * cfg.capacity_factor / cfg.num_experts)
    return max(4, -(-c // 4) * 4)


def moe_block_grouped(cfg: ModelConfig, params, x, th):
    """Grouped-dispatch MoE: buffers (B, E, cap_pe, d); per-example DP norms
    are block-diagonal (dp_expert_linear_grouped). Same routing semantics as
    moe_block; capacity is enforced PER (example, expert) instead of
    globally (documented difference; both drop overflow tokens)."""
    b, t, d = x.shape
    e, k, f = cfg.num_experts, cfg.num_experts_per_tok, cfg.moe_d_ff
    cap = capacity_per_example(cfg, t)

    logits = L.linear(params["router"], x, th["router"])  # (B, T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (B, T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # slot of (token, k) within its (example, expert) bucket
    onehot = jax.nn.one_hot(gate_idx.reshape(b, t * k), e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot  # (B, T*k, E)
    slot = jnp.take_along_axis(
        pos, gate_idx.reshape(b, t * k)[..., None], axis=-1)[..., 0]
    keep = slot < cap
    slot = jnp.where(keep, slot, cap)

    exp_flat = gate_idx.reshape(b, t * k)
    tok_rep = jnp.broadcast_to(jnp.repeat(jnp.arange(t), k)[None],
                               (b, t * k))
    # vmap the scatter over the batch axis: a batched scatter keeps the
    # sharded batch dim trivially local under GSPMD, whereas scattering with
    # computed (bidx, e, slot) indices forces a replicate+all-reduce
    # (measured 1.9 TB/step on granite; EXPERIMENTS.md §Perf A3)
    xtok = jnp.take_along_axis(x, tok_rep[..., None], axis=1)  # (B, T*k, d)

    def scatter_one(xe, ee, ss):
        return jnp.zeros((e, cap + 1, d), x.dtype).at[ee, ss].set(
            xe, mode="drop")

    buf = jax.vmap(scatter_one)(xtok, exp_flat, slot)[:, :, :cap]

    h = dpl.dp_expert_linear_grouped(params["w_gu"], buf, th["w_gu"])
    act = jax.nn.silu(h[..., :f].astype(jnp.float32)).astype(h.dtype) \
        * h[..., f:]
    out_buf = dpl.dp_expert_linear_grouped(params["w_down"], act,
                                           th["w_down"])  # (B, E, cap, d)

    gathered = jax.vmap(lambda ob, ee, ss: ob[ee, ss])(
        out_buf, exp_flat, jnp.minimum(slot, cap - 1))
    gathered = gathered * (keep * gate_vals.reshape(b, t * k)
                           )[..., None].astype(gathered.dtype)
    y = jax.vmap(lambda g, tr: jax.ops.segment_sum(g, tr, num_segments=t)
                 )(gathered, tok_rep)
    y = y.astype(x.dtype)

    if cfg.num_shared_experts:
        y = y + L.swiglu(params["shared"], x, subth(th, "shared"),
                         f=f * cfg.num_shared_experts)

    pe = probs
    frac_prob = jnp.mean(pe, axis=1)
    top1 = jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)
    frac_tok = jnp.mean(top1, axis=1)
    aux = cfg.router_aux_coef * e * jnp.sum(frac_prob * frac_tok, axis=-1)
    return y, aux
