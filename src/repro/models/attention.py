"""Attention: GQA (qk-norm / QKV-bias / sliding-window) and MLA.

Training/prefill use a flash-style double-blocked online-softmax attention
(pure XLA: scan over query blocks, inner scan over KV blocks) so no (T, S)
score matrix is ever materialized — the same blocking a TPU flash kernel
would use in VMEM, expressed at the XLA level so it lowers on any backend.

Decode paths:
  * GQA: ring-buffer-capable KV cache, one-token query against S cached
    entries (keys stored post-RoPE).
  * MLA (DeepSeek-V3): the compressed-latent cache (kv_lora_rank + rope dim
    per token instead of 2·H·hd) with the ABSORBED decode form — W_UK folded
    into the query and W_UV applied after attending over latents — so decode
    FLOPs/bytes scale with kv_lora_rank, not with H·hd. This is the paper's
    per-device-clipping showcase arch; the absorption is a beyond-paper perf
    optimization recorded in EXPERIMENTS.md.

All projections are DP primitives (clip-in-backprop).

Serving hooks: every decode entry point takes an optional `active` (B,)
row mask — the per-slot write/retire hook of the continuous-batching
engine (launch.engine). Rows with `active=False` leave their cache slot
bit-identical and do not advance their position, so a slot-pool step can
carry retired / still-prefilling / empty slots through the same dispatch
without polluting their state. `masked_state` is the matching hook for
recurrent caches (Mamba conv/ssm, RWKV wkv), whose whole state tensor
turns over every step.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dp_layers as dpl
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.core.spec import P

_SINGLE_SHOT_MAX = 2048 * 2048  # T*S above this -> blocked attention
_QB, _KB = 512, 1024  # query/kv block sizes for the blocked path

NEG_INF = -1e30


def masked_state(active, new, old):
    """Row-freeze hook for recurrent decode caches: keep `old` state on
    rows where `active` is False. `active=None` means every row advances
    (the non-serving fast path — no select is emitted at all)."""
    if active is None:
        return new
    m = active.reshape((active.shape[0],) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def _masked_cache_write(cache, new, slot, active):
    """Per-row dynamic-slice write into a (B, S, ...) cache at `slot`,
    suppressed (read-modify-write of the old entry) on inactive rows."""
    if active is None:
        return jax.vmap(
            lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i,
                                                                axis=0)
        )(cache, new, slot)

    def upd(c, n, i, a):
        cur = jax.lax.dynamic_slice_in_dim(c, i, n.shape[0], axis=0)
        return jax.lax.dynamic_update_slice_in_dim(
            c, jnp.where(a, n, cur), i, axis=0)

    return jax.vmap(upd)(cache, new, slot, active)


# ---------------------------------------------------------------------------
# Core attention math (no params).
# ---------------------------------------------------------------------------


def _gqa_scores(q, k):
    """q: (B, T, KV, G, hd), k: (B, S, KV, hd) -> (B, T, KV, G, S)."""
    return jnp.einsum("btkgd,bskd->btkgs", q.astype(jnp.float32),
                      k.astype(jnp.float32))


INVALID_POS = jnp.iinfo(jnp.int32).max - 8  # kpos >= this => masked out


def _mask(qpos, kpos, *, causal, window):
    """(B, T, S) boolean validity mask."""
    m = (kpos[:, None, :] < INVALID_POS) & jnp.ones(
        (qpos.shape[0], qpos.shape[1], kpos.shape[1]), bool)
    if causal:
        m = m & (kpos[:, None, :] <= qpos[:, :, None])
    if window is not None:
        m = m & (kpos[:, None, :] > qpos[:, :, None] - window)
    return m


def attend(q, k, v, qpos, kpos, *, causal=True, window=None, scale=None):
    """Grouped-query attention. q: (B, T, H, hd); k, v: (B, S, KV, hd).

    Chooses single-shot vs double-blocked online softmax by T*S.
    """
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, t, kv, g, hd) * scale

    if t * s <= _SINGLE_SHOT_MAX:
        scores = _gqa_scores(qg, k)  # (B, T, KV, G, S)
        m = _mask(qpos, kpos, causal=causal, window=window)
        scores = jnp.where(m[:, :, None, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("btkgs,bskd->btkgd", w, v.astype(jnp.float32))
        return out.reshape(b, t, h, dv).astype(q.dtype)

    # ---- double-blocked online softmax ----
    qb = min(_QB, t)
    kb = min(_KB, s)
    nqb, nkb = -(-t // qb), -(-s // kb)
    tp, sp = nqb * qb, nkb * kb
    qg_p = jnp.pad(qg, ((0, 0), (0, tp - t), (0, 0), (0, 0), (0, 0)))
    qpos_p = jnp.pad(qpos, ((0, 0), (0, tp - t)), constant_values=-1)
    k_p = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kpos_p = jnp.pad(kpos, ((0, 0), (0, sp - s)),
                     constant_values=jnp.iinfo(jnp.int32).max)

    k_blocks = jnp.moveaxis(k_p.reshape(b, nkb, kb, kv, hd), 1, 0)
    v_blocks = jnp.moveaxis(v_p.reshape(b, nkb, kb, kv, dv), 1, 0)
    kpos_blocks = jnp.moveaxis(kpos_p.reshape(b, nkb, kb), 1, 0)

    def q_block(carry, qblk):
        qi, qpos_i = qblk  # (B, qb, KV, G, hd), (B, qb)

        def kv_block(state, kblk):
            m_run, l_run, acc = state
            ki, vi, kpos_i = kblk
            sc = _gqa_scores(qi, ki)  # (B, qb, KV, G, kb)
            msk = _mask(qpos_i, kpos_i, causal=causal, window=window)
            sc = jnp.where(msk[:, :, None, None, :], sc, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(sc, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "btkgs,bskd->btkgd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, qb, kv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qb, kv, g), jnp.float32)
        a0 = jnp.zeros((b, qb, kv, g, dv), jnp.float32)
        (mf, lf, accf), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (k_blocks, v_blocks, kpos_blocks))
        out = accf / jnp.maximum(lf[..., None], 1e-30)
        return carry, out

    q_blocks = jnp.moveaxis(qg_p.reshape(b, nqb, qb, kv, g, hd), 1, 0)
    qpos_blocks = jnp.moveaxis(qpos_p.reshape(b, nqb, qb), 1, 0)
    _, outs = jax.lax.scan(q_block, 0, (q_blocks, qpos_blocks))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, tp, kv, g, dv)[:, :t]
    return out.reshape(b, t, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block (params + DP).
# ---------------------------------------------------------------------------


def gqa_spec(cfg: ModelConfig, *, stack: tuple[int, ...] = (),
             cross: bool = False, sensitivity_mult: float = 1.0) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    sm = sensitivity_mult
    out = {
        "qkv": L.linear_spec(d, (h + 2 * kv) * hd, bias=cfg.qkv_bias,
                             stack=stack, dtype=cfg.dtype,
                             blocks=cfg.dp_blocks, sensitivity_mult=sm),
        "o": L.linear_spec(h * hd, d, stack=stack, dtype=cfg.dtype,
                           blocks=cfg.dp_blocks, sensitivity_mult=sm),
    }
    if cross:
        # q from decoder, kv from encoder: separate projections
        out["qkv"] = L.linear_spec(d, h * hd, bias=cfg.qkv_bias, stack=stack,
                                   dtype=cfg.dtype, sensitivity_mult=sm)
        out["kv"] = L.linear_spec(d, 2 * kv * hd, bias=cfg.qkv_bias,
                                  stack=stack, dtype=cfg.dtype,
                                  sensitivity_mult=sm)
    if cfg.qk_norm:
        out["q_norm"] = L.rmsnorm_spec(hd, stack=stack, dtype=cfg.dtype)
        out["k_norm"] = L.rmsnorm_spec(hd, stack=stack, dtype=cfg.dtype)
    return out


def _split_qkv(cfg, qkv):
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = qkv[..., : h * hd]
    k = qkv[..., h * hd: (h + kvh) * hd]
    v = qkv[..., (h + kvh) * hd:]
    b, t = qkv.shape[0], qkv.shape[1]
    return (q.reshape(b, t, h, hd), k.reshape(b, t, kvh, hd),
            v.reshape(b, t, kvh, hd))


def _qk_norm(cfg, params, th, q, k):
    if not cfg.qk_norm:
        return q, k
    b, t = q.shape[0], q.shape[1]
    hd = cfg.resolved_head_dim

    def apply(p, x, thx):
        mu = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
        xh = (x.astype(jnp.float32) * jax.lax.rsqrt(mu + cfg.norm_eps)).astype(x.dtype)
        flat = xh.reshape(b, -1, hd)
        return dpl.dp_scale(p["s"], flat, thx).reshape(x.shape)

    return (apply(params["q_norm"], q, th["q_norm"]),
            apply(params["k_norm"], k, th["k_norm"]))


def _proj(cfg, params, x, th, *, lora=None, lora_th=None, alpha=16.0):
    """Projection with optional frozen-base + DP-LoRA adapter."""
    if lora is not None:
        from repro.core import lora as lora_mod
        y = lora_mod.dp_lora_linear(lora["a"], lora["b"], params["w"], x,
                                    lora_th, alpha)
        if "b" in params:
            y = y + params["b"]
        return y
    if cfg.dp_blocks > 1:
        return L.linear_blocked(params, x, th)
    return L.linear(params, x, th)


def gqa_attention(cfg: ModelConfig, params, x, th, positions, *,
                  causal=True, window=None, lora=None, lora_th=None):
    """Self-attention, training/prefill. x: (B, T, D); positions: (B, T).

    lora/lora_th: optional {'qkv': ..., 'o': ...} adapter params/thresholds —
    the paper's DP-LoRA path (base projections frozen)."""
    qkv = _proj(cfg, params["qkv"], x, th.get("qkv"),
                lora=lora and lora.get("qkv"),
                lora_th=lora_th and lora_th.get("qkv"), alpha=cfg.lora_alpha)
    q, k, v = _split_qkv(cfg, qkv)
    q, k = _qk_norm(cfg, params, th, q, k)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    out = attend(q, k, v, positions, positions, causal=causal, window=window)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    return _proj(cfg, params["o"], out, th.get("o"),
                 lora=lora and lora.get("o"),
                 lora_th=lora_th and lora_th.get("o"), alpha=cfg.lora_alpha)


def _stacked_delta(x, lora, tenant, alpha):
    """Serving-side multi-tenant adapter term: `lora` is one projection's
    tenant-stacked pair {'a': (T, d_in, r), 'b': (T, r, d_out)}, `tenant`
    the (B,) int32 adapter-slot ids (core.lora.stacked_lora_delta)."""
    from repro.core.lora import stacked_lora_delta
    return stacked_lora_delta(x, lora["a"], lora["b"], tenant, alpha)


def _paged_write(pool, new, pt, pos, active):
    """One-token scatter through a page table. pool: (N+1, L, ...) with the
    LAST page reserved as the trash page; new: (B, 1, ...); pt: (B, P)
    int32; pos: (B,) write index. Row b lands at physical page
    `pt[b, pos // L]`, offset `pos % L`; inactive rows are redirected to
    the trash page so a masked step never perturbs live pages (trash
    contents are unreachable: every table entry mapping it is past the
    row's valid `pos` range)."""
    page_len = pool.shape[1]
    lp = pos // page_len
    off = pos % page_len
    phys = jnp.take_along_axis(pt, lp[:, None], axis=1)[:, 0]
    if active is not None:
        phys = jnp.where(active, phys, pool.shape[0] - 1)
    return pool.at[phys, off].set(new[:, 0].astype(pool.dtype))


def gqa_decode_paged(cfg: ModelConfig, params, x, th, kpool, vpool, pt,
                     pos, *, active=None, lora=None, tenant=None):
    """One-token GQA decode through a paged KV cache (full-cache only; ring
    windows keep the contiguous path — their O(W) state doesn't fragment).

    kpool/vpool: (N+1, L, KV, hd) physical page pools shared by every slot
    (last page = trash); pt: (B, P) int32 page table; pos: (B,) new token
    index over the P*L logical capacity. The XLA route (`paged_attn_ref`)
    replicates `attend`'s single-shot math over the table-gathered pages,
    so with matching logical capacity the output is bitwise identical to
    `gqa_decode` on a contiguous cache holding the same values; the Pallas
    route is the TPU paged-gather kernel (allclose-level).

    lora/tenant: optional tenant-stacked {'qkv', 'o'} adapters + (B,)
    int32 slot ids for multi-tenant serving (see `gqa_decode`)."""
    from repro.kernels import backend as KB
    qkv = L.linear(params["qkv"], x, th["qkv"])
    if lora is not None:
        qkv = qkv + _stacked_delta(x, lora["qkv"], tenant, cfg.lora_alpha)
    q, k, v = _split_qkv(cfg, qkv)
    q, k = _qk_norm(cfg, params, th, q, k)
    posb = pos[:, None]
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k = L.apply_rope(k, posb, cfg.rope_theta)
    kpool = _paged_write(kpool, k, pt, pos, active)
    vpool = _paged_write(vpool, v, pt, pos, active)

    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = h // kv
    qr = q[:, 0].reshape(b, kv, g, hd)  # same [kv, g] head grouping as attend
    out = KB.active().paged_attn(qr, kpool, vpool, pt, pos,
                                 scale=1.0 / math.sqrt(hd))
    out = out.reshape(b, 1, h * hd).astype(q.dtype)
    y = L.linear(params["o"], out, th["o"])
    if lora is not None:
        y = y + _stacked_delta(out, lora["o"], tenant, cfg.lora_alpha)
    return y, kpool, vpool


def gqa_decode(cfg: ModelConfig, params, x, th, cache_k, cache_v, pos, *,
               window=None, active=None, lora=None, tenant=None):
    """One-token decode. x: (B, 1, D); cache_k/v: (B, S, KV, hd); pos: (B,)
    number of tokens already in the cache (new token index).

    Sliding-window caches are ring buffers of capacity W; full caches have
    capacity seq_len. Keys are stored post-RoPE. `active`: optional (B,)
    bool — rows with False keep their cache entries untouched (their
    returned attention output is garbage and must be discarded; the
    caller also keeps their `pos` frozen, see transformer.serve_step).

    lora/tenant: optional multi-tenant adapters — `lora` holds the
    tenant-stacked {'qkv', 'o'} pairs of ONE layer ({'a': (T, d_in, r),
    'b': (T, r, d_out)}), `tenant` the (B,) int32 adapter-slot ids. Each
    row adds its own tenant's low-rank delta to the frozen-base
    projections (core.lora.stacked_lora_delta), mirroring the training
    side's `dp_lora_linear` forward."""
    qkv = L.linear(params["qkv"], x, th["qkv"])
    if lora is not None:
        qkv = qkv + _stacked_delta(x, lora["qkv"], tenant, cfg.lora_alpha)
    q, k, v = _split_qkv(cfg, qkv)
    q, k = _qk_norm(cfg, params, th, q, k)
    posb = pos[:, None]  # (B, 1)
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k = L.apply_rope(k, posb, cfg.rope_theta)
    cap = cache_k.shape[1]
    slot = (pos % cap) if window is not None else pos

    cache_k = _masked_cache_write(cache_k, k, slot, active)
    cache_v = _masked_cache_write(cache_v, v, slot, active)
    # key positions: full cache -> arange; ring -> recovered from slot algebra
    ar = jnp.arange(cap)[None, :]
    if window is None:
        kpos = jnp.where(ar <= pos[:, None], ar, jnp.iinfo(jnp.int32).max)
    else:
        # entry at slot s holds position: pos - ((slot - s) mod cap)
        kpos = pos[:, None] - ((slot[:, None] - ar) % cap)
        kpos = jnp.where(kpos >= 0, kpos, jnp.iinfo(jnp.int32).max - 1)
    out = attend(q, cache_k, cache_v, posb, kpos, causal=True, window=window)
    out = out.reshape(x.shape[0], 1, -1)
    y = L.linear(params["o"], out, th["o"])
    if lora is not None:
        y = y + _stacked_delta(out, lora["o"], tenant, cfg.lora_alpha)
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3).
# ---------------------------------------------------------------------------


def mla_spec(cfg: ModelConfig, *, stack: tuple[int, ...] = ()) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lr, qlr = cfg.kv_lora_rank, cfg.q_lora_rank
    out = {
        "kv_a": L.linear_spec(d, lr + rope, stack=stack, dtype=cfg.dtype),
        "kv_norm": L.rmsnorm_spec(lr, stack=stack, dtype=cfg.dtype),
        "kv_b": L.linear_spec(lr, h * (nope + vd), stack=stack, dtype=cfg.dtype),
        "o": L.linear_spec(h * vd, d, stack=stack, dtype=cfg.dtype),
    }
    if qlr:
        out["q_a"] = L.linear_spec(d, qlr, stack=stack, dtype=cfg.dtype)
        out["q_norm"] = L.rmsnorm_spec(qlr, stack=stack, dtype=cfg.dtype)
        out["q_b"] = L.linear_spec(qlr, h * (nope + rope), stack=stack,
                                   dtype=cfg.dtype)
    else:
        out["q"] = L.linear_spec(d, h * (nope + rope), stack=stack,
                                 dtype=cfg.dtype)
    return out


def _mla_q(cfg, params, x, th):
    b, t = x.shape[0], x.shape[1]
    h = cfg.num_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        qa = L.linear(params["q_a"], x, th["q_a"])
        qa = L.rmsnorm(params["q_norm"], qa, th["q_norm"], eps=cfg.norm_eps)
        q = L.linear(params["q_b"], qa, th["q_b"])
    else:
        q = L.linear(params["q"], x, th["q"])
    q = q.reshape(b, t, h, nope + rope)
    return q[..., :nope], q[..., nope:]


def mla_attention(cfg: ModelConfig, params, x, th, positions, *, causal=True,
                  lora=None, lora_th=None):
    """Training/prefill MLA: materialize per-head K/V from the latent.

    lora targets: 'kv_b' and 'o' (the per-head expansion and output)."""
    b, t = x.shape[0], x.shape[1]
    h = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lr = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(cfg, params, x, th)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = L.linear(params["kv_a"], x, th["kv_a"])  # (B, T, lr + rope)
    ckv = L.rmsnorm(params["kv_norm"], kv_a[..., :lr], th["kv_norm"],
                    eps=cfg.norm_eps)
    k_rope = kv_a[..., lr:].reshape(b, t, 1, rope)
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)
    kv = _proj(cfg, params["kv_b"], ckv, th.get("kv_b"),
               lora=lora and lora.get("kv_b"),
               lora_th=lora_th and lora_th.get("kv_b"),
               alpha=cfg.lora_alpha).reshape(b, t, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, t, h, rope))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(nope + rope)
    out = attend(q, k, v, positions, positions, causal=causal, scale=scale)
    out = out.reshape(b, t, h * vd)
    return _proj(cfg, params["o"], out, th.get("o"),
                 lora=lora and lora.get("o"),
                 lora_th=lora_th and lora_th.get("o"), alpha=cfg.lora_alpha)


def _mla_lora_sel(cfg, lora, tenant):
    """Gather each row's tenant kv_b adapter factors for absorbed decode.

    Returns (A (B, lr, r), Bn (B, r, H, nope), Bv (B, r, H, vd), scale):
    the low-rank factors of the per-tenant delta on W_UK / W_UV — the
    absorbed MLA form applies the adapter WITHOUT materializing the dense
    (lr, H·(nope+vd)) per-row weight delta; both sides stay O(r)."""
    h = cfg.num_heads
    nope, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
    rk = lora["kv_b"]["a"].shape[-1]
    a = jnp.take(lora["kv_b"]["a"], tenant, axis=0).astype(jnp.float32)
    bm = jnp.take(lora["kv_b"]["b"], tenant, axis=0).astype(jnp.float32)
    bm = bm.reshape(bm.shape[0], rk, h, nope + vd)
    return a, bm[..., :nope], bm[..., nope:], cfg.lora_alpha / rk


def mla_decode(cfg: ModelConfig, params, x, th, cache_ckv, cache_krope, pos,
               *, active=None, lora=None, tenant=None):
    """Absorbed-form MLA decode against the latent cache.

    cache_ckv: (B, S, lr); cache_krope: (B, S, rope). One new token.
    W_UK is folded into the query (q_lat = q_nope @ W_UK per head) and W_UV
    applied after attending over latents, so per-step cost is O(S·lr), not
    O(S·H·hd). `active`: optional (B,) row mask, as in `gqa_decode`.

    lora/tenant: optional tenant-stacked {'kv_b', 'o'} adapters + (B,)
    int32 slot ids. The kv_b delta rides THROUGH the absorption: its
    W_UK part shifts q_lat (score side), its W_UV part shifts the
    post-attention latent expansion — each in low-rank factored form via
    `_mla_lora_sel`, per row (multi-tenant serving)."""
    b = x.shape[0]
    h = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lr = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(cfg, params, x, th)  # (B, 1, H, *)
    posb = pos[:, None]
    q_rope = L.apply_rope(q_rope, posb, cfg.rope_theta)

    kv_a = L.linear(params["kv_a"], x, th["kv_a"])
    ckv_new = L.rmsnorm(params["kv_norm"], kv_a[..., :lr], th["kv_norm"],
                        eps=cfg.norm_eps)
    krope_new = L.apply_rope(kv_a[..., lr:].reshape(b, 1, 1, rope), posb,
                             cfg.rope_theta).reshape(b, 1, rope)

    cache_ckv = _masked_cache_write(cache_ckv, ckv_new, pos, active)
    cache_krope = _masked_cache_write(cache_krope, krope_new, pos, active)

    # absorb W_UK / W_UV (per-head slices of kv_b)
    w_kv_b = params["kv_b"]["w"].reshape(lr, h, nope + vd)
    w_uk = w_kv_b[..., :nope]  # (lr, H, nope)
    w_uv = w_kv_b[..., nope:]  # (lr, H, vd)
    q_lat = jnp.einsum("bohn,lhn->bohl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))  # (B, 1, H, lr)
    if lora is not None:
        la, lbn, lbv, lsc = _mla_lora_sel(cfg, lora, tenant)
        t1 = jnp.einsum("bohn,brhn->bohr", q_nope.astype(jnp.float32), lbn)
        q_lat = q_lat + jnp.einsum("bohr,blr->bohl", t1, la) * lsc
    scores = (jnp.einsum("bohl,bsl->bhos", q_lat,
                         cache_ckv.astype(jnp.float32))
              + jnp.einsum("bohr,bsr->bhos", q_rope.astype(jnp.float32),
                           cache_krope.astype(jnp.float32)))
    scores = scores / math.sqrt(nope + rope)
    s = cache_ckv.shape[1]
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)  # (B, H, 1, S)
    lat = jnp.einsum("bhos,bsl->bohl", w, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bohl,lhv->bohv", lat, w_uv.astype(jnp.float32))
    if lora is not None:
        t2 = jnp.einsum("bohl,blr->bohr", lat, la)
        out = out + jnp.einsum("bohr,brhv->bohv", t2, lbv) * lsc
    out = out.reshape(b, 1, h * vd).astype(x.dtype)
    y = L.linear(params["o"], out, th["o"])
    if lora is not None:
        y = y + _stacked_delta(out, lora["o"], tenant, cfg.lora_alpha)
    return y, cache_ckv, cache_krope


def mla_decode_paged(cfg: ModelConfig, params, x, th, latpool, pt, pos, *,
                     active=None, lora=None, tenant=None):
    """Absorbed-form MLA decode through a paged latent cache.

    latpool: (N+1, L, lr + rope) physical page pool storing the
    concatenated compressed latent and decoupled-rope key per token (the
    two contiguous caches of `mla_decode` fused into one pool — slicing
    the concat back apart is bitwise free); pt: (B, P) int32; pos: (B,).

    The XLA route gathers the latents through the table and then runs
    `mla_decode`'s exact two-einsum score / post-sum scale / softmax /
    latent-attend sequence, so it is bitwise identical to the contiguous
    absorbed decode at matching logical capacity. The Pallas route feeds
    the generic paged kernel with q = concat(q_lat, q_rope) against the
    latent pool (kv=1, g=H, dv=lr truncating the value read to the
    compressed latent).

    lora/tenant: optional tenant-stacked {'kv_b', 'o'} adapters + (B,)
    int32 slot ids, applied in absorbed low-rank form as in
    `mla_decode` (the q_lat shift lands BEFORE the paged gather, so both
    kernel routes see the adapted query)."""
    from repro.kernels import backend as KB
    b = x.shape[0]
    h = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lr = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(cfg, params, x, th)  # (B, 1, H, *)
    posb = pos[:, None]
    q_rope = L.apply_rope(q_rope, posb, cfg.rope_theta)

    kv_a = L.linear(params["kv_a"], x, th["kv_a"])
    ckv_new = L.rmsnorm(params["kv_norm"], kv_a[..., :lr], th["kv_norm"],
                        eps=cfg.norm_eps)
    krope_new = L.apply_rope(kv_a[..., lr:].reshape(b, 1, 1, rope), posb,
                             cfg.rope_theta).reshape(b, 1, rope)
    lat_new = jnp.concatenate([ckv_new, krope_new], axis=-1)  # (B, 1, lr+r)
    latpool = _paged_write(latpool, lat_new, pt, pos, active)

    w_kv_b = params["kv_b"]["w"].reshape(lr, h, nope + vd)
    w_uk = w_kv_b[..., :nope]  # (lr, H, nope)
    w_uv = w_kv_b[..., nope:]  # (lr, H, vd)
    q_lat = jnp.einsum("bohn,lhn->bohl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))  # (B, 1, H, lr)
    if lora is not None:
        la, lbn, lbv, lsc = _mla_lora_sel(cfg, lora, tenant)
        t1 = jnp.einsum("bohn,brhn->bohr", q_nope.astype(jnp.float32), lbn)
        q_lat = q_lat + jnp.einsum("bohr,blr->bohl", t1, la) * lsc

    # shape hints keep this branch and the engine's own paged_attn dispatch
    # on the SAME autotune bucket (t = logical context, din/dout = head dims)
    if KB.active().paged_impl(t=pt.shape[1] * latpool.shape[1],
                              din=lr + rope, dout=lr) == "pallas":
        q_cat = jnp.concatenate(
            [q_lat, q_rope.astype(jnp.float32)], axis=-1)  # (B, 1, H, lr+r)
        lat = KB.active().paged_attn(
            q_cat, latpool, latpool, pt, pos,
            scale=1.0 / math.sqrt(nope + rope), dv=lr)  # (B, 1, H, lr)
    else:
        # gather + line-for-line replica of mla_decode's absorbed math
        page_len = latpool.shape[1]
        s_log = pt.shape[1] * page_len
        gath = latpool[pt].reshape(b, s_log, lr + rope)
        cache_ckv, cache_krope = gath[..., :lr], gath[..., lr:]
        scores = (jnp.einsum("bohl,bsl->bhos", q_lat,
                             cache_ckv.astype(jnp.float32))
                  + jnp.einsum("bohr,bsr->bhos", q_rope.astype(jnp.float32),
                               cache_krope.astype(jnp.float32)))
        scores = scores / math.sqrt(nope + rope)
        valid = jnp.arange(s_log)[None, :] <= pos[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)  # (B, H, 1, S)
        lat = jnp.einsum("bhos,bsl->bohl", w, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bohl,lhv->bohv", lat, w_uv.astype(jnp.float32))
    if lora is not None:
        t2 = jnp.einsum("bohl,blr->bohr", lat, la)
        out = out + jnp.einsum("bohr,brhv->bohv", t2, lbv) * lsc
    out = out.reshape(b, 1, h * vd).astype(x.dtype)
    y = L.linear(params["o"], out, th["o"])
    if lora is not None:
        y = y + _stacked_delta(out, lora["o"], tenant, cfg.lora_alpha)
    return y, latpool
