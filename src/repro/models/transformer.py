"""Model assembly: every assigned architecture as one configurable LM.

Compile-time scalability: homogeneous layer stacks are `jax.lax.scan`s over
stacked parameters, so HLO size is O(1) in depth (95-layer DeepSeek-67B and
81-layer Zamba2 lower as fast as 2-layer smoke variants). Mixed-depth models
(DeepSeek-V3's first-k-dense) use one scan per homogeneous run. Zamba2's
SHARED attention block is applied inside the backbone scan under lax.cond
at its sites, with a sensitivity multiplier equal to the number of sites
(see DESIGN.md on parameter sharing).

The public surface per architecture:
    m = build_model(cfg)
    m.spec / m.layout                      # params + clipping groups
    m.loss_fn(params, batch, thresholds)   # (B,) per-example losses
    m.serve_step(params, cache, batch)     # one-token decode
    m.init_cache(batch_size, cache_len)    # decode cache pytree
    (launch.dryrun builds abstract ShapeDtypeStruct inputs from these)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp_layers as dpl
from repro.core.spec import GroupLayout, P, subth
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Block specs.
# ---------------------------------------------------------------------------


def _attn_block_spec(cfg: ModelConfig, n: int, *, moe_layer: bool,
                     cross: bool = False, sens: float = 1.0) -> dict:
    stack = (n,) if n else ()
    spec = {
        "attn_norm": L.rmsnorm_spec(cfg.d_model, stack=stack, dtype=cfg.dtype),
        "attn": (A.mla_spec(cfg, stack=stack)
                 if cfg.attention_kind == "mla"
                 else A.gqa_spec(cfg, stack=stack, sensitivity_mult=sens)),
        "mlp_norm": L.rmsnorm_spec(cfg.d_model, stack=stack, dtype=cfg.dtype),
    }
    if cross:
        spec["cross_norm"] = L.rmsnorm_spec(cfg.d_model, stack=stack,
                                            dtype=cfg.dtype)
        spec["cross"] = A.gqa_spec(cfg, stack=stack, cross=True)
    if moe_layer:
        spec["moe"] = MOE.moe_spec(cfg, stack=stack)
    else:
        if sens > 1.0:
            spec["mlp"] = {
                "gate_up": L.linear_spec(cfg.d_model, 2 * cfg.d_ff,
                                         stack=stack, dtype=cfg.dtype,
                                         sensitivity_mult=sens),
                "down": L.linear_spec(cfg.d_ff, cfg.d_model, stack=stack,
                                      dtype=cfg.dtype, sensitivity_mult=sens),
            }
        else:
            spec["mlp"] = L.swiglu_spec(cfg.d_model, cfg.d_ff, stack=stack,
                                        dtype=cfg.dtype)
    return spec


def _mamba_block_spec(cfg: ModelConfig, n: int) -> dict:
    stack = (n,) if n else ()
    return M2.mamba2_spec(cfg, stack=stack)


def _rwkv_block_spec(cfg: ModelConfig, n: int) -> dict:
    stack = (n,) if n else ()
    return R6.rwkv6_spec(cfg, stack=stack)


# ---------------------------------------------------------------------------
# Block applies (one layer; thresholds pre-sliced by the scan).
# ---------------------------------------------------------------------------


def _apply_attn_block(cfg, params, x, th, positions, *, causal=True,
                      window=None, enc_out=None, moe_layer=False,
                      lora=None, lora_th=None):
    h = L.rmsnorm(params["attn_norm"], x, th["attn_norm"], eps=cfg.norm_eps)
    if cfg.attention_kind == "mla":
        att = A.mla_attention(cfg, params["attn"], h, subth(th, "attn"),
                              positions, causal=causal, lora=lora,
                              lora_th=lora_th)
    else:
        att = A.gqa_attention(cfg, params["attn"], h, subth(th, "attn"),
                              positions, causal=causal, window=window,
                              lora=lora, lora_th=lora_th)
    x = x + att
    aux = jnp.zeros((x.shape[0],), jnp.float32)
    if enc_out is not None:
        h = L.rmsnorm(params["cross_norm"], x, th["cross_norm"],
                      eps=cfg.norm_eps)
        ca = _cross_attention(cfg, params["cross"], h, subth(th, "cross"),
                              enc_out)
        x = x + ca
    h = L.rmsnorm(params["mlp_norm"], x, th["mlp_norm"], eps=cfg.norm_eps)
    if moe_layer:
        moe_fn = (MOE.moe_block_grouped if cfg.moe_dispatch == "grouped"
                  else MOE.moe_block)
        y, aux = moe_fn(cfg, params["moe"], h, subth(th, "moe"))
    else:
        y = L.swiglu(params["mlp"], h, subth(th, "mlp"), f=cfg.d_ff)
    return x + y, aux


def _cross_attention(cfg, params, x, th, enc_out):
    b, t = x.shape[0], x.shape[1]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = L.linear(params["qkv"], x, th["qkv"]).reshape(b, t, h, hd)
    kv = L.linear(params["kv"], enc_out, th["kv"])
    s = enc_out.shape[1]
    k = kv[..., : kvh * hd].reshape(b, s, kvh, hd)
    v = kv[..., kvh * hd:].reshape(b, s, kvh, hd)
    qpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    kpos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = A.attend(q, k, v, qpos, kpos, causal=False)
    return L.linear(params["o"], out.reshape(b, t, h * hd), th["o"])


def _apply_mamba_block(cfg, params, x, th):
    h = M2.mamba2_block(cfg, params["m"], L.rmsnorm(
        params["norm"], x, th["norm"], eps=cfg.norm_eps), subth(th, "m"))
    return x + h


def _apply_rwkv_block(cfg, params, x, th, *, tm_prev, cm_prev, state,
                      formulation="scan"):
    h = L.rmsnorm(params["norm1"], x, th["norm1"], eps=cfg.norm_eps)
    att, tm_new, s_new = R6.time_mix(cfg, params["tm"], h, subth(th, "tm"),
                                     x_prev=tm_prev, state=state,
                                     formulation=formulation)
    x = x + att
    h = L.rmsnorm(params["norm2"], x, th["norm2"], eps=cfg.norm_eps)
    ff, cm_new = R6.channel_mix(cfg, params["cm"], h, subth(th, "cm"),
                                x_prev=cm_prev)
    return x + ff, tm_new, cm_new, s_new


def _maybe_remat(fn, cfg):
    """Activation-checkpoint a per-layer apply (saves only block inputs)."""
    return jax.checkpoint(fn) if cfg.remat else fn


# ---------------------------------------------------------------------------
# The Model container.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    spec: dict
    layout: GroupLayout
    loss_fn: Callable  # (params, batch, thresholds) -> (B,) losses
    serve_step: Callable  # (params, cache, batch) -> (logits, cache)
    init_cache: Callable  # (batch_size, cache_len) -> cache pytree
    num_params: int

    def abstract_cache(self, batch_size: int, cache_len: int):
        shapes = jax.eval_shape(lambda: self.init_cache(batch_size, cache_len))
        return shapes


def _count(spec) -> int:
    total = 0

    def walk(node):
        nonlocal total
        if isinstance(node, P):
            total += int(np.prod(node.shape, dtype=np.int64))
        else:
            for v in node.values():
                walk(v)

    walk(spec)
    return total


def build_model(cfg: ModelConfig, *, rwkv_formulation: str = "scan") -> Model:
    cfg.validate()
    if cfg.arch_type == "audio":
        return _build_encdec(cfg)
    return _build_decoder(cfg, rwkv_formulation)


# ---------------------------------------------------------------------------
# Decoder-only family (dense / moe / ssm / hybrid / vlm).
# ---------------------------------------------------------------------------


def _build_decoder(cfg: ModelConfig, rwkv_formulation: str) -> Model:
    pat = cfg.pattern()
    d, v = cfg.d_model, cfg.vocab_size

    spec: dict = {"embed": {"w": P((v, d), init="embed", dtype=cfg.dtype)},
                  "final_norm": L.rmsnorm_spec(d, dtype=cfg.dtype),
                  "head": {"w": P((d, v), dtype=cfg.dtype)}}

    kinds = sorted(set(pat))
    if cfg.shared_attention:
        # Zamba2: pure-mamba backbone + ONE shared attention block applied
        # before every `shared_every`-th layer inside the scan.
        n_backbone = cfg.num_layers
        n_sites = -(-n_backbone // cfg.shared_every)
        spec["backbone"] = {"norm": L.rmsnorm_spec(d, stack=(n_backbone,),
                                                   dtype=cfg.dtype),
                            "m": M2.mamba2_spec(cfg, stack=(n_backbone,))}
        spec["shared"] = _attn_block_spec(cfg, 0, moe_layer=False,
                                          sens=float(n_sites))
    else:
        if len(kinds) == 1:
            k = kinds[0]
            n = cfg.num_layers
            if k == "a":
                n_moe = n - cfg.first_k_dense if cfg.num_experts else 0
                n_dense = n - n_moe
                if n_dense:
                    spec["dense_blocks"] = _attn_block_spec(
                        cfg, n_dense, moe_layer=False)
                if n_moe:
                    spec["moe_blocks"] = _attn_block_spec(
                        cfg, n_moe, moe_layer=True)
            elif k == "m":
                spec["blocks"] = {"norm": L.rmsnorm_spec(
                    d, stack=(n,), dtype=cfg.dtype),
                    "m": M2.mamba2_spec(cfg, stack=(n,))}
            elif k == "r":
                spec["blocks"] = {"norm1": L.rmsnorm_spec(d, stack=(n,),
                                                          dtype=cfg.dtype),
                                  "norm2": L.rmsnorm_spec(d, stack=(n,),
                                                          dtype=cfg.dtype),
                                  **_rwkv_block_spec(cfg, n)}
            else:
                raise ValueError(k)
        else:
            raise NotImplementedError(
                "mixed patterns without shared_attention: use shared_attention"
                " or homogeneous patterns")

    if cfg.mtp_depth:
        spec["mtp"] = {"proj": L.linear_spec(2 * d, d, dtype=cfg.dtype),
                       "block": _attn_block_spec(cfg, 0, moe_layer=False),
                       "norm": L.rmsnorm_spec(d, dtype=cfg.dtype)}

    # ----- DP LoRA (the paper's large-model recipe): adapters on the
    # attention projections; everything else frozen. -----
    lora_on = cfg.lora_rank > 0
    lora_tree: dict = {}
    if lora_on:
        from repro.core.lora import lora_spec as _lspec
        h_, kv_, hd_ = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        for name in ("dense_blocks", "moe_blocks"):
            if name not in spec:
                continue
            n = spec[name]["attn_norm"]["s"].shape[0]
            if cfg.attention_kind == "mla":
                lora_tree[name] = {
                    "kv_b": _lspec(cfg.kv_lora_rank,
                                   h_ * (cfg.qk_nope_head_dim + cfg.v_head_dim),
                                   cfg.lora_rank, stack=(n,), dtype=cfg.dtype),
                    "o": _lspec(h_ * cfg.v_head_dim, d, cfg.lora_rank,
                                stack=(n,), dtype=cfg.dtype),
                }
            else:
                lora_tree[name] = {
                    "qkv": _lspec(d, (h_ + 2 * kv_) * hd_, cfg.lora_rank,
                                  stack=(n,), dtype=cfg.dtype),
                    "o": _lspec(h_ * hd_, d, cfg.lora_rank, stack=(n,),
                                dtype=cfg.dtype),
                }
        spec["lora"] = lora_tree

    base_spec = {k: v for k, v in spec.items() if k != "lora"}
    base_layout = GroupLayout(base_spec)
    layout = GroupLayout({"lora": lora_tree}) if lora_on else base_layout

    # ---------------- shared helpers ----------------

    def embed(params, tokens, th):
        return dpl.dp_embed(params["embed"]["w"], tokens, th["embed"])

    def head(params, x, th):
        return dpl.dp_linear(params["head"]["w"], None, x, th["head"])

    def positions_of(batch, bsz, t):
        if "positions" in batch:
            return batch["positions"]
        return jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (bsz, t))

    window = cfg.sliding_window

    # ---------------- forward over blocks (training / prefill) -------------

    def backbone_fwd(params, x, th, positions, batch):
        bsz = x.shape[0]
        aux = jnp.zeros((bsz,), jnp.float32)

        if cfg.shared_attention:
            n = cfg.num_layers
            shared_every = cfg.shared_every
            bb_th = subth(th, "backbone")
            sh_th = subth(th, "shared")

            def body(carry, xs):
                h, i = carry
                bp, bt = xs

                def with_shared(hh):
                    out, _ = _apply_attn_block(
                        cfg, params["shared"], hh, sh_th, positions,
                        causal=True, window=window, moe_layer=False)
                    return out

                h = jax.lax.cond(i % shared_every == 0,
                                 _maybe_remat(with_shared, cfg),
                                 lambda hh: hh, h)
                h = _maybe_remat(
                    lambda hh, bp_, bt_: _apply_mamba_block(cfg, bp_, hh, bt_),
                    cfg)(h, bp, bt)
                return (h, i + 1), None

            (x, _), _ = jax.lax.scan(
                body, (x, jnp.int32(0)), (params["backbone"], bb_th))
            return x, aux

        if "blocks" in spec and "m" in spec["blocks"]:
            bb_th = subth(th, "blocks")

            def body(h, xs):
                bp, bt = xs
                f = _maybe_remat(
                    lambda hh, bp_, bt_: _apply_mamba_block(cfg, bp_, hh, bt_),
                    cfg)
                return f(h, bp, bt), None

            x, _ = jax.lax.scan(body, x, (params["blocks"], bb_th))
            return x, aux

        if "blocks" in spec and "tm" in spec["blocks"]:
            bb_th = subth(th, "blocks")
            nh = d // cfg.rwkv_head_dim
            hd = cfg.rwkv_head_dim

            def body(h, xs):
                bp, bt = xs

                def blk(hh, bp_, bt_):
                    tm_prev = jnp.zeros((bsz, 1, d), hh.dtype)
                    cm_prev = jnp.zeros((bsz, 1, d), hh.dtype)
                    s0 = jnp.zeros((bsz, nh, hd, hd), jnp.float32)
                    out, _, _, _ = _apply_rwkv_block(
                        cfg, bp_, hh, bt_, tm_prev=tm_prev, cm_prev=cm_prev,
                        state=s0, formulation=rwkv_formulation)
                    return out

                return _maybe_remat(blk, cfg)(h, bp, bt), None

            x, _ = jax.lax.scan(body, x, (params["blocks"], bb_th))
            return x, aux

        # attention stacks (dense and/or moe runs)
        for name, moe_layer in (("dense_blocks", False), ("moe_blocks", True)):
            if name not in spec or name == "lora":
                continue
            run_th = subth(th, name)
            if lora_on:
                lora_run_th = subth(th, "lora/" + name)

                def body(carry, xs, moe_layer=moe_layer):
                    h, aux_c = carry
                    bp, bt, lp, lt = xs

                    def blk(hh, bp_, bt_, lp_, lt_):
                        return _apply_attn_block(
                            cfg, bp_, hh, bt_, positions, causal=True,
                            window=window, moe_layer=moe_layer,
                            lora=lp_, lora_th=lt_)

                    h, aux_l = _maybe_remat(blk, cfg)(h, bp, bt, lp, lt)
                    return (h, aux_c + aux_l), None

                (x, aux), _ = jax.lax.scan(
                    body, (x, aux),
                    (params[name], run_th, params["lora"][name],
                     lora_run_th))
            else:
                def body(carry, xs, moe_layer=moe_layer):
                    h, aux_c = carry
                    bp, bt = xs

                    def blk(hh, bp_, bt_):
                        return _apply_attn_block(
                            cfg, bp_, hh, bt_, positions, causal=True,
                            window=window, moe_layer=moe_layer)

                    h, aux_l = _maybe_remat(blk, cfg)(h, bp, bt)
                    return (h, aux_c + aux_l), None

                (x, aux), _ = jax.lax.scan(body, (x, aux),
                                           (params[name], run_th))
        return x, aux

    # ---------------- loss ----------------

    def loss_fn(params, batch, th):
        tokens = batch["tokens"]  # (B, T)
        bsz, t = tokens.shape
        if lora_on:
            # base groups get +inf (frozen, unused grads DCE'd); real
            # thresholds arrive only for the lora/... groups
            th = {**base_layout.pack_value(jnp.inf, bsz), **th}
        x = embed(params, tokens, th)
        tv = 0
        if "vision_embeds" in batch:  # VLM: prepend stub patch embeddings
            ve = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([ve, x], axis=1)
            tv = ve.shape[1]
        if cfg.m_rope:
            if "positions3_full" in batch:
                # batch-major (B, 3, Tv+T) -> (3, B, Tv+T)
                positions = jnp.moveaxis(batch["positions3_full"], 1, 0)
            elif "positions3" in batch:
                positions = jnp.moveaxis(batch["positions3"], 1, 0)
            else:
                p1 = positions_of(batch, bsz, t + tv)
                positions = jnp.broadcast_to(p1[None], (3,) + p1.shape)
        else:
            positions = positions_of(batch, bsz, t + tv)

        if cfg.m_rope:
            x, aux = _mrope_backbone(cfg, spec, params, x, th, positions,
                                     backbone_fwd)
        else:
            x, aux = backbone_fwd(params, x, th, positions, batch)

        if tv:
            x = x[:, tv:]
        x = L.rmsnorm(params["final_norm"], x, th["final_norm"],
                      eps=cfg.norm_eps)
        logits = head(params, x, th)  # (B, T, V)
        targets = batch["targets"]  # (B, T) with -1 = ignore
        ce = _per_example_ce(logits, targets)
        if cfg.mtp_depth:
            ce = ce + 0.3 * _mtp_loss(cfg, params, x, th, batch, positions
                                      if not cfg.m_rope else None)
        return ce + aux

    def _mtp_loss(cfg, params, x, th, batch, positions):
        # DeepSeek-V3 MTP: combine h_t with embed(token_{t+1}) to predict
        # token_{t+2} through one extra block sharing the main head.
        tokens = batch["tokens"]
        bsz, t = tokens.shape
        nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
        e = embed(params, nxt, th)
        h = L.linear(params["mtp"]["proj"],
                     jnp.concatenate([x, e], axis=-1),
                     th["mtp/proj"])
        pos = positions if positions is not None else jnp.broadcast_to(
            jnp.arange(t, dtype=jnp.int32)[None], (bsz, t))
        h, _ = _apply_attn_block(cfg, params["mtp"]["block"], h,
                                 subth(th, "mtp/block"), pos, causal=True,
                                 moe_layer=False)
        h = L.rmsnorm(params["mtp"]["norm"], h, th["mtp/norm"],
                      eps=cfg.norm_eps)
        logits = head(params, h, th)
        tgt = batch["targets"]
        tgt2 = jnp.concatenate(
            [tgt[:, 2:], jnp.full((bsz, 2), -1, tgt.dtype)], axis=1)
        return _per_example_ce(logits, tgt2)

    # ---------------- decode ----------------

    serve_step, init_cache, init_paged_cache = _make_decoder_serve(
        cfg, base_spec, base_layout)

    def prefill_step(params, batch):
        """Full-sequence forward -> last-position logits (B, V): the
        inference-prefill workload (prefill_32k)."""
        tokens = batch["tokens"]
        bsz, t = tokens.shape
        th = base_layout.pack_value(jnp.inf, bsz)
        if lora_on:
            th = {**th, **layout.pack_value(jnp.inf, bsz)}
        x = embed(params, tokens, th)
        tv = 0
        if "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([ve, x], axis=1)
            tv = ve.shape[1]
        if cfg.m_rope:
            if "positions3_full" in batch:
                positions = jnp.moveaxis(batch["positions3_full"], 1, 0)
            else:
                p1 = positions_of(batch, bsz, t + tv)
                positions = jnp.broadcast_to(p1[None], (3,) + p1.shape)
            x, _ = _mrope_backbone(cfg, spec, params, x, th, positions,
                                   backbone_fwd)
        else:
            positions = positions_of(batch, bsz, t + tv)
            x, _ = backbone_fwd(params, x, th, positions, batch)
        x = x[:, -1:]
        x = L.rmsnorm(params["final_norm"], x, th["final_norm"],
                      eps=cfg.norm_eps)
        logits = head(params, x, th)
        return logits[:, 0]

    m = Model(cfg=cfg, spec=spec, layout=layout, loss_fn=loss_fn,
              serve_step=serve_step, init_cache=init_cache,
              num_params=_count(spec))
    m.prefill_step = prefill_step  # type: ignore[attr-defined]
    m.init_paged_cache = init_paged_cache  # type: ignore[attr-defined]
    m.cache_slot_axes = cache_slot_axes  # type: ignore[attr-defined]
    m.base_layout = base_layout  # type: ignore[attr-defined]
    m.trainable_key = "lora" if lora_on else None  # type: ignore
    m.dp_spec = {"lora": lora_tree} if lora_on else spec  # type: ignore
    return m


def _mrope_backbone(cfg, spec, params, x, th, positions3, backbone_fwd):
    """Qwen2-VL: swap plain rope for M-RoPE by monkey-free config plumbing:
    attention reads (B, T) positions normally; for M-RoPE we pass the 3-D
    streams through a closure-level override."""
    # We implement M-RoPE by rotating q/k inside gqa via positions packed as
    # complex trick: simplest correct route — temporarily replace apply_rope.
    # Instead we run the standard stack but with positions = temporal stream,
    # then add the (h, w) rotations via the sections: implemented directly in
    # layers.apply_m_rope by calling the stack with a wrapped config.
    return _backbone_mrope_impl(cfg, spec, params, x, th, positions3)


def _backbone_mrope_impl(cfg, spec, params, x, th, positions3):
    bsz = x.shape[0]
    aux = jnp.zeros((bsz,), jnp.float32)
    run_th = subth(th, "dense_blocks")
    sections = cfg.m_rope_sections
    lora_on = "lora" in params

    if lora_on:
        lora_run_th = subth(th, "lora/dense_blocks")

        def body(carry, xs):
            h, aux_c = carry
            bp, bt, lp, lt = xs

            def blk(hh, bp_, bt_, lp_, lt_):
                hn = L.rmsnorm(bp_["attn_norm"], hh, bt_["attn_norm"],
                               eps=cfg.norm_eps)
                att = _mrope_attention(cfg, bp_["attn"], hn,
                                       subth(bt_, "attn"), positions3,
                                       sections, lora=lp_, lora_th=lt_)
                hh = hh + att
                hn = L.rmsnorm(bp_["mlp_norm"], hh, bt_["mlp_norm"],
                               eps=cfg.norm_eps)
                y = L.swiglu(bp_["mlp"], hn, subth(bt_, "mlp"), f=cfg.d_ff)
                return hh + y

            h = _maybe_remat(blk, cfg)(h, bp, bt, lp, lt)
            return (h, aux_c), None

        (x, aux), _ = jax.lax.scan(
            body, (x, aux), (params["dense_blocks"], run_th,
                             params["lora"]["dense_blocks"], lora_run_th))
        return x, aux

    def body(carry, xs):
        h, aux_c = carry
        bp, bt = xs

        def blk(hh, bp_, bt_):
            hn = L.rmsnorm(bp_["attn_norm"], hh, bt_["attn_norm"],
                           eps=cfg.norm_eps)
            att = _mrope_attention(cfg, bp_["attn"], hn, subth(bt_, "attn"),
                                   positions3, sections)
            hh = hh + att
            hn = L.rmsnorm(bp_["mlp_norm"], hh, bt_["mlp_norm"],
                           eps=cfg.norm_eps)
            y = L.swiglu(bp_["mlp"], hn, subth(bt_, "mlp"), f=cfg.d_ff)
            return hh + y

        h = _maybe_remat(blk, cfg)(h, bp, bt)
        return (h, aux_c), None

    (x, aux), _ = jax.lax.scan(body, (x, aux),
                               (params["dense_blocks"], run_th))
    return x, aux


def _mrope_attention(cfg, params, x, th, positions3, sections, *,
                     lora=None, lora_th=None):
    qkv = A._proj(cfg, params["qkv"], x, th.get("qkv"),
                  lora=lora and lora.get("qkv"),
                  lora_th=lora_th and lora_th.get("qkv"),
                  alpha=cfg.lora_alpha)
    q, k, v = A._split_qkv(cfg, qkv)
    q = L.apply_m_rope(q, positions3, cfg.rope_theta, sections)
    k = L.apply_m_rope(k, positions3, cfg.rope_theta, sections)
    b, t = x.shape[0], x.shape[1]
    pos = positions3[0]  # temporal stream drives causal masking
    out = A.attend(q, k, v, pos, pos, causal=True,
                   window=cfg.sliding_window)
    out = out.reshape(b, t, -1)
    return A._proj(cfg, params["o"], out, th.get("o"),
                   lora=lora and lora.get("o"),
                   lora_th=lora_th and lora_th.get("o"),
                   alpha=cfg.lora_alpha)


def _per_example_ce(logits, targets):
    """(B,) mean CE over valid (target >= 0) positions."""
    valid = targets >= 0
    tsafe = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    tok_ll = jnp.take_along_axis(
        logits.astype(jnp.float32), tsafe[..., None], axis=-1)[..., 0]
    ce = (lse - tok_ll) * valid
    return jnp.sum(ce, axis=-1) / jnp.maximum(jnp.sum(valid, axis=-1), 1)


# ---------------------------------------------------------------------------
# Decode (serve_step) for the decoder family.
# ---------------------------------------------------------------------------

# Which axis of each decode-cache tensor indexes the slot (the engine's
# batch row). Explicit, per cache family — the old engine hardcoded
# `0 if k == "pos" else 1`, which happened to hold for every family but
# silently relied on it; paged pools break the pattern (they are SHARED by
# all slots, axis None) and a wrong axis in the recycle program would
# cross-contaminate slots without any test tripping locally.
_SLOT_AXIS_BY_KEY = {
    "pos": 0, "pt": 0,
    "conv": 1, "ssm": 1,                       # mamba2 recurrent state
    "tm_prev": 1, "cm_prev": 1, "wkv": 1,      # rwkv6 recurrent state
    "shared_k": 1, "shared_v": 1,              # zamba2 shared-attention KV
    "dec_k": 1, "dec_v": 1, "cross_k": 1, "cross_v": 1,  # enc-dec
}


def cache_slot_axes(cache) -> dict:
    """Map every decode-cache key to its slot axis (None = slot-free).

    Slot-free tensors (physical page pools) must pass through a slot
    recycle untouched: zeroing them would destroy other slots' pages.
    Unknown keys raise — a new cache family must declare its layout here
    before the engine will recycle it."""
    out = {}
    for k in cache:
        if k in _SLOT_AXIS_BY_KEY:
            out[k] = _SLOT_AXIS_BY_KEY[k]
        elif k.endswith(("_kpool", "_vpool", "_latpool")):
            out[k] = None
        elif k.endswith(("_k", "_v", "_ckv", "_krope")):
            out[k] = 1  # per-stack attention caches: (n, B, S, ...)
        else:
            raise KeyError(
                f"decode-cache key {k!r} has no slot-axis entry; add it to "
                "transformer._SLOT_AXIS_BY_KEY (or a suffix rule) so the "
                "engine's recycle program knows which axis to mask")
    return out


def _make_decoder_serve(cfg: ModelConfig, spec, layout):
    d = cfg.d_model
    window = cfg.sliding_window
    hd = cfg.resolved_head_dim
    kvh = cfg.num_kv_heads

    def init_cache(batch_size: int, cache_len: int):
        b = batch_size
        cap = min(window, cache_len) if window else cache_len
        cache = {"pos": jnp.zeros((b,), jnp.int32)}
        if cfg.shared_attention:
            n = cfg.num_layers
            n_sites = -(-n // cfg.shared_every)
            d_in, nh, nst, p = M2.dims(cfg)
            cache["conv"] = jnp.zeros(
                (n, b, cfg.ssm_conv_kernel - 1, d_in + 2 * nst), cfg.dtype)
            cache["ssm"] = jnp.zeros((n, b, nh, p, nst), jnp.float32)
            cache["shared_k"] = jnp.zeros((n_sites, b, cap, kvh, hd), cfg.dtype)
            cache["shared_v"] = jnp.zeros((n_sites, b, cap, kvh, hd), cfg.dtype)
            return cache
        if "blocks" in spec and "m" in spec["blocks"]:
            n = cfg.num_layers
            d_in, nh, nst, p = M2.dims(cfg)
            cache["conv"] = jnp.zeros(
                (n, b, cfg.ssm_conv_kernel - 1, d_in + 2 * nst), cfg.dtype)
            cache["ssm"] = jnp.zeros((n, b, nh, p, nst), jnp.float32)
            return cache
        if "blocks" in spec and "tm" in spec["blocks"]:
            n = cfg.num_layers
            nh = d // cfg.rwkv_head_dim
            rhd = cfg.rwkv_head_dim
            cache["tm_prev"] = jnp.zeros((n, b, 1, d), cfg.dtype)
            cache["cm_prev"] = jnp.zeros((n, b, 1, d), cfg.dtype)
            cache["wkv"] = jnp.zeros((n, b, nh, rhd, rhd), jnp.float32)
            return cache
        # attention stacks
        for name in ("dense_blocks", "moe_blocks"):
            if name not in spec:
                continue
            n = spec[name]["attn_norm"]["s"].shape[0]
            if cfg.attention_kind == "mla":
                cache[f"{name}_ckv"] = jnp.zeros(
                    (n, b, cache_len, cfg.kv_lora_rank), cfg.dtype)
                cache[f"{name}_krope"] = jnp.zeros(
                    (n, b, cache_len, cfg.qk_rope_head_dim), cfg.dtype)
            else:
                cache[f"{name}_k"] = jnp.zeros((n, b, cap, kvh, hd), cfg.dtype)
                cache[f"{name}_v"] = jnp.zeros((n, b, cap, kvh, hd), cfg.dtype)
        return cache

    # paging applies to full (position-bounded) attention caches only:
    # ring windows and recurrent state are O(W)/O(1) per slot and have
    # nothing to fragment, so those families keep the contiguous plane
    paged_ok = (not cfg.shared_attention
                and not ("blocks" in spec and ("m" in spec["blocks"]
                                               or "tm" in spec["blocks"]))
                and (cfg.attention_kind == "mla" or window is None))

    def init_paged_cache(batch_size: int, cache_len: int, *, num_pages: int,
                         page_len: int):
        """Paged decode cache: per-slot int32 page tables plus physical
        page pools shared by every slot. Pools carry `num_pages + 1`
        pages — the extra LAST page is the trash page absorbing writes
        from inactive rows (attention._paged_write). Tables start fully
        trash-mapped; the engine overwrites them at admission."""
        if not paged_ok:
            raise ValueError(
                "paged cache is only supported for full-attention decoder "
                "stacks (ring-window / recurrent families bypass paging)")
        b = batch_size
        p_tab = -(-cache_len // page_len)
        cache = {"pos": jnp.zeros((b,), jnp.int32),
                 "pt": jnp.full((b, p_tab), num_pages, jnp.int32)}
        for name in ("dense_blocks", "moe_blocks"):
            if name not in spec:
                continue
            n = spec[name]["attn_norm"]["s"].shape[0]
            if cfg.attention_kind == "mla":
                cache[f"{name}_latpool"] = jnp.zeros(
                    (n, num_pages + 1, page_len,
                     cfg.kv_lora_rank + cfg.qk_rope_head_dim), cfg.dtype)
            else:
                cache[f"{name}_kpool"] = jnp.zeros(
                    (n, num_pages + 1, page_len, kvh, hd), cfg.dtype)
                cache[f"{name}_vpool"] = jnp.zeros(
                    (n, num_pages + 1, page_len, kvh, hd), cfg.dtype)
        return cache

    def serve_step(params, cache, batch):
        """batch: {'token': (B, 1) int32, optional 'active': (B,) bool,
        optional 'tenant': (B,) int32}; returns (logits (B, V), cache).

        `active` is the slot-pool write/retire hook (launch.engine): rows
        with `active=False` come back with a bit-identical cache slot and
        an unchanged position — their logits are garbage and must be
        ignored by the caller. Omitting the key advances every row (the
        historical single-batch path, no masking cost).

        Multi-tenant serving: when `params` carries a 'lora_stack' subtree
        (tenant-stacked adapters, leaves (n, T, ...) — see
        core.lora.stacked_adapter_zeros) AND the batch carries 'tenant'
        (per-row int32 adapter-slot ids), every attention projection adds
        its row's tenant adapter delta (attention.gqa_decode /
        mla_decode and their paged variants). Both are data: admitting a
        tenant or hot-swapping an adapter never retraces this program."""
        token = batch["token"]
        active = batch.get("active")
        lstack = params.get("lora_stack")
        tenant = batch.get("tenant")
        if (lstack is None) != (tenant is None):
            raise ValueError(
                "multi-tenant serve_step needs BOTH params['lora_stack'] "
                "and batch['tenant'] (or neither)")
        b = token.shape[0]
        pos = cache["pos"]
        th = layout.pack_value(jnp.inf, b)
        x = dpl.dp_embed(params["embed"]["w"], token, th["embed"])
        new_cache = dict(cache)

        if cfg.shared_attention:
            shared_every = cfg.shared_every
            inf_b = jnp.full((b,), jnp.inf)

            def subth_bb(prefix):
                names = [k for k in layout._by_name
                         if k.startswith(f"backbone/{prefix}/")]
                return {k[len(f"backbone/{prefix}/"):]: inf_b for k in names}

            def mk_shared(sub):
                names = [k for k in layout._by_name
                         if k.startswith(f"shared/{sub}/")]
                return {k[len(f"shared/{sub}/"):]: inf_b for k in names}

            def body(carry, xs):
                h, i, sk_all, sv_all = carry
                bp, conv_s, ssm_s = xs
                site = i // shared_every

                def with_shared(args):
                    hh, sk_all, sv_all = args
                    hn = L.rmsnorm(params["shared"]["attn_norm"], hh,
                                   inf_b, eps=cfg.norm_eps)
                    ck = jax.lax.dynamic_index_in_dim(sk_all, site,
                                                      keepdims=False)
                    cv = jax.lax.dynamic_index_in_dim(sv_all, site,
                                                      keepdims=False)
                    att, ck, cv = A.gqa_decode(
                        cfg, params["shared"]["attn"], hn,
                        mk_shared("attn"), ck, cv, pos, window=window,
                        active=active)
                    sk_all = jax.lax.dynamic_update_index_in_dim(
                        sk_all, ck, site, axis=0)
                    sv_all = jax.lax.dynamic_update_index_in_dim(
                        sv_all, cv, site, axis=0)
                    hh = hh + att
                    hn = L.rmsnorm(params["shared"]["mlp_norm"], hh,
                                   inf_b, eps=cfg.norm_eps)
                    y = L.swiglu(params["shared"]["mlp"], hn,
                                 mk_shared("mlp"), f=cfg.d_ff)
                    return hh + y, sk_all, sv_all

                h, sk_all, sv_all = jax.lax.cond(
                    i % shared_every == 0, with_shared,
                    lambda a: a, (h, sk_all, sv_all))
                hn = L.rmsnorm(bp["norm"], h, inf_b, eps=cfg.norm_eps)
                out, conv_n, ssm_n = M2.mamba2_decode(
                    cfg, bp["m"], hn, subth_bb("m"), conv_s, ssm_s)
                conv_n = A.masked_state(active, conv_n, conv_s)
                ssm_n = A.masked_state(active, ssm_n, ssm_s)
                return (h + out, i + 1, sk_all, sv_all), (conv_n, ssm_n)

            (x, _, sk_all, sv_all), (conv_n, ssm_n) = jax.lax.scan(
                body, (x, jnp.int32(0), cache["shared_k"], cache["shared_v"]),
                (params["backbone"], cache["conv"], cache["ssm"]))
            new_cache["conv"], new_cache["ssm"] = conv_n, ssm_n
            new_cache["shared_k"], new_cache["shared_v"] = sk_all, sv_all
        elif "conv" in cache:  # pure mamba
            inf_b = jnp.full((b,), jnp.inf)

            def body(h, xs):
                bp, conv_s, ssm_s = xs
                names = [k for k in layout._by_name
                         if k.startswith("blocks/m/")]
                tm = {k[len("blocks/m/"):]: inf_b for k in names}
                hn = L.rmsnorm(bp["norm"], h, inf_b, eps=cfg.norm_eps)
                out, conv_n, ssm_n = M2.mamba2_decode(cfg, bp["m"], hn, tm,
                                                      conv_s, ssm_s)
                conv_n = A.masked_state(active, conv_n, conv_s)
                ssm_n = A.masked_state(active, ssm_n, ssm_s)
                return h + out, (conv_n, ssm_n)

            x, (conv_n, ssm_n) = jax.lax.scan(
                body, x, (params["blocks"], cache["conv"], cache["ssm"]))
            new_cache["conv"], new_cache["ssm"] = conv_n, ssm_n
        elif "wkv" in cache:  # rwkv
            inf_b = jnp.full((b,), jnp.inf)

            def mk(prefix):
                names = [k for k in layout._by_name
                         if k.startswith(prefix + "/")]
                return {k[len(prefix) + 1:]: inf_b for k in names}

            def body(h, xs):
                bp, tm_p, cm_p, st = xs
                hn = L.rmsnorm(bp["norm1"], h, inf_b, eps=cfg.norm_eps)
                att, tm_n, st_n = R6.time_mix_decode(
                    cfg, bp["tm"], hn, mk("blocks/tm"), x_prev=tm_p, state=st)
                h = h + att
                hn = L.rmsnorm(bp["norm2"], h, inf_b, eps=cfg.norm_eps)
                ff, cm_n = R6.channel_mix_decode(cfg, bp["cm"], hn,
                                                 mk("blocks/cm"), x_prev=cm_p)
                tm_n = A.masked_state(active, tm_n, tm_p)
                cm_n = A.masked_state(active, cm_n, cm_p)
                st_n = A.masked_state(active, st_n, st)
                return h + ff, (tm_n, cm_n, st_n)

            x, (tm_n, cm_n, st_n) = jax.lax.scan(
                body, x, (params["blocks"], cache["tm_prev"],
                          cache["cm_prev"], cache["wkv"]))
            new_cache["tm_prev"], new_cache["cm_prev"] = tm_n, cm_n
            new_cache["wkv"] = st_n
        else:  # attention stacks
            for name in ("dense_blocks", "moe_blocks"):
                if name not in spec:
                    continue
                moe_layer = name == "moe_blocks"
                run_prefix = name
                inf_b = jnp.full((b,), jnp.inf)

                def mk(sub):
                    names = [k for k in layout._by_name
                             if k.startswith(f"{run_prefix}/{sub}/")]
                    return {k[len(f"{run_prefix}/{sub}/"):]: inf_b
                            for k in names}

                # tenant-stacked adapters ride the layer scan as one more
                # xs leaf (None when single-tenant: an empty pytree)
                ls = lstack[name] if lstack is not None else None

                if cfg.attention_kind == "mla" and "pt" in cache:
                    def body(h, xs, mk=mk, moe_layer=moe_layer):
                        bp, lp, latpool = xs
                        hn = L.rmsnorm(bp["attn_norm"], h, inf_b,
                                       eps=cfg.norm_eps)
                        att, lat_n = A.mla_decode_paged(
                            cfg, bp["attn"], hn, mk("attn"), latpool,
                            cache["pt"], pos, active=active, lora=lp,
                            tenant=tenant)
                        h = h + att
                        hn = L.rmsnorm(bp["mlp_norm"], h, inf_b,
                                       eps=cfg.norm_eps)
                        if moe_layer:
                            moe_fn = (MOE.moe_block_grouped
                                      if cfg.moe_dispatch == "grouped"
                                      else MOE.moe_block)
                            y, _ = moe_fn(cfg, bp["moe"], hn, mk("moe"))
                        else:
                            y = L.swiglu(bp["mlp"], hn, mk("mlp"),
                                         f=cfg.d_ff)
                        return h + y, lat_n

                    x, lat_n = jax.lax.scan(
                        body, x, (params[name], ls,
                                  cache[f"{name}_latpool"]))
                    new_cache[f"{name}_latpool"] = lat_n
                elif cfg.attention_kind == "mla":
                    def body(h, xs, mk=mk, moe_layer=moe_layer):
                        bp, lp, ckv, krope = xs
                        hn = L.rmsnorm(bp["attn_norm"], h, inf_b,
                                       eps=cfg.norm_eps)
                        att, ckv_n, krope_n = A.mla_decode(
                            cfg, bp["attn"], hn, mk("attn"), ckv, krope, pos,
                            active=active, lora=lp, tenant=tenant)
                        h = h + att
                        hn = L.rmsnorm(bp["mlp_norm"], h, inf_b,
                                       eps=cfg.norm_eps)
                        if moe_layer:
                            moe_fn = (MOE.moe_block_grouped
                                      if cfg.moe_dispatch == "grouped"
                                      else MOE.moe_block)
                            y, _ = moe_fn(cfg, bp["moe"], hn, mk("moe"))
                        else:
                            y = L.swiglu(bp["mlp"], hn, mk("mlp"),
                                         f=cfg.d_ff)
                        return h + y, (ckv_n, krope_n)

                    x, (ckv_n, kr_n) = jax.lax.scan(
                        body, x, (params[name], ls, cache[f"{name}_ckv"],
                                  cache[f"{name}_krope"]))
                    new_cache[f"{name}_ckv"] = ckv_n
                    new_cache[f"{name}_krope"] = kr_n
                elif "pt" in cache:
                    def body(h, xs, mk=mk, moe_layer=moe_layer):
                        bp, lp, kpool, vpool = xs
                        hn = L.rmsnorm(bp["attn_norm"], h, inf_b,
                                       eps=cfg.norm_eps)
                        att, kp_n, vp_n = A.gqa_decode_paged(
                            cfg, bp["attn"], hn, mk("attn"), kpool, vpool,
                            cache["pt"], pos, active=active, lora=lp,
                            tenant=tenant)
                        h = h + att
                        hn = L.rmsnorm(bp["mlp_norm"], h, inf_b,
                                       eps=cfg.norm_eps)
                        if moe_layer:
                            moe_fn = (MOE.moe_block_grouped
                                      if cfg.moe_dispatch == "grouped"
                                      else MOE.moe_block)
                            y, _ = moe_fn(cfg, bp["moe"], hn, mk("moe"))
                        else:
                            y = L.swiglu(bp["mlp"], hn, mk("mlp"),
                                         f=cfg.d_ff)
                        return h + y, (kp_n, vp_n)

                    x, (kp_n, vp_n) = jax.lax.scan(
                        body, x, (params[name], ls, cache[f"{name}_kpool"],
                                  cache[f"{name}_vpool"]))
                    new_cache[f"{name}_kpool"] = kp_n
                    new_cache[f"{name}_vpool"] = vp_n
                else:
                    def body(h, xs, mk=mk, moe_layer=moe_layer):
                        bp, lp, ck, cv = xs
                        hn = L.rmsnorm(bp["attn_norm"], h, inf_b,
                                       eps=cfg.norm_eps)
                        att, ck_n, cv_n = A.gqa_decode(
                            cfg, bp["attn"], hn, mk("attn"), ck, cv, pos,
                            window=window, active=active, lora=lp,
                            tenant=tenant)
                        h = h + att
                        hn = L.rmsnorm(bp["mlp_norm"], h, inf_b,
                                       eps=cfg.norm_eps)
                        if moe_layer:
                            moe_fn = (MOE.moe_block_grouped
                                      if cfg.moe_dispatch == "grouped"
                                      else MOE.moe_block)
                            y, _ = moe_fn(cfg, bp["moe"], hn, mk("moe"))
                        else:
                            y = L.swiglu(bp["mlp"], hn, mk("mlp"),
                                         f=cfg.d_ff)
                        return h + y, (ck_n, cv_n)

                    x, (ck_n, cv_n) = jax.lax.scan(
                        body, x, (params[name], ls, cache[f"{name}_k"],
                                  cache[f"{name}_v"]))
                    new_cache[f"{name}_k"] = ck_n
                    new_cache[f"{name}_v"] = cv_n

        x = L.rmsnorm(params["final_norm"], x, th["final_norm"],
                      eps=cfg.norm_eps)
        logits = dpl.dp_linear(params["head"]["w"], None, x, th["head"])
        new_cache["pos"] = (pos + 1 if active is None
                            else pos + active.astype(jnp.int32))
        return logits[:, 0], new_cache

    return serve_step, init_cache, (init_paged_cache if paged_ok else None)


# ---------------------------------------------------------------------------
# Encoder-decoder (Whisper backbone; conv/mel frontend stubbed per task spec:
# `frames` are precomputed frame embeddings of shape (B, S_enc, D)).
# ---------------------------------------------------------------------------


def _build_encdec(cfg: ModelConfig) -> Model:
    d, v = cfg.d_model, cfg.vocab_size
    n_enc, n_dec = cfg.encoder_layers, cfg.num_layers

    spec = {
        "embed": {"w": P((v, d), init="embed", dtype=cfg.dtype)},
        "enc_blocks": _attn_block_spec(cfg, n_enc, moe_layer=False),
        "enc_norm": L.rmsnorm_spec(d, dtype=cfg.dtype),
        "dec_blocks": _attn_block_spec(cfg, n_dec, moe_layer=False,
                                       cross=True),
        "final_norm": L.rmsnorm_spec(d, dtype=cfg.dtype),
        "head": {"w": P((d, v), dtype=cfg.dtype)},
    }
    layout = GroupLayout(spec)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def encode(params, frames, th):
        s = frames.shape[1]
        x = frames.astype(cfg.dtype) + L.sinusoidal_positions(s, d).astype(
            cfg.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (frames.shape[0], s))
        run_th = subth(th, "enc_blocks")

        def body(h, xs):
            bp, bt = xs

            def blk(hh, bp_, bt_):
                out, _ = _apply_attn_block(cfg, bp_, hh, bt_, positions,
                                           causal=False, moe_layer=False)
                return out

            return _maybe_remat(blk, cfg)(h, bp, bt), None

        x, _ = jax.lax.scan(body, x, (params["enc_blocks"], run_th))
        return L.rmsnorm(params["enc_norm"], x, th["enc_norm"],
                         eps=cfg.norm_eps)

    def loss_fn(params, batch, th):
        frames, tokens = batch["frames"], batch["tokens"]
        bsz, t = tokens.shape
        enc_out = encode(params, frames, th)
        x = dpl.dp_embed(params["embed"]["w"], tokens, th["embed"])
        x = x + L.sinusoidal_positions(t, d).astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                     (bsz, t))
        run_th = subth(th, "dec_blocks")

        def body(h, xs):
            bp, bt = xs

            def blk(hh, bp_, bt_, enc_):
                out, _ = _apply_attn_block(cfg, bp_, hh, bt_, positions,
                                           causal=True, enc_out=enc_,
                                           moe_layer=False)
                return out

            return _maybe_remat(blk, cfg)(h, bp, bt, enc_out), None

        x, _ = jax.lax.scan(body, x, (params["dec_blocks"], run_th))
        x = L.rmsnorm(params["final_norm"], x, th["final_norm"],
                      eps=cfg.norm_eps)
        logits = dpl.dp_linear(params["head"]["w"], None, x, th["head"])
        return _per_example_ce(logits, batch["targets"])

    def init_cache(batch_size: int, cache_len: int):
        b = batch_size
        return {
            "pos": jnp.zeros((b,), jnp.int32),
            "dec_k": jnp.zeros((n_dec, b, cache_len, kvh, hd), cfg.dtype),
            "dec_v": jnp.zeros((n_dec, b, cache_len, kvh, hd), cfg.dtype),
            "cross_k": jnp.zeros((n_dec, b, cfg.encoder_seq_len, kvh, hd),
                                 cfg.dtype),
            "cross_v": jnp.zeros((n_dec, b, cfg.encoder_seq_len, kvh, hd),
                                 cfg.dtype),
        }

    def prefill_cross(params, frames, batch_size: int, cache_len: int):
        """Run the encoder and fill the cross-attention KV cache."""
        th = layout.pack_value(jnp.inf, batch_size)
        enc_out = encode(params, frames, th)
        cache = init_cache(batch_size, cache_len)
        inf_b = jnp.full((batch_size,), jnp.inf)
        s = enc_out.shape[1]

        def body(carry, bp):
            kv = L.linear(bp["cross"]["kv"], enc_out, inf_b)
            k = kv[..., : kvh * hd].reshape(batch_size, s, kvh, hd)
            vv = kv[..., kvh * hd:].reshape(batch_size, s, kvh, hd)
            return carry, (k, vv)

        _, (ck, cv) = jax.lax.scan(body, 0, params["dec_blocks"])
        cache["cross_k"], cache["cross_v"] = ck, cv
        return cache

    def serve_step(params, cache, batch):
        token = batch["token"]
        active = batch.get("active")  # (B,) slot write/retire mask
        b = token.shape[0]
        pos = cache["pos"]
        inf_b = jnp.full((b,), jnp.inf)
        th = layout.pack_value(jnp.inf, b)
        x = dpl.dp_embed(params["embed"]["w"], token, th["embed"])
        postab = L.sinusoidal_positions(cfg.max_seq_len, d).astype(x.dtype)
        x = x + postab[jnp.minimum(pos, cfg.max_seq_len - 1)][:, None, :]

        def mk(sub):
            names = [k for k in layout._by_name
                     if k.startswith(f"dec_blocks/{sub}/")]
            return {k[len(f"dec_blocks/{sub}/"):]: inf_b for k in names}

        def body(h, xs):
            bp, ck, cv, xk, xv = xs
            hn = L.rmsnorm(bp["attn_norm"], h, inf_b, eps=cfg.norm_eps)
            att, ck_n, cv_n = A.gqa_decode(cfg, bp["attn"], hn, mk("attn"),
                                           ck, cv, pos, active=active)
            h = h + att
            # cross attention over the precomputed encoder KV
            hn = L.rmsnorm(bp["cross_norm"], h, inf_b, eps=cfg.norm_eps)
            q = L.linear(bp["cross"]["qkv"], hn, inf_b).reshape(
                b, 1, cfg.num_heads, hd)
            qpos = pos[:, None]
            kpos = jnp.broadcast_to(
                jnp.arange(xk.shape[1], dtype=jnp.int32)[None],
                (b, xk.shape[1]))
            ca = A.attend(q, xk, xv, qpos, kpos, causal=False)
            ca = L.linear(bp["cross"]["o"],
                          ca.reshape(b, 1, cfg.num_heads * hd), inf_b)
            h = h + ca
            hn = L.rmsnorm(bp["mlp_norm"], h, inf_b, eps=cfg.norm_eps)
            y = L.swiglu(bp["mlp"], hn, mk("mlp"), f=cfg.d_ff)
            return h + y, (ck_n, cv_n)

        x, (ck_n, cv_n) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["dec_k"], cache["dec_v"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache)
        new_cache["dec_k"], new_cache["dec_v"] = ck_n, cv_n
        new_cache["pos"] = (pos + 1 if active is None
                            else pos + active.astype(jnp.int32))
        x = L.rmsnorm(params["final_norm"], x, th["final_norm"],
                      eps=cfg.norm_eps)
        logits = dpl.dp_linear(params["head"]["w"], None, x, th["head"])
        return logits[:, 0], new_cache

    def prefill_step(params, batch):
        frames, tokens = batch["frames"], batch["tokens"]
        bsz, t = tokens.shape
        th = layout.pack_value(jnp.inf, bsz)
        enc_out = encode(params, frames, th)
        x = dpl.dp_embed(params["embed"]["w"], tokens, th["embed"])
        x = x + L.sinusoidal_positions(t, d).astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                     (bsz, t))
        run_th = subth(th, "dec_blocks")

        def body(h, xs):
            bp, bt = xs

            def blk(hh, bp_, bt_, enc_):
                out, _ = _apply_attn_block(cfg, bp_, hh, bt_, positions,
                                           causal=True, enc_out=enc_,
                                           moe_layer=False)
                return out

            return _maybe_remat(blk, cfg)(h, bp, bt, enc_out), None

        x, _ = jax.lax.scan(body, x, (params["dec_blocks"], run_th))
        x = L.rmsnorm(params["final_norm"], x[:, -1:], th["final_norm"],
                      eps=cfg.norm_eps)
        return dpl.dp_linear(params["head"]["w"], None, x, th["head"])[:, 0]

    model = Model(cfg=cfg, spec=spec, layout=layout, loss_fn=loss_fn,
                  serve_step=serve_step, init_cache=init_cache,
                  num_params=_count(spec))
    model.prefill_cross = prefill_cross  # type: ignore[attr-defined]
    model.encode = encode  # type: ignore[attr-defined]
    model.prefill_step = prefill_step  # type: ignore[attr-defined]
    model.init_paged_cache = None  # type: ignore[attr-defined]
    model.cache_slot_axes = cache_slot_axes  # type: ignore[attr-defined]
    return model
