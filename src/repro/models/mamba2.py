"""Mamba2 (SSD) block — Zamba2's backbone layer.

Implements the chunked State-Space-Duality computation of Dao & Gu (2024):
within a chunk the output is an attention-like masked product; across chunks
a cheap recurrence carries the (H, P, N) state. Chunk size `ssm_chunk`
bounds the (Q, Q) decay matrices the same way a TPU kernel would tile VMEM.

DP mapping:
  * in_proj / out_proj: dp_linear (ghost norms) — the dominant params;
  * depthwise conv, dt_bias, A_log, D skip, gated-norm scale: small vector
    params via the broadcast trick (dp_broadcast) — per-example cotangents
    materialize at O(B x |param|), negligible here;
  * each of those is its own clipping group (per-layer clipping semantics).

Decode keeps (conv ring state, SSM state) per layer: O(1) in sequence
length — this is why Zamba2/RWKV run the long_500k shape natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dp_layers as dpl
from repro.core.spec import P
from repro.models import layers as L
from repro.models.config import ModelConfig


def dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_state, cfg.ssm_head_dim


def mamba2_spec(cfg: ModelConfig, *, stack: tuple[int, ...] = (),
                sensitivity_mult: float = 1.0) -> dict:
    d = cfg.d_model
    d_in, nh, n, p = dims(cfg)
    conv_ch = d_in + 2 * n  # x, B, C go through the depthwise conv
    s = len(stack)
    sm = sensitivity_mult
    return {
        "in_proj": L.linear_spec(d, 2 * d_in + 2 * n + nh, stack=stack,
                                 dtype=cfg.dtype, sensitivity_mult=sm),
        "conv_w": P(stack + (cfg.ssm_conv_kernel, conv_ch), init="normal",
                    scale=0.2, dtype=cfg.dtype, stack=s, sensitivity_mult=sm),
        "dt_bias": P(stack + (nh,), init="uniform", scale=0.5, dtype=cfg.dtype,
                     stack=s, sensitivity_mult=sm),
        "a_log": P(stack + (nh,), init="uniform", scale=0.5, dtype=cfg.dtype,
                   stack=s, sensitivity_mult=sm),
        "d_skip": P(stack + (nh,), init="ones", dtype=cfg.dtype, stack=s,
                    sensitivity_mult=sm),
        "norm": L.rmsnorm_spec(d_in, stack=stack, dtype=cfg.dtype),
        "out_proj": L.linear_spec(d_in, d, stack=stack, dtype=cfg.dtype,
                                  sensitivity_mult=sm),
    }


def _split_in(cfg, zxbcdt):
    d_in, nh, n, p = dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: 2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, w_b):
    """Depthwise causal conv via K shifted adds. xbc: (B, T, C); w_b: (B, K, C)."""
    k = w_b.shape[1]
    out = jnp.zeros_like(xbc)
    for i in range(k):
        shift = k - 1 - i
        seg = xbc[:, : xbc.shape[1] - shift] if shift else xbc
        seg = jnp.pad(seg, ((0, 0), (shift, 0), (0, 0)))
        out = out + seg * w_b[:, i][:, None, :]
    return out


def _ssd_chunked(xh, dt, a, B_, C_, chunk):
    """Chunked SSD. xh: (B,T,H,P); dt: (B,T,H); a: (B,H) (negative);
    B_, C_: (B,T,N). Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    b, t, h, p = xh.shape
    n = B_.shape[-1]
    q = min(chunk, t)
    nc = -(-t // q)
    pad = nc * q - t

    def padt(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))

    xh_, dt_, B__, C__ = padt(xh), padt(dt), padt(B_), padt(C_)
    adt = a[:, None, :] * dt_  # (B, T', H) log-decay per step (<=0)
    xdt = xh_ * dt_[..., None]

    def r(x, extra=()):  # (B, nc, q, ...)
        return x.reshape((b, nc, q) + x.shape[2:])

    adt_c, xdt_c = r(adt), r(xdt)
    B_c, C_c = r(B__), r(C__)
    cum = jnp.cumsum(adt_c, axis=2)  # (B, nc, q, H)
    total = cum[:, :, -1]  # (B, nc, H)

    # intra-chunk: Y[q_] = sum_{k<=q_} C_q·B_k exp(cum_q - cum_k) xdt_k
    ldiff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,q,q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(ldiff), 0.0)
    cb = jnp.einsum("bcqn,bckn->bcqk", C_c.astype(jnp.float32),
                    B_c.astype(jnp.float32))
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", cb, lmat,
                         xdt_c.astype(jnp.float32))

    # chunk states: S_c = sum_k exp(total - cum_k) B_k xdt_k^T
    decay_k = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", B_c.astype(jnp.float32),
                        decay_k, xdt_c.astype(jnp.float32))

    # inter-chunk recurrence
    def step(s_prev, inp):
        st_c, tot_c = inp  # (B,H,P,N), (B,H)
        s_new = s_prev * jnp.exp(tot_c)[:, :, None, None] + st_c
        return s_new, s_prev

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (B, nc, H, P, N): state BEFORE chunk

    # inter-chunk contribution: Y[q] += C_q · (exp(cum_q) * S_prev)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", C_c.astype(jnp.float32),
                         jnp.exp(cum), s_prevs)
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :t]
    return y.astype(xh.dtype), s_final


def mamba2_block(cfg: ModelConfig, params, x, th):
    """x: (B, T, D) -> (B, T, D)."""
    b, t, d = x.shape
    d_in, nh, n, p = dims(cfg)
    zxbcdt = L.linear(params["in_proj"], x, th["in_proj"])
    z, xbc, dt_raw = _split_in(cfg, zxbcdt)

    conv_w = dpl.dp_broadcast(params["conv_w"], th["conv_w"])  # (B, K, C)
    xbc = jax.nn.silu(_causal_conv(xbc, conv_w).astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :d_in]
    B_ = xbc[..., d_in: d_in + n]
    C_ = xbc[..., d_in + n:]

    dt_bias = dpl.dp_broadcast(params["dt_bias"], th["dt_bias"])  # (B, H)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + dt_bias[:, None, :])
    a_log = dpl.dp_broadcast(params["a_log"], th["a_log"])  # (B, H)
    a = -jnp.exp(a_log.astype(jnp.float32))

    xh = xs.reshape(b, t, nh, p)
    y, _ = _ssd_chunked(xh, dt, a, B_, C_, cfg.ssm_chunk)
    d_skip = dpl.dp_broadcast(params["d_skip"], th["d_skip"])  # (B, H)
    y = y + xh.astype(jnp.float32) * d_skip[:, None, :, None]
    y = y.reshape(b, t, d_in).astype(x.dtype)

    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = L.rmsnorm(params["norm"], y, th["norm"], eps=cfg.norm_eps)
    return L.linear(params["out_proj"], y, th["out_proj"])


def mamba2_decode(cfg: ModelConfig, params, x, th, conv_state, ssm_state):
    """One-token decode. x: (B, 1, D); conv_state: (B, K-1, C);
    ssm_state: (B, H, P, N)."""
    b = x.shape[0]
    d_in, nh, n, p = dims(cfg)
    zxbcdt = L.linear(params["in_proj"], x, th["in_proj"])
    z, xbc_new, dt_raw = _split_in(cfg, zxbcdt)  # (B,1,*)

    conv_w = dpl.dp_broadcast(params["conv_w"], th["conv_w"])  # (B, K, C)
    window = jnp.concatenate([conv_state, xbc_new[:, 0][:, None]], axis=1)  # (B,K,C)
    xbc = jnp.sum(window * conv_w, axis=1, keepdims=True)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    new_conv_state = window[:, 1:]

    xs = xbc[..., :d_in]
    B_ = xbc[..., d_in: d_in + n]
    C_ = xbc[..., d_in + n:]
    dt_bias = dpl.dp_broadcast(params["dt_bias"], th["dt_bias"])
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + dt_bias)  # (B, H)
    a_log = dpl.dp_broadcast(params["a_log"], th["a_log"])
    a = -jnp.exp(a_log.astype(jnp.float32))

    xh = xs[:, 0].reshape(b, nh, p).astype(jnp.float32)
    decay = jnp.exp(a * dt)  # (B, H)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, B_[:, 0].astype(jnp.float32), dt)
    new_ssm = ssm_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, C_[:, 0].astype(jnp.float32))
    d_skip = dpl.dp_broadcast(params["d_skip"], th["d_skip"])
    y = y + xh * d_skip[:, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = L.rmsnorm(params["norm"], y, th["norm"], eps=cfg.norm_eps)
    return (L.linear(params["out_proj"], y, th["out_proj"]),
            new_conv_state, new_ssm)
