"""Shared building blocks: norms, MLPs, rotary embeddings (incl. M-RoPE).

Every parametric op routes through the DP primitives so clipping is fused
into backprop; `th` is the encoded-threshold dict slice for this module
(see core.dp_layers). During inference the thresholds are +inf and the
custom VJPs are never exercised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dp_layers as dpl
from repro.core.spec import P


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int, *, stack: tuple[int, ...] = (), dtype=jnp.float32) -> dict:
    return {"s": P(stack + (d,), init="ones", dtype=dtype, stack=len(stack))}


def rmsnorm(params, x, th, *, eps: float = 1e-5):
    mu = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    xhat = (x.astype(jnp.float32) * jax.lax.rsqrt(mu + eps)).astype(x.dtype)
    return dpl.dp_scale(params["s"], xhat, th)


def head_rmsnorm(scale, x, *, eps: float = 1e-5):
    """Per-head q/k norm (Qwen3): non-DP param-free normalization + DP scale
    is applied by the caller via dp_scale on the flattened head dim."""
    mu = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    xhat = (x.astype(jnp.float32) * jax.lax.rsqrt(mu + eps)).astype(x.dtype)
    return xhat * scale


# ---------------------------------------------------------------------------
# Linear / MLP.
# ---------------------------------------------------------------------------


def linear_spec(din: int, dout: int, *, bias: bool = False,
                stack: tuple[int, ...] = (), dtype=jnp.float32,
                blocks: int = 1, sensitivity_mult: float = 1.0) -> dict:
    s = len(stack)
    out = {"w": P(stack + (din, dout), dtype=dtype, stack=s, blocks=blocks,
                  sensitivity_mult=sensitivity_mult)}
    if bias:
        # blocked layers split the bias into the same M column blocks so the
        # {w, b} pair stays one group per block (dp_linear_blocked semantics)
        out["b"] = P(stack + (dout,), init="zeros", dtype=dtype, stack=s,
                     blocks=blocks, sensitivity_mult=sensitivity_mult)
    return out


def linear(params, x, th):
    return dpl.dp_linear(params["w"], params.get("b"), x, th)


def linear_blocked(params, x, th):
    """th: (M, B) from the layout -> (B, M) for the primitive."""
    return dpl.dp_linear_blocked(params["w"], params.get("b"), x, th.T, "out")


def swiglu_spec(d: int, f: int, *, stack: tuple[int, ...] = (),
                dtype=jnp.float32) -> dict:
    return {
        "gate_up": linear_spec(d, 2 * f, stack=stack, dtype=dtype),
        "down": linear_spec(f, d, stack=stack, dtype=dtype),
    }


def swiglu(params, x, th_prefix, *, f: int):
    gu = linear(params["gate_up"], x, th_prefix["gate_up"])
    gate, up = gu[..., :f], gu[..., f:]
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return linear(params["down"], h, th_prefix["down"])


def gelu_mlp_spec(d: int, f: int, *, stack: tuple[int, ...] = (),
                  bias: bool = True, dtype=jnp.float32) -> dict:
    return {
        "up": linear_spec(d, f, bias=bias, stack=stack, dtype=dtype),
        "down": linear_spec(f, d, bias=bias, stack=stack, dtype=dtype),
    }


def gelu_mlp(params, x, th_prefix):
    h = linear(params["up"], x, th_prefix["up"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return linear(params["down"], h, th_prefix["down"])


# ---------------------------------------------------------------------------
# Rotary embeddings.
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


def apply_m_rope(x: jax.Array, positions3: jax.Array, theta: float,
                 sections: tuple[int, ...]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): positions3 (3, B, T) = (t, h, w) streams;
    the head_dim/2 frequency slots are split into `sections` per stream."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    assert sum(sections) == hd // 2, (sections, hd)
    # section id per frequency slot
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos = positions3.astype(jnp.float32)  # (3, B, T)
    pos_per_slot = pos[sec_ids]  # (hd/2, B, T) gathered per slot
    ang = jnp.moveaxis(pos_per_slot, 0, -1) * freqs  # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (B-agnostic table)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    out = jnp.zeros((seq_len, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
