"""RWKV-6 "Finch": linear-attention time-mix with data-dependent decay.

Time-mix recurrence per head (k-dim x v-dim state S):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with data-dependent decay w_t = exp(-exp(w_base + tanh(x A_w) B_w)) —
the defining Finch feature. Channel-mix is the usual squared-ReLU FFN with
token shift.

Training path offers two formulations (selectable, identical math):
  * 'scan'    — lax.scan over time (baseline; sequential length-T chain)
  * 'chunked' — block-parallel linear attention (intra-chunk masked products
    + inter-chunk state recurrence, SSD-style) — the TPU-friendly form used
    for the §Perf hillclimb.

DP mapping: r/k/v/g/o projections and the decay LoRA are dp_linear groups;
mix vectors, w_base, bonus u, and the group-norm scale use dp_broadcast /
dp_scale. Decode state is O(1) in sequence length (long_500k native).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dp_layers as dpl
from repro.core.spec import P
from repro.models import layers as L
from repro.models.config import ModelConfig

_DECAY_LORA = 64


def dims(cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    nh = d // hd
    return d, nh, hd


def rwkv6_spec(cfg: ModelConfig, *, stack: tuple[int, ...] = ()) -> dict:
    d, nh, hd = dims(cfg)
    s = len(stack)
    lora = _DECAY_LORA
    return {
        "tm": {  # time mix
            "mix": P(stack + (5, d), init="uniform", scale=0.5,
                     dtype=cfg.dtype, stack=s),  # r,k,v,g,w token-shift mixes
            "r": L.linear_spec(d, d, stack=stack, dtype=cfg.dtype),
            "k": L.linear_spec(d, d, stack=stack, dtype=cfg.dtype),
            "v": L.linear_spec(d, d, stack=stack, dtype=cfg.dtype),
            "g": L.linear_spec(d, d, stack=stack, dtype=cfg.dtype),
            "o": L.linear_spec(d, d, stack=stack, dtype=cfg.dtype),
            "w_base": P(stack + (d,), init="uniform", scale=1.0,
                        dtype=cfg.dtype, stack=s),
            "w_lora_a": L.linear_spec(d, lora, stack=stack, dtype=cfg.dtype),
            "w_lora_b": L.linear_spec(lora, d, stack=stack, dtype=cfg.dtype),
            "u": P(stack + (nh, hd), init="uniform", scale=0.5,
                   dtype=cfg.dtype, stack=s),  # per-head bonus
            "ln": L.rmsnorm_spec(d, stack=stack, dtype=cfg.dtype),
        },
        "cm": {  # channel mix
            "mix": P(stack + (2, d), init="uniform", scale=0.5,
                     dtype=cfg.dtype, stack=s),
            "k": L.linear_spec(d, cfg.d_ff, stack=stack, dtype=cfg.dtype),
            "v": L.linear_spec(cfg.d_ff, d, stack=stack, dtype=cfg.dtype),
            "r": L.linear_spec(d, d, stack=stack, dtype=cfg.dtype),
        },
    }


def _token_shift(x, x_prev_last):
    """shifted(x)[t] = x[t-1]; position 0 takes x_prev_last (B, 1, D)."""
    return jnp.concatenate([x_prev_last, x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """Sequential recurrence. r,k,v: (B,T,H,hd); w: (B,T,H,hd) decay in (0,1);
    u: (B,H,hd); s0: (B,H,hd,hd). Returns (o (B,T,H,hd), sT)."""

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        o = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    sT, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), sT


def _wkv_chunked(r, k, v, w, u, s0, chunk: int):
    """Block-parallel form. Same contract as _wkv_scan.

    Within a chunk:  o_t = r_t S_prev W(<t) + sum_{j<t} r_t diag(W(j+1..t-1))
    ... expressed with cumulative log-decay products; across chunks the
    (hd, hd) state recurs once per chunk.
    """
    b, t, h, d = r.shape
    q = min(chunk, t)
    nc = -(-t // q)
    pad = nc * q - t

    def padt(x, val=0.0):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=val)

    r_, k_, v_ = padt(r), padt(k), padt(v)
    w_ = padt(w, 1.0)
    logw = jnp.log(jnp.clip(w_.astype(jnp.float32), 1e-12, 1.0))

    def rs(x):
        return x.reshape(b, nc, q, h, d)

    rc, kc, vc, lw = rs(r_), rs(k_), rs(v_), rs(logw)
    # cumulative decay within chunk: P_t = prod_{j<=t} w_j  (inclusive)
    cum = jnp.cumsum(lw, axis=2)  # (B,nc,q,H,D)
    # attention-like intra weights: A[t,j] = r_t · (P_{t-1}/P_j) k_j for j < t
    #                              + r_t · (u k_t) for j == t
    # Factorized as (r_t P_{t-1}/P_ref) · (k_j P_ref/P_j) with the chunk-median
    # reference so both exponents are bounded by half a chunk's log-decay
    # (the unshifted form overflows f32 for strong decay); exponents are
    # additionally clamped at ±70 — pairs hitting the clamp have true decay
    # factors below e-70 and contribute nothing.
    ref = cum[:, :, q // 2][:, :, None]  # (B,nc,1,H,D)
    rt_scaled = rc.astype(jnp.float32) * jnp.exp(
        jnp.clip(cum - lw - ref, -70.0, 70.0))  # ~ r_t * P_{t-1}/P_ref
    kj_scaled = kc.astype(jnp.float32) * jnp.exp(
        jnp.clip(ref - cum, -70.0, 70.0))  # ~ k_j * P_ref/P_j
    scores = jnp.einsum("bcthd,bcjhd->bcthj", rt_scaled, kj_scaled)
    mask = jnp.tril(jnp.ones((q, q), bool), k=-1)  # strictly lower
    scores = jnp.where(mask[None, None, :, None, :], scores, 0.0)
    diag = jnp.einsum("bcthd,bhd,bcthd->bcth", rc.astype(jnp.float32),
                      u.astype(jnp.float32), kc.astype(jnp.float32))
    o_intra = (jnp.einsum("bcthj,bcjhd->bcthd", scores, vc.astype(jnp.float32))
               + diag[..., None] * vc.astype(jnp.float32))

    # chunk state contribution: S_c = sum_j (P_total/P_j) k_j v_j^T
    total = cum[:, :, -1]  # (B,nc,H,D)
    decay_j = jnp.exp(total[:, :, None] - cum)  # (B,nc,q,H,D)
    states = jnp.einsum("bcjhk,bcjhv->bchkv",
                        (kc.astype(jnp.float32) * decay_j),
                        vc.astype(jnp.float32))

    def step(s_prev, inp):
        st, tot = inp
        s_new = s_prev * jnp.exp(tot)[..., :, None] + st
        return s_new, s_prev

    sT, s_prevs = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (B,nc,H,K,V)

    # inter-chunk: r_t P_{t-1} S_prev = rt_scaled · (exp(ref) ⊙_k S_prev)
    s_prevs_scaled = s_prevs * jnp.exp(ref[:, :, 0])[..., None]  # decay on K
    o_inter = jnp.einsum("bcthk,bchkv->bcthv", rt_scaled, s_prevs_scaled)
    o = (o_intra + o_inter).reshape(b, nc * q, h, d)[:, :t]
    return o.astype(r.dtype), sT


def _ddlerp(x, xs, mix):
    """mix in [0,1]-ish: x + mix * (xs - x); mix: (B, D) broadcast."""
    return x + mix[:, None, :] * (xs - x)


def time_mix(cfg: ModelConfig, params, x, th, *, x_prev, state,
             formulation: str = "scan", chunk: int = 128):
    """x: (B,T,D). x_prev: (B,1,D) last token of previous segment (zeros at
    start). state: (B,H,hd,hd). Returns (out, new_x_prev, new_state)."""
    d, nh, hd = dims(cfg)
    b, t, _ = x.shape
    p = params
    xs = _token_shift(x, x_prev)
    mix = dpl.dp_broadcast(p["mix"], th["mix"])  # (B, 5, D)
    xr = _ddlerp(x, xs, mix[:, 0])
    xk = _ddlerp(x, xs, mix[:, 1])
    xv = _ddlerp(x, xs, mix[:, 2])
    xg = _ddlerp(x, xs, mix[:, 3])
    xw = _ddlerp(x, xs, mix[:, 4])

    r = L.linear(p["r"], xr, th["r"]).reshape(b, t, nh, hd)
    k = L.linear(p["k"], xk, th["k"]).reshape(b, t, nh, hd)
    v = L.linear(p["v"], xv, th["v"]).reshape(b, t, nh, hd)
    g = L.linear(p["g"], xg, th["g"])

    w_base = dpl.dp_broadcast(p["w_base"], th["w_base"])  # (B, D)
    dd = L.linear(p["w_lora_b"],
                  jnp.tanh(L.linear(p["w_lora_a"], xw, th["w_lora_a"])),
                  th["w_lora_b"])  # (B, T, D)
    w = jnp.exp(-jnp.exp(w_base[:, None].astype(jnp.float32)
                         + dd.astype(jnp.float32)))  # (0,1)
    w = w.reshape(b, t, nh, hd)

    u = dpl.dp_broadcast(p["u"], th["u"])  # (B, H, hd)
    if formulation == "chunked":
        o, sT = _wkv_chunked(r, k, v, w.astype(jnp.float32), u, state, chunk)
    else:
        o, sT = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), w, u, state)
    o = o.reshape(b, t, d)
    o = L.rmsnorm(p["ln"], o.astype(x.dtype), th["ln"], eps=cfg.norm_eps)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(o.dtype)
    out = L.linear(p["o"], o, th["o"])
    return out, x[:, -1:], sT


def channel_mix(cfg: ModelConfig, params, x, th, *, x_prev):
    p = params
    xs = _token_shift(x, x_prev)
    mix = dpl.dp_broadcast(p["mix"], th["mix"])  # (B, 2, D)
    xk = _ddlerp(x, xs, mix[:, 0])
    xr = _ddlerp(x, xs, mix[:, 1])
    k = L.linear(p["k"], xk, th["k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = L.linear(p["v"], k, th["v"])
    rgate = jax.nn.sigmoid(L.linear(p["r"], xr, th["r"]).astype(jnp.float32))
    return (rgate * kv.astype(jnp.float32)).astype(x.dtype), x[:, -1:]


def time_mix_decode(cfg: ModelConfig, params, x, th, *, x_prev, state):
    """Single-token decode: x (B,1,D), x_prev (B,1,D), state (B,H,hd,hd)."""
    d, nh, hd = dims(cfg)
    b = x.shape[0]
    p = params
    xs = x_prev
    mix = dpl.dp_broadcast(p["mix"], th["mix"])
    xr = _ddlerp(x, xs, mix[:, 0])
    xk = _ddlerp(x, xs, mix[:, 1])
    xv = _ddlerp(x, xs, mix[:, 2])
    xg = _ddlerp(x, xs, mix[:, 3])
    xw = _ddlerp(x, xs, mix[:, 4])
    r = L.linear(p["r"], xr, th["r"]).reshape(b, nh, hd).astype(jnp.float32)
    k = L.linear(p["k"], xk, th["k"]).reshape(b, nh, hd).astype(jnp.float32)
    v = L.linear(p["v"], xv, th["v"]).reshape(b, nh, hd).astype(jnp.float32)
    g = L.linear(p["g"], xg, th["g"])
    w_base = dpl.dp_broadcast(p["w_base"], th["w_base"])
    dd = L.linear(p["w_lora_b"],
                  jnp.tanh(L.linear(p["w_lora_a"], xw, th["w_lora_a"])),
                  th["w_lora_b"])
    w = jnp.exp(-jnp.exp(w_base[:, None].astype(jnp.float32)
                         + dd.astype(jnp.float32))).reshape(b, nh, hd)
    u = dpl.dp_broadcast(p["u"], th["u"]).astype(jnp.float32)
    kv = k[..., :, None] * v[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., :, None] * kv)
    new_state = w[..., :, None] * state + kv
    o = o.reshape(b, 1, d)
    o = L.rmsnorm(p["ln"], o.astype(x.dtype), th["ln"], eps=cfg.norm_eps)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(o.dtype)
    return L.linear(p["o"], o, th["o"]), x, new_state


def channel_mix_decode(cfg: ModelConfig, params, x, th, *, x_prev):
    p = params
    mix = dpl.dp_broadcast(p["mix"], th["mix"])
    xk = _ddlerp(x, x_prev, mix[:, 0])
    xr = _ddlerp(x, x_prev, mix[:, 1])
    k = L.linear(p["k"], xk, th["k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = L.linear(p["v"], k, th["v"])
    rgate = jax.nn.sigmoid(L.linear(p["r"], xr, th["r"]).astype(jnp.float32))
    return (rgate * kv.astype(jnp.float32)).astype(x.dtype), x
