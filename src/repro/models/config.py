"""Model configuration covering every assigned architecture family.

One dataclass drives the whole zoo: dense decoder LMs (llama/qwen style),
MoE (token-choice top-k, shared experts, MLA), SSM (Mamba2, RWKV6), hybrids
(Zamba2), encoder-decoder audio backbones (Whisper) and M-RoPE VLM decoders
(Qwen2-VL). `repro.configs.<id>` instantiates the exact assigned numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    # ----- attention -----
    num_heads: int = 0  # 0 => attention-free (pure SSM)
    num_kv_heads: int = 0
    head_dim: int | None = None  # default d_model // num_heads
    qk_norm: bool = False  # per-head RMSNorm on q,k (Qwen3)
    qkv_bias: bool = False  # Qwen1.5
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # window size; None = full attention
    attention_kind: str = "gqa"  # gqa | mla | none
    # ----- MLA (DeepSeek-V3) -----
    q_lora_rank: int = 0  # 0 => direct q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # ----- MoE -----
    num_experts: int = 0  # 0 => dense MLP
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (d_ff used for dense layers)
    first_k_dense: int = 0  # leading dense layers (DeepSeek-V3 style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_dispatch: str = "flat"  # flat (global-capacity scatter, exact
    #   masked-gram DP norms) | grouped (per-(example, expert) buffers:
    #   block-diagonal DP norms, ~B x cheaper — §Perf optimization)
    # ----- SSM: Mamba2 -----
    ssm_state: int = 0  # d_state (0 => no mamba layers)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256
    # ----- SSM: RWKV6 -----
    rwkv_head_dim: int = 64
    # ----- hybrid layout -----
    layer_pattern: str | None = None  # e.g. "mmmmma": m=mamba2, a=attn, r=rwkv
    shared_attention: bool = False  # Zamba2: ONE attn block shared across sites
    shared_every: int = 6  # apply the shared block before every k-th layer
    # ----- encoder-decoder (Whisper) -----
    encoder_layers: int = 0
    encoder_seq_len: int = 1500  # stub frame-embedding length (whisper-medium)
    # ----- VLM (Qwen2-VL) -----
    m_rope: bool = False
    m_rope_sections: tuple[int, ...] = (16, 24, 24)  # (t, h, w) of head_dim/2
    # ----- MTP (DeepSeek-V3 multi-token prediction) -----
    mtp_depth: int = 0
    # ----- misc -----
    max_seq_len: int = 131_072
    tie_embeddings: bool = False
    dtype: Any = jnp.float32
    norm_eps: float = 1e-5
    # per-shard clipping layout (per-device analogue): M column blocks
    dp_blocks: int = 1
    # DP LoRA (the paper's large-model recipe): 0 = full fine-tune
    lora_rank: int = 0
    lora_alpha: float = 16.0
    # rematerialize layer-scan bodies (activation checkpointing): without it
    # the L-layer scan saves every block's residuals and peak memory is
    # O(L x activations); with it, O(1 block) at ~1.33x flops.
    remat: bool = True

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def has_attention(self) -> bool:
        return self.attention_kind != "none" and self.num_heads > 0

    def pattern(self) -> str:
        """Per-layer block kinds, length num_layers."""
        if self.layer_pattern is None:
            base = "a" if self.has_attention else ("r" if self.ssm_state == 0 else "m")
            return base * self.num_layers
        pat = (self.layer_pattern * (self.num_layers // len(self.layer_pattern) + 1))
        return pat[: self.num_layers]

    def validate(self) -> None:
        if self.has_attention:
            assert self.num_kv_heads > 0 and self.num_heads % self.num_kv_heads == 0
        if self.num_experts:
            assert self.num_experts_per_tok > 0
            assert self.moe_d_ff > 0
        if self.arch_type == "audio":
            assert self.encoder_layers > 0

    def param_count(self) -> int:
        """Exact dense-equivalent parameter count from the spec (filled in by
        models.transformer at build time); here: rough analytic estimate."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        per_layer = 4 * d * d + 3 * d * f
        return l * per_layer + 2 * v * d


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned workload shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
