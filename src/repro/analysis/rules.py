"""HLO rules engine: named, severity-tagged checks over post-SPMD HLO.

Generalizes the ad-hoc assertions that grew around `analysis.hlo`
(backward-pass counting in tests/test_bk.py, model-axis norm-collective
filtering in tests/sharded_checks.py, donation aliasing checked nowhere
— the PR-7 gap) into one rule catalog with machine-readable findings.

Each rule takes the compiled HLO text plus a `StepExpectation` describing
what the config CLAIMS (mode, execution, mesh) and returns findings; the
engine never asserts — `repro.launch.audit` (and CI) decide that any
ERROR finding fails the run.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.findings import ERROR, INFO, WARNING, Finding
from repro.analysis.hlo import (backward_passes, classify_collectives,
                                dynamic_shape_instrs, entry_aliases,
                                filter_model_norm_rows)

# rule id -> (severity when violated, invariant)
RULES = {
    "JAXPR-CLIP-PATH": (
        ERROR,
        "every batch-derived dataflow path into a trainable parameter's "
        "update passes a dp_clip_factor multiply (per-example clipping is "
        "structurally unskippable)"),
    "JAXPR-NOISE-ONCE": (
        ERROR,
        "exactly one Gaussian noise draw joins each trainable leaf's "
        "summed clipped gradient before the optimizer consumes it"),
    "JAXPR-KEY-LINEAGE": (
        ERROR,
        "every noise key is folded from a static per-leaf hash and no two "
        "leaves fold to the same key signature (PR-6 bug class)"),
    "HLO-COLL-LEAK": (
        ERROR,
        "no model-axis collective carries per-example norm data, except "
        "ghost_flat's single whitelisted flat_norm_psum (paper Sec. 4 "
        "communication contract)"),
    "HLO-BWD-COUNT": (
        ERROR,
        "the compiled step traverses the layer stack backward exactly once "
        "under execution=bk (twice under the twopass reference)"),
    "HLO-DONATION": (
        ERROR,
        "every params/opt_state/dp_state leaf is input_output_aliased in "
        "the entry computation — donation actually took (PR-7 bug class)"),
    "HLO-SHAPE-STABLE": (
        ERROR,
        "no instruction carries a bounded-dynamic (data-dependent) shape; "
        "compiled programs are traffic-independent"),
}


@dataclasses.dataclass(frozen=True)
class StepExpectation:
    """What the config under audit claims about its compiled step."""

    mode: str                 # base clipping mode (no _twopass suffix)
    execution: str = "bk"     # bk | twopass
    sharded: bool = False
    layer_trip: int | None = None     # scan trip count of the layer stack
    donated_leaves: int | None = None  # leaves of (params, opt, dp_state)
    model_axis: str = "model"
    # model-axis norm collectives whose site contains one of these
    # substrings are the mode's documented, intentional traffic
    norm_whitelist: tuple = ("flat_norm_psum",)


def _expected_backward(expect: StepExpectation) -> int | None:
    if expect.mode in ("ghost_flat", "per_group"):
        return 2 if expect.execution == "twopass" else 1
    if expect.mode in ("per_layer", "non_private"):
        return 1
    return None  # naive_flat: jacrev does not lower to a transposed scan


def rule_collective_leak(text: str, expect: StepExpectation, mesh=None
                         ) -> list[Finding]:
    if not expect.sharded or mesh is None:
        return []
    rows = classify_collectives(text, mesh)
    norm_rows = filter_model_norm_rows(rows, model_axis=expect.model_axis)
    allowed = (expect.norm_whitelist if expect.mode == "ghost_flat" else ())
    findings = []
    whitelisted = 0
    for r in norm_rows:
        if any(w in r["site"] for w in allowed):
            whitelisted += 1
            continue
        findings.append(Finding(
            "HLO-COLL-LEAK", ERROR,
            f"{r['kind']} over axes {'+'.join(r['axes'])} carries "
            f"per-example norm data ({int(r['count'])}x, "
            f"{int(r['bytes'])} bytes) outside the whitelist",
            r["site"]))
    if expect.mode == "ghost_flat" and whitelisted == 0:
        findings.append(Finding(
            "HLO-COLL-LEAK", WARNING,
            "ghost_flat compiled WITHOUT its flat_norm_psum model-axis "
            "collective — program does not match the claimed structure",
            "flat_norm_psum"))
    if not findings:
        findings.append(Finding(
            "HLO-COLL-LEAK", INFO,
            f"{len(norm_rows)} model-axis norm collective site(s), all "
            f"whitelisted" if norm_rows else
            "zero model-axis norm collectives", "collectives"))
    return findings


def rule_backward_count(text: str, expect: StepExpectation) -> list[Finding]:
    if expect.layer_trip is None or expect.layer_trip < 2:
        return []
    want = _expected_backward(expect)
    got = backward_passes(text, expect.layer_trip)
    if want is None:
        return [Finding("HLO-BWD-COUNT", INFO,
                        f"measured {got} transposed layer loops "
                        f"(no expectation for mode={expect.mode})",
                        "layer scan")]
    if got != want:
        return [Finding(
            "HLO-BWD-COUNT", ERROR,
            f"{got} backward layer-stack traversals compiled, expected "
            f"{want} for mode={expect.mode} execution={expect.execution}",
            "layer scan")]
    return [Finding("HLO-BWD-COUNT", INFO,
                    f"{got} backward traversal(s), as claimed by "
                    f"execution={expect.execution}", "layer scan")]


def rule_donation(text: str, expect: StepExpectation) -> list[Finding]:
    if expect.donated_leaves is None:
        return []
    aliases = entry_aliases(text)
    aliased_params = {a["param"] for a in aliases}
    want = expect.donated_leaves
    if len(aliased_params) >= want:
        return [Finding("HLO-DONATION", INFO,
                        f"{len(aliased_params)} entry parameters aliased "
                        f"(>= {want} state leaves)", "entry")]
    # donated argnums come first in the flattened entry signature, so the
    # un-aliased state leaves are the missing low parameter numbers
    missing = sorted(set(range(want)) - aliased_params)[:8]
    return [Finding(
        "HLO-DONATION", ERROR,
        f"only {len(aliased_params)}/{want} state leaves are "
        f"input_output_aliased; donation was stripped or ignored "
        f"(first missing params: {missing})", "entry")]


def rule_shape_stability(text: str, expect: StepExpectation) -> list[Finding]:
    dyn = dynamic_shape_instrs(text)
    if not dyn:
        return [Finding("HLO-SHAPE-STABLE", INFO,
                        "no bounded-dynamic shapes", "module")]
    return [Finding("HLO-SHAPE-STABLE", ERROR,
                    f"bounded-dynamic shape {shape}", name)
            for name, shape in dyn[:8]]


def run_hlo_rules(text: str, expect: StepExpectation, mesh=None
                  ) -> list[Finding]:
    """All HLO rules over one compiled step. INFO findings record the
    positive evidence; ERROR findings are the CI failures."""
    out: list[Finding] = []
    out.extend(rule_collective_leak(text, expect, mesh))
    out.extend(rule_backward_count(text, expect))
    out.extend(rule_donation(text, expect))
    out.extend(rule_shape_stability(text, expect))
    return out
