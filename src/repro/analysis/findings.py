"""Finding/severity vocabulary shared by both static-audit passes.

A finding is one rule firing at one location. ERROR findings are the CI
contract: `python -m repro.launch.audit` exits non-zero iff any config in
the matrix produces one. WARNING marks structure the auditor could not
prove either way (it should be investigated, not gate CI); INFO records
positive evidence (e.g. the measured backward-pass count) so AUDIT.json
documents what WAS verified, not just what failed.
"""
from __future__ import annotations

import dataclasses

ERROR = "ERROR"
WARNING = "WARNING"
INFO = "INFO"
SEVERITIES = (ERROR, WARNING, INFO)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule firing: (rule id, severity, message, location)."""

    rule: str       # e.g. "JAXPR-CLIP-PATH", "HLO-DONATION"
    severity: str   # ERROR | WARNING | INFO
    message: str
    location: str = ""  # param leaf path / HLO site / instruction name

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "message": self.message, "location": self.location}

    def __str__(self) -> str:
        loc = f" @ {self.location}" if self.location else ""
        return f"[{self.severity}] {self.rule}{loc}: {self.message}"


def errors(findings: list[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity == ERROR]


def worst_severity(findings: list[Finding]) -> str | None:
    for sev in SEVERITIES:  # ordered worst-first
        if any(f.severity == sev for f in findings):
            return sev
    return None
