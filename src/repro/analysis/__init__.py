"""Static DP-safety analysis: jaxpr taint + HLO rules.

Two cooperating passes over the compiled artifacts of
`repro.core.dp_sgd.make_dp_train_step`:

  * `jaxpr_taint` — walks the closed jaxpr and proves, per trainable
    leaf, that every batch-derived dataflow path is clip-factor-scaled
    before the parameter-update sink and that exactly one PRNG noise
    draw (with a leaf-unique key lineage) reaches it.
  * `rules` — named, severity-tagged rules over the post-SPMD HLO text
    (collective leaks across the model axis, backward-pass counts,
    donation coverage, shape stability), built on the `hlo` parser that
    previously lived at `repro.launch.hlo_analysis`.

`repro.launch.audit` drives both over the clipping x execution x mesh
matrix and emits benchmarks/AUDIT.json.
"""
from repro.analysis.findings import (ERROR, INFO, WARNING, Finding, errors,
                                     worst_severity)

__all__ = ["ERROR", "INFO", "WARNING", "Finding", "errors", "worst_severity"]
