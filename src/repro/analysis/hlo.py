"""Trip-count-aware cost analysis over post-SPMD HLO text.

XLA's built-in `compiled.cost_analysis()` visits every instruction ONCE, so
`lax.scan`/`while` bodies (our layer stacks, microbatch loops, flash
attention blocks) are undercounted by their trip counts — useless for a
roofline. This module re-derives per-device totals from the optimized HLO
text, multiplying loop bodies by their `known_trip_count` annotations:

  flops        — dot ops: 2 * |result| * K (contraction size from the lhs
                 symbol table); elementwise ops: |result|
  bytes        — per instruction: result + operand bytes; fusions count only
                 their boundary (internals never touch HBM)
  collectives  — per kind: count and result bytes, loop-multiplied

Conditionals take the max-flops branch (one branch executes per visit).
This intentionally mirrors HloCostAnalysis semantics where they are sound
and fixes them where they are not (loops).
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OPCODE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")


def _parse_instr_line(line: str):
    """'%name = SHAPE opcode(operands), attrs' -> (name, shape, op, rest).

    Robust to tuple shapes with embedded '/*index=N*/' comments and layout
    annotations (which defeat naive '[^=]*' shape groups)."""
    ls = line.strip()
    if not (ls.startswith("%") or ls.startswith("ROOT ")):
        return None
    if " = " not in ls:
        return None
    lhs, rhs = ls.split(" = ", 1)
    name = lhs.replace("ROOT", "").strip().lstrip("%")
    m = _OPCODE.search(rhs)
    if not m:
        return None
    return name, rhs[: m.start()].strip(), m.group(1), rhs[m.end():]

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}
_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "cosine", "sine", "logistic", "expm1", "log1p", "erf",
                   "atan2", "cbrt"}


def _dims(shape_str: str) -> list[tuple[str, list[int]]]:
    """All (dtype, dims) element shapes in a possibly-tuple shape string."""
    return [(m.group(1), [int(d) for d in m.group(2).split(",") if d])
            for m in _SHAPE_RE.finditer(shape_str)]


def _nelems(dims: list[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _shape_bytes(shape_str: str) -> int:
    return sum(_nelems(d) * _DTYPE_BYTES.get(dt, 4)
               for dt, d in _dims(shape_str))


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.transcendentals += mult * other.transcendentals
        for k, v in other.collectives.items():
            slot = self.collectives.setdefault(k, {"count": 0.0, "bytes": 0.0})
            slot["count"] += mult * v["count"]
            slot["bytes"] += mult * v["bytes"]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str  # operand list + attributes (the remainder of the line)


def parse_module(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in text.splitlines():
        if line and not line[0].isspace() and "->" in line and "{" in line:
            m = _COMP_HDR.match(line)
            if m:
                cur = []
                comps[m.group(1)] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            cur.append(Instr(*parsed))
    return comps


_CALLED = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUEFALSE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[^\d]*(\d+)')
_OPERANDS = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = self._find_entry(text)
        self._memo: dict[str, Totals] = {}

    @staticmethod
    def _find_entry(text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR.match(line)
                if m:
                    return m.group(1)
        raise ValueError("no ENTRY computation found")

    def analyze(self) -> Totals:
        return self._comp(self.entry)

    def _comp(self, name: str) -> Totals:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Totals()  # cycle guard
        instrs = self.comps.get(name, [])
        shapes = {i.name: i.shape for i in instrs}
        t = Totals()
        for ins in instrs:
            self._instr(ins, shapes, t)
        self._memo[name] = t
        return t

    def _operand_shapes(self, ins: Instr, shapes: dict[str, str]
                        ) -> list[str]:
        # operands are the leading %refs before the closing paren of the
        # operand list; attribute refs come after "), " — take refs up to
        # the first ")" at depth 0
        depth, end = 1, len(ins.rest)
        for idx, ch in enumerate(ins.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = idx
                    break
        ops = _OPERANDS.findall(ins.rest[:end])
        return [shapes.get(o, "") for o in ops]

    def _instr(self, ins: Instr, shapes: dict[str, str], t: Totals) -> None:
        op = ins.op
        if op in _SKIP_OPS:
            return
        rbytes = _shape_bytes(ins.shape)
        if op == "while":
            body = cond = None
            bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
            cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            trip_m = _TRIP.search(ins.rest)
            trip = int(trip_m.group(1)) if trip_m else 1
            if bm:
                t.add(self._comp(bm.group(1)), trip)
            if cm:
                t.add(self._comp(cm.group(1)), trip)
            return
        if op == "conditional":
            branches = []
            bm = _BRANCHES.search(ins.rest)
            if bm:
                branches = [b.strip().lstrip("%")
                            for b in bm.group(1).split(",")]
            else:
                branches = _TRUEFALSE.findall(ins.rest)
            if branches:
                subs = [self._comp(b) for b in branches]
                best = max(subs, key=lambda s: s.flops)
                t.add(best)
            return
        if op in ("call", "async-start"):
            cm = _CALLED.search(ins.rest)
            if cm:
                t.add(self._comp(cm.group(1)))
            return
        if op == "fusion":
            cm = _CALLED.search(ins.rest)
            if cm:
                sub = self._comp(cm.group(1))
                t.flops += sub.flops
                t.transcendentals += sub.transcendentals
                for k, v in sub.collectives.items():
                    slot = t.collectives.setdefault(
                        k, {"count": 0.0, "bytes": 0.0})
                    slot["count"] += v["count"]
                    slot["bytes"] += v["bytes"]
            t.bytes += rbytes + sum(_shape_bytes(s)
                                    for s in self._operand_shapes(ins, shapes))
            return
        if op in COLLECTIVE_OPS:
            base = op.replace("-start", "")
            slot = t.collectives.setdefault(base, {"count": 0.0, "bytes": 0.0})
            slot["count"] += 1
            slot["bytes"] += rbytes
            t.bytes += rbytes
            return
        opnd_bytes = sum(_shape_bytes(s)
                         for s in self._operand_shapes(ins, shapes))
        t.bytes += rbytes + opnd_bytes
        if op in ("dot", "dot-general"):
            opshapes = self._operand_shapes(ins, shapes)
            k = 1
            if opshapes and opshapes[0]:
                lhs_dims = _dims(opshapes[0])[0][1]
                cm = _LHS_CONTRACT.search(ins.rest)
                if cm and cm.group(1):
                    for ci in cm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
            nres = sum(_nelems(d) for _, d in _dims(ins.shape))
            t.flops += 2.0 * nres * k
            return
        if op == "convolution":
            # not used by our models; approximate as elementwise
            t.flops += sum(_nelems(d) for _, d in _dims(ins.shape))
            return
        if op == "custom-call":
            cm = _CALLED.search(ins.rest)
            if cm and cm.group(1) in self.comps:
                t.add(self._comp(cm.group(1)))
            return
        # elementwise / reduce / everything else: 1 flop per output element
        nres = sum(_nelems(d) for _, d in _dims(ins.shape))
        t.flops += nres
        if op in _TRANSCENDENTAL:
            t.transcendentals += nres


def analyze_hlo(text: str) -> Totals:
    return HloAnalyzer(text).analyze()


# ---------------------------------------------------------------------------
# Backward-pass counting: assert (don't assume) the BK engine's win.
# ---------------------------------------------------------------------------


def _reachable(an: HloAnalyzer) -> set:
    """Computations reachable from ENTRY (skips dead leftovers)."""
    seen: set[str] = set()
    stack = [an.entry]
    while stack:
        comp = stack.pop()
        if comp in seen:
            continue
        seen.add(comp)
        for ins in an.comps.get(comp, []):
            for m in _CALLED.finditer(ins.rest):
                if m.group(1) in an.comps:
                    stack.append(m.group(1))
            bm = _BRANCHES.search(ins.rest)
            if bm:
                stack.extend(b.strip().lstrip("%")
                             for b in bm.group(1).split(","))
            stack.extend(_TRUEFALSE.findall(ins.rest))
    return seen


def _comp_has(an: HloAnalyzer, comp: str, pred, memo: dict) -> bool:
    """Does `comp` (transitively) contain an instruction matching pred?"""
    if comp in memo:
        return memo[comp]
    memo[comp] = False  # cycle guard
    for ins in an.comps.get(comp, []):
        if pred(ins):
            memo[comp] = True
            return True
        for m in _CALLED.finditer(ins.rest):
            if m.group(1) in an.comps and _comp_has(an, m.group(1), pred,
                                                    memo):
                memo[comp] = True
                return True
    return memo[comp]


_TRANSPOSED = re.compile(r'op_name="[^"]*transpose\(jvp')


def _layer_loops(text: str, trip: int) -> tuple[int, int]:
    """(forward, backward) counts of innermost dot-bearing layer loops.

    A scanned layer stack of depth L lowers to one `while` with
    known_trip_count == L per traversal direction. Direction comes from
    JAX's op_name metadata: the transposed (reverse) scan of a backward
    pass tags its body `transpose(jvp(while))/...`, the forward scan
    `jvp(while)`/`while`. Outer loops that merely CONTAIN trip-matching
    loops (e.g. a microbatch scan whose trip count collides with L) are
    excluded, as are dot-free bookkeeping loops (data pipelines, quantile
    updates).
    """
    an = HloAnalyzer(text)
    has_dot: dict = {}
    has_inner: dict = {}
    has_transpose: dict = {}

    def is_dot(ins):
        return ins.op in ("dot", "dot-general")

    def is_trip_while(ins):
        if ins.op != "while":
            return False
        t = _TRIP.search(ins.rest)
        return bool(t) and int(t.group(1)) == trip

    def is_transposed(ins):
        return bool(_TRANSPOSED.search(ins.rest))

    fwd = bwd = 0
    for comp in _reachable(an):
        for ins in an.comps.get(comp, []):
            if not is_trip_while(ins):
                continue
            bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
            if not bm or bm.group(1) not in an.comps:
                continue
            body = bm.group(1)
            if not _comp_has(an, body, is_dot, has_dot):
                continue
            if _comp_has(an, body, is_trip_while, has_inner):
                continue  # outer loop wrapping the real layer loops
            if _comp_has(an, body, is_transposed, has_transpose):
                bwd += 1
            else:
                fwd += 1
    return fwd, bwd


def backward_passes(text: str, layer_trip: int) -> int:
    """Full model backward passes in a compiled train step.

    Counts the transposed (reverse-iterating) layer-stack loops — see
    `_layer_loops`. The BK engine's claim is thereby asserted from the
    compiled HLO, not assumed: ONE backward pass for execution=bk (and
    per_layer / non_private), TWO for the `*_twopass` flat/group drivers —
    at any microbatch count (each microbatch body repeats the same
    structure; loops are counted statically). For models with several
    homogeneous stack runs pass the depth of the run of interest.
    """
    return _layer_loops(text, layer_trip)[1]


# ---------------------------------------------------------------------------
# Collective attribution: which program sites emit the bytes.
# ---------------------------------------------------------------------------

_OPNAME = re.compile(r'op_name="([^"]*)"')


def _comp_multiplicities(an: HloAnalyzer) -> dict[str, float]:
    """Visit multiplicity of every computation from ENTRY (loop-aware)."""
    mult: dict[str, float] = {}

    def visit(comp: str, m: float):
        mult[comp] = mult.get(comp, 0.0) + m
        for ins in an.comps.get(comp, []):
            if ins.op == "while":
                t = _TRIP.search(ins.rest)
                trip = int(t.group(1)) if t else 1
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                if bm:
                    visit(bm.group(1), m * trip)
                if cm:
                    visit(cm.group(1), m * trip)
            elif ins.op == "conditional":
                bs = _BRANCHES.search(ins.rest)
                names = ([b.strip().lstrip("%") for b in
                          bs.group(1).split(",")] if bs
                         else _TRUEFALSE.findall(ins.rest))
                for n in names:
                    visit(n, m)
            elif ins.op in ("fusion", "call", "custom-call", "async-start"):
                cm2 = _CALLED.search(ins.rest)
                if cm2 and cm2.group(1) in an.comps:
                    visit(cm2.group(1), m)

    visit(an.entry, 1.0)
    return mult


def collective_breakdown(text: str, top: int = 15) -> list[dict]:
    """Attribute collective result-bytes to source op_name sites.

    Loop multipliers are applied by locating each collective's enclosing
    computations through the analyzer's call graph (a site inside the
    36-layer scan counts 36x). Returns the top sites by total bytes.
    """
    an = HloAnalyzer(text)
    mult = _comp_multiplicities(an)
    sites: dict[tuple[str, str], dict] = {}
    for comp, instrs in an.comps.items():
        m = mult.get(comp, 0.0)
        if m == 0:
            continue
        for ins in instrs:
            base = ins.op.replace("-start", "")
            if base not in {"all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute"}:
                continue
            if ins.op.endswith("-done"):
                continue
            nm = _OPNAME.search(ins.rest)
            site = nm.group(1) if nm else "<unattributed>"
            # trim jit prefixes for readability
            site = site.split("jit(step_fn)/")[-1][:120]
            key = (base, site)
            slot = sites.setdefault(key, {"bytes": 0.0, "count": 0.0})
            slot["bytes"] += m * _shape_bytes(ins.shape)
            slot["count"] += m
    rows = [{"kind": k[0], "site": k[1], **v} for k, v in sites.items()]
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:top]


# ---------------------------------------------------------------------------
# Axis classification: WHICH mesh axes does each collective cross?
#
# The paper's per-device-clipping claim (Sec 4) is an axis statement: flat
# clipping moves per-example norm information across the MODEL axis; per-
# device clipping must not. Post-SPMD collectives carry `replica_groups`
# (flat device-id groups), so given the mesh's device->coordinate map we can
# decide, per collective, the set of mesh axes along which its groups vary —
# and tests can assert "zero model-axis collectives in norm computation"
# from the compiled HLO rather than assume it.
# ---------------------------------------------------------------------------

_REPLICA_GROUPS = re.compile(
    r"replica_groups=(\{\}|\{\{[\d,{} ]*\}\}|\[[\d,]+\]<=\[[\d,]+\]"
    r"(?:T\([\d,]+\))?)")
_SOURCE_TARGET = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_PAIR = re.compile(r"\{(\d+),(\d+)\}")
_IOTA_RG = re.compile(r"\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def mesh_device_coords(mesh) -> dict[int, tuple[int, ...]]:
    """device id -> mesh coordinates, read off the mesh's device array
    (robust to non-row-major physical orderings)."""
    import numpy as np
    coords: dict[int, tuple[int, ...]] = {}
    for idx in np.ndindex(*mesh.devices.shape):
        coords[int(mesh.devices[idx].id)] = tuple(int(i) for i in idx)
    return coords


def _parse_replica_groups(s: str, n_devices: int) -> list[list[int]] | None:
    """Flat device-id groups from either HLO replica_groups syntax."""
    import numpy as np
    if s == "{}":
        return [list(range(n_devices))]
    if s.startswith("{{"):
        return [[int(x) for x in grp.split(",") if x]
                for grp in re.findall(r"\{([\d, ]+)\}", s.replace(" ", ""))]
    m = _IOTA_RG.match(s)
    if not m:  # unknown format: caller treats as spanning everything
        return None
    gshape = [int(d) for d in m.group(1).split(",")]
    dims = [int(d) for d in m.group(2).split(",")]
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(3):
        ids = ids.transpose([int(p) for p in m.group(3).split(",")])
    return ids.reshape(gshape[0], -1).tolist()


def _axes_of_groups(groups: list[list[int]], coords: dict,
                    axis_names: tuple) -> tuple[str, ...]:
    """Mesh axes along which membership varies within any group."""
    spanned = set()
    for grp in groups:
        if len(grp) < 2:
            continue
        base = coords.get(grp[0])
        if base is None:
            return tuple(axis_names)  # ids outside the mesh: assume global
        for gid in grp[1:]:
            c = coords.get(gid)
            if c is None:
                return tuple(axis_names)
            for a, (x, y) in enumerate(zip(base, c)):
                if x != y:
                    spanned.add(axis_names[a])
    return tuple(a for a in axis_names if a in spanned)


def classify_collectives(text: str, mesh) -> list[dict]:
    """Per-site collective rows with the mesh axes each one crosses.

    Returns [{kind, site, axes: tuple[str,...], count, bytes}], loop-
    multiplied like `collective_breakdown`. `site` is the trimmed op_name
    (jax name_stack), so engine-inserted collectives wrapped in
    `jax.named_scope(...)` are attributable (e.g. 'flat_norm_psum').
    An unparsable replica_groups conservatively spans every axis.
    """
    coords = mesh_device_coords(mesh)
    axis_names = tuple(mesh.axis_names)
    n_dev = len(coords)
    an = HloAnalyzer(text)
    mult = _comp_multiplicities(an)
    sites: dict[tuple, dict] = {}
    for comp, instrs in an.comps.items():
        m = mult.get(comp, 0.0)
        if m == 0:
            continue
        for ins in instrs:
            base = ins.op.replace("-start", "")
            if base not in {"all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute"}:
                continue
            if ins.op.endswith("-done"):
                continue
            if base == "collective-permute":
                pm = _SOURCE_TARGET.search(ins.rest)
                groups = ([[int(a), int(b)] for a, b in
                           _PAIR.findall(pm.group(1))] if pm else None)
            else:
                gm = _REPLICA_GROUPS.search(ins.rest)
                groups = (_parse_replica_groups(gm.group(1), n_dev)
                          if gm else None)
            axes = (tuple(axis_names) if groups is None
                    else _axes_of_groups(groups, coords, axis_names))
            nm = _OPNAME.search(ins.rest)
            site = nm.group(1) if nm else "<unattributed>"
            site = site.split("jit(step_fn)/")[-1][:160]
            key = (base, axes, site)
            slot = sites.setdefault(key, {"bytes": 0.0, "count": 0.0})
            slot["bytes"] += m * _shape_bytes(ins.shape)
            slot["count"] += m
    rows = [{"kind": k[0], "axes": k[1], "site": k[2], **v}
            for k, v in sites.items()]
    rows.sort(key=lambda r: -r["bytes"])
    return rows


def summarize_axis_rows(rows: list[dict]) -> dict:
    """Aggregate `classify_collectives` rows to {axes-key: {count, bytes}}.

    Keys are '+'-joined spanned axes ('model', 'data', 'data+model', ...)
    or 'intra' for degenerate single-device groups — the shape consumed by
    BENCH_sharded.json and the zero-model-norm-traffic assertions.
    """
    out: dict[str, dict] = {}
    for r in rows:
        key = "+".join(r["axes"]) or "intra"
        slot = out.setdefault(key, {"count": 0.0, "bytes": 0.0})
        slot["count"] += r["count"]
        slot["bytes"] += r["bytes"]
    return out


def filter_model_norm_rows(rows: list[dict], *,
                           model_axis: str = "model") -> list[dict]:
    """Rows that BOTH cross the model axis AND belong to norm computation
    (site mentions 'norm' — the engine names its norm psums via
    `jax.named_scope`). Per-device clipping must yield []; flat clipping
    pays exactly its (B,) total-norm psum here."""
    return [r for r in rows
            if model_axis in r["axes"] and "norm" in r["site"].lower()]


def collective_axis_summary(text: str, mesh) -> dict:
    return summarize_axis_rows(classify_collectives(text, mesh))


def model_axis_norm_collectives(text: str, mesh, *,
                                model_axis: str = "model") -> list[dict]:
    return filter_model_norm_rows(classify_collectives(text, mesh),
                                  model_axis=model_axis)


# ---------------------------------------------------------------------------
# Entry-computation structure: donation aliases + shape stability.
#
# These feed the HLO rules engine (repro.analysis.rules). Donation shows up
# on the HloModule header line as
#   input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, may-alias) }
# mapping output tuple indices to entry parameter numbers. A jit with
# donate_argnums that silently fails to alias (the PR-7 corruption class
# was the inverse: an alias map applied to the WRONG buffers after cache
# deserialization) is statically visible here.
# ---------------------------------------------------------------------------

_ALIAS_PAIR = re.compile(
    r"\{([\d, ]*)\}:\s*\((\d+),\s*\{[\d, ]*\}(?:,\s*(may-alias|must-alias))?\)")


def _balanced_attr(line: str, attr: str) -> str | None:
    """The `{...}` payload of `attr={...}` with nested braces balanced."""
    tag = attr + "={"
    start = line.find(tag)
    if start < 0:
        return None
    start += len(attr) + 1
    depth = 0
    for idx in range(start, len(line)):
        ch = line[idx]
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                return line[start:idx + 1]
    return None


def entry_aliases(text: str) -> list[dict]:
    """Donation map of the module: [{output_index, param, kind}].

    Parsed from the HloModule header's `input_output_alias` attribute;
    empty when the executable donates nothing."""
    for line in text.splitlines():
        if "input_output_alias=" not in line:
            continue
        blob = _balanced_attr(line, "input_output_alias")
        if blob is None:
            continue
        return [
            {"output_index": tuple(int(x) for x in
                                   m.group(1).replace(" ", "").split(",")
                                   if x),
             "param": int(m.group(2)),
             "kind": m.group(3) or "may-alias"}
            for m in _ALIAS_PAIR.finditer(blob)
        ]
    return []


def entry_param_count(text: str) -> int:
    """Number of (flat) parameters of the ENTRY computation."""
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                args = m.group(2)
                return args.count(": ") if args.strip() else 0
    raise ValueError("no ENTRY computation found")


def dynamic_shape_instrs(text: str) -> list[tuple[str, str]]:
    """(name, shape) of instructions with bounded-dynamic dims (`[<=N,...]`).

    A data-dependent entry shape means recompiles (or padding bugs) under
    traffic — the serving/training programs must be shape-stable. The
    check inspects parsed instruction SHAPES only, so `<=` inside iota
    replica_groups attrs (e.g. `[16]<=[16]`) never false-positives."""
    out = []
    for line in text.splitlines():
        if "<=" not in line:
            continue
        parsed = _parse_instr_line(line)
        if parsed and "<=" in parsed[1]:
            out.append((parsed[0], parsed[1]))
    return out
