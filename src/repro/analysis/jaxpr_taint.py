"""Jaxpr taint/dataflow pass: prove clip -> noise -> aggregate statically.

The DP guarantee of every clipping mode is a DATAFLOW property of the
train step: every path from a batch-derived value to a trainable
parameter's update sink must pass through a per-example clip-factor
multiply, and exactly one Gaussian draw — keyed by a leaf-unique PRNG
fold — must join each leaf's gradient before the optimizer consumes it.
This pass walks the closed jaxpr of `make_dp_train_step`'s step function
(plain or shard_map) and checks those properties per trainable leaf.

Taint lattice (monotone, finite -> the scan/while fixpoints terminate):

  raw      — value depends on the batch without an intervening clip factor
  clipped  — batch-derived but absorbed through a clip-factor multiply
  factor   — value produced under the `dp_clip_factor` named scope
  draws    — set of noise-draw ids (one per `random_bits` under a
             `dp_noise_add:<leaf>` scope) that reached this value
  key      — PRNG lineage: the set of fold-in constants applied to the
             base step key on the way to this value (None = not a key)

The clipping engine marks its semantics with `jax.named_scope`:
`dp_clip_factor` around factor computation (core.clipping / core.ghost)
and `dp_noise_add:<leaf-path>` around each leaf's draw (core.dp_sgd /
core.noise). Name stacks survive into (sub-)jaxprs, so the walk sees
them inside pjit bodies, scan bodies, shard_map regions and custom-vjp
transposes alike.

Soundness notes (why the green matrix is not a false negative):
  * the absorb rule fires only on multiplicative primitives
    (mul/div/dot_general/conv) with a factor/clipped operand — a raw
    value joined ADDITIVELY to anything stays raw;
  * scatter ops ignore their index operand's taint (embedding-gradient
    scatter-adds index by raw token ids; the indices choose WHERE a
    clipped update lands, they do not contribute magnitude);
  * unknown higher-order primitives fall back to joining every input
    into every output (conservative: can only create false POSITIVES).

The audit matrix pins `backend="xla"` (like launch.dryrun): the fused
Pallas `linear_clip` custom-call takes (a, g, c) with the factor applied
INSIDE the kernel, which an operand-level taint pass cannot see through.
The xla path is the bitwise-parity-tested reference for that kernel
(tests/test_kernels.py), so auditing it audits the same dataflow.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax

from repro.analysis.findings import ERROR, WARNING, Finding

try:  # jax >= 0.5 moved core off the public root
    from jax.core import Literal as _Literal
except Exception:  # noqa: BLE001
    from jax._src.core import Literal as _Literal

CLIP_SCOPE = "dp_clip_factor"
NOISE_SCOPE = "dp_noise_add:"
_NOISE_LEAF = re.compile(r"dp_noise_add:([^/]+)")

# primitives where a clip-factor operand scales (rather than adds to) the
# result: a raw operand multiplied by a factor/clipped operand is clipped
_MULTIPLICATIVE = frozenset({
    "mul", "div", "dot_general", "conv_general_dilated",
})
# (operand, indices, updates): indices route, they do not contribute value
_SCATTER = frozenset({
    "scatter", "scatter-add", "scatter_add", "scatter_mul", "scatter_min",
    "scatter_max", "scatter_sub",
})
_DRAW_PRIMS = frozenset({"random_bits", "threefry2x32"})


@dataclasses.dataclass(frozen=True)
class Taint:
    raw: bool = False
    clipped: bool = False
    factor: bool = False
    draws: frozenset = frozenset()
    key: frozenset | None = None  # fold signature; None = not key-derived

    def join(self, other: "Taint") -> "Taint":
        if self == other:
            return self
        if self.key is None and other.key is None:
            key = None
        else:
            key = (self.key or frozenset()) | (other.key or frozenset())
        return Taint(self.raw or other.raw, self.clipped or other.clipped,
                     self.factor or other.factor, self.draws | other.draws,
                     key)


CLEAN = Taint()


@dataclasses.dataclass
class DrawSite:
    draw_id: str
    leaf: str | None          # dp_noise_add leaf name, None outside scopes
    key_sig: frozenset | None  # fold signature of the consumed key
    scope: str


class _State:
    def __init__(self):
        self.draws: dict[str, DrawSite] = {}  # keyed by structural id so
        #   scan-fixpoint re-evaluation never double-counts a draw


def _join_all(taints) -> Taint:
    out = CLEAN
    for t in taints:
        out = out.join(t)
    return out


def _unwrap(jx):
    """(jaxpr, consts?) from either a raw Jaxpr or a ClosedJaxpr.

    shard_map carries a RAW Jaxpr in params['jaxpr'] while pjit/scan carry
    ClosedJaxprs — both must recurse or the whole sharded path would be
    silently unanalyzed."""
    inner = getattr(jx, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner, True
    return jx, False


def _sub_jaxpr(eqn):
    for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = eqn.params.get(k)
        if sub is not None and (hasattr(sub, "eqns")
                                or hasattr(getattr(sub, "jaxpr", None),
                                           "eqns")):
            return sub
    return None


class _Interp:
    def __init__(self, state: _State):
        self.state = state

    def _read(self, env, atom) -> Taint:
        if isinstance(atom, _Literal):
            return CLEAN
        return env.get(atom, CLEAN)

    def eval_jaxpr(self, jx, in_taints, scope_prefix: str, id_prefix: str
                   ) -> list[Taint]:
        jaxpr, _ = _unwrap(jx)
        env: dict[Any, Taint] = {}
        for v in jaxpr.constvars:
            env[v] = CLEAN
        if len(in_taints) != len(jaxpr.invars):
            # operand-mapping mismatch (exotic call convention): smear the
            # join of everything over every binder — conservative
            smear = _join_all(in_taints)
            in_taints = [smear] * len(jaxpr.invars)
        for v, t in zip(jaxpr.invars, in_taints):
            env[v] = env.get(v, CLEAN).join(t) if v in env else t
        for idx, eqn in enumerate(jaxpr.eqns):
            outs = self._eval_eqn(eqn, [self._read(env, a)
                                        for a in eqn.invars],
                                  scope_prefix, f"{id_prefix}/{idx}")
            for v, t in zip(eqn.outvars, outs):
                env[v] = t
        return [self._read(env, a) for a in jaxpr.outvars]

    # -- one equation ------------------------------------------------------

    def _eval_eqn(self, eqn, ins: list[Taint], scope_prefix: str,
                  eqn_id: str) -> list[Taint]:
        name = eqn.primitive.name
        scope = scope_prefix + "/" + str(eqn.source_info.name_stack)
        nout = len(eqn.outvars)

        sub = self._higher_order(eqn, ins, scope, eqn_id)
        if sub is not None:
            outs = sub
        elif name in _SCATTER and len(ins) >= 3:
            outs = [_join_all([ins[0]] + ins[2:])] * nout
        elif name == "random_fold_in":
            outs = [self._fold(eqn, ins)] * nout
        elif name in _DRAW_PRIMS:
            outs = [self._draw(eqn, ins, scope, eqn_id)] * nout
        elif name in _MULTIPLICATIVE:
            joined = _join_all(ins)
            if joined.raw and any(t.factor or t.clipped for t in ins):
                joined = dataclasses.replace(joined, raw=False, clipped=True)
            outs = [joined] * nout
        else:
            outs = [_join_all(ins)] * nout

        if CLIP_SCOPE in scope:
            # everything produced under the marker IS factor data; norms
            # feeding it are consumed here, not leaked onward as raw
            outs = [dataclasses.replace(t, raw=False, factor=True)
                    for t in outs]
        return outs

    def _fold(self, eqn, ins: list[Taint]) -> Taint:
        joined = _join_all(ins)
        key = joined.key if joined.key is not None else frozenset()
        fold = eqn.invars[1] if len(eqn.invars) > 1 else None
        if isinstance(fold, _Literal):
            entry = f"lit:{fold.val}"
        else:
            # data-dependent fold (e.g. fold_in(key, dp_state.step)):
            # identified by the folded VALUE's identity, shared by every
            # consumer of the same fold
            entry = f"dyn:{id(fold)}"
        return dataclasses.replace(joined, key=key | {entry})

    def _draw(self, eqn, ins: list[Taint], scope: str, eqn_id: str) -> Taint:
        joined = _join_all(ins)
        m = _NOISE_LEAF.search(scope)
        leaf = m.group(1) if m else None
        key_sig = None
        for t in ins:
            if t.key is not None:
                key_sig = frozenset(t.key) if key_sig is None \
                    else key_sig | t.key
        if leaf is not None:
            self.state.draws[eqn_id] = DrawSite(eqn_id, leaf, key_sig, scope)
            return Taint(raw=joined.raw, clipped=joined.clipped,
                         factor=joined.factor, draws=joined.draws | {eqn_id})
        return dataclasses.replace(joined, key=None)

    # -- higher-order primitives -------------------------------------------

    def _higher_order(self, eqn, ins, scope, eqn_id):
        name = eqn.primitive.name
        p = eqn.params
        if name == "scan":
            return self._scan(eqn, ins, scope, eqn_id)
        if name == "while":
            return self._while(eqn, ins, scope, eqn_id)
        if name == "cond":
            branches = p.get("branches") or ()
            outs = None
            pred = ins[0] if ins else CLEAN
            for bi, br in enumerate(branches):
                got = self.eval_jaxpr(br, ins[1:], scope, f"{eqn_id}.b{bi}")
                outs = got if outs is None else [a.join(b) for a, b
                                                 in zip(outs, got)]
            if outs is None:
                return None
            return [t.join(dataclasses.replace(pred, key=None))
                    for t in outs]
        sub = _sub_jaxpr(eqn)
        if sub is None:
            return None
        return self.eval_jaxpr(sub, ins, scope, eqn_id)

    def _scan(self, eqn, ins, scope, eqn_id):
        p = eqn.params
        body = p["jaxpr"]
        nc, ncar = p.get("num_consts", 0), p.get("num_carry", 0)
        consts, carry, xs = ins[:nc], list(ins[nc:nc + ncar]), ins[nc + ncar:]
        outs = carry + [CLEAN] * (len(eqn.outvars) - ncar)
        for _ in range(64):
            outs = self.eval_jaxpr(body, consts + carry + xs, scope, eqn_id)
            new_carry = [a.join(b) for a, b in zip(carry, outs[:ncar])]
            if new_carry == carry:
                break
            carry = new_carry
        return carry + list(outs[ncar:])

    def _while(self, eqn, ins, scope, eqn_id):
        p = eqn.params
        body, cond = p.get("body_jaxpr"), p.get("cond_jaxpr")
        nb, ncnd = p.get("body_nconsts", 0), p.get("cond_nconsts", 0)
        if body is None:
            return None
        cconsts = ins[:ncnd]
        bconsts = ins[ncnd:ncnd + nb]
        carry = list(ins[ncnd + nb:])
        if cond is not None:
            self.eval_jaxpr(cond, cconsts + carry, scope, f"{eqn_id}.c")
        for _ in range(64):
            outs = self.eval_jaxpr(body, bconsts + carry, scope, eqn_id)
            new_carry = [a.join(b) for a, b in zip(carry, outs)]
            if new_carry == carry:
                break
            carry = new_carry
        return carry


# ---------------------------------------------------------------------------
# Driver: taint the step function's jaxpr, check the per-leaf invariants.
# ---------------------------------------------------------------------------


def _leaf_name(path) -> str:
    parts = []
    for entry in path:
        name = getattr(entry, "key", None)
        if name is None:
            name = getattr(entry, "idx", None)
        if name is None:
            name = getattr(entry, "name", str(entry))
        parts.append(str(name))
    return ".".join(parts)


def audit_train_step(
    step_fn: Callable,
    args: tuple,  # (params, opt_state, dp_state, batch, key) abstract/conc.
    *,
    private: bool = True,
    trainable_key: str | None = None,
) -> list[Finding]:
    """Taint-check one train step. Returns findings (empty = proven green).

    Rules:
      JAXPR-CLIP-PATH   — a trainable leaf's new value depends on the batch
                          WITHOUT passing a `dp_clip_factor` multiply
      JAXPR-NOISE-ONCE  — a trainable leaf receives != 1 noise draw
      JAXPR-KEY-LINEAGE — a noise draw's key is not folded from a static
                          leaf hash, or two leaves' keys share an identical
                          fold signature (the PR-6 `stable_hash` class and
                          the `noise._leaf_key` crc32-collision class)
    """
    closed = jax.make_jaxpr(step_fn)(*args)
    flat_in, _ = jax.tree_util.tree_flatten_with_path(tuple(args))
    in_taints = []
    for path, _leaf in flat_in:
        arg_idx = path[0].idx
        if arg_idx == 3:       # batch
            in_taints.append(Taint(raw=True))
        elif arg_idx == 4:     # PRNG key
            in_taints.append(Taint(key=frozenset()))
        else:                  # params / opt_state / dp_state
            in_taints.append(CLEAN)

    state = _State()
    interp = _Interp(state)
    out_taints = interp.eval_jaxpr(closed.jaxpr, in_taints, "", "")

    out_shapes = jax.eval_shape(step_fn, *args)
    flat_out, _ = jax.tree_util.tree_flatten_with_path(out_shapes)
    if len(flat_out) != len(out_taints):
        return [Finding("JAXPR-CLIP-PATH", WARNING,
                        f"output arity mismatch ({len(flat_out)} leaves vs "
                        f"{len(out_taints)} outvars); taint results not "
                        f"attributable", "outputs")]

    findings: list[Finding] = []
    if not private:
        return findings

    for (path, _leaf), taint in zip(flat_out, out_taints):
        if path[0].idx != 0:
            continue  # params output only; opt/dp/metrics are not the sink
        if trainable_key is not None and str(getattr(path[1], "key", "")) \
                != trainable_key:
            continue
        leaf = _leaf_name(path[1:])
        if taint.raw:
            findings.append(Finding(
                "JAXPR-CLIP-PATH", ERROR,
                "batch-derived gradient reaches the parameter update "
                "without passing a dp_clip_factor multiply", leaf))
        ndraws = len(taint.draws)
        if ndraws != 1:
            findings.append(Finding(
                "JAXPR-NOISE-ONCE", ERROR,
                f"{ndraws} noise draws reach this leaf's update "
                f"(exactly 1 required)", leaf))

    findings.extend(_key_lineage(state))
    return findings


def _key_lineage(state: _State) -> list[Finding]:
    findings = []
    by_sig: dict[frozenset, DrawSite] = {}
    for site in state.draws.values():
        if not site.key_sig:
            findings.append(Finding(
                "JAXPR-KEY-LINEAGE", ERROR,
                "noise draw consumes a key with no leaf-specific fold "
                "(base key reused verbatim)", site.leaf or site.scope))
            continue
        other = by_sig.get(site.key_sig)
        if other is not None and other.leaf != site.leaf:
            findings.append(Finding(
                "JAXPR-KEY-LINEAGE", ERROR,
                f"leaves {other.leaf!r} and {site.leaf!r} fold to an "
                f"IDENTICAL key signature — their noise draws are "
                f"correlated, breaking the Gaussian-mechanism sensitivity "
                f"bound", f"{other.leaf} ~ {site.leaf}"))
        else:
            by_sig[site.key_sig] = site
    # dedupe repeated pairs (stacked leaves can collide many times)
    seen, out = set(), []
    for f in findings:
        k = (f.rule, f.location)
        if k in seen:
            continue
        seen.add(k)
        out.append(f)
    return out
