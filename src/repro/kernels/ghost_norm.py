"""Pallas TPU kernel: per-example gradient norms² without materialization.

Computes n_b = <A_b A_bᵀ, G_b G_bᵀ> for every example b — the ghost-norm
identity at the heart of the paper's fused per-layer clipping — with the
(T, T) grams built BLOCK BY BLOCK in VMEM and never written to HBM:

  grid = (B, T/bt, T/bt, max(din, dout)/dk)   (k innermost, sequential)

  for each (b, i, j) with j >= i: two f32 VMEM scratch accumulators hold the
  (bt, bt) gram blocks A_i A_jᵀ and G_i G_jᵀ, accumulated over feature chunks
  k (the MXU contraction dim stays hardware-aligned); on the last chunk the
  blocks are multiplied elementwise, reduced, and accumulated into out[b].
  The summand <A_iA_jᵀ, G_iG_jᵀ> is SYMMETRIC in (i, j), so tile pairs with
  j < i are skipped and off-diagonal contributions doubled — ~2x fewer MXU
  flops at large T (the j < i grid steps issue no dots).

VMEM footprint: 4 input blocks (bt x dk) + 2 scratch (bt x bt) f32
  = 4·256·512·4B + 2·256·256·4B ≈ 2.6 MiB  « 16 MiB v5e VMEM.

HBM traffic: A and G are each read (T/bt) times (once per row-block pass) —
vs. the XLA path which writes/reads the (B, T, T) grams to HBM. For
T=4096, d=2560: kernel moves 2·T·d·(T/bt) ≈ 0.7 GB/example of reads and no
gram writes; XLA moves ≥ 2·T²·4 = 134 MB/example of gram writes + reads
plus the same input reads. The win grows with T — exactly the regime the
paper's per-layer clipping targets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 256  # sequence tile
DEFAULT_DK = 512  # feature-chunk tile


def _kernel(a_i, a_j, g_i, g_j, out_ref, acc_a, acc_g, *, nda, ndg, nk):
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)
    upper = j >= i  # symmetry: skip the strict lower triangle of tile pairs

    @pl.when(k == 0)
    def _init():
        acc_a[...] = jnp.zeros_like(acc_a)
        acc_g[...] = jnp.zeros_like(acc_g)

    @pl.when(upper & (k < nda))
    def _acc_a():
        ab_i = a_i[0].astype(jnp.float32)
        ab_j = a_j[0].astype(jnp.float32)
        acc_a[...] += jax.lax.dot_general(
            ab_i, ab_j, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(upper & (k < ndg))
    def _acc_g():
        gb_i = g_i[0].astype(jnp.float32)
        gb_j = g_j[0].astype(jnp.float32)
        acc_g[...] += jax.lax.dot_general(
            gb_i, gb_j, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _emit():
        # off-diagonal (i, j) tiles stand in for (j, i) as well -> double
        val = (jnp.sum(acc_a[...] * acc_g[...])
               * jnp.where(i == j, 1.0, 2.0)
               * jnp.where(upper, 1.0, 0.0))
        first = (i == 0) & (j == 0)
        out_ref[0, 0] = jnp.where(first, val, out_ref[0, 0] + val)


def ghost_norm(a: jax.Array, g: jax.Array, *, bt: int = DEFAULT_BT,
               dk: int = DEFAULT_DK, interpret: bool = True) -> jax.Array:
    """(B,) squared per-example grad norms. a: (B,T,din); g: (B,T,dout).

    interpret=True executes the kernel body on CPU (validation mode);
    on TPU pass interpret=False.
    """
    b, t, din = a.shape
    dout = g.shape[-1]
    bt = min(bt, t)
    # pad T to a multiple of bt and features to multiples of dk
    tp = -(-t // bt) * bt
    dap = -(-din // dk) * dk if din > dk else din
    dgp = -(-dout // dk) * dk if dout > dk else dout
    dka = min(dk, dap)
    dkg = min(dk, dgp)
    a_p = jnp.pad(a, ((0, 0), (0, tp - t), (0, dap - din)))
    g_p = jnp.pad(g, ((0, 0), (0, tp - t), (0, dgp - dout)))
    nda, ndg = dap // dka, dgp // dkg
    nk = max(nda, ndg)
    nt = tp // bt

    grid = (b, nt, nt, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nda=nda, ndg=ndg, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, dka), lambda bb, i, j, k: (bb, i, jnp.minimum(k, nda - 1))),
            pl.BlockSpec((1, bt, dka), lambda bb, i, j, k: (bb, j, jnp.minimum(k, nda - 1))),
            pl.BlockSpec((1, bt, dkg), lambda bb, i, j, k: (bb, i, jnp.minimum(k, ndg - 1))),
            pl.BlockSpec((1, bt, dkg), lambda bb, i, j, k: (bb, j, jnp.minimum(k, ndg - 1))),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bb, i, j, k: (bb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        scratch_shapes=[
            # two gram-block accumulators held in VMEM across the k loop
            pltpu.VMEM((bt, bt), jnp.float32),
            pltpu.VMEM((bt, bt), jnp.float32),
        ],
        interpret=interpret,
    )(a_p, a_p, g_p, g_p)
    return out[:, 0]


# ---------------------------------------------------------------------------
# Blocked (per-shard) ghost norms: (B, M) per-block norms² in one kernel.
# ---------------------------------------------------------------------------


def _blocked_kernel(s_i, s_j, x_i, x_j, out_ref, acc_s, acc_x, *,
                    nds, ndx, nk):
    i = pl.program_id(2)
    j = pl.program_id(3)
    k = pl.program_id(4)
    upper = j >= i

    @pl.when(k == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        acc_x[...] = jnp.zeros_like(acc_x)

    @pl.when(upper & (k < nds))
    def _acc_s():
        acc_s[...] += jax.lax.dot_general(
            s_i[0].astype(jnp.float32), s_j[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(upper & (k < ndx))
    def _acc_x():
        acc_x[...] += jax.lax.dot_general(
            x_i[0, 0].astype(jnp.float32), x_j[0, 0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _emit():
        val = (jnp.sum(acc_s[...] * acc_x[...])
               * jnp.where(i == j, 1.0, 2.0)
               * jnp.where(upper, 1.0, 0.0))
        first = (i == 0) & (j == 0)
        out_ref[0, 0] = jnp.where(first, val, out_ref[0, 0] + val)


def ghost_norm_blocked(a: jax.Array, g: jax.Array, num_blocks: int, *,
                       block_axis: str = "out", bt: int = DEFAULT_BT,
                       dk: int = DEFAULT_DK, interpret: bool = True
                       ) -> jax.Array:
    """(B, M) squared per-example norms of M weight blocks — the per-shard
    (per-device) clipping hot path. a: (B, T, din); g: (B, T, dout).

    block_axis='out': block m is columns [m*dout/M, (m+1)*dout/M) of W
    (Megatron column parallel); 'in' blocks rows of W (row parallel). The
    ghost identity per block needs the SHARED tensor's full gram and the
    blocked tensor's per-block gram:

        n[b, m] = <S_b S_bᵀ, X_b^m (X_b^m)ᵀ>,  S = a, X = g for 'out'
                                                (roles swap for 'in').

    grid = (B, M, T/bt, T/bt, nk), j >= i via the same symmetry trick as
    `ghost_norm`; the shared gram block is recomputed per m (reads stay in
    HBM->VMEM streams; nothing is duplicated in HBM).
    """
    b, t, din = a.shape
    dout = g.shape[-1]
    m = num_blocks
    if block_axis == "out":
        if dout % m:
            raise ValueError(f"dout={dout} not divisible by num_blocks={m}")
        shared, ds = a, din
        blocked = g.reshape(b, t, m, dout // m).transpose(0, 2, 1, 3)
        dx = dout // m
    elif block_axis == "in":
        if din % m:
            raise ValueError(f"din={din} not divisible by num_blocks={m}")
        shared, ds = g, dout
        blocked = a.reshape(b, t, m, din // m).transpose(0, 2, 1, 3)
        dx = din // m
    else:
        raise ValueError(f"block_axis must be 'out' or 'in', got {block_axis!r}")

    bt = min(bt, t)
    tp = -(-t // bt) * bt
    dsp = -(-ds // dk) * dk if ds > dk else ds
    dxp = -(-dx // dk) * dk if dx > dk else dx
    dks = min(dk, dsp)
    dkx = min(dk, dxp)
    s_p = jnp.pad(shared, ((0, 0), (0, tp - t), (0, dsp - ds)))
    x_p = jnp.pad(blocked, ((0, 0), (0, 0), (0, tp - t), (0, dxp - dx)))
    nds, ndx = dsp // dks, dxp // dkx
    nk = max(nds, ndx)
    nt = tp // bt

    grid = (b, m, nt, nt, nk)
    out = pl.pallas_call(
        functools.partial(_blocked_kernel, nds=nds, ndx=ndx, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, dks),
                         lambda bb, mm, i, j, k: (bb, i, jnp.minimum(k, nds - 1))),
            pl.BlockSpec((1, bt, dks),
                         lambda bb, mm, i, j, k: (bb, j, jnp.minimum(k, nds - 1))),
            pl.BlockSpec((1, 1, bt, dkx),
                         lambda bb, mm, i, j, k: (bb, mm, i, jnp.minimum(k, ndx - 1))),
            pl.BlockSpec((1, 1, bt, dkx),
                         lambda bb, mm, i, j, k: (bb, mm, j, jnp.minimum(k, ndx - 1))),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bb, mm, i, j, k: (bb, mm)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt, bt), jnp.float32),
            pltpu.VMEM((bt, bt), jnp.float32),
        ],
        interpret=interpret,
    )(s_p, s_p, x_p, x_p)
    return out
