"""Pallas TPU kernel: per-example gradient norms² without materialization.

Computes n_b = <A_b A_bᵀ, G_b G_bᵀ> for every example b — the ghost-norm
identity at the heart of the paper's fused per-layer clipping — with the
(T, T) grams built BLOCK BY BLOCK in VMEM and never written to HBM:

  grid = (B, T/bt, T/bt, max(din, dout)/dk)   (k innermost, sequential)

  for each (b, i, j): two f32 VMEM scratch accumulators hold the (bt, bt)
  gram blocks A_i A_jᵀ and G_i G_jᵀ, accumulated over feature chunks k (the
  MXU contraction dim stays hardware-aligned); on the last chunk the blocks
  are multiplied elementwise, reduced, and accumulated into out[b].

VMEM footprint: 4 input blocks (bt x dk) + 2 scratch (bt x bt) f32
  = 4·256·512·4B + 2·256·256·4B ≈ 2.6 MiB  « 16 MiB v5e VMEM.

HBM traffic: A and G are each read (T/bt) times (once per row-block pass) —
vs. the XLA path which writes/reads the (B, T, T) grams to HBM. For
T=4096, d=2560: kernel moves 2·T·d·(T/bt) ≈ 0.7 GB/example of reads and no
gram writes; XLA moves ≥ 2·T²·4 = 134 MB/example of gram writes + reads
plus the same input reads. The win grows with T — exactly the regime the
paper's per-layer clipping targets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 256  # sequence tile
DEFAULT_DK = 512  # feature-chunk tile


def _kernel(a_i, a_j, g_i, g_j, out_ref, acc_a, acc_g, *, nda, ndg, nk):
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_a[...] = jnp.zeros_like(acc_a)
        acc_g[...] = jnp.zeros_like(acc_g)

    @pl.when(k < nda)
    def _acc_a():
        ab_i = a_i[0].astype(jnp.float32)
        ab_j = a_j[0].astype(jnp.float32)
        acc_a[...] += jax.lax.dot_general(
            ab_i, ab_j, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k < ndg)
    def _acc_g():
        gb_i = g_i[0].astype(jnp.float32)
        gb_j = g_j[0].astype(jnp.float32)
        acc_g[...] += jax.lax.dot_general(
            gb_i, gb_j, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _emit():
        val = jnp.sum(acc_a[...] * acc_g[...])
        first = (i == 0) & (j == 0)
        out_ref[0, 0] = jnp.where(first, val, out_ref[0, 0] + val)


def ghost_norm(a: jax.Array, g: jax.Array, *, bt: int = DEFAULT_BT,
               dk: int = DEFAULT_DK, interpret: bool = True) -> jax.Array:
    """(B,) squared per-example grad norms. a: (B,T,din); g: (B,T,dout).

    interpret=True executes the kernel body on CPU (validation mode);
    on TPU pass interpret=False.
    """
    b, t, din = a.shape
    dout = g.shape[-1]
    bt = min(bt, t)
    # pad T to a multiple of bt and features to multiples of dk
    tp = -(-t // bt) * bt
    dap = -(-din // dk) * dk if din > dk else din
    dgp = -(-dout // dk) * dk if dout > dk else dout
    dka = min(dk, dap)
    dkg = min(dk, dgp)
    a_p = jnp.pad(a, ((0, 0), (0, tp - t), (0, dap - din)))
    g_p = jnp.pad(g, ((0, 0), (0, tp - t), (0, dgp - dout)))
    nda, ndg = dap // dka, dgp // dkg
    nk = max(nda, ndg)
    nt = tp // bt

    grid = (b, nt, nt, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nda=nda, ndg=ndg, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, dka), lambda bb, i, j, k: (bb, i, jnp.minimum(k, nda - 1))),
            pl.BlockSpec((1, bt, dka), lambda bb, i, j, k: (bb, j, jnp.minimum(k, nda - 1))),
            pl.BlockSpec((1, bt, dkg), lambda bb, i, j, k: (bb, i, jnp.minimum(k, ndg - 1))),
            pl.BlockSpec((1, bt, dkg), lambda bb, i, j, k: (bb, j, jnp.minimum(k, ndg - 1))),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bb, i, j, k: (bb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        scratch_shapes=[
            # two gram-block accumulators held in VMEM across the k loop
            pltpu.VMEM((bt, bt), jnp.float32),
            pltpu.VMEM((bt, bt), jnp.float32),
        ],
        interpret=interpret,
    )(a_p, a_p, g_p, g_p)
    return out[:, 0]
