"""Pallas TPU kernels for the paper's fused per-layer clipping hot path,
plus the backend engine that makes them load-bearing.

  ghost_norm.py    per-example grad norms² (full + per-shard blocked)
  clip_reduce.py   fused clip-scale-accumulate Σ_i c_i A_iᵀ G_i
  fused_clip.py    norms² + clip + reduce in ONE pass over A, G
  bk.py            book-keeping epilogue Σ_i f_i A_iᵀ G_i per stack slice
                   (the contraction over residuals cached by core.bk)
  ref.py           pure-jnp oracles (the allclose ground truth)
  ops.py           thin jitted wrappers for tests/benchmarks
  backend.py       xla | pallas | auto engine registry + scoped config

`repro.core.dp_layers` resolves every ghost op through `backend.active()`;
import `backend` and use `backend.scoped("pallas")` (or
`DPConfig(backend=...)`) to route training through the kernels.
"""
from repro.kernels import backend  # noqa: F401

__all__ = ["backend"]
