"""Measured kernel autotuning: an empirical table behind the `auto` backend.

The static flop model in `repro.kernels.backend.choose_linear_path` predicts
which implementation (xla reference path vs pallas kernel) wins for a given
ghost-op shape — and `benchmarks/BENCH_kernels.json` already contradicts it
on several shapes (e.g. pallas `clip_sum` measured faster than xla on CPU
while the model resolves to xla off-TPU unconditionally). This module makes
the `auto` decision *empirical*:

  * a one-time per-(op, shape-bucket, backend) timing sweep (`sweep()` /
    ``python -m repro.kernels.autotune --sweep``) measures the registered
    backends on representative data and records the median wall time;
  * results persist to a versioned on-disk JSON table keyed by the
    **topology stamp** (jax backend, device kind, device count, XLA flags,
    jax version) with a crc32 over the canonical payload — a table written
    on a different topology, a different schema version, or a torn/corrupt
    file loads as an EMPTY table (clean miss, never a crash) and is simply
    rebuilt by the next sweep;
  * `repro.kernels.backend.choose_op` consults the *installed* table at
    trace time: the measured argmin wins on ANY jax backend (including the
    interpret-mode kernels off-TPU — if they measured faster, they are
    faster), and the static flop model remains the fallback for unmeasured
    buckets;
  * `benchmarks/bench_kernels.py` seeds measured entries from its sweep and
    `benchmarks/roofline.py` seeds model-estimated entries for unmeasured
    buckets, so a fleet image can ship a pre-warmed table and thousands of
    workers never re-autotune.

Shapes are bucketed to the next power of two per dimension so one
measurement covers the whole bucket; entries carry their provenance
(``"measured"`` beats ``"model"`` — a model-seeded row never overwrites a
measured one).

Installation is EXPLICIT: library code never reads the filesystem behind
your back. Entry points (train/serve/service CLIs) call
`install_default()` under their ``--autotune`` knob; tests scope a
synthetic table with `use_table(...)`. `EngineConfig.autotune=False`
disables consultation even with a table installed.
"""
from __future__ import annotations

import argparse
import contextlib
import contextvars
import dataclasses
import json
import os
import time
import zlib

import jax

TABLE_VERSION = 1

# every engine op the auto backend dispatches on; bench_kernels uses the
# same keys so its records seed the table directly
OPS = ("norms", "clip_sum", "linear_clip", "scale_contract", "paged_attn")

_BACKEND_CHOICES = ("xla", "pallas")


# ---------------------------------------------------------------------------
# Topology stamp + cache locations.
# ---------------------------------------------------------------------------


def device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:  # noqa: BLE001 - no devices: stamp still well-formed
        return "unknown"


def topology_stamp() -> dict:
    """What a timing measurement is conditioned on. Tables (and the
    compile-cache manifest) keyed on this stamp never leak measurements
    across machines, device counts, XLA flag sets, or jax versions."""
    return {
        "jax_backend": jax.default_backend(),
        "device_kind": device_kind(),
        "device_count": jax.device_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "jax_version": jax.__version__,
    }


def stamp_crc(stamp: dict | None = None) -> str:
    blob = json.dumps(stamp or topology_stamp(), sort_keys=True)
    return f"{zlib.crc32(blob.encode()):08x}"


def repo_cache_root(override: str | None = None) -> str:
    """Repo-local cache root: <repo>/.cache (REPRO_CACHE_DIR overrides).

    Repo-local on purpose: pre-warming a fleet image = building the image
    with this directory populated (docs: README "Autotuning & compilation
    cache")."""
    if override:
        return override
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))  # src/repro/kernels
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(repo, ".cache")


def default_path(cache_root: str | None = None,
                 stamp: dict | None = None) -> str:
    """One table file per topology: autotune/<stamp-crc>.json."""
    return os.path.join(repo_cache_root(cache_root), "autotune",
                        f"table-{stamp_crc(stamp)}.json")


# ---------------------------------------------------------------------------
# Shape bucketing.
# ---------------------------------------------------------------------------


def bucket_dim(n: int) -> int:
    """Next power of two (0 stays 0): one measurement covers the bucket."""
    n = int(n)
    if n <= 0:
        return 0
    return 1 << (n - 1).bit_length()


def bucket_key(op: str, t: int, din: int, dout: int) -> str:
    return f"{op}|t{bucket_dim(t)}|i{bucket_dim(din)}|o{bucket_dim(dout)}"


# ---------------------------------------------------------------------------
# The table.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AutotuneTable:
    """Bucketed (op, shape) -> {backend: {us, source}} timings for ONE
    topology. `best()` is the measured argmin; buckets it has never seen
    return None so the caller falls back to the static model."""

    topology: dict = dataclasses.field(default_factory=topology_stamp)
    entries: dict = dataclasses.field(default_factory=dict)
    path: str | None = None
    stale_reason: str | None = None  # why a load came back empty

    def record(self, op: str, t: int, din: int, dout: int, backend: str,
               us: float, *, source: str = "measured") -> bool:
        """Record one timing; measured entries always beat model-seeded
        ones (a model estimate never overwrites a measurement). Returns
        True if the entry was stored."""
        if backend not in _BACKEND_CHOICES:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected one of {_BACKEND_CHOICES}")
        if not (us > 0.0) or us != us or us == float("inf"):
            raise ValueError(f"bad timing {us!r} for {op}")
        key = bucket_key(op, t, din, dout)
        slot = self.entries.setdefault(key, {})
        prev = slot.get(backend)
        if prev is not None and prev.get("source") == "measured" \
                and source != "measured":
            return False
        slot[backend] = {"us": float(us), "source": source}
        return True

    def lookup(self, op: str, t: int, din: int, dout: int) -> dict | None:
        return self.entries.get(bucket_key(op, t, din, dout))

    def best(self, op: str, t: int, din: int, dout: int) -> str | None:
        """Measured argmin for this bucket, or None if unmeasured.

        Measured rows win outright; model-seeded rows only decide a bucket
        with no measurements at all."""
        slot = self.lookup(op, t, din, dout)
        if not slot:
            return None
        measured = {b: v for b, v in slot.items()
                    if v.get("source") == "measured"}
        pool = measured or slot
        return min(pool, key=lambda b: pool[b]["us"])

    def __len__(self) -> int:
        return len(self.entries)

    # -- persistence -------------------------------------------------------

    def _payload(self) -> dict:
        return {"version": TABLE_VERSION, "topology": self.topology,
                "entries": self.entries}

    def save(self, path: str | None = None) -> str:
        """Atomic, checksummed write (tmp + fsync + os.replace — the PR 6
        checkpoint discipline), so a killed writer leaves either the old
        table or the new one, never a torn file that parses."""
        path = path or self.path or default_path(stamp=self.topology)
        self.path = path
        os.makedirs(os.path.dirname(path), exist_ok=True)
        payload = self._payload()
        blob = json.dumps(payload, sort_keys=True)
        doc = {"crc32": zlib.crc32(blob.encode()), **payload}
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(doc, fh, indent=1)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
        return path


def load(path: str | None = None, *,
         topology: dict | None = None) -> AutotuneTable:
    """Load a table; NEVER raises. Missing, unparseable, truncated,
    checksum-mismatched, wrong-version, or wrong-topology files all come
    back as an empty table (with `stale_reason` saying why) — the auto
    backend then falls back to the static model and the next sweep
    rebuilds the file."""
    topo = topology or topology_stamp()
    path = path or default_path(stamp=topo)
    fresh = AutotuneTable(topology=topo, path=path)
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except FileNotFoundError:
        fresh.stale_reason = "missing"
        return fresh
    except (OSError, ValueError) as e:
        fresh.stale_reason = f"unreadable: {type(e).__name__}"
        return fresh
    if not isinstance(doc, dict):
        fresh.stale_reason = "malformed"
        return fresh
    if doc.get("version") != TABLE_VERSION:
        fresh.stale_reason = f"version {doc.get('version')!r}"
        return fresh
    payload = {"version": doc.get("version"), "topology": doc.get("topology"),
               "entries": doc.get("entries")}
    blob = json.dumps(payload, sort_keys=True)
    if zlib.crc32(blob.encode()) != doc.get("crc32"):
        fresh.stale_reason = "crc mismatch"
        return fresh
    if doc.get("topology") != topo:
        fresh.stale_reason = "topology mismatch"
        return fresh
    if not isinstance(doc.get("entries"), dict):
        fresh.stale_reason = "malformed entries"
        return fresh
    return AutotuneTable(topology=topo, entries=doc["entries"], path=path)


# ---------------------------------------------------------------------------
# Installed-table resolution (what the auto backend consults at trace time).
# ---------------------------------------------------------------------------

# context-local override (tests / nested scopes) over a process-wide install
_OVERRIDE: contextvars.ContextVar[AutotuneTable | None] = \
    contextvars.ContextVar("autotune_table_override", default=None)
_INSTALLED: AutotuneTable | None = None


def installed_table() -> AutotuneTable | None:
    ov = _OVERRIDE.get()
    if ov is not None:
        return ov
    return _INSTALLED


def install(table: AutotuneTable | None) -> AutotuneTable | None:
    """Process-wide install (entry points); None uninstalls."""
    global _INSTALLED
    _INSTALLED = table
    return table


def install_default(cache_root: str | None = None) -> AutotuneTable:
    """Load the table for the current topology from the cache root and
    install it. Empty/stale/corrupt files install an empty table — auto
    then behaves exactly like the static model until a sweep runs."""
    return install(load(default_path(cache_root)))


@contextlib.contextmanager
def use_table(table: AutotuneTable | None):
    """Scope a table for the dynamic extent of the block (tests; also how
    bench_kernels reports post-seeding auto choices)."""
    token = _OVERRIDE.set(table)
    try:
        yield table
    finally:
        _OVERRIDE.reset(token)


# ---------------------------------------------------------------------------
# The measured sweep.
# ---------------------------------------------------------------------------

# (B, T, din, dout) buckets worth measuring by default — the bench_kernels
# grid plus the production-ish tails. Interpret-mode pallas off-TPU is
# minutes-slow above ~256²; the sweep caps itself unless forced.
SWEEP_SHAPES_QUICK = ((4, 128, 128, 128), (4, 256, 256, 256))
SWEEP_SHAPES_FULL = ((4, 512, 256, 256), (8, 1024, 512, 512),
                     (8, 2048, 1024, 1024))


def _median_us(fn, args, *, warmup: int = 2, iters: int = 5) -> float:
    import numpy as np
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _op_data(op: str, shape):
    """Representative operands for one op at one (B, T, din, dout)."""
    import jax.numpy as jnp
    b, t, din, dout = shape
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
    f = jax.random.uniform(jax.random.fold_in(key, 2), (b,))
    c = jnp.full((b,), 0.5)
    if op == "norms":
        return (a, g)
    if op == "clip_sum":
        return (a, g, f)
    if op == "linear_clip":
        return (a, g, c)
    if op == "scale_contract":
        # S=2 stacked residuals (the BK epilogue's layout)
        a2 = jnp.stack([a, a * 0.5])
        g2 = jnp.stack([g, g * 2.0])
        f2 = jnp.stack([f, f])
        return (a2, g2, f2)
    if op == "paged_attn":
        return paged_attn_data(shape)
    raise ValueError(f"unknown op {op!r}; expected one of {OPS}")


def paged_attn_data(shape, *, page_len: int = 16, kv: int = 2, grp: int = 2):
    """Decode-attention operands whose table key maps t -> logical context
    and (din, dout) -> (query head dim, value head dim). Shared with
    bench_kernels so seeding and lookup agree on the bucket."""
    import jax.numpy as jnp
    b, t, din, dout = shape
    dq = min(din, 64)
    dv = min(dout, 64)
    t = max(t, page_len)
    p_tab = -(-t // page_len)
    n_pages = b * p_tab + 1  # + trash page
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (b, kv, grp, dq))
    kpool = jax.random.normal(jax.random.fold_in(key, 1),
                              (n_pages, page_len, kv, dq))
    vpool = jax.random.normal(jax.random.fold_in(key, 2),
                              (n_pages, page_len, kv, dv))
    pt = (jnp.arange(b * p_tab, dtype=jnp.int32).reshape(b, p_tab) + 1)
    pos = jnp.full((b,), t - 1, jnp.int32)
    return (q, kpool, vpool, pt, pos)


def paged_attn_dims(q, pt, page_len: int, dv: int) -> tuple[int, int, int]:
    """(t, din, dout) table coordinates for a paged_attn call."""
    return int(pt.shape[1]) * int(page_len), int(q.shape[-1]), int(dv)


def _op_fn(engine, op: str, shape):
    import functools
    if op == "paged_attn":
        b, t, din, dout = shape
        scale = 1.0 / (min(din, 64) ** 0.5)
        return jax.jit(functools.partial(engine.paged_attn, scale=scale))
    return jax.jit(getattr(engine, {
        "norms": "linear_norms_sq",
        "clip_sum": "clipped_sum_linear",
        "linear_clip": "linear_clip",
        "scale_contract": "scale_contract",
    }[op]))


def measure_op(op: str, shape, *, backends=_BACKEND_CHOICES,
               warmup: int = 2, iters: int = 5) -> dict[str, float]:
    """Median wall µs per backend for one (op, shape). Backends whose run
    fails (e.g. a kernel that cannot lower here) are skipped, not fatal."""
    from repro.kernels import backend as KB
    args = _op_data(op, shape)
    out: dict[str, float] = {}
    for name in backends:
        eng = KB.make_engine(name)
        try:
            out[name] = _median_us(_op_fn(eng, op, shape), args,
                                   warmup=warmup, iters=iters)
        except Exception:  # noqa: BLE001 - unmeasurable backend: no entry
            continue
    return out


def sweep(*, ops=OPS, shapes=None, table: AutotuneTable | None = None,
          quick: bool = True, save: bool = True,
          cache_root: str | None = None,
          progress=None) -> AutotuneTable:
    """The one-time timing sweep: measure every (op, shape, backend) and
    record the results. Idempotent — rerunning refreshes measurements."""
    if shapes is None:
        shapes = (SWEEP_SHAPES_QUICK if quick
                  else SWEEP_SHAPES_QUICK + SWEEP_SHAPES_FULL)
    if table is None:
        table = load(default_path(cache_root))
    for shape in shapes:
        b, t, din, dout = shape
        for op in ops:
            timings = measure_op(op, shape)
            for name, us in timings.items():
                if op == "paged_attn":
                    q, kp, vp, pt, pos = _op_data(op, shape)
                    tt, di, do = paged_attn_dims(q, pt, kp.shape[1],
                                                 vp.shape[-1])
                else:
                    tt, di, do = t, din, dout
                table.record(op, tt, di, do, name, us)
            if progress is not None:
                progress(op, shape, timings)
    if save:
        table.save()
    return table


def seed_from_records(records, table: AutotuneTable | None = None,
                      *, source: str = "measured") -> AutotuneTable:
    """Seed the table from bench_kernels-style records
    ({name: kernel_<op>_<backend>, t, din, dout, us_per_call}). Rows with
    no timing (skipped backends, naive baselines) are ignored."""
    if table is None:
        table = load()
    for rec in records:
        name = rec.get("name", "")
        backend_name = rec.get("backend")
        us = rec.get("us_per_call")
        if backend_name not in _BACKEND_CHOICES or not us:
            continue
        if not name.startswith("kernel_"):
            continue
        op = name[len("kernel_"):-(len(backend_name) + 1)]
        if op not in OPS:
            continue
        table.record(op, rec["t"], rec["din"], rec["dout"],
                     backend_name, float(us), source=source)
    return table


# ---------------------------------------------------------------------------
# CLI: pre-warm a fleet image / inspect the installed table.
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="measured kernel autotuner (see module docstring)")
    ap.add_argument("--sweep", action="store_true",
                    help="run the timing sweep and persist the table")
    ap.add_argument("--full", action="store_true",
                    help="sweep the production-size shapes too (off-TPU "
                         "this times interpret-mode kernels: slow)")
    ap.add_argument("--show", action="store_true",
                    help="print the persisted table for this topology")
    ap.add_argument("--cache-dir", default=None,
                    help="cache root (default <repo>/.cache or "
                         "$REPRO_CACHE_DIR)")
    args = ap.parse_args(argv)
    path = default_path(args.cache_dir)
    if args.sweep:
        def progress(op, shape, timings):
            t = {k: f"{v:.0f}us" for k, v in timings.items()}
            print(f"# {op} {shape}: {t}", flush=True)
        table = sweep(quick=not args.full, cache_root=args.cache_dir,
                      progress=progress)
        print(f"# wrote {table.path} ({len(table)} buckets)")
    if args.show or not args.sweep:
        table = load(path)
        print(json.dumps({"path": path, "topology": table.topology,
                          "stale_reason": table.stale_reason,
                          "buckets": table.entries}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
