"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

`paged_attn_ref` is special: besides being the kernel's oracle it IS the
production XLA path for paged decode attention (kernels/backend.py), and
its math is a line-for-line replica of the single-shot decode branch of
`models.attention.attend` applied to the table-gathered cache — that
replica is what makes the paged engine bitwise identical to the
contiguous engine when the logical capacity matches (page tables gather
the same values; masked score entries are exactly NEG_INF on both sides).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ghost_norm_ref(a: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """(B,) squared Frobenius norms of per-example grads A_iᵀG_i.

    a: (B, T, din); g: (B, T, dout). Direct gram-identity evaluation.
    """
    a32, g32 = a.astype(jnp.float32), g.astype(jnp.float32)
    gram_a = jnp.einsum("bti,bsi->bts", a32, a32)
    gram_g = jnp.einsum("bto,bso->bts", g32, g32)
    return jnp.sum(gram_a * gram_g, axis=(1, 2))


def clip_reduce_ref(a: jnp.ndarray, g: jnp.ndarray,
                    factors: jnp.ndarray) -> jnp.ndarray:
    """sum_i c_i A_iᵀ G_i. a: (B, T, din); g: (B, T, dout); factors: (B,)."""
    a32, g32 = a.astype(jnp.float32), g.astype(jnp.float32)
    return jnp.einsum("bti,bto->io", a32, g32 * factors[:, None, None])


def ghost_norm_blocked_ref(a: jnp.ndarray, g: jnp.ndarray, num_blocks: int,
                           block_axis: str = "out") -> jnp.ndarray:
    """(B, M) per-block squared norms via direct per-block evaluation."""
    a32, g32 = a.astype(jnp.float32), g.astype(jnp.float32)
    b, t, din = a32.shape
    dout = g32.shape[-1]
    m = num_blocks
    if block_axis == "out":
        gb = g32.reshape(b, t, m, dout // m)
        pg = jnp.einsum("bti,btmo->bmio", a32, gb)  # per-block grads
    else:
        ab = a32.reshape(b, t, m, din // m)
        pg = jnp.einsum("btmi,bto->bmio", ab, g32)
    return jnp.sum(pg * pg, axis=(2, 3))


def scale_contract_ref(a: jnp.ndarray, g: jnp.ndarray,
                       factors: jnp.ndarray) -> jnp.ndarray:
    """BK epilogue: Σ_i f[s,i] A[s,i]ᵀ G[s,i] per stack slice.

    a: (S, B, T, din); g: (S, B, T, dout); factors: (S, B) -> (S, din, dout).
    Also accepts the unstacked 3-D/(B,) form (returns (din, dout))."""
    if a.ndim == 3:
        return clip_reduce_ref(a, g, factors)
    a32, g32 = a.astype(jnp.float32), g.astype(jnp.float32)
    gs = g32 * factors[:, :, None, None].astype(jnp.float32)
    return jnp.einsum("sbti,sbto->sio", a32, gs)


def paged_attn_ref(q, kpool, vpool, pt, pos, *, scale: float,
                   dv: int | None = None) -> jnp.ndarray:
    """Paged-gather one-token attention (kernels/paged_attn.py shapes).

    q: (B, KV, G, dq); kpool: (N, L, KV, dq); vpool: (N, L, KV, dvp);
    pt: (B, P) int32; pos: (B,) int32 -> (B, KV, G, dv) float32.

    Gather k/v through the page table, then the exact einsum/softmax
    sequence of `attend`'s single-shot branch with the full-cache kpos
    validity (logical index <= pos). `dv` truncates the value read (MLA
    latents: vpool aliases kpool, values are the first `dv` features).
    """
    b, kv, g, dq = q.shape
    page_len = kpool.shape[1]
    p_tab = pt.shape[1]
    s_log = p_tab * page_len
    k = kpool[pt].reshape(b, s_log, kv, dq)
    v = vpool[pt].reshape(b, s_log, kv, vpool.shape[-1])
    if dv is not None:
        v = v[..., :dv]
    # scale BEFORE the f32 cast, exactly as `attend` does (bitwise parity
    # with the contiguous path for sub-f32 query dtypes)
    qg = (q[:, None] * scale).astype(jnp.float32)     # (B, 1, KV, G, dq)
    scores = jnp.einsum("btkgd,bskd->btkgs", qg, k.astype(jnp.float32))
    valid = jnp.arange(s_log, dtype=jnp.int32)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", w, v.astype(jnp.float32))
    return out[:, 0]


def fused_norm_clip_ref(a: jnp.ndarray, g: jnp.ndarray, c: jnp.ndarray,
                        extra_norms_sq: jnp.ndarray | None = None):
    """(norms_sq (B,), clipped summed grad) with the shared encoded-threshold
    factor (c > 0 clip, +inf pass, negative direct-scale)."""
    from repro.core.ghost import clip_factor
    n = ghost_norm_ref(a, g)
    total = n if extra_norms_sq is None else n + extra_norms_sq
    f = clip_factor(c, total)
    return n, clip_reduce_ref(a, g, f)


# Registry-op -> oracle. Every op the autotuner measures (autotune.OPS)
# must have a pure-jnp ground truth here AND a parity test exercising it;
# tests/test_kernel_registry.py enforces the bijection so a new kernel
# cannot land without its oracle.
ORACLES = {
    "norms": ghost_norm_ref,
    "clip_sum": clip_reduce_ref,
    "linear_clip": fused_norm_clip_ref,
    "scale_contract": scale_contract_ref,
    "paged_attn": paged_attn_ref,
}
