"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp


def ghost_norm_ref(a: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """(B,) squared Frobenius norms of per-example grads A_iᵀG_i.

    a: (B, T, din); g: (B, T, dout). Direct gram-identity evaluation.
    """
    a32, g32 = a.astype(jnp.float32), g.astype(jnp.float32)
    gram_a = jnp.einsum("bti,bsi->bts", a32, a32)
    gram_g = jnp.einsum("bto,bso->bts", g32, g32)
    return jnp.sum(gram_a * gram_g, axis=(1, 2))


def clip_reduce_ref(a: jnp.ndarray, g: jnp.ndarray,
                    factors: jnp.ndarray) -> jnp.ndarray:
    """sum_i c_i A_iᵀ G_i. a: (B, T, din); g: (B, T, dout); factors: (B,)."""
    a32, g32 = a.astype(jnp.float32), g.astype(jnp.float32)
    return jnp.einsum("bti,bto->io", a32, g32 * factors[:, None, None])
