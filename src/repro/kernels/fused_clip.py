"""Pallas TPU kernel: fused ghost-norm + clip + reduce in ONE pass over A, G.

The paper's Sec. 3.1 fused per-layer clipping, taken one step further: the
separate norm kernel (`ghost_norm`) and clipped-sum kernel (`clip_reduce`)
each stream A and G from HBM. This kernel computes, per example b,

    n_b  = <A_b A_bᵀ, G_b G_bᵀ>                     (ghost norm²)
    f_b  = clip_factor(c_b, n_b + extra_b)          (threshold encoding)
    dW  += f_b · A_bᵀ G_b                           (clipped summed grad)

with A and G read from HBM ONCE. `extra_b` carries norm² contributions of
co-grouped parameters (the bias of the layer) so the factor matches the
whole clipping group.

Grid = (B, T/bt, T/bt), b outermost, sequentially executed:
  * (i, j) with j >= i accumulate the gram contraction into an SMEM norm
    accumulator (off-diagonal doubled — symmetry, as in `ghost_norm`);
  * diagonal steps (i == j) also accumulate A_iᵀ G_i into a VMEM dW
    accumulator — the unscaled per-example grad, built from blocks already
    resident in VMEM for the gram pass;
  * the last step for b computes f_b from the completed norm and adds
    f_b · dW_b into the kernel output (fixed output block, revisited per b).

Feature dims are NOT tiled: the VMEM budget is 2·din·dout f32 (acc + out
block) + 4 sequence blocks, so this kernel is for din·dout up to ~1-2M
elements; the backend engine guards on `vmem_limit_bytes` and falls back to
the two-kernel composition for larger layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 256


def padded_dims(din: int, dout: int) -> tuple[int, int]:
    """Feature-dim padding this kernel applies (f32 sublane/lane tiles).

    Shared with the backend engine's VMEM guard so footprint estimates and
    actual kernel buffers stay in lockstep.
    """
    dip = -(-din // 8) * 8
    djp = -(-dout // 128) * 128 if dout > 128 else dout
    return dip, djp


def _kernel(a_i, a_j, g_i, g_j, c_ref, e_ref, n_out, dw_out, n_acc, dw_acc,
            *, nt):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    upper = j >= i

    @pl.when((i == 0) & (j == 0))
    def _init():
        n_acc[0, 0] = 0.0
        dw_acc[...] = jnp.zeros_like(dw_acc)

    @pl.when(upper)
    def _norm():
        gram_a = jax.lax.dot_general(
            a_i[0].astype(jnp.float32), a_j[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        gram_g = jax.lax.dot_general(
            g_i[0].astype(jnp.float32), g_j[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        n_acc[0, 0] += (jnp.sum(gram_a * gram_g)
                        * jnp.where(i == j, 1.0, 2.0))

    @pl.when(i == j)
    def _grad():
        dw_acc[...] += jax.lax.dot_general(
            a_i[0].astype(jnp.float32), g_i[0].astype(jnp.float32),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when((i == nt - 1) & (j == nt - 1))
    def _emit():
        # lazy import: core.__init__ transitively imports this module, so a
        # top-level import would see it partially initialized. The shared
        # encoded-threshold helper is plain jnp and runs on the VPU.
        from repro.core.ghost import clip_factor
        n = n_acc[0, 0]
        n_out[0, 0] = n
        f = clip_factor(c_ref[0, 0], n + e_ref[0, 0])
        scaled = f * dw_acc[...]
        dw_out[...] = jnp.where(b == 0, scaled, dw_out[...] + scaled)


def fused_norm_clip(a: jax.Array, g: jax.Array, c: jax.Array,
                    extra_norms_sq: jax.Array | None = None, *,
                    bt: int = DEFAULT_BT, interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array]:
    """Returns (norms_sq (B,), clipped summed grad (din, dout) f32).

    a: (B, T, din); g: (B, T, dout); c: (B,) ENCODED thresholds (see
    core.dp_layers: +inf = no clip, negative = direct scale |c|);
    extra_norms_sq: (B,) norm² of co-grouped params folded into the factor
    (e.g. the layer bias), or None. The returned norms_sq is the WEIGHT
    contribution only (caller adds extra back for the side channel).
    """
    b, t, din = a.shape
    dout = g.shape[-1]
    bt = min(bt, t)
    tp = -(-t // bt) * bt
    # pad feature dims to the f32 lane/sublane tile so MXU shapes align
    dip, djp = padded_dims(din, dout)
    a_p = jnp.pad(a, ((0, 0), (0, tp - t), (0, dip - din)))
    g_p = jnp.pad(g, ((0, 0), (0, tp - t), (0, djp - dout)))
    c2 = c.reshape(b, 1).astype(jnp.float32)
    e2 = (jnp.zeros((b, 1), jnp.float32) if extra_norms_sq is None
          else extra_norms_sq.reshape(b, 1).astype(jnp.float32))
    nt = tp // bt

    grid = (b, nt, nt)
    norms, dw = pl.pallas_call(
        functools.partial(_kernel, nt=nt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, dip), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, bt, dip), lambda bb, i, j: (bb, j, 0)),
            pl.BlockSpec((1, bt, djp), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, bt, djp), lambda bb, i, j: (bb, j, 0)),
            pl.BlockSpec((1, 1), lambda bb, i, j: (bb, 0)),
            pl.BlockSpec((1, 1), lambda bb, i, j: (bb, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), lambda bb, i, j: (bb, 0)),
            pl.BlockSpec((dip, djp), lambda bb, i, j: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((dip, djp), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.float32),      # per-example norm² acc
            pltpu.VMEM((dip, djp), jnp.float32),  # per-example grad acc
        ],
        interpret=interpret,
    )(a_p, a_p, g_p, g_p, c2, e2)
    return norms[:, 0], dw[:din, :dout]
