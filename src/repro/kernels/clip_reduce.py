"""Pallas TPU kernel: fused clip-scale-accumulate  Σ_i c_i A_iᵀ G_i.

The second half of the paper's fused per-layer clipping op: once clip
factors c_i are known, the clipped summed weight gradient is one scaled
contraction. The kernel fuses the per-row scaling into the matmul's RHS
load so the scaled G is never written to HBM:

  rows r = flattened (B·T);    grid = (din/bi, dout/bj, R/bt)  (r innermost)
  acc(bi, bj) f32 scratch; acc += A[r-block]ᵀ (G[r-block] ⊙ c[r-block])

VMEM: (bt x bi) + (bt x bj) + (bt x 1) + acc (bi x bj) f32
  = 256·256·4·3 + 256·4 ≈ 0.8 MiB.  MXU dims (bi, bj, bt) all 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BI = 256
DEFAULT_BJ = 256
DEFAULT_BT = 256


def _kernel(a_ref, g_ref, c_ref, out_ref, acc, *, nr):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a_blk = a_ref[...].astype(jnp.float32)  # (bt, bi)
    g_blk = g_ref[...].astype(jnp.float32)  # (bt, bj)
    c_blk = c_ref[...].astype(jnp.float32)  # (bt, 1)
    acc[...] += jax.lax.dot_general(
        a_blk, g_blk * c_blk, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(r == nr - 1)
    def _emit():
        out_ref[...] = acc[...]


def clip_reduce(a: jax.Array, g: jax.Array, factors: jax.Array, *,
                bi: int = DEFAULT_BI, bj: int = DEFAULT_BJ,
                bt: int = DEFAULT_BT, interpret: bool = True) -> jax.Array:
    """(din, dout) = Σ_i c_i A_iᵀ G_i.  a: (B,T,din); g: (B,T,dout);
    factors: (B,)."""
    b, t, din = a.shape
    dout = g.shape[-1]
    rows = b * t
    a2 = a.reshape(rows, din)
    g2 = g.reshape(rows, dout)
    c2 = jnp.repeat(factors.astype(jnp.float32), t)[:, None]  # (rows, 1)
    bi = min(bi, din)
    bj = min(bj, dout)
    bt = min(bt, rows)
    dip = -(-din // bi) * bi
    djp = -(-dout // bj) * bj
    rp = -(-rows // bt) * bt
    a2 = jnp.pad(a2, ((0, rp - rows), (0, dip - din)))
    g2 = jnp.pad(g2, ((0, rp - rows), (0, djp - dout)))
    c2 = jnp.pad(c2, ((0, rp - rows), (0, 0)))
    nr = rp // bt
    grid = (dip // bi, djp // bj, nr)
    out = pl.pallas_call(
        functools.partial(_kernel, nr=nr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bi), lambda i, j, r: (r, i)),
            pl.BlockSpec((bt, bj), lambda i, j, r: (r, j)),
            pl.BlockSpec((bt, 1), lambda i, j, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dip, djp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(a2, g2, c2)
    return out[:din, :dout]
