"""jit'd dispatch wrappers around the Pallas kernels.

Kernel routing is owned by the backend engine (`repro.kernels.backend`):
select it per training run with `DPConfig(backend="pallas" | "auto")` or
scope it manually with `backend.scoped(...)` — there is no module-global
switch. The wrappers here are thin jitted entry points for tests and
benchmarks that want to hit one kernel directly.

On TPU the kernels compile through Mosaic; on CPU (this container) they run
in interpret mode for correctness validation and the XLA reference paths
stay the production default. Dry-run lowering always uses the XLA paths (a
TPU custom-call cannot lower on the CPU backend). See the backend module
docstring for the full op x backend selection matrix.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.bk import scale_contract
from repro.kernels.clip_reduce import clip_reduce
from repro.kernels.fused_clip import fused_norm_clip
from repro.kernels.ghost_norm import ghost_norm, ghost_norm_blocked
from repro.kernels.paged_attn import paged_attn

_INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("bt", "dk"))
def ghost_norm_op(a, g, *, bt: int = 256, dk: int = 512):
    return ghost_norm(a, g, bt=bt, dk=dk, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("num_blocks", "block_axis", "bt", "dk"))
def ghost_norm_blocked_op(a, g, num_blocks: int, *, block_axis: str = "out",
                          bt: int = 256, dk: int = 512):
    return ghost_norm_blocked(a, g, num_blocks, block_axis=block_axis,
                              bt=bt, dk=dk, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("bi", "bj", "bt"))
def clip_reduce_op(a, g, factors, *, bi: int = 256, bj: int = 256,
                   bt: int = 256):
    return clip_reduce(a, g, factors, bi=bi, bj=bj, bt=bt,
                       interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("bt",))
def fused_norm_clip_op(a, g, c, extra_norms_sq=None, *, bt: int = 256):
    return fused_norm_clip(a, g, c, extra_norms_sq, bt=bt,
                           interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("bi", "bj", "bt"))
def scale_contract_op(a, g, factors, *, bi: int = 256, bj: int = 256,
                      bt: int = 256):
    return scale_contract(a, g, factors, bi=bi, bj=bj, bt=bt,
                          interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("scale", "dv"))
def paged_attn_op(q, kpool, vpool, pt, pos, *, scale: float,
                  dv: int | None = None):
    return paged_attn(q, kpool, vpool, pt, pos, scale=scale, dv=dv,
                      interpret=_INTERPRET)
