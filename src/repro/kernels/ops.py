"""jit'd dispatch wrappers around the Pallas kernels.

On TPU, `use_kernels(True)` routes `repro.core.ghost`'s hot paths through
pallas_call; on CPU (this container) the kernels run in interpret mode for
correctness validation and the XLA reference paths stay the production
default. Dry-run lowering always uses the XLA paths (a TPU custom-call
cannot lower on the CPU backend)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.clip_reduce import clip_reduce
from repro.kernels.ghost_norm import ghost_norm

_INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("bt", "dk"))
def ghost_norm_op(a, g, *, bt: int = 256, dk: int = 512):
    return ghost_norm(a, g, bt=bt, dk=dk, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("bi", "bj", "bt"))
def clip_reduce_op(a, g, factors, *, bi: int = 256, bj: int = 256,
                   bt: int = 256):
    return clip_reduce(a, g, factors, bi=bi, bj=bj, bt=bt,
                       interpret=_INTERPRET)
