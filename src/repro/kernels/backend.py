"""Ghost-op backend engine: pluggable dispatch for the fused-clipping ops.

Every `custom_vjp` backward rule in `repro.core.dp_layers` (and the LoRA
primitive in `repro.core.lora`) resolves its ghost ops through the engine
returned by `active()` instead of calling `repro.core.ghost` directly. Three
backends are registered:

  xla     the pure-jnp reference paths of `repro.core.ghost` (gram /
          gram_chunked / outer auto-dispatch). Always available; the
          semantics oracle for the others.
  pallas  real `pallas_call` kernels for the linear-layer hot paths
          (kernels/ghost_norm.py, kernels/clip_reduce.py,
          kernels/fused_clip.py). On TPU they compile to Mosaic; on CPU
          they run in interpret mode (correctness validation — slow, tests
          only). Ops with no kernel fall back to the xla implementations.
  auto    per-op empirical choice between the two: when an autotune table
          (repro.kernels.autotune) is installed and has measured this
          (op, shape-bucket), the measured argmin wins — on ANY jax
          backend. Unmeasured buckets fall back to the static cost model
          (`gram_path_cost` / `outer_path_cost` plus a VMEM-footprint
          guard), where the non-TPU short-circuit to xla still applies
          (interpret-mode kernels are validation-only *until measured
          faster*).

Backend selection matrix (op x backend), CPU behavior in parens:

  op                        xla            pallas (CPU)          auto on TPU
  ------------------------- -------------- --------------------- -----------------
  linear_norms_sq           gram/outer     ghost_norm (interp)   cost model + VMEM
  linear_norms_sq_blocked   einsum         ghost_norm_blocked    cost model + VMEM
  clipped_sum_linear        einsum         clip_reduce (interp)  pallas if big T
  clipped_sum_linear_blk    einsum         scale + clip_reduce   like unblocked
  linear_clip (norm+clip)   composed       fused_norm_clip*      fused if VMEM fits
  bias/embed/scale/vector   einsum/scatter = xla (no kernel)     = xla
  clipped_sum_bias/embed/.. einsum/scatter = xla (no kernel)     = xla
  paged_attn (decode)       gather+attend  paged_attn (interp)   pallas on TPU

  (*) falls back to the two-kernel composition when 2·din·dout f32 exceeds
      `vmem_limit_bytes`, or when `prefer_fused=False`. The fused kernel
      emits norms AND the clipped sum from one pallas_call, which a
      norms-only pass could not dead-code-eliminate — so the two-pass
      drivers (ghost_flat/per_group pass 1, core/clipping.py) scope
      `prefer_fused=False` around their norms-only backward.

How `auto` chooses for a linear (B, T, din, dout):
  0. `config.autotune` and the installed autotune table has a measurement
     for this (op, shape bucket) -> the measured argmin backend;
  1. outer path allowed (din·dout <= outer_max_elems) and cheaper by flops
     -> xla outer path (one einsum, no kernel beats it);
  2. else gram regime: T >= bt and the kernel's working set
     (4·bt·dk + 2·bt²) f32 fits vmem_limit_bytes -> pallas gram kernel
     (the (B,T,T) gram never touches HBM);
  3. else -> xla gram/gram_chunked.

Engine config is SCOPED, not global: `with backend.scoped("pallas"): ...`
pushes an engine for the dynamic extent of the block, so jitted step
functions capture their backend statically at trace time (this replaces the
old `ghost.configure()` module-global mutation). Unspecified fields inherit
from the enclosing scope, so e.g. the dry-run can widen `outer_max_elems`
and a `make_dp_train_step(cfg)` inside still honors it.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ghost
from repro.core.ghost import clip_factor
from repro.kernels import autotune
from repro.kernels.bk import scale_contract as scale_contract_kernel
from repro.kernels.clip_reduce import clip_reduce
from repro.kernels.fused_clip import fused_norm_clip
from repro.kernels.fused_clip import padded_dims as fused_clip_padded_dims
from repro.kernels.ghost_norm import ghost_norm, ghost_norm_blocked
from repro.kernels.paged_attn import paged_attn as paged_attn_kernel
from repro.kernels.ref import paged_attn_ref

__all__ = [
    "EngineConfig", "Backend", "XlaBackend", "PallasBackend", "AutoBackend",
    "register_backend", "backends", "make_engine", "active", "scoped",
    "clip_factor", "choose_linear_path", "choose_op",
]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static (trace-time) engine configuration.

    Jitted programs capture the active config at trace time via
    `backend.scoped(...)`; nothing here is a runtime value. Knobs:

    * `backend` (default `"xla"`; `--backend` on the CLIs, `auto` from
      `DPConfig`): `xla` einsum/gram reference paths, `pallas` TPU
      kernels (interpret-mode off TPU — correctness only), `auto`
      measured-table argmin then static cost model.
    * `outer_max_elems` / `gram_chunk`: xla path policy — max din·dout
      elements for the outer-product norms path, and the gram-matrix
      chunk size (elements along B·T). `None` inherits the
      `repro.core.ghost` module defaults.
    * `bt`, `dk`, `bi`, `bj`: pallas tile sizes (rows of the sequence,
      feature-chunk, din and dout tiles respectively; units = array
      elements). Defaults suit ~16 MB VMEM cores; the autotune sweep
      measures alternatives.
    * `interpret` (default `None` = interpret off-TPU, compiled on
      TPU): force pallas interpret mode either way.
    * `vmem_limit_bytes` (default 12 MiB): kernel-selection guard —
      `auto` rejects a pallas candidate whose working set exceeds it.
    * `prefer_fused` (default True): allow the single-pallas_call fused
      norm+clip kernel; scoped off by the two-pass drivers so the
      norms-only pass can dead-code-eliminate the unused contraction.
    * `autotune` (default True; `--autotune off` to disable): let
      measured (op, shape-bucket) entries from the installed table
      override the static model, on any jax backend.
    * `capture_residuals` (default False): BK capture pass marker —
      scoped on by `bk.capture_clipped` ONLY; primitives refuse
      BkChannels outside it (a capture pass returns zero param
      cotangents and must never be mistaken for a gradient pass).
      Interacts with `--execution bk`: the norm backprop runs under
      this scope, the epilogue (`scale_contract`) outside it.
    """

    backend: str = "xla"
    # xla path policy; None -> fall through to the repro.core.ghost module
    # globals, so legacy ghost.configure() callers stay honored
    outer_max_elems: int | None = None
    gram_chunk: int | None = None
    # pallas tile sizes
    bt: int = 256   # sequence tile (ghost_norm / fused)
    dk: int = 512   # feature-chunk tile (ghost_norm)
    bi: int = 256   # clip_reduce din tile
    bj: int = 256   # clip_reduce dout tile
    # None -> interpret off TPU, compiled on TPU; bools force it
    interpret: bool | None = None
    # VMEM-footprint guard for kernel selection (bytes)
    vmem_limit_bytes: int = 12 << 20
    # False -> linear_clip composes norm + reduce ops instead of the fused
    # kernel. Two-pass drivers (ghost_flat/per_group pass 1) scope this off:
    # they only consume norms², and XLA can dead-code-eliminate the unused
    # dW einsum of the composed path but never half of one pallas_call.
    prefer_fused: bool = True
    # True -> the auto backend consults the installed autotune table
    # (repro.kernels.autotune.installed_table()) before the static cost
    # model; measured (op, shape-bucket) argmins then win on any jax
    # backend. False pins auto to the static model regardless of tables.
    autotune: bool = True
    # True -> the dp_* custom VJPs are in a book-keeping capture pass
    # (repro.core.bk): when a BkChannel threshold reaches a primitive, its
    # backward rule emits per-example norms² AND stashes the (a, g) ghost
    # residuals through the channel's sink cotangent instead of contracting
    # weight grads. Scoped on by bk.capture_clipped only; primitives refuse
    # BkChannels outside this scope (a capture pass returns ZERO param
    # cotangents, so it must never be mistaken for a gradient pass).
    capture_residuals: bool = False


_REGISTRY: dict[str, type["Backend"]] = {}


def register_backend(name: str):
    """Class decorator: register a Backend under `name`."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


class Backend:
    """The full ghost-op surface. Base implementations are the xla
    reference paths; subclasses override the ops they accelerate."""

    name = "base"

    def __init__(self, config: EngineConfig):
        self.config = config

    def _interpret(self) -> bool:
        if self.config.interpret is not None:
            return self.config.interpret
        return jax.default_backend() != "tpu"

    # -- norms² ------------------------------------------------------------
    def linear_norms_sq(self, a, g):
        return ghost.linear_norms_sq(
            a, g, outer_max_elems=self.config.outer_max_elems,
            gram_chunk=self.config.gram_chunk)

    def linear_norms_sq_blocked(self, a, g, num_blocks, *, block_axis="out"):
        return ghost.linear_norms_sq_blocked(a, g, num_blocks,
                                             block_axis=block_axis)

    def bias_norms_sq(self, g):
        return ghost.bias_norms_sq(g)

    def embed_norms_sq(self, ids, g):
        return ghost.embed_norms_sq(ids, g,
                                    gram_chunk=self.config.gram_chunk)

    def scale_norms_sq(self, xhat, g):
        return ghost.scale_norms_sq(xhat, g)

    def vector_norms_sq(self, per_example_grad):
        return ghost.vector_norms_sq(per_example_grad)

    # -- fused clipped sums ------------------------------------------------
    def clipped_sum_linear(self, a, g, factors):
        return ghost.clipped_sum_linear(a, g, factors)

    def clipped_sum_linear_blocked(self, a, g, factors, *, block_axis="out"):
        return ghost.clipped_sum_linear_blocked(a, g, factors,
                                                block_axis=block_axis)

    def clipped_sum_bias(self, g, factors):
        return ghost.clipped_sum_bias(g, factors)

    def clipped_sum_embed(self, ids, g, factors, vocab):
        return ghost.clipped_sum_embed(ids, g, factors, vocab)

    def clipped_sum_scale(self, xhat, g, factors):
        return ghost.clipped_sum_scale(xhat, g, factors)

    # -- BK epilogue: scaled contraction over cached residuals -------------
    def scale_contract(self, a, g, factors):
        """Σ_i f[s,i] A[s,i]ᵀ G[s,i] per stack slice (repro.core.bk).

        a: (S, B, T, din); g: (S, B, T, dout); factors: (S, B) ->
        (S, din, dout) f32. Accepts the unstacked 3-D form too.
        """
        if a.ndim == 3:
            return ghost.clipped_sum_linear(a, g, factors)
        a32 = a.astype(jnp.float32)
        gs = (g.astype(jnp.float32)
              * factors[:, :, None, None].astype(jnp.float32))
        return jnp.einsum("sbti,sbto->sio", a32, gs)

    # -- paged decode attention (launch.engine data plane) -----------------
    def paged_impl(self, *, t=None, din=None, dout=None) -> str:
        """Which implementation `paged_attn` resolves to: 'xla'|'pallas'.

        The serve paths branch on this statically at trace time: the xla
        gather path is the bitwise oracle (its math replicates the
        contiguous decode exactly), the pallas kernel is the TPU
        paged-gather path (allclose-level, different softmax association).
        The auto backend takes optional shape hints so its decision can
        come from the autotune table; fixed backends ignore them.
        """
        return "xla"

    def paged_attn(self, q, kpool, vpool, pt, pos, *, scale, dv=None):
        """One-token attention through a page table (kernels/paged_attn.py
        shapes). Base = the gather + attend-replica reference."""
        return paged_attn_ref(q, kpool, vpool, pt, pos, scale=scale, dv=dv)

    # -- fused norm + clip + reduce ---------------------------------------
    def linear_clip(self, a, g, c, extra_norms_sq=None):
        """One linear layer's whole backward clip:  (n_total, f, dW).

        n_total includes `extra_norms_sq` (co-grouped params, e.g. bias);
        f = clip_factor(c, n_total); dW = sum_i f_i A_iᵀ G_i. Backends may
        fuse all three into one kernel.
        """
        n = self.linear_norms_sq(a, g)
        if extra_norms_sq is not None:
            n = n + extra_norms_sq
        f = clip_factor(c, n)
        return n, f, self.clipped_sum_linear(a, g, f)


@register_backend("xla")
class XlaBackend(Backend):
    """Pure-jnp reference paths (repro.core.ghost) — the semantics oracle."""


@register_backend("pallas")
class PallasBackend(Backend):
    """pallas_call kernels for the linear hot paths; xla fallbacks for the
    cheap ops (bias/embed/scale/vector) that have no kernel."""

    def _fused_fits(self, din: int, dout: int) -> bool:
        dip, djp = fused_clip_padded_dims(din, dout)
        bt = self.config.bt
        need = 4 * (2 * dip * djp + 2 * bt * (dip + djp))
        return need <= self.config.vmem_limit_bytes

    def linear_norms_sq(self, a, g):
        a3, g3 = ghost._as3d(a), ghost._as3d(g)
        return ghost_norm(a3, g3, bt=self.config.bt, dk=self.config.dk,
                          interpret=self._interpret())

    def linear_norms_sq_blocked(self, a, g, num_blocks, *, block_axis="out"):
        a3, g3 = ghost._as3d(a), ghost._as3d(g)
        return ghost_norm_blocked(a3, g3, num_blocks, block_axis=block_axis,
                                  bt=self.config.bt, dk=self.config.dk,
                                  interpret=self._interpret())

    def clipped_sum_linear(self, a, g, factors):
        a3, g3 = ghost._as3d(a), ghost._as3d(g)
        return clip_reduce(a3, g3, factors, bi=self.config.bi,
                           bj=self.config.bj, bt=self.config.bt,
                           interpret=self._interpret())

    def clipped_sum_linear_blocked(self, a, g, factors, *, block_axis="out"):
        # fold the per-block factors into the blocked operand (shared helper
        # with the jnp path), then run the big contraction through the
        # kernel with unit row factors
        a3, g3 = ghost.fold_block_factors(ghost._as3d(a), ghost._as3d(g),
                                          factors, block_axis)
        ones = jnp.ones((a3.shape[0],), jnp.float32)
        return clip_reduce(a3, g3, ones, bi=self.config.bi,
                           bj=self.config.bj, bt=self.config.bt,
                           interpret=self._interpret())

    def linear_clip(self, a, g, c, extra_norms_sq=None):
        a3, g3 = ghost._as3d(a), ghost._as3d(g)
        din, dout = a3.shape[-1], g3.shape[-1]
        if not self.config.prefer_fused or not self._fused_fits(din, dout):
            return super().linear_clip(a3, g3, c, extra_norms_sq)
        n_w, dw = fused_norm_clip(a3, g3, c, extra_norms_sq,
                                  bt=self.config.bt,
                                  interpret=self._interpret())
        n = n_w if extra_norms_sq is None else n_w + extra_norms_sq
        return n, clip_factor(c, n), dw

    def scale_contract(self, a, g, factors):
        return scale_contract_kernel(a, g, factors, bi=self.config.bi,
                                     bj=self.config.bj, bt=self.config.bt,
                                     interpret=self._interpret())

    def paged_impl(self, *, t=None, din=None, dout=None) -> str:
        return "pallas"

    def paged_attn(self, q, kpool, vpool, pt, pos, *, scale, dv=None):
        return paged_attn_kernel(q, kpool, vpool, pt, pos, scale=scale,
                                 dv=dv, interpret=self._interpret())


def choose_linear_path(t: int, din: int, dout: int, config: EngineConfig,
                       *, on_tpu: bool | None = None) -> str:
    """The STATIC cost model's decision for one linear ghost op:
    'xla'|'pallas'. This is the fallback for shape buckets the autotune
    table has never measured (`choose_op` is the full decision); pure
    function of static shapes + config, exposed for tests and for the
    benchmark sweep to report what the model alone would pick.
    """
    if on_tpu is None:
        on_tpu = jax.default_backend() == "tpu"
    if not on_tpu and config.interpret is not True:
        # unmeasured + off-TPU: interpret-mode kernels are validation-only
        # (a MEASURED interpret-mode win is honored by choose_op above)
        return "xla"
    outer_cap = (ghost._OUTER_MAX_ELEMS if config.outer_max_elems is None
                 else config.outer_max_elems)
    outer_ok = din * dout <= outer_cap
    if outer_ok and (ghost.outer_path_cost(t, din, dout)
                     < ghost.gram_path_cost(t, din, dout)):
        return "xla"  # one einsum, transient fits: nothing to fuse
    if t < config.bt:
        return "xla"  # sub-tile sequence: kernel grid degenerates
    working_set = 4 * (4 * config.bt * config.dk + 2 * config.bt * config.bt)
    if working_set > config.vmem_limit_bytes:
        return "xla"
    return "pallas"


def choose_op(op: str, t: int, din: int, dout: int, config: EngineConfig,
              *, on_tpu: bool | None = None,
              table: "autotune.AutotuneTable | None" = None) -> str:
    """The auto backend's FULL decision for one engine op: measured argmin
    from the autotune table when this (op, shape bucket) has measurements
    — honored on any jax backend — else the static model.

    op is one of `autotune.OPS`; `table=None` consults the installed table
    (`autotune.installed_table()`), which entry points install under their
    --autotune knob and tests scope with `autotune.use_table`.
    """
    if config.autotune:
        tab = table if table is not None else autotune.installed_table()
        if tab is not None:
            measured = tab.best(op, t, din, dout)
            if measured is not None:
                return measured
    if op == "paged_attn":
        # static fallback: the paged-gather DMA only pays off on TPU;
        # off-TPU the xla gather path is the bitwise oracle
        if on_tpu is None:
            on_tpu = jax.default_backend() == "tpu"
        return "pallas" if (on_tpu or config.interpret is True) else "xla"
    return choose_linear_path(t, din, dout, config, on_tpu=on_tpu)


@register_backend("auto")
class AutoBackend(Backend):
    """Cost-model dispatch between the xla and pallas backends per op."""

    def __init__(self, config: EngineConfig):
        super().__init__(config)
        self._xla = XlaBackend(config)
        self._pallas = PallasBackend(config)

    def _pick(self, op: str, a, g) -> Backend:
        a3, g3 = ghost._as3d(a), ghost._as3d(g)
        t, din, dout = a3.shape[1], a3.shape[-1], g3.shape[-1]
        choice = choose_op(op, t, din, dout, self.config)
        return self._pallas if choice == "pallas" else self._xla

    # blocked variants run the same underlying kernels as their unblocked
    # ops, so they share the "norms"/"clip_sum" table buckets
    def linear_norms_sq(self, a, g):
        return self._pick("norms", a, g).linear_norms_sq(a, g)

    def linear_norms_sq_blocked(self, a, g, num_blocks, *, block_axis="out"):
        return self._pick("norms", a, g).linear_norms_sq_blocked(
            a, g, num_blocks, block_axis=block_axis)

    def clipped_sum_linear(self, a, g, factors):
        return self._pick("clip_sum", a, g).clipped_sum_linear(a, g, factors)

    def clipped_sum_linear_blocked(self, a, g, factors, *, block_axis="out"):
        return self._pick("clip_sum", a, g).clipped_sum_linear_blocked(
            a, g, factors, block_axis=block_axis)

    def linear_clip(self, a, g, c, extra_norms_sq=None):
        return self._pick("linear_clip", a, g).linear_clip(
            a, g, c, extra_norms_sq)

    def scale_contract(self, a, g, factors):
        if a.ndim == 3:
            return self._pick("scale_contract", a, g).scale_contract(
                a, g, factors)
        t, din, dout = a.shape[2], a.shape[-1], g.shape[-1]
        choice = choose_op("scale_contract", t, din, dout, self.config)
        eng = self._pallas if choice == "pallas" else self._xla
        return eng.scale_contract(a, g, factors)

    def paged_impl(self, *, t=None, din=None, dout=None) -> str:
        """With shape hints (logical context, query dim, value dim) this
        consults the autotune table like every other op; without hints —
        or unmeasured — the static rule applies: pallas only where the
        paged-gather DMA pays off (TPU), xla's bitwise-oracle gather path
        elsewhere (unless interpret is forced)."""
        if t is not None:
            return choose_op("paged_attn", t, din or 0, dout or 0,
                             self.config)
        if jax.default_backend() == "tpu" or self.config.interpret is True:
            return "pallas"
        return "xla"

    def paged_attn(self, q, kpool, vpool, pt, pos, *, scale, dv=None):
        t, din, dout = autotune.paged_attn_dims(
            q, pt, kpool.shape[1], dv if dv is not None else vpool.shape[-1])
        impl = self.paged_impl(t=t, din=din, dout=dout)
        eng = self._pallas if impl == "pallas" else self._xla
        return eng.paged_attn(q, kpool, vpool, pt, pos, scale=scale, dv=dv)


# ---------------------------------------------------------------------------
# Scoped engine resolution.
# ---------------------------------------------------------------------------

_DEFAULT: Backend | None = None
# context-local, not a process-global list: concurrent tracers (threads /
# async tasks) each see their own scope stack and cannot cross-contaminate
_STACK: contextvars.ContextVar[tuple[Backend, ...]] = contextvars.ContextVar(
    "ghost_backend_stack", default=())


def make_engine(backend: str | None = None, **overrides) -> Backend:
    """Build an engine; unspecified fields inherit from the active scope."""
    base = active().config
    cfg = dataclasses.replace(
        base, backend=base.backend if backend is None else backend,
        **overrides)
    try:
        cls = _REGISTRY[cfg.backend]
    except KeyError:
        raise ValueError(
            f"unknown ghost backend {cfg.backend!r}; "
            f"registered: {backends()}") from None
    return cls(cfg)


def active() -> Backend:
    """The engine in effect (innermost `scoped`, else the xla default)."""
    stack = _STACK.get()
    if stack:
        return stack[-1]
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = XlaBackend(EngineConfig())
    return _DEFAULT


@contextlib.contextmanager
def scoped(backend: str | None = None, **overrides):
    """Push an engine for the dynamic extent of the block.

    Trace jitted functions inside the block and they capture the engine
    statically; nesting composes (inner scopes inherit unspecified fields).
    """
    eng = make_engine(backend, **overrides)
    token = _STACK.set(_STACK.get() + (eng,))
    try:
        yield eng
    finally:
        _STACK.reset(token)
