"""Pallas paged-gather decode attention.

One-token GQA/MQA attention that reads K/V THROUGH a per-row page table
instead of a contiguous (B, S, ...) cache. The page table is a scalar-
prefetch operand (`pltpu.PrefetchScalarGridSpec`), so the physical page id
feeds the K/V BlockSpec index_map directly: grid step (b, p) DMAs physical
page `pt[b, p]` into VMEM — the gather happens in the pipeline's address
generation and the (B, S) gathered cache is never materialized in HBM.

Softmax is the standard online accumulation over page steps (running max
/ denominator / weighted-value scratch in VMEM, emitted at the last page),
identical in structure to a flash decode kernel with `page_len`-sized KV
blocks. Validity masking reuses the engine's kpos algebra: logical index
`p * page_len + i` attends iff `<= pos[b]` — partially filled last pages
and trash-mapped (unallocated) table entries mask out for free.

Shapes (decode only, T == 1):
  q:     (B, KV, G, dq)   post-RoPE, UNscaled query, G = heads per KV head
  kpool: (N, L, KV, dq)   physical page pool (N pages of L tokens)
  vpool: (N, L, KV, dvp)  value pool; may alias kpool (MLA latents) with
                          the value read truncated to `dv` (dv <= dvp)
  pt:    (B, P) int32     page table (any id in [0, N); invalid entries
                          must still be IN RANGE — point them at a trash
                          page, the pos mask discards their scores)
  pos:   (B,)   int32     index of the newest written token (all logical
                          indices <= pos are valid)
  out:   (B, KV, G, dv) float32

The pure-jnp oracle is `repro.kernels.ref.paged_attn_ref` (which is also
the production XLA backend path — see kernels/backend.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, page_len: int, dv: int,
                       scale: float, num_pt_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (KV, G, dq)
    k = k_ref[0].astype(jnp.float32)                  # (L, KV, dq)
    v = v_ref[0, :, :, :dv].astype(jnp.float32)       # (L, KV, dv)

    kt = jnp.transpose(k, (1, 0, 2))                  # (KV, L, dq)
    s = jax.lax.dot_general(q, kt, (((2,), (2,)), ((0,), (0,))))  # (KV,G,L)

    # kpos validity: logical index of row i on this page is p*L + i
    idx = p * page_len + jax.lax.broadcasted_iota(jnp.int32, (1, page_len), 1)
    valid = (idx <= pos_ref[b])[0]                    # (L,)
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_prev * alpha + jnp.sum(pexp, axis=-1)
    m_ref[...] = m_new
    vt = jnp.transpose(v, (1, 0, 2))                  # (KV, L, dv)
    pv = jax.lax.dot_general(pexp, vt, (((2,), (1,)), ((0,), (0,))))
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(p == num_pt_pages - 1)
    def _emit():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]


def paged_attn(q, kpool, vpool, pt, pos, *, scale: float,
               dv: int | None = None, interpret: bool = False):
    """Paged-gather decode attention (see module doc). Returns
    (B, KV, G, dv) float32."""
    b, kv, g, dq = q.shape
    n_pages, page_len = kpool.shape[0], kpool.shape[1]
    dvp = vpool.shape[-1]
    dv = dvp if dv is None else dv
    p_tab = pt.shape[1]

    kernel = functools.partial(
        _paged_attn_kernel, page_len=page_len, dv=dv, scale=float(scale),
        num_pt_pages=p_tab)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # pt, pos
        grid=(b, p_tab),
        in_specs=[
            pl.BlockSpec((1, kv, g, dq),
                         lambda bb, pp, pt_s, pos_s: (bb, 0, 0, 0)),
            pl.BlockSpec((1, page_len, kv, dq),
                         lambda bb, pp, pt_s, pos_s: (pt_s[bb, pp], 0, 0, 0)),
            pl.BlockSpec((1, page_len, kv, dvp),
                         lambda bb, pp, pt_s, pos_s: (pt_s[bb, pp], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kv, g, dv),
                               lambda bb, pp, pt_s, pos_s: (bb, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),       # running max
            pltpu.VMEM((kv, g), jnp.float32),       # running denominator
            pltpu.VMEM((kv, g, dv), jnp.float32),   # weighted-value acc
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dv), jnp.float32),
        interpret=interpret,
    )(pt.astype(jnp.int32), pos.astype(jnp.int32), q, kpool, vpool)
