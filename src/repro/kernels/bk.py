"""Pallas TPU kernel for the book-keeping (BK) epilogue:  Σ_i f_i A_iᵀ G_i
per stack element, in ONE pass over the cached ghost residuals.

The BK execution engine (Bu et al. 2022, arXiv:2210.00038; see
`repro.core.bk`) replaces the second backward pass of flat / per-group
clipping with a cheap contraction over residuals (a, g) cached during the
single norm-computing backprop. This kernel is that contraction for linear
layers, including the scanned-layer case where residuals carry a leading
stack axis S (one slice per scanned layer):

    out[s] = Σ_i f[s, i] · A[s, i]ᵀ G[s, i]        (din × dout, f32)

Layout mirrors `clip_reduce`: rows r = flattened (B·T) per stack slice,
grid = (S, din/bi, dout/bj, R/bt) with r innermost and sequential; the
per-row factor is fused into the RHS load so the scaled G never exists in
HBM. VMEM per step: (bt×bi) + (bt×bj) + (bt×1) inputs + (bi×bj) f32
accumulator ≈ 0.8 MiB at the 256-tile defaults — same budget as
clip_reduce, once per stack slice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BI = 256
DEFAULT_BJ = 256
DEFAULT_BT = 256


def _kernel(a_ref, g_ref, f_ref, out_ref, acc, *, nr):
    r = pl.program_id(3)

    @pl.when(r == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a_blk = a_ref[0].astype(jnp.float32)  # (bt, bi)
    g_blk = g_ref[0].astype(jnp.float32)  # (bt, bj)
    f_blk = f_ref[0].astype(jnp.float32)  # (bt, 1)
    acc[...] += jax.lax.dot_general(
        a_blk, g_blk * f_blk, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(r == nr - 1)
    def _emit():
        out_ref[0] = acc[...]


def scale_contract(a: jax.Array, g: jax.Array, factors: jax.Array, *,
                   bi: int = DEFAULT_BI, bj: int = DEFAULT_BJ,
                   bt: int = DEFAULT_BT, interpret: bool = True) -> jax.Array:
    """(S, din, dout) = Σ_i f[s,i] A[s,i]ᵀ G[s,i] from cached BK residuals.

    a: (S, B, T, din) or (B, T, din); g: same leading shape with dout;
    factors: (S, B) or (B,). The 3-D form returns (din, dout).
    """
    squeeze = a.ndim == 3
    if squeeze:
        a, g, factors = a[None], g[None], factors[None]
    s, b, t, din = a.shape
    dout = g.shape[-1]
    rows = b * t
    a2 = a.reshape(s, rows, din)
    g2 = g.reshape(s, rows, dout)
    f2 = jnp.repeat(factors.astype(jnp.float32), t, axis=-1)[..., None]
    bi = min(bi, din)
    bj = min(bj, dout)
    bt = min(bt, rows)
    dip = -(-din // bi) * bi
    djp = -(-dout // bj) * bj
    rp = -(-rows // bt) * bt
    a2 = jnp.pad(a2, ((0, 0), (0, rp - rows), (0, dip - din)))
    g2 = jnp.pad(g2, ((0, 0), (0, rp - rows), (0, djp - dout)))
    f2 = jnp.pad(f2, ((0, 0), (0, rp - rows), (0, 0)))
    nr = rp // bt
    grid = (s, dip // bi, djp // bj, nr)
    out = pl.pallas_call(
        functools.partial(_kernel, nr=nr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bi), lambda ss, i, j, r: (ss, r, i)),
            pl.BlockSpec((1, bt, bj), lambda ss, i, j, r: (ss, r, j)),
            pl.BlockSpec((1, bt, 1), lambda ss, i, j, r: (ss, r, 0)),
        ],
        out_specs=pl.BlockSpec((1, bi, bj), lambda ss, i, j, r: (ss, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, dip, djp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(a2, g2, f2)
    out = out[:, :din, :dout]
    return out[0] if squeeze else out
