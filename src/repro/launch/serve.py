"""Serving driver: batched greedy decode against the KV/state cache.

CPU demo at reduced scale; the identical serve_step lowers on the
production mesh (see launch.dryrun decode shapes).

Prefill is FUSED by default: the whole prompt is consumed by one jitted
`lax.scan` over positions — a single XLA dispatch that builds the decode
cache, instead of P eager `serve_step` dispatches each paying a python
round-trip (the perf extension previously flagged here). The historical
token-at-a-time loop stays behind `--prefill loop` as the reference path
(same math, same cache; only the dispatch granularity differs).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \\
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.spec import init_params
from repro.models.transformer import build_model


def fused_prefill(model, params, prompts: jnp.ndarray, cache_len: int):
    """One jitted scan over the prompt: returns (last logits, filled cache).

    Call through `jax.jit` (see `greedy_decode`): the P decode steps fuse
    into one dispatch whose cache round-trips stay on device.
    """
    b = prompts.shape[0]
    cache = model.init_cache(b, cache_len)

    def step(cache, tok):
        logits, cache = model.serve_step(params, cache, {"token": tok[:, None]})
        return cache, logits

    cache, logits = jax.lax.scan(step, cache, prompts.T)  # scan over P
    return logits[-1], cache


def greedy_decode(model, params, prompts: jnp.ndarray, gen: int,
                  cache_len: int, *, prefill: str = "fused"):
    """prompts: (B, P) int32. prefill: 'fused' (single jitted scan) or
    'loop' (reference: one dispatch per token)."""
    b, p = prompts.shape
    step = jax.jit(model.serve_step)
    if prefill == "fused":
        pf = jax.jit(lambda pr, ps: fused_prefill(model, ps, pr, cache_len))
        logits, cache = pf(prompts, params)
    else:
        cache = model.init_cache(b, cache_len)
        logits = None
        for t in range(p):
            logits, cache = step(params, cache, {"token": prompts[:, t:t + 1]})
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen):
        out.append(tok)
        logits, cache = step(params, cache, {"token": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny", choices=ARCH_IDS + ["tiny"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--prefill", default="fused", choices=["fused", "loop"],
                    help="fused: single jitted scan over the prompt (one "
                         "dispatch); loop: reference token-at-a-time path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(args.seed))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = greedy_decode(model, params, prompts,
                         args.gen, args.prompt_len + args.gen + 8,
                         prefill=args.prefill)
    wall = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen)
    print(f"# arch={cfg.name} batch={args.batch} prefill={args.prefill} "
          f"generated {args.gen} tokens/seq in {wall:.2f}s "
          f"({total / wall:.1f} tok/s incl. prefill)")
    print(np.asarray(toks)[:, :16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
