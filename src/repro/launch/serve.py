"""Serving driver: ragged-batch greedy decode and the slot-pool engine.

Two entry points share the model's `serve_step`:

  * `greedy_decode` / `fused_prefill` — the STATIC-batch reference path.
    Prompts may be right-padded ragged (`lengths=`): pad tokens are
    length-masked out of the cache (serve_step's `active` row mask) and
    the first generated token comes from each sequence's TRUE last prompt
    token, so a ragged batch decodes exactly like each prompt run alone
    unpadded. Prefill is fused by default (one jitted `lax.scan` over the
    prompt — a single XLA dispatch); `--prefill loop` keeps the
    token-at-a-time dispatch loop as the reference oracle.
  * `launch.engine.DecodeEngine` — continuous batching over a fixed slot
    pool: requests admitted mid-flight, one dispatch advances all live
    slots, EOS/max-token retirement and slot recycling. The CLI serves a
    ragged synthetic request set through it by default (`--mode engine`).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \\
      --batch 4 --prompt-len 16 --min-prompt-len 4 --gen 32 --slots 3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.spec import init_params
from repro.models.transformer import build_model


def fused_prefill(model, params, prompts: jnp.ndarray, cache_len: int,
                  lengths: jnp.ndarray | None = None):
    """One jitted scan over the prompt: returns (last logits, filled cache).

    prompts: (B, P) right-padded; lengths: optional (B,) true prompt
    lengths (None means every row uses all P tokens). Pad positions are
    masked out of the cache and the returned logits are each row's TRUE
    last-token logits (float32), not `logits[-1]`.

    Call through `jax.jit` (see `greedy_decode`): the P decode steps fuse
    into one dispatch whose cache round-trips stay on device.
    """
    b, p = prompts.shape
    cache = model.init_cache(b, cache_len)
    last0 = jnp.zeros((b, model.cfg.vocab_size), jnp.float32)

    if lengths is None:
        # equal-length fast path: no row mask, plain cache writes
        def step(carry, tok):
            cache, _ = carry
            logits, cache = model.serve_step(params, cache,
                                             {"token": tok[:, None]})
            return (cache, logits.astype(jnp.float32)), None

        (cache, last), _ = jax.lax.scan(step, (cache, last0), prompts.T)
        return last, cache

    def step(carry, xs):
        cache, last = carry
        tok, t = xs
        act = t < lengths
        logits, cache = model.serve_step(
            params, cache, {"token": tok[:, None], "active": act})
        last = jnp.where(act[:, None], logits.astype(jnp.float32), last)
        return (cache, last), None

    (cache, last), _ = jax.lax.scan(
        step, (cache, last0),
        (prompts.T, jnp.arange(p, dtype=jnp.int32)))  # scan over P
    return last, cache


def _jitted(model, key, build):
    """Per-model cache of jitted serving programs, so repeat greedy_decode
    calls (examples, benchmarks) re-dispatch instead of re-tracing."""
    cache = getattr(model, "_serve_jit_cache", None)
    if cache is None:
        cache = model._serve_jit_cache = {}
    if key not in cache:
        cache[key] = jax.jit(build())
    return cache[key]


def greedy_decode(model, params, prompts: jnp.ndarray, gen: int,
                  cache_len: int, *, prefill: str = "fused",
                  lengths=None):
    """prompts: (B, P) int32, right-padded if ragged; lengths: optional
    (B,) true prompt lengths. prefill: 'fused' (single jitted scan) or
    'loop' (reference oracle: one dispatch per token — same math)."""
    b, p = prompts.shape
    if p == 0:
        raise ValueError(
            "empty prompt (P == 0): greedy_decode needs at least one prompt "
            "token per sequence — seed requests with a BOS token")
    step = _jitted(model, "step", lambda: model.serve_step)
    if prefill == "fused":
        if lengths is None:
            pf = _jitted(
                model, ("prefill", cache_len),
                lambda: lambda pr, ps: fused_prefill(model, ps, pr,
                                                     cache_len))
            logits, cache = pf(prompts, params)
        else:
            ln = jnp.asarray(lengths, jnp.int32)
            pf = _jitted(
                model, ("prefill_ragged", cache_len),
                lambda: lambda pr, l, ps: fused_prefill(model, ps, pr,
                                                        cache_len, l))
            logits, cache = pf(prompts, ln, params)
    else:
        cache = model.init_cache(b, cache_len)
        logits = jnp.zeros((b, model.cfg.vocab_size), jnp.float32)
        ln = (None if lengths is None
              else jnp.asarray(lengths, jnp.int32))
        for t in range(p):
            if ln is None:  # equal-length fast path: no row mask
                lg, cache = step(params, cache,
                                 {"token": prompts[:, t:t + 1]})
                logits = lg.astype(jnp.float32)
                continue
            act = jnp.full((b,), t, jnp.int32) < ln
            lg, cache = step(params, cache,
                             {"token": prompts[:, t:t + 1], "active": act})
            # true-last-token gather: only rows still inside their prompt
            # update, so the final value is each row's length-1 logits
            logits = jnp.where(act[:, None], lg.astype(jnp.float32), logits)
    if gen <= 0:
        return jnp.zeros((b, 0), jnp.int32)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen):
        out.append(tok)
        logits, cache = step(params, cache, {"token": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def build_serve_parser() -> argparse.ArgumentParser:
    """The serve CLI's argument surface (importable so tests/docs can
    introspect it — tests/test_docs.py asserts every flag is documented)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny", choices=ARCH_IDS + ["tiny"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests in the synthetic set")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="maximum prompt length")
    ap.add_argument("--min-prompt-len", type=int, default=None,
                    help="minimum prompt length (default = --prompt-len, "
                         "i.e. an equal-length batch; set lower for a "
                         "ragged request set)")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mode", default="engine", choices=["engine", "batch"],
                    help="engine: continuous-batching slot pool "
                         "(launch.engine.DecodeEngine); batch: the static "
                         "padded-batch greedy_decode reference")
    ap.add_argument("--slots", type=int, default=None,
                    help="engine slot-pool size (default = --batch)")
    ap.add_argument("--prefill", default="fused", choices=["fused", "loop"],
                    help="batch mode: fused = single jitted scan over the "
                         "prompt (one dispatch); loop = reference "
                         "token-at-a-time oracle")
    ap.add_argument("--paging", default="auto", choices=["auto", "on", "off"],
                    help="engine mode KV data plane: auto pages "
                         "full-attention families (block pool + page "
                         "tables + prefix sharing), off keeps per-slot "
                         "contiguous caches, on forces paging")
    ap.add_argument("--page-len", type=int, default=16,
                    help="tokens per physical KV page")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (default: slots * cache pages, "
                         "i.e. the contiguous footprint; set lower to "
                         "exercise eviction/spill)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many common system-prompt tokens "
                         "to every request; full pages of it are shared "
                         "physically when paging is on")
    ap.add_argument("--backend", default="auto",
                    choices=["xla", "pallas", "auto"],
                    help="decode-attention engine scope "
                         "(repro.kernels.backend); auto resolves the "
                         "paged-attention path from the measured autotune "
                         "table, falling back to xla off-TPU")
    ap.add_argument("--autotune", default="on", choices=["on", "off"],
                    help="on: auto consults the measured table for this "
                         "topology (repro.kernels.autotune)")
    ap.add_argument("--cache", default="on", choices=["on", "off"],
                    help="persistent compilation cache: warm starts "
                         "deserialize the serving programs instead of "
                         "recompiling (repro.launch.compile_cache)")
    ap.add_argument("--cache-dir", default=None,
                    help="cache root (default <repo>/.cache or "
                         "$REPRO_CACHE_DIR)")
    ap.add_argument("--tenants", type=int, default=None,
                    help="serve multi-tenant: adapter-slot count of the "
                         "tenant-stacked DP-LoRA buffer (engine mode only; "
                         "implies --lora-rank > 0); requests round-robin "
                         "over the tenants")
    ap.add_argument("--lora-rank", type=int, default=4,
                    help="adapter rank for multi-tenant serving (must "
                         "match the rank the adapters were trained at)")
    ap.add_argument("--adapter-dir", action="append", default=None,
                    metavar="DIR",
                    help="publish directory of a training service "
                         "(<service_dir>/publish) to load tenant adapters "
                         "from; repeatable — one tenant per directory, "
                         "extra tenants (up to --tenants) serve the base "
                         "model")
    ap.add_argument("--watch", action="store_true",
                    help="poll each --adapter-dir between pool steps and "
                         "hot-swap newly published adapters into the live "
                         "engine (launch.swap.AdapterWatcher)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main():
    args = build_serve_parser().parse_args()

    from repro.launch.train import record_cache_program, setup_caches
    setup_caches(args)

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.tenants is not None:
        if args.mode != "engine":
            raise SystemExit("--tenants requires --mode engine")
        import dataclasses as _dc
        cfg = _dc.replace(cfg, lora_rank=args.lora_rank)
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(args.seed))
    record_cache_program(args, entry="serve", arch=cfg.name)

    from repro.launch.inputs import pad_ragged_prompts, synthetic_requests
    lo = (args.prompt_len if args.min_prompt_len is None
          else args.min_prompt_len)
    reqs = synthetic_requests(cfg.vocab_size, args.batch, min_len=lo,
                              max_len=args.prompt_len, seed=1)
    if args.shared_prefix:
        rng = np.random.RandomState(args.seed + 100)
        sysp = rng.randint(1, cfg.vocab_size,
                           args.shared_prefix).astype(np.int32)
        reqs = [np.concatenate([sysp, np.asarray(r, np.int32)])
                for r in reqs]
    cache_len = args.shared_prefix + args.prompt_len + args.gen + 8

    # scoped engine: the serving traces capture the backend (and its
    # autotune consultation) statically, exactly like the train step
    from contextlib import ExitStack

    from repro.kernels import backend as KB
    scope = ExitStack()
    scope.enter_context(KB.scoped(args.backend,
                                  autotune=args.autotune != "off"))

    t0 = time.time()
    if args.mode == "engine":
        from repro.launch.engine import DecodeEngine
        num_slots = args.batch if args.slots is None else args.slots
        if args.paging != "off":
            # paging needs cache_len % page_len == 0 (that divisibility is
            # what makes the paged plane bitwise-identical); round up
            cache_len = -(-cache_len // args.page_len) * args.page_len
        eng = DecodeEngine(model, params, num_slots=num_slots,
                           cache_len=cache_len, paging=args.paging,
                           page_len=args.page_len, num_pages=args.num_pages,
                           max_tenants=args.tenants)
        watchers = []
        tids = [None]
        if args.tenants is not None:
            from repro.launch.swap import AdapterWatcher
            tids = [eng.add_tenant(name=f"tenant-{i}")
                    for i in range(args.tenants)]
            for tid, d in zip(tids, args.adapter_dir or []):
                w = AdapterWatcher(eng, tid, d)
                got = w.poll()  # install whatever is already published
                print(f"# tenant {tid} <- {d}: "
                      f"{'step ' + str(got.step) if got else 'base model'}")
                watchers.append(w)
        for i, r in enumerate(reqs):
            eng.submit(r, max_new_tokens=args.gen,
                       tenant=tids[i % len(tids)])
        if args.watch and watchers:
            # pump the pool in short bursts, polling the publish dirs in
            # the gaps — a swap lands between dispatches, never inside one
            done = {}
            while eng.num_pending or eng.num_live:
                eng.run(max_steps=8)
                for w in watchers:
                    got = w.poll()
                    if got is not None:
                        print(f"# hot swap: tenant {got.tenant} -> step "
                              f"{got.step} (v{got.version}, bitwise ok)")
            done = eng.completions()
        else:
            done = eng.run()
        wall = time.time() - t0
        toks = np.full((args.batch, args.gen), -1, np.int32)
        for rid, c in done.items():
            toks[rid, :len(c.tokens)] = c.tokens
        extra = (f"slots={eng.num_slots} "
                 f"dispatches={eng.stats['decode_dispatches']}d"
                 f"+{eng.stats['prefill_dispatches']}p "
                 f"paged={'yes' if eng.paged else 'no'}")
        if eng.multi_tenant:
            extra += (f" tenants={len(tids)} "
                      f"swaps={eng.stats['adapter_swaps']} "
                      f"traces={sum(eng.trace_counts.values())}")
            for tid in tids:
                ts = eng.tenant_stats(tid)
                print(f"# tenant {tid} ({ts['name']}): v{ts['version']} "
                      f"done={ts['requests_done']} "
                      f"tokens={ts['tokens_out']}")
        if eng.paged:
            s = eng.stats
            extra += (f" pages={eng.num_pages}x{eng.page_len} "
                      f"peak_pages={s['peak_pages_in_use']} "
                      f"prefix_hits={s['prefix_hits']} "
                      f"shared={s['shared_pages']} "
                      f"evicted={s['evicted_pages']} "
                      f"readmitted={s['readmitted_pages']} "
                      f"cache_mb={eng.cache_bytes() / 2**20:.1f}")
    else:
        prompts, lengths = pad_ragged_prompts(reqs)
        toks = np.asarray(greedy_decode(
            model, params, jnp.asarray(prompts), args.gen, cache_len,
            prefill=args.prefill, lengths=jnp.asarray(lengths)))
        wall = time.time() - t0
        extra = f"prefill={args.prefill}"
    scope.close()
    total = sum(len(r) for r in reqs) + args.batch * args.gen
    print(f"# arch={cfg.name} mode={args.mode} batch={args.batch} "
          f"prompt_lens={[len(r) for r in reqs]} {extra} "
          f"generated {args.gen} tokens/seq in {wall:.2f}s "
          f"({total / wall:.1f} tok/s incl. prefill)")
    print(toks[:, :16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
