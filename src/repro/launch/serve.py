"""Serving driver: batched greedy decode against the KV/state cache.

CPU demo at reduced scale; the identical serve_step lowers on the
production mesh (see launch.dryrun decode shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \\
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.spec import init_params
from repro.models.transformer import build_model


def greedy_decode(model, params, prompts: jnp.ndarray, gen: int,
                  cache_len: int):
    """prompts: (B, P) int32. Prefill by stepping tokens one at a time
    (decode-path prefill keeps one code path; a fused prefill is the
    serve-side perf extension tracked in EXPERIMENTS.md)."""
    b, p = prompts.shape
    cache = model.init_cache(b, cache_len)
    step = jax.jit(model.serve_step)
    logits = None
    for t in range(p):
        logits, cache = step(params, cache, {"token": prompts[:, t:t + 1]})
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen):
        out.append(tok)
        logits, cache = step(params, cache, {"token": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny", choices=ARCH_IDS + ["tiny"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(args.seed))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = greedy_decode(model, params, prompts,
                         args.gen, args.prompt_len + args.gen + 8)
    wall = time.time() - t0
    total = args.batch * (args.prompt_len + args.gen)
    print(f"# arch={cfg.name} batch={args.batch} generated "
          f"{args.gen} tokens/seq in {wall:.2f}s "
          f"({total / wall:.1f} tok/s incl. prefill)")
    print(np.asarray(toks)[:, :16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
