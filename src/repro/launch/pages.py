"""Paged KV-cache data plane: page pool, page tables, prefix sharing.

The contiguous decode cache gives every slot `cache_len` rows up front, so
HBM scales as slots x max-context and one long request strands capacity the
pool could be serving. This module is the HOST-side bookkeeping of the
vLLM-style fix: a fixed physical pool of `(num_pages, page_len, ...)` KV
blocks, per-slot int32 page tables mapping logical pages -> physical pages,
and refcounted pages so requests sharing a common prefix (system prompt)
map the SAME physical pages.

Sharing is full-page granularity (vLLM block-hash style): only whole pages
whose `page_len` tokens match byte-for-byte are shared, so the first
divergent write always lands on a page boundary and "copy-on-write" never
copies — a fork is just: map the shared prefix pages (+refcount), allocate
private pages from the fork point on. The shared pages are never written
by any holder (every holder's write position starts past them), and since
keys are stored post-RoPE at absolute positions the shared K/V state is
bitwise identical to what the forker would have computed itself.

Tiered eviction: registered prefixes whose pages are otherwise idle can be
spilled to a HOST-memory tier (the engine fetches the page bytes and calls
`PrefixStore.spill`), freeing device pages; a later prefix hit against a
host-tier entry is re-admitted by uploading into freshly allocated pages.
The roundtrip is a bitwise copy, so a request resuming on re-admitted
pages decodes token-for-token identically.

Device arrays never appear here — `launch.engine.DecodeEngine` owns the
pool tensors and executes the fetch/upload plans; everything in this
module is numpy/host state, unit-testable without a model.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PagePool", "PrefixStore", "Prefix", "pages_needed"]


def pages_needed(tokens: int, page_len: int) -> int:
    """Physical pages required to hold `tokens` cache rows."""
    return -(-tokens // page_len)


class PagePool:
    """Free-list allocator over `num_pages` physical pages with refcounts.

    A page is FREE (on the free list, rc == 0) or HELD (rc >= 1). Holders
    are slot page-table mappings and prefix-registry entries; each holds
    one reference. `decref` returns pages whose count hit zero to the free
    list. The pool knows nothing about what a page stores.
    """

    def __init__(self, num_pages: int, page_len: int):
        if num_pages < 1:
            raise ValueError("num_pages must be >= 1")
        if page_len < 1:
            raise ValueError("page_len must be >= 1")
        self.num_pages = num_pages
        self.page_len = page_len
        # LIFO free list: recently freed pages are reused first (their old
        # contents are dead, masked by the kpos validity algebra anyway)
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        self._rc = np.zeros((num_pages,), np.int64)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Take `n` pages (rc=1 each); None if the pool can't cover it."""
        if n < 0:
            raise ValueError("alloc of negative page count")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._rc[pages] += 1
        return pages

    def incref(self, pages) -> None:
        for p in pages:
            if self._rc[p] <= 0:
                raise ValueError(f"incref of free page {p}")
            self._rc[p] += 1

    def decref(self, pages) -> list[int]:
        """Drop one reference per page; returns the pages freed by this."""
        freed = []
        for p in pages:
            if self._rc[p] <= 0:
                raise ValueError(f"decref of free page {p}")
            self._rc[p] -= 1
            if self._rc[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def refcount(self, page: int) -> int:
        return int(self._rc[page])


@dataclasses.dataclass(eq=False)  # identity semantics: entries hold arrays
class Prefix:
    """One registered shareable prefix: `len(pages)` FULL pages covering
    `tokens` (`len(pages) * page_len` token ids). Device tier: `pages` are
    live pool page ids (one registry reference each). Host tier: `pages`
    is empty and `host_data` maps cache keys -> numpy page payloads of
    shape (n_layers, n_pages, page_len, ...)."""

    tokens: np.ndarray
    pages: list[int]
    tier: str  # "device" | "host"
    host_data: dict[str, np.ndarray] | None = None
    last_use: int = 0
    # namespace baked into every lookup key: the multi-tenant engine scopes
    # prefixes to (tenant, adapter version) — KV bytes depend on the
    # adapter, so sharing across tenants (or across a hot swap) would
    # replay the WRONG cache
    ns: bytes = b""

    @property
    def n_pages(self) -> int:
        if self.tier == "device":
            return len(self.pages)
        first = next(iter(self.host_data.values()))
        return first.shape[1]


class PrefixStore:
    """Full-page prefix registry with a device tier and a host spill tier.

    Keys are the raw bytes of the first `j * page_len` prompt tokens for
    every j up to the entry's page count, so a probe hits the LONGEST
    registered full-page prefix of a new prompt. Registering holds one
    pool reference per device page; `evict_lru` hands the coldest device
    entry back to the caller, who fetches the page bytes, calls `spill`
    (moving the entry to the host tier) and decrefs the pages.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_len = pool.page_len
        # key -> (entry, j): key covers entry.tokens[: j * page_len]
        self._dev: dict[bytes, tuple[Prefix, int]] = {}
        self._host: dict[bytes, tuple[Prefix, int]] = {}
        self._dev_entries: list[Prefix] = []
        self._clock = 0

    # -- keys ----------------------------------------------------------

    def _key(self, tokens: np.ndarray, j: int, ns: bytes = b"") -> bytes:
        return ns + np.ascontiguousarray(
            tokens[: j * self.page_len], dtype=np.int32).tobytes()

    def _touch(self, entry: Prefix) -> None:
        self._clock += 1
        entry.last_use = self._clock

    # -- probe ---------------------------------------------------------

    def probe(self, prompt: np.ndarray, ns: bytes = b""):
        """Longest full-page prefix hit for `prompt` in namespace `ns`,
        or None.

        Returns (entry, j, tier). j < pages_needed(len(prompt)) strictly:
        at least one prompt token is always left for the tail prefill (the
        true-last-token logits must come from a freshly processed token),
        hence the (len - 1) below. Device hits win ties over host hits.
        """
        j_max = (len(prompt) - 1) // self.page_len
        for j in range(j_max, 0, -1):
            key = self._key(np.asarray(prompt), j, ns)
            for tier, table in (("device", self._dev), ("host", self._host)):
                got = table.get(key)
                if got is not None:
                    entry, _ = got
                    self._touch(entry)
                    return entry, j, tier
        return None

    # -- register ------------------------------------------------------

    def register(self, prompt: np.ndarray, pages: list[int],
                 ns: bytes = b"") -> bool:
        """Register `pages` (the slot's first full pages) as a device-tier
        shareable prefix; increfs each page. Dedupes: if the full key is
        already registered (either tier) nothing happens and False is
        returned — the caller keeps sole ownership of its pages."""
        j = len(pages)
        if j == 0:
            return False
        tokens = np.asarray(prompt, np.int32)[: j * self.page_len].copy()
        if len(tokens) != j * self.page_len:
            raise ValueError("register needs j full pages of tokens")
        full_key = self._key(tokens, j, ns)
        if full_key in self._dev or full_key in self._host:
            return False
        entry = Prefix(tokens=tokens, pages=list(pages), tier="device",
                       ns=ns)
        self.pool.incref(entry.pages)
        self._touch(entry)
        self._dev_entries.append(entry)
        for i in range(1, j + 1):
            self._dev.setdefault(self._key(tokens, i, ns), (entry, i))
        return True

    # -- eviction / tiering --------------------------------------------

    def evict_lru(self) -> Prefix | None:
        """Unlink and return the coldest device-tier entry (its pages keep
        their registry reference until the caller calls `spill` or
        `drop`). None if the device tier is empty."""
        if not self._dev_entries:
            return None
        entry = min(self._dev_entries, key=lambda e: e.last_use)
        self._dev_entries.remove(entry)
        for i in range(1, len(entry.pages) + 1):
            key = self._key(entry.tokens, i, entry.ns)
            if self._dev.get(key, (None, 0))[0] is entry:
                del self._dev[key]
        return entry

    def spill(self, entry: Prefix, host_data: dict[str, np.ndarray]) -> list[int]:
        """Move an evicted entry to the host tier. `host_data` holds the
        fetched page payloads. Returns the pages freed by dropping the
        registry references (the caller removes them from its tables)."""
        freed = self.pool.decref(entry.pages)
        entry.tier = "host"
        entry.host_data = host_data
        entry.pages = []
        j = len(entry.tokens) // self.page_len
        for i in range(1, j + 1):
            self._host.setdefault(self._key(entry.tokens, i, entry.ns),
                                  (entry, i))
        return freed

    def drop(self, entry: Prefix) -> list[int]:
        """Discard an evicted entry without spilling (host tier disabled)."""
        return self.pool.decref(entry.pages)

    def readmit(self, entry: Prefix, pages: list[int]) -> None:
        """Promote a host-tier entry back to the device tier on `pages`
        (freshly allocated by the caller, who also uploaded the payloads).
        The alloc reference becomes the registry reference."""
        j = len(entry.tokens) // self.page_len
        for i in range(1, j + 1):
            key = self._key(entry.tokens, i, entry.ns)
            if self._host.get(key, (None, 0))[0] is entry:
                del self._host[key]
        entry.tier = "device"
        entry.host_data = None
        entry.pages = list(pages)
        self._touch(entry)
        self._dev_entries.append(entry)
        for i in range(1, j + 1):
            self._dev.setdefault(self._key(entry.tokens, i, entry.ns),
                                 (entry, i))

    # -- introspection -------------------------------------------------

    @property
    def num_device_entries(self) -> int:
        return len(self._dev_entries)

    @property
    def num_host_entries(self) -> int:
        return len({id(e) for e, _ in self._host.values()})

    def evictable_pages(self) -> int:
        """Pages the device tier could free if every entry were spilled:
        pages whose only reference is the registry's."""
        seen: set[int] = set()
        n = 0
        for e in self._dev_entries:
            for p in e.pages:
                if p not in seen and self.pool.refcount(p) == 1:
                    seen.add(p)
                    n += 1
        return n
