"""Abstract input builders: ShapeDtypeStruct stand-ins for every workload.

This is the ONLY place the frontend stubs live (task-spec carve-out):
audio archs receive precomputed frame embeddings, VLMs receive precomputed
patch embeddings — weak-type-correct, shardable, no allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

VLM_PATCHES = 256  # stub vision-token count prepended to the text sequence


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, t = shape.global_batch, shape.seq_len
    batch = {
        "tokens": sds((b, t), jnp.int32),
        "targets": sds((b, t), jnp.int32),
    }
    if cfg.arch_type == "audio":
        batch["frames"] = sds((b, cfg.encoder_seq_len, cfg.d_model),
                              cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = sds((b, VLM_PATCHES, cfg.d_model), cfg.dtype)
        batch["positions3_full"] = sds((b, 3, t + VLM_PATCHES), jnp.int32)
    return batch


def serve_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    return {"token": sds((shape.global_batch, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# Ragged request batching (serving): real traffic never arrives as an
# equal-length batch, so the serving paths take right-padded prompts plus
# explicit true lengths (launch.serve) or raw per-request token arrays
# (launch.engine).
# ---------------------------------------------------------------------------


def pad_ragged_prompts(prompts) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad a list of variable-length prompts into one batch.

    prompts: sequence of 1-D int token sequences (len >= 1 each).
    Returns (tokens (B, Pmax) int32, lengths (B,) int32). The pad value is
    0 — it never reaches the cache: the serving paths mask every position
    >= lengths[i] out of both the cache write and the logit gather.
    """
    rows = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
    if not rows:
        raise ValueError("empty request set")
    if any(r.size == 0 for r in rows):
        raise ValueError("empty prompt in request set: seed with a BOS token")
    pmax = max(r.size for r in rows)
    toks = np.zeros((len(rows), pmax), np.int32)
    lengths = np.zeros((len(rows),), np.int32)
    for i, r in enumerate(rows):
        toks[i, : r.size] = r
        lengths[i] = r.size
    return toks, lengths


def synthetic_requests(vocab_size: int, n: int, *, min_len: int,
                       max_len: int, seed: int = 0) -> list[np.ndarray]:
    """n random prompts with lengths uniform in [min_len, max_len] — the
    ragged request sets used by the serve CLI, the engine smoke and
    benchmarks/bench_serve.py."""
    if not 1 <= min_len <= max_len:
        raise ValueError(f"need 1 <= min_len <= max_len, got "
                         f"[{min_len}, {max_len}]")
    rng = np.random.default_rng(seed)
    lens = rng.integers(min_len, max_len + 1, size=n)
    return [rng.integers(0, vocab_size, size=int(l)).astype(np.int32)
            for l in lens]


def concrete_train_batch(cfg: ModelConfig, b: int, t: int, key) -> dict:
    """Small concrete batch for smoke tests / examples."""
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    tgt = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
    batch = {"tokens": toks, "targets": tgt}
    if cfg.arch_type == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model), dtype=jnp.float32
        ).astype(cfg.dtype)
    if cfg.arch_type == "vlm":
        tv = 8
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            key, (b, tv, cfg.d_model), dtype=jnp.float32).astype(cfg.dtype)
        pos = jnp.broadcast_to(jnp.arange(t + tv, dtype=jnp.int32)[None],
                               (b, t + tv))
        batch["positions3_full"] = jnp.broadcast_to(
            pos[:, None, :], (b, 3, t + tv))
    return batch
