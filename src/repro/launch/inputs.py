"""Abstract input builders: ShapeDtypeStruct stand-ins for every workload.

This is the ONLY place the frontend stubs live (task-spec carve-out):
audio archs receive precomputed frame embeddings, VLMs receive precomputed
patch embeddings — weak-type-correct, shardable, no allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

VLM_PATCHES = 256  # stub vision-token count prepended to the text sequence


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, t = shape.global_batch, shape.seq_len
    batch = {
        "tokens": sds((b, t), jnp.int32),
        "targets": sds((b, t), jnp.int32),
    }
    if cfg.arch_type == "audio":
        batch["frames"] = sds((b, cfg.encoder_seq_len, cfg.d_model),
                              cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = sds((b, VLM_PATCHES, cfg.d_model), cfg.dtype)
        batch["positions3_full"] = sds((b, 3, t + VLM_PATCHES), jnp.int32)
    return batch


def serve_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    return {"token": sds((shape.global_batch, 1), jnp.int32)}


def concrete_train_batch(cfg: ModelConfig, b: int, t: int, key) -> dict:
    """Small concrete batch for smoke tests / examples."""
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    tgt = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
    batch = {"tokens": toks, "targets": tgt}
    if cfg.arch_type == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model), dtype=jnp.float32
        ).astype(cfg.dtype)
    if cfg.arch_type == "vlm":
        tv = 8
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            key, (b, tv, cfg.d_model), dtype=jnp.float32).astype(cfg.dtype)
        pos = jnp.broadcast_to(jnp.arange(t + tv, dtype=jnp.int32)[None],
                               (b, t + tv))
        batch["positions3_full"] = jnp.broadcast_to(
            pos[:, None, :], (b, 3, t + tv))
    return batch
