import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) combo.

For each combination this driver builds the REAL step function (the full
adaptive per-layer DP-SGD train step — clipping, quantile update, noise,
optimizer — or the one-token serve step), jits it with explicit
in/out_shardings on the production mesh, lowers it against
ShapeDtypeStruct inputs (no allocation), compiles, and extracts:

  * memory_analysis()  — per-device argument/output/temp/peak bytes
  * cost_analysis()    — HLO flops / bytes accessed
  * collective bytes   — parsed from the post-SPMD HLO text per collective
                         kind (all-reduce, all-gather, reduce-scatter,
                         all-to-all, collective-permute)

Results go to benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json, which
benchmarks/roofline.py and EXPERIMENTS.md consume.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import ARCH_IDS, get_config
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import abstract_params
from repro.launch import inputs as I
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze_hlo, backward_passes
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   opt_state_shardings, params_shardings,
                                   replicated)
from repro.models.config import INPUT_SHAPES
from repro.models.transformer import build_model

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# Large archs take the paper's DP-LoRA path for the train shape (frozen base
# does not fit optimizer+grads on 16 GB/chip otherwise; see DESIGN.md).
LORA_TRAIN_ARCHS = {"deepseek-v3-671b": 32, "qwen2-vl-72b": 32}

# long_500k policy (DESIGN.md §4): native sub-quadratic, MLA-latent, or the
# documented sliding-window variant; pure full-attention archs skip.
LONG_OK = {"zamba2-7b": None, "rwkv6-7b": None, "deepseek-v3-671b": None,
           "qwen3-4b": "swa", "minicpm-2b": "swa"}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _mesh_context(mesh):
    """`jax.set_mesh(mesh)` on new jax; on <=0.4 the Mesh IS the context."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape like 'f32[16,128]' (tuples handled upstream)."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the partitioned HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g.:  %all-reduce.5 = f32[256,512]{1,0} all-reduce(...)
        m = re.match(r"%?[\w.\-]+ = (\(?)(.*?) ([a-z\-]+)\(", line)
        if not m:
            continue
        op = m.group(3)
        if op not in COLLECTIVES:
            continue
        shapes_part = m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(shapes_part):
            total += _shape_bytes(sm.group(0))
        out[op]["count"] += 1
        out[op]["bytes"] += total
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _shape_for(shape_name: str, debug: bool):
    from repro.models.config import InputShape
    if not debug:
        return INPUT_SHAPES[shape_name]
    kind = INPUT_SHAPES[shape_name].kind
    return InputShape("debug_" + shape_name, 64 if kind == "train" else 128,
                      8, kind)


def build_train_lowering(arch: str, shape_name: str, mesh, *,
                         clipping: str = "per_layer",
                         execution: str = "bk",
                         microbatches: int = 8,
                         rwkv_formulation: str = "chunked",
                         debug: bool = False,
                         moe_dispatch: str | None = None,
                         sharded: bool = False):
    shape = _shape_for(shape_name, debug)
    variant = LONG_OK.get(arch) if shape_name == "long_500k" else None
    cfg = get_config(arch, reduced=debug, variant=variant)
    lora_rank = LORA_TRAIN_ARCHS.get(arch, 0)
    if lora_rank and not debug:
        cfg = dataclasses.replace(cfg, lora_rank=lora_rank)
    if clipping == "per_shard":
        # per-device clipping analogue: blocked groups aligned with the
        # Megatron column shards; the DP mode itself is per_layer over the
        # finer (layer x shard) groups.
        cfg = dataclasses.replace(cfg, dp_blocks=int(mesh.shape["model"]))
        clipping = "per_shard_resolved"
    if moe_dispatch is not None:
        cfg = dataclasses.replace(cfg, moe_dispatch=moe_dispatch)
    model = build_model(cfg, rwkv_formulation=rwkv_formulation)

    from repro.launch.mesh import data_axes
    if clipping == "per_shard_resolved":
        clipping = "per_layer"
    assign, nsuper = None, None
    if clipping.startswith("per_group") and not sharded:
        # per-DEVICE supergroups from model-axis shard ownership — the SAME
        # helper the sharded executing path and bench_sharded use (under
        # `sharded` the factory derives this from the mesh itself)
        from repro.launch.sharding import group_shard_assignment
        nsuper = int(mesh.shape["model"])
        assign = group_shard_assignment(model.layout, nsuper)
    # backend="xla": dry-run lowering must stay on the reference paths (a
    # TPU pallas custom-call cannot lower on the CPU backend used here).
    # sharded: shard_map splits the batch manually, so the GSPMD microbatch
    # pin (batch_axes) does not apply inside the manual region.
    dpc = DPConfig(mode=clipping, sigma=1.0, sampling_rate=1e-3,
                   steps=1000, adaptive=True, init_threshold=1.0,
                   microbatches=microbatches, execution=execution,
                   group_assignment=assign, num_supergroups=nsuper,
                   batch_axes=None if sharded else data_axes(mesh),
                   backend="xla")
    init_fn, step_fn, plan = make_dp_train_step(
        model.loss_fn, getattr(model, "dp_spec", model.spec), model.layout,
        optim.adam(1e-4), dpc, batch_size=shape.global_batch,
        trainable_key=getattr(model, "trainable_key", None),
        mesh=mesh if sharded else None)

    params_abs = abstract_params(model.spec)
    opt_abs, dp_abs = jax.eval_shape(init_fn, params_abs)
    batch_abs = I.train_batch_specs(cfg, shape)
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)

    pshard = params_shardings(model.spec, mesh)
    oshard = opt_state_shardings(
        opt_abs, pshard if getattr(model, "trainable_key", None) is None
        else pshard["lora"], mesh)
    dshard = replicated(dp_abs, mesh)
    bshard = batch_shardings(batch_abs, mesh)
    kshard = replicated(key_abs, mesh)

    jitted = jax.jit(
        step_fn,
        in_shardings=(pshard, oshard, dshard, bshard, kshard),
        out_shardings=(pshard, oshard, dshard, None),
        donate_argnums=(0, 1, 2),  # params/opt/dp buffers update in place
    )
    with _mesh_context(mesh):
        lowered = jitted.lower(params_abs, opt_abs, dp_abs, batch_abs,
                               key_abs)
    return lowered, model, cfg


def build_serve_lowering(arch: str, shape_name: str, mesh, *,
                         debug: bool = False):
    shape = _shape_for(shape_name, debug)
    variant = LONG_OK.get(arch) if shape_name == "long_500k" else None
    cfg = get_config(arch, reduced=debug, variant=variant)
    model = build_model(cfg)
    params_abs = abstract_params(model.spec)
    # weight-FSDP only when model-axis sharding cannot hold the weights
    # (blanket FSDP re-gathers weights inside attention/scan loops and
    # multiplies prefill collectives ~10x — measured; EXPERIMENTS.md)
    import numpy as _np
    param_bytes = sum(
        int(_np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(params_abs))
    per_dev = param_bytes / mesh.shape["model"]
    # decode only: prefill's remat/flash loops re-gather FSDP weights and
    # blow up both collectives and (analyzer-visible) compute; for prefill
    # the 671B case is honestly reported as not fitting single-pod v5e
    serving_fsdp = per_dev > 12 * 2**30 and shape.kind == "decode"
    pshard = params_shardings(model.spec, mesh, serving=serving_fsdp)

    if shape.kind == "prefill":
        batch_abs = I.train_batch_specs(cfg, shape)
        batch_abs.pop("targets")
        bshard = batch_shardings(batch_abs, mesh)
        jitted = jax.jit(model.prefill_step,
                         in_shardings=(pshard, bshard), out_shardings=None)
        with _mesh_context(mesh):
            lowered = jitted.lower(params_abs, batch_abs)
        return lowered, model, cfg

    cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
    batch_abs = I.serve_batch_specs(cfg, shape)
    cshard = cache_shardings(cache_abs, mesh)
    bshard = batch_shardings(batch_abs, mesh)
    jitted = jax.jit(model.serve_step,
                     in_shardings=(pshard, cshard, bshard),
                     out_shardings=(None, cshard),
                     donate_argnums=(1,))  # KV/state cache updates in place
    with _mesh_context(mesh):
        lowered = jitted.lower(params_abs, cache_abs, batch_abs)
    return lowered, model, cfg


def _layer_trip(cfg) -> int:
    """Depth of the model's dominant homogeneous scan run (the
    `known_trip_count` its layer loops carry in the compiled HLO)."""
    n = cfg.num_layers
    runs = [n]
    if getattr(cfg, "num_experts", 0) and getattr(cfg, "first_k_dense", 0):
        runs = [cfg.first_k_dense, n - cfg.first_k_dense]
    if getattr(cfg, "encoder_layers", 0):
        runs.append(cfg.encoder_layers)
    return max(r for r in runs)


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            clipping: str = "per_layer", execution: str = "bk",
            save: bool = True,
            rwkv_formulation: str = "chunked",
            microbatches: int | None = None, debug: bool = False,
            ghost_outer_cap: int | None = None,
            moe_dispatch: str | None = None,
            sharded: bool = False,
            audit: bool = False,
            tag: str = "") -> dict:
    shape = _shape_for(shape_name, debug)
    if shape_name == "long_500k" and arch not in LONG_OK:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "skipped",
                  "reason": "full-attention arch; long_500k requires "
                            "sub-quadratic attention (DESIGN.md)"}
        if save:
            os.makedirs(RESULTS_DIR, exist_ok=True)
            with open(os.path.join(
                    RESULTS_DIR,
                    f"{arch}__{shape_name}__{mesh_kind}.json"), "w") as f:
                json.dump(result, f, indent=1)
        return result
    if mesh_kind == "debug":
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(2, 2)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    kind = shape.kind  # train | prefill | decode
    from contextlib import ExitStack

    from repro.kernels import backend as _backend
    # scoped engine config (not a module-global mutation): the step trace
    # inside build_*_lowering inherits the widened outer cap — see the
    # sharding note in repro.core.ghost.
    eng_scope = ExitStack()
    if ghost_outer_cap is not None:
        eng_scope.enter_context(
            _backend.scoped(outer_max_elems=ghost_outer_cap))
    try:
        if kind == "train":
            mb = microbatches if microbatches is not None else (2 if debug else 8)
            lowered, model, cfg = build_train_lowering(
                arch, shape_name, mesh, clipping=clipping,
                execution=execution, microbatches=mb,
                rwkv_formulation=rwkv_formulation, debug=debug,
                moe_dispatch=moe_dispatch, sharded=sharded)
        else:
            lowered, model, cfg = build_serve_lowering(arch, shape_name, mesh,
                                                       debug=debug)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        mem_d = {}
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem_d[f] = int(getattr(mem, f, 0) or 0)
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # jax<=0.4 returns [dict]
            cost = cost[0] if cost else {}
        cost_d = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and k in
                  ("flops", "bytes accessed", "transcendentals")}
        hlo = compiled.as_text()
        t0 = time.time()
        totals = analyze_hlo(hlo)  # trip-count-aware (scan bodies x L)
        t_analyze = time.time() - t0
        coll = {k: {"count": v["count"], "bytes": v["bytes"]}
                for k, v in totals.collectives.items()}
        coll["total_bytes"] = sum(v["bytes"] for v in coll.values())
        # assert (not assume) the pass structure: how many full backward
        # traversals of the layer stack did this step actually compile to?
        trip = _layer_trip(cfg)
        bw_passes = (backward_passes(hlo, trip)
                     if kind == "train" and trip >= 2 else None)
        audit_d = None
        if audit and kind == "train":
            from repro.analysis.findings import errors
            from repro.analysis.rules import StepExpectation, run_hlo_rules
            from repro.core.clipping import base_mode
            # donated_leaves=None: the dry-run varies donation with cache
            # settings; full donation coverage is audited by launch.audit
            expect = StepExpectation(
                mode=base_mode(clipping), execution=execution,
                sharded=sharded, layer_trip=trip, donated_leaves=None)
            fs = run_hlo_rules(hlo, expect, mesh if sharded else None)
            audit_d = {"findings": [f.to_dict() for f in fs],
                       "num_errors": len(errors(fs))}
        axis_coll = None
        if sharded and kind == "train":
            from repro.launch.hlo_analysis import (classify_collectives,
                                                   filter_model_norm_rows,
                                                   summarize_axis_rows)
            rows = classify_collectives(hlo, mesh)  # parse the HLO once
            axis_coll = {
                "by_axis": summarize_axis_rows(rows),
                "model_axis_norm_count": sum(
                    r["count"] for r in filter_model_norm_rows(rows)),
            }
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "kind": kind, "clipping": clipping if kind == "train" else None,
            "execution": execution if kind == "train" else None,
            "sharded": sharded if kind == "train" else None,
            "backward_passes": bw_passes,
            "collectives_by_axis": axis_coll,
            "audit": audit_d,
            "status": "ok",
            "num_params": model.num_params,
            "num_groups": model.layout.num_groups,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "analyze_s": round(t_analyze, 2),
            "memory": mem_d,
            "flops": totals.flops,                  # per device, loop-aware
            "bytes_accessed": totals.bytes,         # per device, loop-aware
            "transcendentals": totals.transcendentals,
            "xla_cost_analysis": cost_d,            # raw (loop bodies x1)
            "collectives": coll,
            "devices": int(np.prod(list(mesh.shape.values()))),
            "hlo_bytes": len(hlo),
        }
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "kind": kind, "status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    finally:
        eng_scope.close()
    if tag:
        result["tag"] = tag
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = "" if clipping == "per_layer" else f"__{clipping}"
        if execution != "bk":
            suffix += f"__{execution}"
        if sharded:
            suffix += "__sharded"
        if tag:
            suffix += f"__{tag}"
        fn = os.path.join(
            RESULTS_DIR, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both", "debug"],
                    default="single")
    ap.add_argument("--clipping", default="per_layer")
    ap.add_argument("--execution", default="bk", choices=["bk", "twopass"],
                    help="flat/group clipping execution: bk (single "
                         "backprop + book-keeping epilogue) or twopass "
                         "(reference two-backward driver)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--sharded", action="store_true",
                    help="lower the shard_map executing path (manual-SPMD "
                         "clipping engine) instead of the GSPMD jit; "
                         "results gain a per-mesh-axis collective "
                         "breakdown (collectives_by_axis)")
    ap.add_argument("--audit", action="store_true",
                    help="run the static DP-safety HLO rules "
                         "(repro.analysis.rules) on each compiled train "
                         "step; any ERROR finding fails the run")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--cache", default="off", choices=["on", "off"],
                    help="persistent compilation cache for the lowered "
                         "programs (repro.launch.compile_cache). Default "
                         "OFF: the dry-run's compile_s numbers measure the "
                         "compiler, and a warm cache would zero them; turn "
                         "on to pre-warm a fleet cache from the production "
                         "program set")
    ap.add_argument("--cache-dir", default=None,
                    help="cache root for --cache on (default <repo>/.cache "
                         "or $REPRO_CACHE_DIR)")
    args = ap.parse_args()

    if args.cache != "off":
        from repro.launch import compile_cache
        compile_cache.enable(args.cache_dir)

    debug = args.mesh == "debug"
    combos = []
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for mk in meshes:
                combos.append((a, s, mk))

    failures = 0
    for a, s, mk in combos:
        suffix = "" if args.clipping == "per_layer" else f"__{args.clipping}"
        if args.execution != "bk":
            suffix += f"__{args.execution}"
        if args.sharded:
            suffix += "__sharded"
        fn = os.path.join(RESULTS_DIR, f"{a}__{s}__{mk}{suffix}.json")
        if args.skip_existing and os.path.exists(fn):
            with open(fn) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[skip-existing] {a} {s} {mk}: {prev['status']}")
                continue
        r = run_one(a, s, mk, clipping=args.clipping,
                    execution=args.execution,
                    microbatches=args.microbatches, save=not debug,
                    debug=debug, sharded=args.sharded, audit=args.audit)
        if r["status"] == "ok" and (r.get("audit") or {}).get("num_errors"):
            failures += 1
            bad = [f for f in r["audit"]["findings"]
                   if f["severity"] == "ERROR"]
            print(f"[FAIL] {a:22s} {s:12s} {mk:6s} audit: "
                  + "; ".join(f"{f['rule']}: {f['message']}" for f in bad),
                  flush=True)
        elif r["status"] == "ok":
            gb = r["memory"].get("temp_size_in_bytes", 0) / 2**30
            print(f"[ok]   {a:22s} {s:12s} {mk:6s} "
                  f"flops={r['flops']:.3e} temp={gb:.2f}GiB "
                  f"coll={r['collectives']['total_bytes']/2**30:.2f}GiB "
                  f"(lower {r['lower_s']}s compile {r['compile_s']}s)",
                  flush=True)
        elif r["status"] == "skipped":
            print(f"[skip] {a:22s} {s:12s} {mk:6s} {r['reason']}", flush=True)
        else:
            failures += 1
            print(f"[FAIL] {a:22s} {s:12s} {mk:6s} {r['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
