import os
import sys

if __name__ == "__main__":
    # the sharded half of the matrix needs 8 virtual CPU devices, and the
    # flag must land before jax initializes — module code runs top-down,
    # so this executes before the jax import below (in-process importers,
    # e.g. tests, are NOT affected and audit only the unsharded configs)
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

"""Static DP-safety audit CLI: the clipping x execution x mesh matrix.

For every config in the matrix this driver builds the REAL train step
(`make_dp_train_step`, tiny arch), runs BOTH static passes —
`repro.analysis.jaxpr_taint` on the closed jaxpr and
`repro.analysis.rules` on the compiled post-SPMD HLO — and aggregates
the findings into benchmarks/AUDIT.json (stamped with the same topology
record as the BENCH artifacts). Any ERROR finding exits non-zero: the
audit is a CI gate, not a report.

The matrix pins `backend="xla"` like launch.dryrun: the fused Pallas
linear_clip kernel applies the factor INSIDE its custom call, which an
operand-level taint pass cannot see through; the xla path is the
bitwise-parity-tested reference for it (tests/test_kernels.py).

`--selftest` proves the auditor has teeth: each seeded violation
(drop the clip multiply, double/drop the noise add, reuse a key, strip
donation) must be flagged by exactly its expected rule.

Usage:
  python -m repro.launch.audit --matrix
  python -m repro.launch.audit --mode ghost_flat --execution twopass --sharded
  python -m repro.launch.audit --selftest
"""
import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.analysis.findings import ERROR, Finding, errors
from repro.analysis.jaxpr_taint import audit_train_step
from repro.analysis.rules import RULES, StepExpectation, run_hlo_rules
from repro.configs import get_config
from repro.core.clipping import base_mode
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import abstract_params
from repro.launch.inputs import train_batch_specs
from repro.models.config import InputShape
from repro.models.transformer import build_model

AUDIT_PATH = os.path.join(os.path.dirname(__file__),
                          "../../../benchmarks/AUDIT.json")

# (mode, execution, sharded): every private clipping mode under both
# executions and both placements where they are defined — per_layer's
# execution knob is a no-op and naive_flat is the single-device oracle,
# so their redundant/unsupported points are omitted rather than faked
MATRIX: tuple = tuple(
    (mode, execution, sharded)
    for mode in ("ghost_flat", "per_group")
    for execution in ("bk", "twopass")
    for sharded in (False, True)
) + (
    ("per_layer", "bk", False),
    ("per_layer", "bk", True),
    ("naive_flat", "bk", False),
)

_SHARDED_MESH = (2, 4)  # (data, model): 8 virtual devices


def _layer_trip(cfg) -> int:
    """Scan trip count of the dominant layer run (mirrors dryrun's helper;
    duplicated because importing dryrun forces a 512-device XLA flag)."""
    n = cfg.num_layers
    runs = [n]
    if getattr(cfg, "num_experts", 0) and getattr(cfg, "first_k_dense", 0):
        runs = [cfg.first_k_dense, n - cfg.first_k_dense]
    return max(runs)


def build_case(mode: str, execution: str, sharded: bool, *,
               arch: str = "tiny", batch: int = 8, seq: int = 16,
               microbatches: int = 2):
    """(step_fn, abstract args, mesh, StepExpectation) for one config."""
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = None
    if sharded:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(*_SHARDED_MESH)
    assign = nsuper = None
    if mode == "per_group" and not sharded:
        # mirror the per-DEVICE partition the sharded engine would derive
        from repro.launch.sharding import group_shard_assignment
        nsuper = _SHARDED_MESH[1]
        assign = group_shard_assignment(model.layout, nsuper)
    dpc = DPConfig(mode=mode, sigma=1.0, sampling_rate=1e-3, steps=100,
                   adaptive=True, microbatches=microbatches,
                   execution=execution, backend="xla",
                   group_assignment=assign, num_supergroups=nsuper)
    init_fn, step_fn, _plan = make_dp_train_step(
        model.loss_fn, model.spec, model.layout, optim.adam(1e-4), dpc,
        batch_size=batch, mesh=mesh)
    params_abs = abstract_params(model.spec)
    opt_abs, dp_abs = jax.eval_shape(init_fn, params_abs)
    batch_abs = train_batch_specs(cfg, InputShape("audit", seq, batch,
                                                  "train"))
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
    args = (params_abs, opt_abs, dp_abs, batch_abs, key_abs)
    expect = StepExpectation(
        mode=base_mode(mode), execution=execution, sharded=sharded,
        layer_trip=_layer_trip(cfg),
        donated_leaves=len(jax.tree_util.tree_leaves(
            (params_abs, opt_abs, dp_abs))))
    return step_fn, args, mesh, expect


def audit_config(mode: str, execution: str, sharded: bool, *,
                 arch: str = "tiny", donate: bool = True,
                 jaxpr_only: bool = False) -> dict:
    """Run both passes on one config; returns the AUDIT.json record."""
    t0 = time.time()
    step_fn, args, mesh, expect = build_case(mode, execution, sharded,
                                             arch=arch)
    findings: list[Finding] = list(audit_train_step(step_fn, args))
    if not jaxpr_only:
        jitted = jax.jit(step_fn,
                         donate_argnums=(0, 1, 2) if donate else ())
        hlo = jitted.lower(*args).compile().as_text()
        findings.extend(run_hlo_rules(hlo, expect, mesh))
    errs = errors(findings)
    return {
        "mode": mode, "execution": execution, "sharded": sharded,
        "arch": arch,
        "status": "error" if errs else "ok",
        "num_errors": len(errs),
        "findings": [f.to_dict() for f in findings],
        "elapsed_s": round(time.time() - t0, 2),
    }


# ---------------------------------------------------------------------------
# Seeded violations: the auditor's own mutation tests (also used by
# tests/test_audit.py). Each mutation surgically breaks ONE invariant in
# the real engine and must be flagged by exactly its expected rule.
# ---------------------------------------------------------------------------

# mutation -> the single rule that must flag it
MUTATIONS = {
    "drop_clip": "JAXPR-CLIP-PATH",        # factor computed unmarked/raw
    "double_noise": "JAXPR-NOISE-ONCE",    # noise added twice per leaf
    "drop_noise": "JAXPR-NOISE-ONCE",      # noise skipped entirely
    "reuse_key": "JAXPR-KEY-LINEAGE",      # PR-6 class: constant key fold
    "strip_donation": "HLO-DONATION",      # PR-7 class: donation dropped
}


@contextlib.contextmanager
def seeded_violation(name: str):
    """Monkeypatch the engine into one specific DP bug (restored on exit).

    `strip_donation` is a no-op here — callers pass `donate=False` to
    `audit_config` instead (the bug lives in the jit call, not the step).
    """
    from repro.core import clipping, dp_sgd
    if name == "drop_clip":
        # the factor math inlined WITHOUT the dp_clip_factor marker — the
        # numerics still clip, but nothing proves it; structurally this is
        # what an ad-hoc reimplementation at a call site would look like
        orig = clipping.flat_clip_factors
        clipping.flat_clip_factors = lambda total, c: jnp.minimum(
            1.0, jnp.asarray(c, jnp.float32) / jnp.sqrt(total + 1e-12))
        try:
            yield
        finally:
            clipping.flat_clip_factors = orig
    elif name == "double_noise":
        orig = dp_sgd.add_noise_to_grads

        def twice(spec, layout, grads, stds, key, dtype=jnp.float32):
            once = orig(spec, layout, grads, stds, key, dtype)
            return orig(spec, layout, once, stds, key, dtype)

        dp_sgd.add_noise_to_grads = twice
        try:
            yield
        finally:
            dp_sgd.add_noise_to_grads = orig
    elif name == "drop_noise":
        orig = dp_sgd.add_noise_to_grads
        dp_sgd.add_noise_to_grads = \
            lambda spec, layout, grads, stds, key, dtype=jnp.float32: grads
        try:
            yield
        finally:
            dp_sgd.add_noise_to_grads = orig
    elif name == "reuse_key":
        # every leaf folds the SAME constant: exactly the PR-6 failure
        # shape (process-randomized hash() collapsed cross-process, here
        # collapsed across leaves)
        orig = dp_sgd.stable_hash
        dp_sgd.stable_hash = lambda s: 0
        try:
            yield
        finally:
            dp_sgd.stable_hash = orig
    elif name == "strip_donation":
        yield
    else:
        raise ValueError(f"unknown mutation {name!r}; "
                         f"known: {sorted(MUTATIONS)}")


def run_selftest(arch: str = "tiny") -> list[str]:
    """Each seeded violation must raise exactly its expected rule (and the
    unmutated tree must stay green). Returns a list of failure strings."""
    failures = []
    base = audit_config("ghost_flat", "bk", False, arch=arch,
                        jaxpr_only=True)
    if base["status"] != "ok":
        failures.append(f"green config not green: {base['findings']}")
    for name, want_rule in MUTATIONS.items():
        donate = name != "strip_donation"
        jaxpr_only = name != "strip_donation"
        with seeded_violation(name):
            rec = audit_config("ghost_flat", "bk", False, arch=arch,
                               donate=donate, jaxpr_only=jaxpr_only)
        got = {f["rule"] for f in rec["findings"]
               if f["severity"] == ERROR}
        if got != {want_rule}:
            failures.append(
                f"mutation {name}: expected exactly {{{want_rule}}}, "
                f"got {sorted(got)}")
        else:
            print(f"[selftest ok] {name:16s} -> {want_rule}", flush=True)
    return failures


# ---------------------------------------------------------------------------
# The matrix driver + CLI.
# ---------------------------------------------------------------------------


def run_matrix(*, arch: str = "tiny", out_path: str | None = None,
               configs=MATRIX) -> dict:
    from repro.kernels.autotune import topology_stamp
    need = _SHARDED_MESH[0] * _SHARDED_MESH[1]
    records = []
    for mode, execution, sharded in configs:
        if sharded and jax.device_count() < need:
            records.append({"mode": mode, "execution": execution,
                            "sharded": True, "arch": arch,
                            "status": "skipped", "num_errors": 0,
                            "findings": [],
                            "reason": f"needs {need} devices "
                                      f"(have {jax.device_count()})"})
            print(f"[skip] {mode}/{execution}/sharded: "
                  f"{records[-1]['reason']}", flush=True)
            continue
        rec = audit_config(mode, execution, sharded, arch=arch)
        records.append(rec)
        tag = f"{mode}/{execution}/{'sharded' if sharded else 'unsharded'}"
        print(f"[{rec['status']:5s}] {tag:35s} "
              f"{rec['num_errors']} error(s), "
              f"{len(rec['findings'])} finding(s), "
              f"{rec['elapsed_s']}s", flush=True)
        for f in rec["findings"]:
            if f["severity"] == ERROR:
                print(f"    {f['rule']} @ {f['location']}: {f['message']}",
                      flush=True)
    report = {
        "generated_by": "repro.launch.audit",
        "arch": arch,
        "topology": topology_stamp(),
        "rules": {rid: {"severity": sev, "invariant": inv}
                  for rid, (sev, inv) in RULES.items()},
        "num_errors": sum(r["num_errors"] for r in records),
        "configs": records,
    }
    if out_path:
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as fh:
            json.dump(report, fh, indent=1)
        print(f"wrote {out_path}", flush=True)
    return report


def build_audit_parser() -> argparse.ArgumentParser:
    """CLI surface (tests/test_docs.py introspects this for doc drift)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--matrix", action="store_true",
                    help="run the full clipping x execution x mesh matrix "
                         "(the default when no single config is given)")
    ap.add_argument("--mode", default=None,
                    help="audit one mode (ghost_flat|per_group|per_layer|"
                         "naive_flat)")
    ap.add_argument("--execution", default="bk", choices=["bk", "twopass"])
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--selftest", action="store_true",
                    help="seeded-violation suite: each mutation must trip "
                         "exactly its expected rule")
    ap.add_argument("--out", default=AUDIT_PATH,
                    help="AUDIT.json path (default: benchmarks/AUDIT.json)")
    return ap


def main() -> int:
    args = build_audit_parser().parse_args()

    rc = 0
    if args.selftest:
        failures = run_selftest(arch=args.arch)
        for f in failures:
            print(f"[selftest FAIL] {f}", flush=True)
        rc |= 1 if failures else 0
        if not args.matrix and args.mode is None:
            return rc

    configs = MATRIX
    if args.mode is not None:
        configs = ((args.mode, args.execution, args.sharded),)
    report = run_matrix(arch=args.arch, out_path=args.out, configs=configs)
    if report["num_errors"]:
        print(f"AUDIT FAILED: {report['num_errors']} ERROR finding(s)",
              flush=True)
        rc |= 1
    else:
        print(f"audit green: {len(report['configs'])} config(s), "
              f"0 ERROR findings", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
