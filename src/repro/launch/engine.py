"""Continuous-batching decode engine over a fixed slot pool.

`DecodeEngine` owns a pre-allocated decode cache of `num_slots` slots and
serves an arbitrary stream of ragged requests through THREE compiled
programs whose shapes never depend on the traffic — no recompilation as
requests come and go:

  admission  `_prefill`  — a jitted scan over a fixed-size chunk of
      `prefill_chunk` prompt positions. Only the slots being admitted are
      active (length-masked: serve_step's `active` row mask suppresses
      both the cache write and the position advance, so pad tokens never
      pollute the pool) while every other slot — mid-decode or idle — is
      bit-frozen. Each admitted slot's TRUE-last-token logits accumulate
      in a persistent (S, V) buffer; its argmax is the slot's first
      output token. Co-admission is skew-capped: a queued request whose
      prompt needs more than `prefill_skew_chunks` extra chunks than its
      batch-mates waits for its own batch instead of forcing everyone
      through its padded chunk grid (`prefill_pad_chunks_saved` counts
      the padded slot-chunks this avoids).
  decode     `_decode`   — ONE dispatch advances every live slot by one
      greedy token; retired / free slots ride along masked.
  recycle    `_reset`    — re-arms the slots being handed to a new
      request. The per-key slot axis comes from the model's
      `cache_slot_axes` spec (recurrent state zeroes on its slot axis,
      `pos` resets to the slot's start offset, physical page pools pass
      through untouched — they are shared by every slot).

Two cache data planes:

  contiguous (legacy / ring / recurrent): every slot owns `cache_len`
      rows up front — HBM scales as slots x max-context regardless of
      actual request lengths.
  paged (full-attention families): a fixed physical pool of
      `(num_pages, page_len, ...)` KV blocks plus per-slot int32 page
      tables (launch.pages). Admission reserves exactly
      ceil((prompt+gen)/page_len) pages per request (never OOMs
      mid-decode; requests the pool can't cover yet are deferred, FIFO
      order preserved), retirement is O(table) — pages return to the
      free list, nothing is zeroed (the kpos validity algebra masks
      stale page contents). Full pages of completed prompts register in
      a prefix store: a later request sharing the prefix maps the SAME
      physical pages (refcounted, written by nobody — its first write
      lands past them) and skips their prefill entirely. Cold prefixes
      spill page bytes to host memory under pressure and re-admit
      bitwise on a later hit.

Paging is on by default (`paging="auto"`) when the model family supports
it (full attention, no ring window, not recurrent) and `cache_len` is a
multiple of `page_len` — under that divisibility the paged engine's
output is BITWISE identical to the contiguous engine and to the
per-request loop oracle (the paged XLA attention replicates the
contiguous decode math over table-gathered pages; masked scores are
exactly NEG_INF on both sides). `paging="on"` forces it (raising if
unsupported), `paging="off"` keeps the contiguous plane.

Retirement (EOS / max-token) and the request queue are host-side numpy
bookkeeping over (S,) vectors; every device call has static shapes, so
the three programs compile exactly once per (model, S, chunk). Output is
token-for-token identical to running each request alone, unpadded,
through `launch.serve.greedy_decode(prefill="loop")` — the reference
oracle asserted by tests/test_engine.py — because active-masked slots are
bit-frozen and each live slot's math is row-independent.

    engine = DecodeEngine(model, params, num_slots=8, cache_len=128)
    rid = engine.submit(prompt_tokens, max_new_tokens=32)
    ...                          # submit more any time, even mid-flight
    done = engine.run()          # {rid: Completion}
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.pages import PagePool, PrefixStore, pages_needed


@dataclasses.dataclass
class Completion:
    """One finished request."""

    rid: int
    prompt_len: int
    tokens: list[int]
    finish_reason: str  # "eos" | "length"


class DecodeEngine:
    """Slot-pool continuous-batching greedy decoder (see module doc)."""

    def __init__(self, model, params, *, num_slots: int, cache_len: int,
                 prefill_chunk: int = 8, eos_id: int | None = None,
                 paging: str = "auto", page_len: int = 16,
                 num_pages: int | None = None, host_spill: bool = True,
                 prefill_skew_chunks: int = 1):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if paging not in ("auto", "on", "off"):
            raise ValueError("paging must be 'auto', 'on' or 'off'")
        if page_len < 1:
            raise ValueError("page_len must be >= 1")
        if prefill_skew_chunks < 0:
            raise ValueError("prefill_skew_chunks must be >= 0")
        self.model, self.params = model, params
        self.num_slots, self.cache_len = num_slots, cache_len
        self.eos_id = eos_id
        self._chunk = prefill_chunk
        self._skew = prefill_skew_chunks
        cfg = model.cfg
        # full (non-ring) attention caches hard-bound the horizon; ring /
        # recurrent caches only carry O(1) or windowed state
        self._bounded = cfg.attention_kind == "mla" or (
            cfg.attention_kind == "gqa" and cfg.sliding_window is None)

        can_page = (getattr(model, "init_paged_cache", None) is not None
                    and self._bounded and cache_len % page_len == 0)
        if paging == "on" and not can_page:
            raise ValueError(
                "paging='on' needs a full-attention model family and "
                "cache_len divisible by page_len (ring-window / recurrent "
                "caches bypass paging)")
        self.paged = can_page if paging == "auto" else paging == "on"
        self.page_len = page_len

        if self.paged:
            ptab = cache_len // page_len
            self.num_pages = (num_slots * ptab if num_pages is None
                              else int(num_pages))
            if self.num_pages < 1:
                raise ValueError("num_pages must be >= 1")
            self.cache = model.init_paged_cache(
                num_slots, cache_len, num_pages=self.num_pages,
                page_len=page_len)
            self._pool = PagePool(self.num_pages, page_len)
            self._prefix = PrefixStore(self._pool)
            self._host_spill = host_spill
            # host mirror of cache["pt"]; trash page index = num_pages
            self._table = np.full((num_slots, ptab), self.num_pages,
                                  np.int32)
            self._row_pages: list[list[int]] = [[] for _ in range(num_slots)]
            self._pool_keys = [k for k in self.cache
                               if k.endswith(("_kpool", "_vpool",
                                              "_latpool"))]
        else:
            self.num_pages = None
            self.cache = model.init_cache(num_slots, cache_len)
        self._last = jnp.zeros((num_slots, cfg.vocab_size), jnp.float32)

        # ---- host-side slot table ----
        self._rid = np.full((num_slots,), -1, np.int64)
        self._live = np.zeros((num_slots,), bool)
        self._gen = np.zeros((num_slots,), np.int64)
        self._max = np.zeros((num_slots,), np.int64)
        self._tok = np.zeros((num_slots,), np.int32)  # last emitted token
        self._start = np.zeros((num_slots,), np.int32)  # pos at admission
        self._queue: collections.deque = collections.deque()
        self._out: dict[int, list[int]] = {}
        self._plen: dict[int, int] = {}
        self._done: dict[int, Completion] = {}
        self._next_rid = 0
        self.stats = {
            "prefill_dispatches": 0, "decode_dispatches": 0,
            "tokens_out": 0, "requests_done": 0,
            # admission-skew observability
            "prefill_pad_chunks_saved": 0,
            # occupancy-weighted utilization
            "live_slot_steps": 0, "peak_live_slots": 0,
            "pages_in_use": 0, "peak_pages_in_use": 0,
            # paged data plane
            "prefix_hits": 0, "shared_pages": 0, "evicted_pages": 0,
            "readmitted_pages": 0, "admission_deferrals": 0,
        }

        # ---- the three compiled programs ----
        def prefill_fn(params, cache, last, toks, valid):
            # toks/valid: (S, C); scan over the C chunk positions
            def stepf(carry, xs):
                cache, last = carry
                tok, act = xs
                logits, cache = model.serve_step(
                    params, cache, {"token": tok[:, None], "active": act})
                last = jnp.where(act[:, None], logits.astype(jnp.float32),
                                 last)
                return (cache, last), None

            (cache, last), _ = jax.lax.scan(stepf, (cache, last),
                                            (toks.T, valid.T))
            return cache, last, jnp.argmax(last, axis=-1).astype(jnp.int32)

        def decode_fn(params, cache, tok, live):
            logits, cache = model.serve_step(
                params, cache, {"token": tok[:, None], "active": live})
            nxt = jnp.argmax(logits.astype(jnp.float32),
                             axis=-1).astype(jnp.int32)
            return cache, jnp.where(live, nxt, tok)

        axes = model.cache_slot_axes(self.cache)

        def reset_fn(cache, mask, starts):
            out = {}
            for k, v in cache.items():
                ax = axes[k]
                if ax is None or k == "pt":
                    # slot-free page pools; pt is replaced host-side right
                    # after the reset (the host table is authoritative)
                    out[k] = v
                elif k == "pos":
                    # prefix-sharing slots resume mid-sequence
                    out[k] = jnp.where(mask, starts, v)
                else:
                    m = mask.reshape((1,) * ax + (num_slots,)
                                     + (1,) * (v.ndim - ax - 1))
                    out[k] = jnp.where(m, jnp.zeros_like(v), v)
            return out

        self._prefill = jax.jit(prefill_fn, donate_argnums=(1, 2))
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._reset = jax.jit(reset_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # Public surface.
    # ------------------------------------------------------------------

    @property
    def num_free_slots(self) -> int:
        return int(self.num_slots - self._live.sum())

    @property
    def num_pending(self) -> int:
        return len(self._queue)

    @property
    def num_live(self) -> int:
        return int(self._live.sum())

    def cache_bytes(self) -> int:
        """Device bytes held by the decode cache (pools + tables +
        positions for the paged plane; per-slot caches otherwise)."""
        return int(sum(v.size * v.dtype.itemsize
                       for v in self.cache.values()))

    def submit(self, prompt, max_new_tokens: int) -> int:
        """Enqueue one request; admitted into a free slot at the next
        `step()`. Returns the request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(
                "empty prompt: seed requests with at least one (BOS) token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self._bounded and prompt.size + max_new_tokens > self.cache_len:
            raise ValueError(
                f"request needs {prompt.size}+{max_new_tokens} cache slots "
                f"but the pool was sized with cache_len={self.cache_len}")
        if self.paged:
            need = pages_needed(prompt.size + max_new_tokens, self.page_len)
            if need > self.num_pages:
                raise ValueError(
                    f"request needs {need} pages but the page pool holds "
                    f"only num_pages={self.num_pages}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, prompt, int(max_new_tokens)))
        return rid

    def step(self) -> int:
        """Admit whatever fits into free slots (chunked prefill), then one
        pool-wide decode dispatch advancing every live slot. Returns the
        number of live slots advanced."""
        self._admit()
        live_idx = np.nonzero(self._live)[0]
        if live_idx.size == 0:
            return 0
        self.cache, nxt = self._decode(self.params, self.cache,
                                       jnp.asarray(self._tok),
                                       jnp.asarray(self._live))
        self.stats["decode_dispatches"] += 1
        self.stats["live_slot_steps"] += int(live_idx.size)
        self.stats["peak_live_slots"] = max(self.stats["peak_live_slots"],
                                            int(live_idx.size))
        nxt = np.asarray(nxt)
        for slot in live_idx:
            self._emit(int(slot), int(nxt[slot]))
        return int(live_idx.size)

    def run(self, max_steps: int | None = None) -> dict[int, Completion]:
        """Drive until the queue and the pool drain — or until `max_steps`
        pool steps, whichever comes first — and return the completions
        finished so far (keyed by request id). Callers using `max_steps`
        as a safety bound can check `num_live` / `num_pending` afterwards
        to see whether the engine actually drained."""
        steps = 0
        while self._queue or self._live.any():
            if max_steps is not None and steps >= max_steps:
                break
            self.step()
            steps += 1
        return dict(self._done)

    def completions(self) -> dict[int, Completion]:
        return dict(self._done)

    # ------------------------------------------------------------------
    # Paged data plane (host side; device arrays live in self.cache).
    # ------------------------------------------------------------------

    def _alloc_evicting(self, n: int) -> list[int] | None:
        """Allocate `n` pages, spilling cold registered prefixes to the
        host tier (or dropping them when host_spill=False) until the pool
        can cover it. None if even a fully evicted device tier cannot."""
        got = self._pool.alloc(n)
        while got is None:
            entry = self._prefix.evict_lru()
            if entry is None:
                return None
            if self._host_spill:
                idx = jnp.asarray(np.asarray(entry.pages, np.int32))
                data = {k: np.asarray(jax.device_get(self.cache[k][:, idx]))
                        for k in self._pool_keys}
                freed = self._prefix.spill(entry, data)
            else:
                freed = self._prefix.drop(entry)
            self.stats["evicted_pages"] += len(freed)
            got = self._pool.alloc(n)
        return got

    def _plan_pages(self, prompt, max_new: int, hit):
        """Reserve every page the request will ever touch (shared prefix
        + private tail through the last generated token) — admission is
        all-or-nothing, so a live slot can never run out of pages
        mid-decode. Returns (shared_page_count j, page row) or None when
        the pool can't cover it yet (caller defers the request)."""
        need_total = pages_needed(prompt.size + max_new, self.page_len)
        if hit is None:
            priv = self._alloc_evicting(need_total)
            if priv is None:
                return None
            return 0, priv
        entry, j, tier = hit
        if tier == "host":
            n_up = entry.n_pages
            up = self._alloc_evicting(n_up)
            if up is None:
                return None
            priv = self._alloc_evicting(need_total - j)
            if priv is None:
                self._pool.decref(up)
                return None
            idx = jnp.asarray(np.asarray(up, np.int32))
            for k in self._pool_keys:
                payload = jnp.asarray(entry.host_data[k],
                                      self.cache[k].dtype)
                self.cache[k] = self.cache[k].at[:, idx].set(payload)
            self._prefix.readmit(entry, up)  # alloc ref -> registry ref
            shared = list(up[:j])
            self._pool.incref(shared)        # the slot's own reference
            self.stats["readmitted_pages"] += n_up
        else:
            shared = list(entry.pages[:j])
            self._pool.incref(shared)
            priv = self._alloc_evicting(need_total - j)
            if priv is None:
                self._pool.decref(shared)
                return None
        self.stats["prefix_hits"] += 1
        self.stats["shared_pages"] += j
        return j, shared + priv

    # ------------------------------------------------------------------
    # Internals.
    # ------------------------------------------------------------------

    def _admit(self):
        """Move queued requests into free slots: recycle the slots, then
        length-masked chunked prefill — one jitted dispatch per chunk of
        `prefill_chunk` positions, all admitted slots together, every
        other slot bit-frozen. FIFO with two admission gates (a blocked
        request blocks everything behind it — no reordering):
          * skew cap: a candidate needing > prefill_skew_chunks more
            prefill chunks than its batch-mates waits for its own batch;
          * page reservation (paged plane): a candidate the pool cannot
            cover even after evicting cold prefixes is deferred."""
        free = [s for s in range(self.num_slots) if not self._live[s]]
        batch = []  # (slot, rid, prompt, tail, max_new)
        ch_lo = ch_hi = 0
        while free and self._queue:
            rid, prompt, max_new = self._queue[0]
            hit = self._prefix.probe(prompt) if self.paged else None
            j = hit[1] if hit is not None else 0
            ch = -(-(prompt.size - j * self.page_len) // self._chunk)
            if batch:
                lo, hi = min(ch_lo, ch), max(ch_hi, ch)
                if hi - lo > self._skew:
                    self.stats["prefill_pad_chunks_saved"] += (
                        len(batch) * max(0, ch - ch_hi)
                        + max(0, ch_lo - ch))
                    break
            if self.paged:
                plan = self._plan_pages(prompt, max_new, hit)
                if plan is None:
                    self.stats["admission_deferrals"] += 1
                    break
                j, row = plan
            self._queue.popleft()
            slot = free.pop(0)
            ch_lo, ch_hi = (ch, ch) if not batch else (min(ch_lo, ch),
                                                       max(ch_hi, ch))
            if self.paged:
                self._table[slot, :] = self.num_pages
                self._table[slot, : len(row)] = row
                self._row_pages[slot] = row
            self._start[slot] = j * self.page_len if self.paged else 0
            batch.append((slot, rid, prompt,
                          prompt[j * self.page_len:] if self.paged
                          else prompt, max_new))
        if not batch:
            return
        mask = np.zeros((self.num_slots,), bool)
        for slot, _, _, _, _ in batch:
            mask[slot] = True
        self.cache = self._reset(self.cache, jnp.asarray(mask),
                                 jnp.asarray(self._start))
        if self.paged:
            self.cache["pt"] = jnp.asarray(self._table)

        c = self._chunk
        pmax = max(t.size for _, _, _, t, _ in batch)
        padded = -(-pmax // c) * c
        toks = np.zeros((self.num_slots, padded), np.int32)
        valid = np.zeros((self.num_slots, padded), bool)
        for slot, _, _, tail, _ in batch:
            toks[slot, : tail.size] = tail
            valid[slot, : tail.size] = True
        last = self._last
        for c0 in range(0, padded, c):
            self.cache, last, first = self._prefill(
                self.params, self.cache, last,
                jnp.asarray(toks[:, c0:c0 + c]),
                jnp.asarray(valid[:, c0:c0 + c]))
            self.stats["prefill_dispatches"] += 1
        self._last = last
        first = np.asarray(first)
        for slot, rid, prompt, _, max_new in batch:
            self._rid[slot] = rid
            self._live[slot] = True
            self._gen[slot] = 0
            self._max[slot] = max_new
            self._out[rid] = []
            self._plen[rid] = int(prompt.size)
            if self.paged:
                # every full page of the (now fully cached) prompt becomes
                # shareable — registering here, after the tail prefill,
                # lets requests admitted mid-flight hit it immediately
                j_reg = prompt.size // self.page_len
                if j_reg:
                    self._prefix.register(prompt,
                                          self._row_pages[slot][:j_reg])
            # the first output token falls out of the prefill itself
            self._emit(slot, int(first[slot]))
        if self.paged:
            used = self._pool.num_used
            self.stats["pages_in_use"] = used
            self.stats["peak_pages_in_use"] = max(
                self.stats["peak_pages_in_use"], used)

    def _emit(self, slot: int, tok: int):
        rid = int(self._rid[slot])
        self._out[rid].append(tok)
        self._gen[slot] += 1
        self._tok[slot] = tok
        self.stats["tokens_out"] += 1
        if self.eos_id is not None and tok == self.eos_id:
            self._retire(slot, "eos")
        elif self._gen[slot] >= self._max[slot]:
            self._retire(slot, "length")

    def _retire(self, slot: int, reason: str):
        rid = int(self._rid[slot])
        self._done[rid] = Completion(rid=rid, prompt_len=self._plen.pop(rid),
                                     tokens=self._out.pop(rid),
                                     finish_reason=reason)
        self._live[slot] = False
        self._rid[slot] = -1
        self.stats["requests_done"] += 1
        if self.paged:
            # O(table) recycle: pages go back to the free list (or stay
            # alive under their prefix-registry / co-sharing references);
            # nothing on device is touched — stale pool contents are
            # unreachable through any live table row
            self._pool.decref(self._row_pages[slot])
            self._row_pages[slot] = []
            self._table[slot, :] = self.num_pages
            self.stats["pages_in_use"] = self._pool.num_used
