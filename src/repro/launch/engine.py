"""Continuous-batching decode engine over a fixed slot pool.

`DecodeEngine` owns a pre-allocated decode cache of `num_slots` slots and
serves an arbitrary stream of ragged requests through THREE compiled
programs whose shapes never depend on the traffic — no recompilation as
requests come and go:

  admission  `_prefill`  — a jitted scan over a fixed-size chunk of
      `prefill_chunk` prompt positions. Only the slots being admitted are
      active (length-masked: serve_step's `active` row mask suppresses
      both the cache write and the position advance, so pad tokens never
      pollute the pool) while every other slot — mid-decode or idle — is
      bit-frozen. Each admitted slot's TRUE-last-token logits accumulate
      in a persistent (S, V) buffer; its argmax is the slot's first
      output token. Co-admission is skew-capped: a queued request whose
      prompt needs more than `prefill_skew_chunks` extra chunks than its
      batch-mates waits for its own batch instead of forcing everyone
      through its padded chunk grid (`prefill_pad_chunks_saved` counts
      the padded slot-chunks this avoids).
  decode     `_decode`   — ONE dispatch advances every live slot by one
      greedy token; retired / free slots ride along masked.
  recycle    `_reset`    — re-arms the slots being handed to a new
      request. The per-key slot axis comes from the model's
      `cache_slot_axes` spec (recurrent state zeroes on its slot axis,
      `pos` resets to the slot's start offset, physical page pools pass
      through untouched — they are shared by every slot).

Two cache data planes:

  contiguous (legacy / ring / recurrent): every slot owns `cache_len`
      rows up front — HBM scales as slots x max-context regardless of
      actual request lengths.
  paged (full-attention families): a fixed physical pool of
      `(num_pages, page_len, ...)` KV blocks plus per-slot int32 page
      tables (launch.pages). Admission reserves exactly
      ceil((prompt+gen)/page_len) pages per request (never OOMs
      mid-decode; requests the pool can't cover yet are deferred, FIFO
      order preserved), retirement is O(table) — pages return to the
      free list, nothing is zeroed (the kpos validity algebra masks
      stale page contents). Full pages of completed prompts register in
      a prefix store: a later request sharing the prefix maps the SAME
      physical pages (refcounted, written by nobody — its first write
      lands past them) and skips their prefill entirely. Cold prefixes
      spill page bytes to host memory under pressure and re-admit
      bitwise on a later hit.

Paging is on by default (`paging="auto"`) when the model family supports
it (full attention, no ring window, not recurrent) and `cache_len` is a
multiple of `page_len` — under that divisibility the paged engine's
output is BITWISE identical to the contiguous engine and to the
per-request loop oracle (the paged XLA attention replicates the
contiguous decode math over table-gathered pages; masked scores are
exactly NEG_INF on both sides). `paging="on"` forces it (raising if
unsupported), `paging="off"` keeps the contiguous plane.

Retirement (EOS / max-token) and the request queue are host-side numpy
bookkeeping over (S,) vectors; every device call has static shapes, so
the three programs compile exactly once per (model, S, chunk). Output is
token-for-token identical to running each request alone, unpadded,
through `launch.serve.greedy_decode(prefill="loop")` — the reference
oracle asserted by tests/test_engine.py — because active-masked slots are
bit-frozen and each live slot's math is row-independent.

    engine = DecodeEngine(model, params, num_slots=8, cache_len=128)
    rid = engine.submit(prompt_tokens, max_new_tokens=32)
    ...                          # submit more any time, even mid-flight
    done = engine.run()          # {rid: Completion}

Multi-tenant serving (`max_tenants=`): one base model, many privately
fine-tuned LoRA adapters (the paper's Sec 5.3 recipe productionized).
Adapters for every live tenant live in ONE tenant-stacked device buffer
(core.lora.stacked_adapter_zeros); each pool slot carries an int32
adapter-slot id, and the pool-wide decode applies every row's own
adapter as one batched multi-LoRA gather/einsum inside the compiled
program (core.lora.stacked_lora_delta). The tenant ids and the stacked
buffer are DATA — onboarding a tenant, hot-swapping an adapter
(`update_adapter`, the target of launch.swap's checkpoint watcher) and
retiring a tenant are buffer/host-table writes that NEVER retrace the
three programs (`trace_counts` lets callers assert this).

Tenant lifecycle mirrors the slot pool's own discipline:

  * `add_tenant` fills a free adapter slot; when all `max_tenants` slots
    are held, the tenant WAITS (FIFO, same deferral semantics as paged
    admission) and its requests hold the queue until a slot frees.
  * `update_adapter` is a blue/green swap: with in-flight requests on
    the old version, the new version lands in a spare adapter slot and
    only NEW admissions route to it — the old slot drains (in-flight
    requests keep their version to the last token) and frees on the
    last retirement. With no spare slot (or no in-flight use) the write
    is in-place.
  * `remove_tenant` refuses new submits, drains the tenant's queued and
    in-flight requests, then recycles its adapter slot to the waiters.

Paged-plane prefix sharing is namespaced by (tenant, adapter version):
KV bytes depend on the adapter, so prefixes never cross tenants or
survive a swap.

    eng = DecodeEngine(model, params, num_slots=8, cache_len=128,
                       max_tenants=4)
    alice = eng.add_tenant(adapter_tree, name="alice")
    rid = eng.submit(prompt_tokens, max_new_tokens=32, tenant=alice)
    eng.update_adapter(alice, new_tree)   # hot swap, zero retrace
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.pages import PagePool, PrefixStore, pages_needed


@dataclasses.dataclass
class Completion:
    """One finished request."""

    rid: int
    prompt_len: int
    tokens: list[int]
    finish_reason: str  # "eos" | "length"


class DecodeEngine:
    """Slot-pool continuous-batching greedy decoder (see module doc)."""

    # Every counter in `engine.stats`, with its meaning. The engine-stats
    # table in docs/serving.md is GENERATED from this mapping and
    # tests/test_docs.py asserts the two stay identical, so the docs
    # cannot rot when a counter is added or renamed.
    STATS_DOC = {
        "prefill_dispatches": "jitted chunked-prefill dispatches run at "
                              "admission (one per prefill_chunk positions "
                              "per co-admitted batch)",
        "decode_dispatches": "pool-wide decode dispatches (one per "
                             "`step()` with any live slot)",
        "tokens_out": "tokens emitted across all requests",
        "requests_done": "requests retired (EOS or length)",
        "prefill_pad_chunks_saved": "padded prefill chunks avoided by the "
                                    "admission skew cap (prefill_skew_"
                                    "chunks) splitting mismatched batches",
        "live_slot_steps": "sum over steps of live slots advanced "
                           "(occupancy-weighted utilization numerator)",
        "peak_live_slots": "max live slots in any one decode dispatch",
        "pages_in_use": "pool pages currently held (paged plane)",
        "peak_pages_in_use": "high-water mark of pages_in_use",
        "prefix_hits": "admissions that mapped a registered shared prefix "
                       "instead of recomputing it",
        "shared_pages": "physical pages mapped from shared prefixes "
                        "(cumulative over admissions)",
        "evicted_pages": "pages freed by spilling/dropping cold prefixes "
                         "under pool pressure",
        "readmitted_pages": "host-tier prefix pages uploaded back to the "
                            "device pool on a later hit",
        "admission_deferrals": "admissions deferred because the page pool "
                               "could not cover the request's full "
                               "reservation (FIFO: the head blocks)",
        "tenants_admitted": "tenants granted an adapter slot (multi-"
                            "tenant mode)",
        "adapter_swaps": "hot swaps installed via update_adapter "
                         "(blue/green or in-place)",
        "adapter_slot_deferrals": "admissions deferred because the "
                                  "request's tenant is still waiting for "
                                  "an adapter slot (FIFO, like page "
                                  "reservation deferral)",
    }

    def __init__(self, model, params, *, num_slots: int, cache_len: int,
                 prefill_chunk: int = 8, eos_id: int | None = None,
                 paging: str = "auto", page_len: int = 16,
                 num_pages: int | None = None, host_spill: bool = True,
                 prefill_skew_chunks: int = 1,
                 max_tenants: int | None = None):
        """Build the engine and compile its three programs.

        num_slots: pool width S — the batch dimension of every dispatch
            (default: none; required). cache_len: per-slot logical
            context horizon in tokens. prefill_chunk: prompt positions
            per admission dispatch (default 8). eos_id: retire-on-token
            (default None: length-only). paging/page_len/num_pages/
            host_spill: the paged KV plane (module doc). prefill_skew_
            chunks: co-admission skew cap in chunks (default 1).
        max_tenants: None (default) serves the single model in `params`;
            an int T switches on multi-tenant mode — the model must have
            been built with `lora_rank > 0` (the adapter spec sizes the
            tenant-stacked buffer), `params` holds the FROZEN base
            weights, and every `submit` names a tenant from
            `add_tenant`. T bounds concurrently-resident adapters, not
            tenants ever onboarded (slots recycle)."""
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if paging not in ("auto", "on", "off"):
            raise ValueError("paging must be 'auto', 'on' or 'off'")
        if page_len < 1:
            raise ValueError("page_len must be >= 1")
        if prefill_skew_chunks < 0:
            raise ValueError("prefill_skew_chunks must be >= 0")
        self.model, self.params = model, params
        self.num_slots, self.cache_len = num_slots, cache_len
        self.eos_id = eos_id
        self._chunk = prefill_chunk
        self._skew = prefill_skew_chunks
        cfg = model.cfg
        # full (non-ring) attention caches hard-bound the horizon; ring /
        # recurrent caches only carry O(1) or windowed state
        self._bounded = cfg.attention_kind == "mla" or (
            cfg.attention_kind == "gqa" and cfg.sliding_window is None)

        can_page = (getattr(model, "init_paged_cache", None) is not None
                    and self._bounded and cache_len % page_len == 0)
        if paging == "on" and not can_page:
            raise ValueError(
                "paging='on' needs a full-attention model family and "
                "cache_len divisible by page_len (ring-window / recurrent "
                "caches bypass paging)")
        self.paged = can_page if paging == "auto" else paging == "on"
        self.page_len = page_len

        if self.paged:
            ptab = cache_len // page_len
            self.num_pages = (num_slots * ptab if num_pages is None
                              else int(num_pages))
            if self.num_pages < 1:
                raise ValueError("num_pages must be >= 1")
            self.cache = model.init_paged_cache(
                num_slots, cache_len, num_pages=self.num_pages,
                page_len=page_len)
            self._pool = PagePool(self.num_pages, page_len)
            self._prefix = PrefixStore(self._pool)
            self._host_spill = host_spill
            # host mirror of cache["pt"]; trash page index = num_pages
            self._table = np.full((num_slots, ptab), self.num_pages,
                                  np.int32)
            self._row_pages: list[list[int]] = [[] for _ in range(num_slots)]
            self._pool_keys = [k for k in self.cache
                               if k.endswith(("_kpool", "_vpool",
                                              "_latpool"))]
        else:
            self.num_pages = None
            self.cache = model.init_cache(num_slots, cache_len)
        self._last = jnp.zeros((num_slots, cfg.vocab_size), jnp.float32)

        # ---- host-side slot table ----
        self._rid = np.full((num_slots,), -1, np.int64)
        self._live = np.zeros((num_slots,), bool)
        self._gen = np.zeros((num_slots,), np.int64)
        self._max = np.zeros((num_slots,), np.int64)
        self._tok = np.zeros((num_slots,), np.int32)  # last emitted token
        self._start = np.zeros((num_slots,), np.int32)  # pos at admission
        self._queue: collections.deque = collections.deque()
        self._out: dict[int, list[int]] = {}
        self._plen: dict[int, int] = {}
        self._done: dict[int, Completion] = {}
        self._next_rid = 0
        # one counter per STATS_DOC key; the docstring table and the docs
        # derive from the same mapping
        self.stats = {k: 0 for k in self.STATS_DOC}

        # ---- multi-tenant adapter plane ----
        self.multi_tenant = max_tenants is not None
        # adapter-slot id riding every dispatch; all-zero (and unused by
        # the traced program) in single-model mode
        self._tid = np.zeros((num_slots,), np.int32)
        # per pool slot: owning tenant id / prefix namespace (single-model
        # mode leaves both at their empty values)
        self._slot_tid = np.full((num_slots,), -1, np.int64)
        self._slot_ns: list[bytes] = [b""] * num_slots
        if self.multi_tenant:
            if max_tenants < 1:
                raise ValueError("max_tenants must be >= 1")
            spec_lora = (getattr(model, "spec", None) or {}).get("lora")
            if not spec_lora:
                raise ValueError(
                    "multi-tenant serving needs a model built with "
                    "lora_rank > 0 on an attention-stack family (the "
                    "adapter spec sizes the tenant-stacked buffer)")
            from repro.core.lora import stacked_adapter_zeros
            self.max_tenants = int(max_tenants)
            self._adapters = stacked_adapter_zeros(spec_lora,
                                                   self.max_tenants)
            self._tenants: dict[int, dict] = {}
            self._next_tid = 0
            self._aslot_free: list[int] = list(range(self.max_tenants))
            self._aslot_rc = np.zeros((self.max_tenants,), np.int64)
            self._draining: set[int] = set()
            self._waiting: collections.deque = collections.deque()
            self._serve_params = {**params, "lora_stack": self._adapters}
        else:
            self.max_tenants = None
            self._serve_params = params

        # ---- the three compiled programs ----
        # trace-time side-effect counters: each compiled program body
        # bumps its key exactly once per (re)trace, so tests can assert
        # that tenant onboarding / hot swaps NEVER recompile
        self.trace_counts = {"prefill": 0, "decode": 0, "reset": 0}
        mt = self.multi_tenant

        def prefill_fn(params, cache, last, toks, valid, tids):
            self.trace_counts["prefill"] += 1

            # toks/valid: (S, C); scan over the C chunk positions
            def stepf(carry, xs):
                cache, last = carry
                tok, act = xs
                batch = {"token": tok[:, None], "active": act}
                if mt:
                    batch["tenant"] = tids
                logits, cache = model.serve_step(params, cache, batch)
                last = jnp.where(act[:, None], logits.astype(jnp.float32),
                                 last)
                return (cache, last), None

            (cache, last), _ = jax.lax.scan(stepf, (cache, last),
                                            (toks.T, valid.T))
            return cache, last, jnp.argmax(last, axis=-1).astype(jnp.int32)

        def decode_fn(params, cache, tok, live, tids):
            self.trace_counts["decode"] += 1
            batch = {"token": tok[:, None], "active": live}
            if mt:
                batch["tenant"] = tids
            logits, cache = model.serve_step(params, cache, batch)
            nxt = jnp.argmax(logits.astype(jnp.float32),
                             axis=-1).astype(jnp.int32)
            return cache, jnp.where(live, nxt, tok)

        axes = model.cache_slot_axes(self.cache)

        def reset_fn(cache, mask, starts):
            self.trace_counts["reset"] += 1
            out = {}
            for k, v in cache.items():
                ax = axes[k]
                if ax is None or k == "pt":
                    # slot-free page pools; pt is replaced host-side right
                    # after the reset (the host table is authoritative)
                    out[k] = v
                elif k == "pos":
                    # prefix-sharing slots resume mid-sequence
                    out[k] = jnp.where(mask, starts, v)
                else:
                    m = mask.reshape((1,) * ax + (num_slots,)
                                     + (1,) * (v.ndim - ax - 1))
                    out[k] = jnp.where(m, jnp.zeros_like(v), v)
            return out

        self._prefill = jax.jit(prefill_fn, donate_argnums=(1, 2))
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._reset = jax.jit(reset_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # Public surface.
    # ------------------------------------------------------------------

    @property
    def num_free_slots(self) -> int:
        return int(self.num_slots - self._live.sum())

    @property
    def num_pending(self) -> int:
        return len(self._queue)

    @property
    def num_live(self) -> int:
        return int(self._live.sum())

    def cache_bytes(self) -> int:
        """Device bytes held by the decode cache (pools + tables +
        positions for the paged plane; per-slot caches otherwise). The
        tenant-stacked adapter buffer is NOT included (see
        `adapter_bytes`)."""
        return int(sum(v.size * v.dtype.itemsize
                       for v in self.cache.values()))

    def submit(self, prompt, max_new_tokens: int,
               tenant: int | None = None) -> int:
        """Enqueue one request; admitted into a free slot at the next
        `step()` (FIFO). Returns the request id.

        prompt: 1-D int token ids (>= 1 token). max_new_tokens: >= 1
        generated-token budget, counted toward the slot's `cache_len`
        horizon. tenant: required (a live `add_tenant` id) in
        multi-tenant mode, forbidden otherwise; retiring tenants refuse
        new work."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(
                "empty prompt: seed requests with at least one (BOS) token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self._bounded and prompt.size + max_new_tokens > self.cache_len:
            raise ValueError(
                f"request needs {prompt.size}+{max_new_tokens} cache slots "
                f"but the pool was sized with cache_len={self.cache_len}")
        if self.paged:
            need = pages_needed(prompt.size + max_new_tokens, self.page_len)
            if need > self.num_pages:
                raise ValueError(
                    f"request needs {need} pages but the page pool holds "
                    f"only num_pages={self.num_pages}")
        if self.multi_tenant:
            t = self._tenant(tenant)
            if t["retiring"]:
                raise ValueError(f"tenant {tenant} is retiring: no new "
                                 f"requests accepted")
            t["queued"] += 1
            t["stats"]["requests_submitted"] += 1
        elif tenant is not None:
            raise ValueError("tenant= requires a multi-tenant engine "
                             "(max_tenants=)")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, prompt, int(max_new_tokens), tenant))
        return rid

    # ------------------------------------------------------------------
    # Multi-tenant surface.
    # ------------------------------------------------------------------

    @property
    def num_free_adapter_slots(self) -> int:
        """Adapter slots not held by a live or draining tenant version."""
        self._require_mt()
        return len(self._aslot_free)

    def adapter_bytes(self) -> int:
        """Device bytes held by the tenant-stacked adapter buffer."""
        self._require_mt()
        return int(sum(v.size * v.dtype.itemsize
                       for v in jax.tree_util.tree_leaves(self._adapters)))

    def add_tenant(self, adapters=None, *, name: str | None = None) -> int:
        """Onboard a tenant; returns its tenant id (stable for the
        tenant's lifetime, independent of adapter slots).

        adapters: the tenant's trained adapter tree (the `lora` subtree
        of a fine-tune run — leaves (n, d_in, r)/(n, r, d_out) matching
        `adapter_template()`), or None for the exact base model (zero
        adapter). When a free adapter slot exists the adapter is
        installed immediately — a pure buffer write, ZERO recompilation;
        otherwise the tenant WAITS (FIFO with other waiters, mirroring
        paged admission deferral): its requests may be submitted but hold
        the queue until a retiring tenant's slot frees."""
        self._require_mt()
        tid = self._next_tid
        self._next_tid += 1
        self._tenants[tid] = {
            "tid": tid, "name": name or f"tenant-{tid}", "aslot": None,
            "version": 0, "retiring": False, "removed": False,
            "queued": 0, "inflight": 0, "pending_tree": adapters,
            "stats": {"requests_submitted": 0, "requests_done": 0,
                      "tokens_out": 0, "swaps": 0},
        }
        self._waiting.append(tid)
        self._assign_adapter_slots()
        return tid

    def update_adapter(self, tid: int, adapters) -> None:
        """Hot-swap a tenant's adapter (launch.swap calls this with a
        freshly published, crc-verified checkpoint tree).

        Blue/green: while the tenant has requests in flight on the old
        version, the new version is installed into a SPARE adapter slot
        and only new admissions route to it; the old slot drains with its
        in-flight requests and frees on their last retirement. With no
        in-flight use (or no spare slot) the write is in-place. Either
        way it is data only — no recompilation — and the paged prefix
        namespace rolls over with the version, so no pre-swap KV is ever
        replayed for post-swap requests."""
        t = self._tenant(tid)
        if t["retiring"]:
            raise ValueError(f"tenant {tid} is retiring: cannot swap")
        if t["aslot"] is None:
            t["pending_tree"] = adapters  # not yet installed: restage
        elif self._aslot_rc[t["aslot"]] > 0 and self._aslot_free:
            old = t["aslot"]
            new = self._aslot_free.pop(0)
            self._install_adapter(new, adapters)
            t["aslot"] = new
            self._draining.add(old)  # frees when its last request retires
        else:
            self._install_adapter(t["aslot"], adapters)
        t["version"] += 1
        t["stats"]["swaps"] += 1
        self.stats["adapter_swaps"] += 1

    def remove_tenant(self, tid: int) -> None:
        """Retire a tenant: new submits are refused immediately; queued
        and in-flight requests DRAIN on the tenant's current adapter
        version, and the adapter slot returns to the free list (waking
        FIFO waiters) when the last one retires. Idempotent."""
        t = self._tenant(tid, allow_removed=True)
        if t["retiring"]:
            return
        t["retiring"] = True
        self._maybe_release(tid)

    def tenant_stats(self, tid: int) -> dict:
        """Per-tenant counters + lifecycle state: requests_submitted /
        requests_done / tokens_out / swaps, plus state ('waiting' |
        'active' | 'retiring' | 'removed'), version, adapter_slot,
        queued, inflight."""
        t = self._tenant(tid, allow_removed=True)
        state = ("removed" if t["removed"] else
                 "retiring" if t["retiring"] else
                 "active" if t["aslot"] is not None else "waiting")
        return dict(t["stats"], state=state, version=t["version"],
                    adapter_slot=t["aslot"], queued=t["queued"],
                    inflight=t["inflight"], name=t["name"])

    def tenants(self) -> list[int]:
        """Ids of tenants not yet removed, onboarding order."""
        self._require_mt()
        return [tid for tid, t in self._tenants.items()
                if not t["removed"]]

    def adapter_template(self):
        """A zero per-tenant adapter tree (leaves (n, ...) — the stacked
        buffer minus the tenant axis): the `load_checkpoint` template for
        published adapter checkpoints (launch.swap)."""
        self._require_mt()
        return jax.tree_util.tree_map(
            lambda buf: jnp.zeros(buf.shape[:1] + buf.shape[2:], buf.dtype),
            self._adapters)

    def adapter_crcs(self, tid: int) -> list[int]:
        """crc32 of every adapter leaf INSTALLED on device for `tid`
        (flatten order), over the same raw bytes checkpoint manifests
        checksum — the bitwise hot-swap verification read back from the
        live stacked buffer (launch.swap compares these against the
        published manifest)."""
        t = self._tenant(tid)
        if t["aslot"] is None:
            raise ValueError(f"tenant {tid} has no installed adapter yet")
        from repro.checkpoint.store import leaf_crc32
        return [leaf_crc32(leaf[:, t["aslot"]])
                for leaf in jax.tree_util.tree_leaves(self._adapters)]

    def _require_mt(self):
        if not self.multi_tenant:
            raise ValueError("this engine was built single-model; pass "
                             "max_tenants= for multi-tenant serving")

    def _tenant(self, tid, allow_removed: bool = False) -> dict:
        self._require_mt()
        t = self._tenants.get(tid)
        if t is None:
            raise ValueError(f"unknown tenant id {tid!r}")
        if t["removed"] and not allow_removed:
            raise ValueError(f"tenant {tid} was removed")
        return t

    def _install_adapter(self, aslot: int, tree) -> None:
        from repro.core.lora import stacked_slot_update
        self._adapters = stacked_slot_update(self._adapters, aslot, tree)
        self._serve_params = {**self._serve_params,
                              "lora_stack": self._adapters}

    def _assign_adapter_slots(self) -> None:
        """FIFO: hand freed adapter slots to waiting tenants."""
        while self._waiting and self._aslot_free:
            tid = self._waiting.popleft()
            t = self._tenants[tid]
            if t["removed"] or t["aslot"] is not None:
                continue
            aslot = self._aslot_free.pop(0)
            self._install_adapter(aslot, t.pop("pending_tree", None))
            t["aslot"] = aslot
            self.stats["tenants_admitted"] += 1

    def _maybe_release(self, tid: int) -> None:
        """Retiring tenant with nothing queued or in flight: recycle."""
        t = self._tenants[tid]
        if (not t["retiring"] or t["removed"] or t["queued"]
                or t["inflight"]):
            return
        t["removed"] = True
        if t["aslot"] is not None:
            # inflight == 0 implies no pool slot pins this adapter slot
            self._aslot_free.append(t["aslot"])
            t["aslot"] = None
        self._assign_adapter_slots()

    def _prefix_ns(self, tid) -> bytes:
        """Prefix-store namespace: adapter-dependent KV never crosses a
        tenant boundary or an adapter version."""
        if not self.multi_tenant:
            return b""
        t = self._tenants[tid]
        return f"{tid}:{t['version']}|".encode()

    def step(self) -> int:
        """Admit whatever fits into free slots (chunked prefill), then one
        pool-wide decode dispatch advancing every live slot. Returns the
        number of live slots advanced."""
        self._admit()
        live_idx = np.nonzero(self._live)[0]
        if live_idx.size == 0:
            return 0
        self.cache, nxt = self._decode(self._serve_params, self.cache,
                                       jnp.asarray(self._tok),
                                       jnp.asarray(self._live),
                                       jnp.asarray(self._tid))
        self.stats["decode_dispatches"] += 1
        self.stats["live_slot_steps"] += int(live_idx.size)
        self.stats["peak_live_slots"] = max(self.stats["peak_live_slots"],
                                            int(live_idx.size))
        nxt = np.asarray(nxt)
        for slot in live_idx:
            self._emit(int(slot), int(nxt[slot]))
        return int(live_idx.size)

    def run(self, max_steps: int | None = None) -> dict[int, Completion]:
        """Drive until the queue and the pool drain — or until `max_steps`
        pool steps, whichever comes first — and return the completions
        finished so far (keyed by request id). Callers using `max_steps`
        as a safety bound can check `num_live` / `num_pending` afterwards
        to see whether the engine actually drained.

        An UNBOUNDED run raises RuntimeError if the queue head becomes
        permanently unadmittable (nothing live, nothing admitted, nothing
        retired in a step — e.g. a tenant waiting for an adapter slot no
        drain will free). A bounded run instead returns at `max_steps`,
        which is how callers pump the pool while waiting for external
        action (a `remove_tenant`, a hot swap) to unblock it."""
        steps = 0
        while self._queue or self._live.any():
            if max_steps is not None and steps >= max_steps:
                break
            before = len(self._queue)
            advanced = self.step()
            steps += 1
            if (max_steps is None and advanced == 0
                    and not self._live.any()
                    and len(self._queue) == before and self._queue):
                # nothing live, nothing admitted, nothing retired: the
                # head of the queue is permanently stuck (e.g. its tenant
                # is waiting for an adapter slot no drain will ever free,
                # or a page reservation nothing live can release)
                raise RuntimeError(
                    f"engine stalled with {len(self._queue)} queued "
                    f"request(s) and no live slots: the queue head cannot "
                    f"be admitted (waiting tenant without a free adapter "
                    f"slot, or an unsatisfiable page reservation)")
        return dict(self._done)

    def completions(self) -> dict[int, Completion]:
        return dict(self._done)

    # ------------------------------------------------------------------
    # Paged data plane (host side; device arrays live in self.cache).
    # ------------------------------------------------------------------

    def _alloc_evicting(self, n: int) -> list[int] | None:
        """Allocate `n` pages, spilling cold registered prefixes to the
        host tier (or dropping them when host_spill=False) until the pool
        can cover it. None if even a fully evicted device tier cannot."""
        got = self._pool.alloc(n)
        while got is None:
            entry = self._prefix.evict_lru()
            if entry is None:
                return None
            if self._host_spill:
                idx = jnp.asarray(np.asarray(entry.pages, np.int32))
                data = {k: np.asarray(jax.device_get(self.cache[k][:, idx]))
                        for k in self._pool_keys}
                freed = self._prefix.spill(entry, data)
            else:
                freed = self._prefix.drop(entry)
            self.stats["evicted_pages"] += len(freed)
            got = self._pool.alloc(n)
        return got

    def _plan_pages(self, prompt, max_new: int, hit):
        """Reserve every page the request will ever touch (shared prefix
        + private tail through the last generated token) — admission is
        all-or-nothing, so a live slot can never run out of pages
        mid-decode. Returns (shared_page_count j, page row) or None when
        the pool can't cover it yet (caller defers the request)."""
        need_total = pages_needed(prompt.size + max_new, self.page_len)
        if hit is None:
            priv = self._alloc_evicting(need_total)
            if priv is None:
                return None
            return 0, priv
        entry, j, tier = hit
        if tier == "host":
            n_up = entry.n_pages
            up = self._alloc_evicting(n_up)
            if up is None:
                return None
            priv = self._alloc_evicting(need_total - j)
            if priv is None:
                self._pool.decref(up)
                return None
            idx = jnp.asarray(np.asarray(up, np.int32))
            for k in self._pool_keys:
                payload = jnp.asarray(entry.host_data[k],
                                      self.cache[k].dtype)
                self.cache[k] = self.cache[k].at[:, idx].set(payload)
            self._prefix.readmit(entry, up)  # alloc ref -> registry ref
            shared = list(up[:j])
            self._pool.incref(shared)        # the slot's own reference
            self.stats["readmitted_pages"] += n_up
        else:
            shared = list(entry.pages[:j])
            self._pool.incref(shared)
            priv = self._alloc_evicting(need_total - j)
            if priv is None:
                self._pool.decref(shared)
                return None
        self.stats["prefix_hits"] += 1
        self.stats["shared_pages"] += j
        return j, shared + priv

    # ------------------------------------------------------------------
    # Internals.
    # ------------------------------------------------------------------

    def _admit(self):
        """Move queued requests into free slots: recycle the slots, then
        length-masked chunked prefill — one jitted dispatch per chunk of
        `prefill_chunk` positions, all admitted slots together, every
        other slot bit-frozen. FIFO with two admission gates (a blocked
        request blocks everything behind it — no reordering):
          * skew cap: a candidate needing > prefill_skew_chunks more
            prefill chunks than its batch-mates waits for its own batch;
          * page reservation (paged plane): a candidate the pool cannot
            cover even after evicting cold prefixes is deferred;
          * adapter slot (multi-tenant): a candidate whose tenant is
            still waiting for an adapter slot is deferred the same way
            (FIFO — the queue holds until a retiring tenant drains)."""
        free = [s for s in range(self.num_slots) if not self._live[s]]
        batch = []  # (slot, rid, prompt, tail, max_new)
        ch_lo = ch_hi = 0
        while free and self._queue:
            rid, prompt, max_new, tenant = self._queue[0]
            ns = self._prefix_ns(tenant) if self.multi_tenant else b""
            if self.multi_tenant:
                t = self._tenants[tenant]
                if t["aslot"] is None:
                    # tenant not yet holding an adapter slot: defer (the
                    # slot arrives via _assign_adapter_slots on a drain)
                    self.stats["adapter_slot_deferrals"] += 1
                    break
            hit = self._prefix.probe(prompt, ns) if self.paged else None
            j = hit[1] if hit is not None else 0
            ch = -(-(prompt.size - j * self.page_len) // self._chunk)
            if batch:
                lo, hi = min(ch_lo, ch), max(ch_hi, ch)
                if hi - lo > self._skew:
                    self.stats["prefill_pad_chunks_saved"] += (
                        len(batch) * max(0, ch - ch_hi)
                        + max(0, ch_lo - ch))
                    break
            if self.paged:
                plan = self._plan_pages(prompt, max_new, hit)
                if plan is None:
                    self.stats["admission_deferrals"] += 1
                    break
                j, row = plan
            self._queue.popleft()
            slot = free.pop(0)
            if self.multi_tenant:
                # pin the tenant's CURRENT adapter slot to this pool slot:
                # a blue/green swap mid-request moves the tenant to a new
                # adapter slot, but this request keeps decoding on the old
                # one (rc holds it) until it retires
                aslot = t["aslot"]
                self._tid[slot] = aslot
                self._slot_tid[slot] = tenant
                self._slot_ns[slot] = ns
                self._aslot_rc[aslot] += 1
                t["queued"] -= 1
                t["inflight"] += 1
            ch_lo, ch_hi = (ch, ch) if not batch else (min(ch_lo, ch),
                                                       max(ch_hi, ch))
            if self.paged:
                self._table[slot, :] = self.num_pages
                self._table[slot, : len(row)] = row
                self._row_pages[slot] = row
            self._start[slot] = j * self.page_len if self.paged else 0
            batch.append((slot, rid, prompt,
                          prompt[j * self.page_len:] if self.paged
                          else prompt, max_new))
        if not batch:
            return
        mask = np.zeros((self.num_slots,), bool)
        for slot, _, _, _, _ in batch:
            mask[slot] = True
        self.cache = self._reset(self.cache, jnp.asarray(mask),
                                 jnp.asarray(self._start))
        if self.paged:
            self.cache["pt"] = jnp.asarray(self._table)

        c = self._chunk
        pmax = max(t.size for _, _, _, t, _ in batch)
        padded = -(-pmax // c) * c
        toks = np.zeros((self.num_slots, padded), np.int32)
        valid = np.zeros((self.num_slots, padded), bool)
        for slot, _, _, tail, _ in batch:
            toks[slot, : tail.size] = tail
            valid[slot, : tail.size] = True
        last = self._last
        for c0 in range(0, padded, c):
            self.cache, last, first = self._prefill(
                self._serve_params, self.cache, last,
                jnp.asarray(toks[:, c0:c0 + c]),
                jnp.asarray(valid[:, c0:c0 + c]),
                jnp.asarray(self._tid))
            self.stats["prefill_dispatches"] += 1
        self._last = last
        first = np.asarray(first)
        for slot, rid, prompt, _, max_new in batch:
            self._rid[slot] = rid
            self._live[slot] = True
            self._gen[slot] = 0
            self._max[slot] = max_new
            self._out[rid] = []
            self._plen[rid] = int(prompt.size)
            if self.paged:
                # every full page of the (now fully cached) prompt becomes
                # shareable — registering here, after the tail prefill,
                # lets requests admitted mid-flight hit it immediately
                j_reg = prompt.size // self.page_len
                if j_reg:
                    self._prefix.register(prompt,
                                          self._row_pages[slot][:j_reg],
                                          self._slot_ns[slot])
            # the first output token falls out of the prefill itself
            self._emit(slot, int(first[slot]))
        if self.paged:
            used = self._pool.num_used
            self.stats["pages_in_use"] = used
            self.stats["peak_pages_in_use"] = max(
                self.stats["peak_pages_in_use"], used)

    def _emit(self, slot: int, tok: int):
        rid = int(self._rid[slot])
        self._out[rid].append(tok)
        self._gen[slot] += 1
        self._tok[slot] = tok
        self.stats["tokens_out"] += 1
        if self.multi_tenant:
            self._tenants[self._slot_tid[slot]]["stats"]["tokens_out"] += 1
        if self.eos_id is not None and tok == self.eos_id:
            self._retire(slot, "eos")
        elif self._gen[slot] >= self._max[slot]:
            self._retire(slot, "length")

    def _retire(self, slot: int, reason: str):
        rid = int(self._rid[slot])
        self._done[rid] = Completion(rid=rid, prompt_len=self._plen.pop(rid),
                                     tokens=self._out.pop(rid),
                                     finish_reason=reason)
        self._live[slot] = False
        self._rid[slot] = -1
        self.stats["requests_done"] += 1
        if self.multi_tenant:
            tid = int(self._slot_tid[slot])
            aslot = int(self._tid[slot])
            t = self._tenants[tid]
            t["inflight"] -= 1
            t["stats"]["requests_done"] += 1
            self._aslot_rc[aslot] -= 1
            if self._aslot_rc[aslot] == 0 and aslot in self._draining:
                # last request on a blue/green-superseded adapter version:
                # its slot returns to the pool (and may wake a FIFO waiter)
                self._draining.discard(aslot)
                self._aslot_free.append(aslot)
                self._assign_adapter_slots()
            self._slot_tid[slot] = -1
            self._slot_ns[slot] = b""
            self._maybe_release(tid)
        if self.paged:
            # O(table) recycle: pages go back to the free list (or stay
            # alive under their prefix-registry / co-sharing references);
            # nothing on device is touched — stale pool contents are
            # unreachable through any live table row
            self._pool.decref(self._row_pages[slot])
            self._row_pages[slot] = []
            self._table[slot, :] = self.num_pages
            self.stats["pages_in_use"] = self._pool.num_used
