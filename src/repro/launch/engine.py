"""Continuous-batching decode engine over a fixed slot pool.

`DecodeEngine` owns a pre-allocated decode cache of `num_slots` slots
(the `slot`/`pos` ring algebra of models/attention.py) and serves an
arbitrary stream of ragged requests through THREE compiled programs whose
shapes never depend on the traffic — no recompilation as requests come
and go:

  admission  `_prefill`  — a jitted scan over a fixed-size chunk of
      `prefill_chunk` prompt positions. Only the slots being admitted are
      active (length-masked: serve_step's `active` row mask suppresses
      both the cache write and the position advance, so pad tokens never
      pollute the pool) while every other slot — mid-decode or idle — is
      bit-frozen. Each admitted slot's TRUE-last-token logits accumulate
      in a persistent (S, V) buffer; its argmax is the slot's first
      output token.
  decode     `_decode`   — ONE dispatch advances every live slot by one
      greedy token; retired / free slots ride along masked.
  recycle    `_reset`    — zeroes the cache rows (KV, ring, recurrent
      state, position) of slots being handed to a new request, so a
      recycled slot cannot leak its previous occupant. (For attention
      caches the `pos -> 0` reset alone masks stale entries via the
      kpos validity algebra; recurrent state needs the explicit zero.)

Retirement (EOS / max-token) and the request queue are host-side numpy
bookkeeping over (S,) vectors; every device call has static shapes, so
the three programs compile exactly once per (model, S, chunk). Output is
token-for-token identical to running each request alone, unpadded,
through `launch.serve.greedy_decode(prefill="loop")` — the reference
oracle asserted by tests/test_engine.py — because active-masked slots are
bit-frozen and each live slot's math is row-independent.

    engine = DecodeEngine(model, params, num_slots=8, cache_len=128)
    rid = engine.submit(prompt_tokens, max_new_tokens=32)
    ...                          # submit more any time, even mid-flight
    done = engine.run()          # {rid: Completion}
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Completion:
    """One finished request."""

    rid: int
    prompt_len: int
    tokens: list[int]
    finish_reason: str  # "eos" | "length"


class DecodeEngine:
    """Slot-pool continuous-batching greedy decoder (see module doc)."""

    def __init__(self, model, params, *, num_slots: int, cache_len: int,
                 prefill_chunk: int = 8, eos_id: int | None = None):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.model, self.params = model, params
        self.num_slots, self.cache_len = num_slots, cache_len
        self.eos_id = eos_id
        self._chunk = prefill_chunk
        cfg = model.cfg
        # full (non-ring) attention caches hard-bound the horizon; ring /
        # recurrent caches only carry O(1) or windowed state
        self._bounded = cfg.attention_kind == "mla" or (
            cfg.attention_kind == "gqa" and cfg.sliding_window is None)

        self.cache = model.init_cache(num_slots, cache_len)
        self._last = jnp.zeros((num_slots, cfg.vocab_size), jnp.float32)

        # ---- host-side slot table ----
        self._rid = np.full((num_slots,), -1, np.int64)
        self._live = np.zeros((num_slots,), bool)
        self._gen = np.zeros((num_slots,), np.int64)
        self._max = np.zeros((num_slots,), np.int64)
        self._tok = np.zeros((num_slots,), np.int32)  # last emitted token
        self._queue: collections.deque = collections.deque()
        self._out: dict[int, list[int]] = {}
        self._plen: dict[int, int] = {}
        self._done: dict[int, Completion] = {}
        self._next_rid = 0
        self.stats = {"prefill_dispatches": 0, "decode_dispatches": 0,
                      "tokens_out": 0, "requests_done": 0}

        # ---- the three compiled programs ----
        def prefill_fn(params, cache, last, toks, valid):
            # toks/valid: (S, C); scan over the C chunk positions
            def stepf(carry, xs):
                cache, last = carry
                tok, act = xs
                logits, cache = model.serve_step(
                    params, cache, {"token": tok[:, None], "active": act})
                last = jnp.where(act[:, None], logits.astype(jnp.float32),
                                 last)
                return (cache, last), None

            (cache, last), _ = jax.lax.scan(stepf, (cache, last),
                                            (toks.T, valid.T))
            return cache, last, jnp.argmax(last, axis=-1).astype(jnp.int32)

        def decode_fn(params, cache, tok, live):
            logits, cache = model.serve_step(
                params, cache, {"token": tok[:, None], "active": live})
            nxt = jnp.argmax(logits.astype(jnp.float32),
                             axis=-1).astype(jnp.int32)
            return cache, jnp.where(live, nxt, tok)

        def reset_fn(cache, mask):
            out = {}
            for k, v in cache.items():
                ax = 0 if k == "pos" else 1  # slot axis per cache family
                m = mask.reshape((1,) * ax + (num_slots,)
                                 + (1,) * (v.ndim - ax - 1))
                out[k] = jnp.where(m, jnp.zeros_like(v), v)
            return out

        self._prefill = jax.jit(prefill_fn, donate_argnums=(1, 2))
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._reset = jax.jit(reset_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # Public surface.
    # ------------------------------------------------------------------

    @property
    def num_free_slots(self) -> int:
        return int(self.num_slots - self._live.sum())

    @property
    def num_pending(self) -> int:
        return len(self._queue)

    @property
    def num_live(self) -> int:
        return int(self._live.sum())

    def submit(self, prompt, max_new_tokens: int) -> int:
        """Enqueue one request; admitted into a free slot at the next
        `step()`. Returns the request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(
                "empty prompt: seed requests with at least one (BOS) token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self._bounded and prompt.size + max_new_tokens > self.cache_len:
            raise ValueError(
                f"request needs {prompt.size}+{max_new_tokens} cache slots "
                f"but the pool was sized with cache_len={self.cache_len}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, prompt, int(max_new_tokens)))
        return rid

    def step(self) -> int:
        """Admit whatever fits into free slots (chunked prefill), then one
        pool-wide decode dispatch advancing every live slot. Returns the
        number of live slots advanced."""
        self._admit()
        live_idx = np.nonzero(self._live)[0]
        if live_idx.size == 0:
            return 0
        self.cache, nxt = self._decode(self.params, self.cache,
                                       jnp.asarray(self._tok),
                                       jnp.asarray(self._live))
        self.stats["decode_dispatches"] += 1
        nxt = np.asarray(nxt)
        for slot in live_idx:
            self._emit(int(slot), int(nxt[slot]))
        return int(live_idx.size)

    def run(self, max_steps: int | None = None) -> dict[int, Completion]:
        """Drive until the queue and the pool drain — or until `max_steps`
        pool steps, whichever comes first — and return the completions
        finished so far (keyed by request id). Callers using `max_steps`
        as a safety bound can check `num_live` / `num_pending` afterwards
        to see whether the engine actually drained."""
        steps = 0
        while self._queue or self._live.any():
            if max_steps is not None and steps >= max_steps:
                break
            self.step()
            steps += 1
        return dict(self._done)

    def completions(self) -> dict[int, Completion]:
        return dict(self._done)

    # ------------------------------------------------------------------
    # Internals.
    # ------------------------------------------------------------------

    def _admit(self):
        """Move queued requests into free slots: recycle (zero) the slots,
        then length-masked chunked prefill — one jitted dispatch per chunk
        of `prefill_chunk` positions, all admitted slots together, every
        other slot bit-frozen."""
        free = [s for s in range(self.num_slots) if not self._live[s]]
        batch = []
        while free and self._queue:
            batch.append((free.pop(0),) + tuple(self._queue.popleft()))
        if not batch:
            return
        mask = np.zeros((self.num_slots,), bool)
        for slot, _, _, _ in batch:
            mask[slot] = True
        self.cache = self._reset(self.cache, jnp.asarray(mask))

        c = self._chunk
        pmax = max(p.size for _, _, p, _ in batch)
        padded = -(-pmax // c) * c
        toks = np.zeros((self.num_slots, padded), np.int32)
        valid = np.zeros((self.num_slots, padded), bool)
        for slot, _, prompt, _ in batch:
            toks[slot, : prompt.size] = prompt
            valid[slot, : prompt.size] = True
        last = self._last
        for c0 in range(0, padded, c):
            self.cache, last, first = self._prefill(
                self.params, self.cache, last,
                jnp.asarray(toks[:, c0:c0 + c]),
                jnp.asarray(valid[:, c0:c0 + c]))
            self.stats["prefill_dispatches"] += 1
        self._last = last
        first = np.asarray(first)
        for slot, rid, prompt, max_new in batch:
            self._rid[slot] = rid
            self._live[slot] = True
            self._gen[slot] = 0
            self._max[slot] = max_new
            self._out[rid] = []
            self._plen[rid] = int(prompt.size)
            # the first output token falls out of the prefill itself
            self._emit(slot, int(first[slot]))

    def _emit(self, slot: int, tok: int):
        rid = int(self._rid[slot])
        self._out[rid].append(tok)
        self._gen[slot] += 1
        self._tok[slot] = tok
        self.stats["tokens_out"] += 1
        if self.eos_id is not None and tok == self.eos_id:
            self._retire(slot, "eos")
        elif self._gen[slot] >= self._max[slot]:
            self._retire(slot, "length")

    def _retire(self, slot: int, reason: str):
        rid = int(self._rid[slot])
        self._done[rid] = Completion(rid=rid, prompt_len=self._plen.pop(rid),
                                     tokens=self._out.pop(rid),
                                     finish_reason=reason)
        self._live[slot] = False
        self._rid[slot] = -1
        self.stats["requests_done"] += 1
