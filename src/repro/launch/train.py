"""End-to-end training driver (runs for real on CPU at reduced scale; the
same code path jits under the production mesh on TPU).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \\
      --clipping per_layer --epsilon 8 --steps 50 --batch 16 --seq 64
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.core.accounting import compute_epsilon
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import init_params
from repro.data import PoissonSampler, SyntheticLM, make_lm_batch, pack_documents
from repro.models.transformer import build_model


def parse_mesh(arg: str | None):
    """'--mesh DxM' -> a (data, model) mesh over the first D*M devices."""
    if not arg:
        return None
    d, m = (int(x) for x in arg.lower().split("x"))
    return jax.make_mesh((d, m), ("data", "model"))


def build_everything(args):
    cfg = get_config(args.arch, reduced=args.reduced, variant=args.variant)
    if args.lora_rank:
        import dataclasses
        cfg = dataclasses.replace(cfg, lora_rank=args.lora_rank)
    model = build_model(cfg)
    mesh = parse_mesh(args.mesh)

    src = SyntheticLM(vocab_size=cfg.vocab_size, num_docs=args.docs,
                      doc_len=args.seq * 2, seed=0)
    rows = pack_documents(src.documents(), args.seq)
    sampler = PoissonSampler(num_examples=rows.shape[0],
                             rate=args.batch / rows.shape[0],
                             max_batch=args.batch, seed=1)

    assign, nsuper = None, None
    if args.clipping.startswith("per_group") and mesh is None:
        # per-device semantics without a mesh: supergroup s = "what model
        # shard s would own" under the SAME ownership rule the sharded
        # engine and benchmarks use (launch.sharding); --group-count picks
        # the virtual shard count. (With --mesh the sharded factory derives
        # the assignment from the mesh itself.)
        from repro.launch.sharding import group_shard_assignment
        nsuper = args.group_count or 2
        assign = group_shard_assignment(model.layout, nsuper)
    dpc = DPConfig(
        mode=args.clipping,
        group_assignment=assign,
        num_supergroups=nsuper,
        epsilon=args.epsilon if args.sigma is None else None,
        sigma=args.sigma, delta=args.delta,
        sampling_rate=args.batch / rows.shape[0], steps=args.steps,
        autotune=getattr(args, "autotune", "on") != "off",
        adaptive=not args.fixed_thresholds,
        init_threshold=args.init_threshold,
        target_quantile=args.quantile,
        quantile_budget_fraction=args.quantile_budget,
        noise_strategy=args.noise_strategy,
        microbatches=args.microbatches,
        backend=args.backend,
        execution=args.execution,
    )
    sched = optim.linear_decay(args.lr, args.steps, warmup_steps=args.steps // 20)
    if args.optimizer == "adam":
        opt = optim.adam(sched)
    elif args.optimizer == "adamw":
        opt = optim.adamw(sched)
    else:
        opt = optim.sgd(sched, momentum=0.9)
    init_fn, step_fn, plan = make_dp_train_step(
        model.loss_fn, getattr(model, "dp_spec", model.spec), model.layout,
        opt, dpc, batch_size=args.batch,
        trainable_key=getattr(model, "trainable_key", None), mesh=mesh)
    return cfg, model, rows, sampler, init_fn, step_fn, plan, mesh


def build_arg_parser(**kwargs) -> argparse.ArgumentParser:
    """The training CLI surface, shared with the service daemon
    (repro.launch.service extends this parser with ledger/fault flags)."""
    ap = argparse.ArgumentParser(**kwargs)
    ap.add_argument("--arch", default="tiny",
                    choices=ARCH_IDS + ["tiny"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--clipping", default="per_layer")
    ap.add_argument("--execution", default="bk", choices=["bk", "twopass"],
                    help="flat/group clipping execution: bk runs ONE "
                         "backprop and contracts cached ghost residuals in "
                         "an epilogue (core.bk); twopass is the reference "
                         "two-backward driver")
    ap.add_argument("--epsilon", type=float, default=8.0)
    ap.add_argument("--delta", type=float, default=1e-5)
    ap.add_argument("--sigma", type=float, default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--docs", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lora-rank", type=int, default=0)
    ap.add_argument("--fixed-thresholds", action="store_true")
    ap.add_argument("--init-threshold", type=float, default=1.0)
    ap.add_argument("--quantile", type=float, default=0.5)
    ap.add_argument("--quantile-budget", type=float, default=0.01)
    ap.add_argument("--noise-strategy", default="global")
    ap.add_argument("--group-count", type=int, default=None,
                    help="per_group clipping without --mesh: number of "
                         "virtual model shards whose ownership defines the "
                         "supergroups (launch.sharding."
                         "group_shard_assignment; default 2). With --mesh "
                         "the assignment always comes from the mesh.")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="execute the step under shard_map on a "
                         "(data=D, model=M) mesh (e.g. 2x4; needs D*M "
                         "devices — on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8). "
                         "Batch shards over data; params are STORED "
                         "model-sharded per launch.sharding rules; "
                         "per_group becomes true per-device clipping.")
    ap.add_argument("--backend", default="auto",
                    choices=["xla", "pallas", "auto"],
                    help="ghost-op engine (repro.kernels.backend): xla "
                         "reference paths, pallas kernels (interpret mode "
                         "off-TPU — slow, validation only), or auto "
                         "measured/cost-model dispatch")
    ap.add_argument("--autotune", default="on", choices=["on", "off"],
                    help="on: auto consults the measured autotune table "
                         "for this topology (repro.kernels.autotune; "
                         "pre-warm with `python -m repro.kernels.autotune "
                         "--sweep`); off: static cost model only")
    ap.add_argument("--cache", default="on", choices=["on", "off"],
                    help="persistent compilation cache "
                         "(repro.launch.compile_cache): warm starts "
                         "deserialize compiled step programs instead of "
                         "recompiling")
    ap.add_argument("--cache-dir", default=None,
                    help="cache root for the autotune table AND the "
                         "compile cache (default <repo>/.cache or "
                         "$REPRO_CACHE_DIR)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest VERIFIED checkpoint in "
                         "--checkpoint-dir (params, opt state, thresholds, "
                         "and the Poisson sampler RNG state all restore, so "
                         "the run continues the exact sample stream; torn "
                         "checkpoints are skipped). For the full crash-safe "
                         "service with a persistent privacy ledger use "
                         "repro.launch.service.")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def jit_step(step_fn, model, mesh):
    """jit the step with donated carry state (and model-sharded params
    in/out when a mesh is given) — shared by train.py and the service."""
    if mesh is not None:
        # weights are STORED model-sharded between steps (memory: 1/M per
        # device); the shard_map entry all-gathers them — weight traffic,
        # classified separately from norm traffic by hlo_analysis
        from repro.launch.sharding import params_shardings
        pshard = params_shardings(model.spec, mesh)
        return jax.jit(step_fn,
                       in_shardings=(pshard, None, None, None, None),
                       out_shardings=(pshard, None, None, None),
                       donate_argnums=(0, 1, 2))
    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def setup_caches(args) -> None:
    """Enable the persistent compile cache and install the autotune table
    per the shared --cache/--autotune/--cache-dir flags (train, service,
    and serve all start here). Best-effort: cache trouble never kills a
    worker — it degrades to a cold compile / the static cost model."""
    from repro.kernels import autotune
    from repro.launch import compile_cache
    if getattr(args, "cache", "on") != "off":
        compile_cache.enable(getattr(args, "cache_dir", None))
    if getattr(args, "autotune", "on") != "off":
        autotune.install_default(getattr(args, "cache_dir", None))


def record_cache_program(args, *, entry: str, arch: str) -> None:
    """Stamp this entry point's semantic program key into the cache index
    (observability: which programs a warmed image actually covers)."""
    from repro.launch import compile_cache
    if getattr(args, "cache", "on") == "off":
        return
    import jax as _jax
    compile_cache.record_program({
        "entry": entry, "arch": arch,
        "mesh": getattr(args, "mesh", None) or "none",
        "backend": getattr(args, "backend", "auto"),
        "execution": getattr(args, "execution", "bk"),
        "clipping": getattr(args, "clipping", None) or "none",
        "jax_version": _jax.__version__,
    }, root=getattr(args, "cache_dir", None))


def main():
    args = build_arg_parser().parse_args()
    setup_caches(args)

    (cfg, model, rows, sampler, init_fn, step_fn, plan,
     mesh) = build_everything(args)
    record_cache_program(args, entry="train", arch=cfg.name)
    params = init_params(model.spec, jax.random.PRNGKey(args.seed))
    opt_state, dp_state = init_fn(params)
    start_step = 0
    if args.resume:
        if not args.checkpoint_dir:
            raise SystemExit("--resume requires --checkpoint-dir")
        from repro.checkpoint import load_latest_checkpoint
        found = load_latest_checkpoint(
            args.checkpoint_dir,
            {"params": params, "opt_state": opt_state, "dp_state": dp_state})
        if found is not None:
            start_step, tree, manifest = found
            params, opt_state, dp_state = (
                tree["params"], tree["opt_state"], tree["dp_state"])
            meta = manifest.get("meta") or {}
            if "sampler" in meta:
                sampler.restore(meta["sampler"])
            print(f"# resumed from step {start_step}")
    # donate params/opt_state/dp_state: they update in place every step, so
    # XLA aliases them input->output instead of double-buffering the model
    step = jit_step(step_fn, model, mesh)
    key = jax.random.PRNGKey(args.seed + 1)

    print(f"# arch={cfg.name} params={model.num_params:,} "
          f"groups={model.layout.num_groups} mode={plan.config.mode} "
          f"backend={plan.config.backend} "
          f"mesh={dict(mesh.shape) if mesh is not None else None} "
          f"sigma={plan.sigma:.3f} sigma_new={plan.sigma_new:.3f} "
          f"sigma_b={plan.sigma_b:.3f}")
    t_start = time.time()
    ran = 0
    for i in range(start_step, args.steps):
        idx = sampler.next_indices()
        batch = make_lm_batch(rows, idx, args.batch)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, dp_state, met = step(
            params, opt_state, dp_state, batch, key)
        ran += 1
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(met.loss):.4f} "
                  f"clip_frac {float(met.clip_fraction):.3f} "
                  f"thr {float(met.mean_threshold):.4f} "
                  f"gnorm {float(met.grad_norm):.4f}", flush=True)
    wall = time.time() - t_start
    if plan.config.private and ran:
        eps = compute_epsilon(sigma=plan.sigma,
                              sampling_rate=plan.config.sampling_rate,
                              steps=args.steps, delta=args.delta)
        print(f"# spent epsilon={eps:.3f} (delta={args.delta}) "
              f"in {args.steps} steps, {wall:.1f}s "
              f"({wall/ran*1e3:.1f} ms/step)")
    if args.checkpoint_dir:
        path = save_checkpoint(
            args.checkpoint_dir, args.steps,
            {"params": params, "opt_state": opt_state, "dp_state": dp_state},
            meta={"sampler": sampler.state()})
        print(f"# checkpoint: {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
