"""Crash-safe online DP training service.

`launch.train` is a fixed-steps batch CLI; production DP training is a
long-running *service*, and a service that loses its privacy-accountant
state on a crash either over-spends epsilon (a privacy violation) or
over-refuses (wasted compute). This daemon wraps `make_dp_train_step` with
the durability layer that makes neither possible:

  * **Persistent privacy ledger** — an append-only, per-line-checksummed
    JSONL of (step, q, sigma, orders-crc) records. The record for step i is
    appended and **fsynced before** step i's gradient update runs (the
    ledger-before-commit invariant), so a crash at ANY point leaves a
    ledger that covers every release that might have happened — the ledger
    can over-count by the in-flight step, never under-count. On startup the
    ledger replays through `core.accounting.RdpAccountant` (O(distinct
    mechanisms), not O(records × steps)).
  * **Hard epsilon enforcement** — before a step is admitted, its projected
    epsilon (`RdpAccountant.peek`) is checked against the budget; a step
    that would exceed it is *refused* and the service shuts down cleanly
    with `BudgetExhausted` (final checkpoint written, status printed) — not
    a crash, and not a silent over-spend.
  * **Crash-safe checkpoints** — atomic write-stage/fsync/rename
    (checkpoint.store) carrying params, optimizer state, quantile-threshold
    state, and the `PoissonSampler` RNG state, so a `kill -9` resumes
    bitwise-identically: same sample stream, same noise (the per-step key
    is derived by folding dp_state.step into a fixed seed), same
    thresholds. Steps that were ledgered but not yet committed at the crash
    are *re-executed deterministically* — they reproduce the identical
    release the pre-crash process made, so they are accounted once, not
    twice (their records are recognized and skipped at append time).
  * **Fault injection** — `--fault-at POINT:STEP` kills the process
    (`os._exit`) at a named point: `pre-ledger-append`,
    `post-ledger-append` (before commit), `pre-ckpt-rename` (mid
    checkpoint publish), `post-step-commit`. tests/faults.py drives the
    matrix and asserts bitwise resume parity; `mode="raise"` runs the same
    matrix in-process for tier-1.
  * **Retry / graceful degradation** — transient I/O failures around batch
    fetch, ledger append, and checkpoint save retry with capped exponential
    backoff; a torn/corrupt newest checkpoint falls back to the last step
    that verifies (checkpoint.store.load_latest_checkpoint).

Layout under --service-dir:  ledger.jsonl  +  ckpt/step_<N>/...

Example:
  PYTHONPATH=src python -m repro.launch.service --service-dir /tmp/svc \\
      --arch tiny --steps 40 --batch 8 --seq 32 --budget-eps 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
import zlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (
    load_latest_checkpoint, save_checkpoint)
from repro.core import accounting
from repro.core.quantile import export_state as export_quantile_state
from repro.core.spec import init_params
from repro.data import PoissonSampler, make_lm_batch
from repro.launch.train import build_arg_parser, build_everything, jit_step

# exit code of a deterministically injected fault (distinguishable from a
# real crash in ci.sh); budget exhaustion is a CLEAN exit (0).
EXIT_FAULT = 86

FAULT_POINTS = ("pre-ledger-append", "post-ledger-append",
                "pre-ckpt-rename", "post-step-commit")

_ORDERS_CRC = zlib.crc32(json.dumps(
    list(accounting.DEFAULT_ORDERS)).encode())


class BudgetExhausted(Exception):
    """The next step's projected epsilon exceeds the budget — clean stop."""


class LedgerCorrupt(ValueError):
    """The ledger cannot be trusted (non-trailing corruption, step gaps,
    or a mechanism/orders mismatch) — refuse to train on top of it."""


class SimulatedCrash(RuntimeError):
    """In-process stand-in for `kill -9` (FaultInjector mode='raise')."""


# ---------------------------------------------------------------------------
# Fault injection.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultInjector:
    """Deterministically dies at (point, step).

    mode="exit": `os._exit(EXIT_FAULT)` — no atexit handlers, no buffered
    flushes, the closest userspace gets to `kill -9` (ci.sh uses this via
    --fault-at). mode="raise": raises SimulatedCrash for the in-process
    tier-1 matrix; the service loop does NOT catch it, so on-disk state is
    exactly what the kill would have left.
    """

    point: str | None = None
    step: int = -1
    mode: str = "exit"

    def __post_init__(self):
        if self.point is not None and self.point not in FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r}; one of {FAULT_POINTS}")

    @classmethod
    def parse(cls, spec: str | None, mode: str = "exit") -> "FaultInjector":
        """'POINT:STEP' -> injector (None -> never fires)."""
        if not spec:
            return cls()
        point, _, step = spec.rpartition(":")
        return cls(point=point, step=int(step), mode=mode)

    def fire(self, point: str, step: int) -> None:
        if self.point != point or step != self.step:
            return
        if self.mode == "exit":
            sys.stderr.write(f"# FAULT {point}@{step}\n")
            sys.stderr.flush()
            os._exit(EXIT_FAULT)
        raise SimulatedCrash(f"{point}@{step}")


# ---------------------------------------------------------------------------
# Retry / backoff.
# ---------------------------------------------------------------------------


def with_retries(fn: Callable, *, retries: int = 4, base_delay: float = 0.05,
                 max_delay: float = 2.0, exceptions=(OSError,),
                 sleep: Callable[[float], None] = time.sleep,
                 describe: str = "io"):
    """Run fn(); on a transient failure retry with capped exponential
    backoff (base_delay * 2^attempt, capped at max_delay). The last error
    propagates once `retries` re-attempts are spent."""
    for attempt in range(retries + 1):
        try:
            return fn()
        except exceptions as e:
            if attempt == retries:
                raise
            delay = min(max_delay, base_delay * (2.0 ** attempt))
            sys.stderr.write(
                f"# retry {describe}: attempt {attempt + 1}/{retries} "
                f"failed ({e!r}); backing off {delay:.2f}s\n")
            sleep(delay)


# ---------------------------------------------------------------------------
# The persistent privacy ledger.
# ---------------------------------------------------------------------------


class PrivacyLedger:
    """Append-only checksummed JSONL of per-step privacy spends.

    Line format: ``<compact-json> <crc32-of-json-hex>\\n``. `replay()`
    verifies every line; a torn *trailing* line (the append that a crash
    interrupted) is discarded and truncated away — safe, because the
    ledger-before-commit invariant means the step it described never ran.
    Corruption anywhere else raises LedgerCorrupt: an untrustworthy ledger
    must refuse service, not guess.
    """

    def __init__(self, path: str):
        self.path = path
        self._fh = None

    def replay(self) -> list[dict]:
        if not os.path.exists(self.path):
            return []
        with open(self.path, "rb") as f:
            blob = f.read()
        records, offset = [], 0
        for raw in blob.split(b"\n"):
            if not raw:
                offset += 1  # the newline itself
                continue
            rec = self._parse_line(raw)
            if rec is None:
                if offset + len(raw) >= len(blob):  # torn trailing line
                    with open(self.path, "r+b") as f:
                        f.truncate(offset)
                        f.flush()
                        os.fsync(f.fileno())
                    break
                raise LedgerCorrupt(
                    f"{self.path}: corrupt record at byte {offset} (not the "
                    f"trailing line — the ledger cannot be trusted)")
            records.append(rec)
            offset += len(raw) + 1
        for i, rec in enumerate(records):
            if rec.get("step") != i:
                raise LedgerCorrupt(
                    f"{self.path}: record {i} is for step {rec.get('step')} "
                    f"— ledger steps must be 0..n-1 with no gaps")
            if rec.get("orders_crc") != _ORDERS_CRC:
                raise LedgerCorrupt(
                    f"{self.path}: record {i} was accounted on a different "
                    f"RDP order grid")
        return records

    @staticmethod
    def _parse_line(raw: bytes) -> dict | None:
        payload, sep, crc = raw.rpartition(b" ")
        if not sep:
            return None
        try:
            if int(crc, 16) != zlib.crc32(payload):
                return None
            rec = json.loads(payload)
        except ValueError:
            return None
        return rec if isinstance(rec, dict) else None

    def append(self, record: dict) -> None:
        """Durably append one record: write + flush + fsync before
        returning — the caller only commits the step after this returns."""
        payload = json.dumps(record, sort_keys=True,
                             separators=(",", ":")).encode()
        line = payload + b" " + f"{zlib.crc32(payload):08x}".encode() + b"\n"
        if self._fh is None:
            self._fh = open(self.path, "ab")
        self._fh.write(line)
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


# ---------------------------------------------------------------------------
# The service.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServiceRuntime:
    """Everything deterministic and reusable across service incarnations
    (model, packed corpus, the jitted step). tests share one runtime across
    crash/resume cycles so the in-process fault matrix pays one compile."""

    cfg: object
    model: object
    rows: np.ndarray
    init_fn: Callable
    step: Callable  # jitted
    plan: object
    batch: int
    seed: int

    def make_sampler(self) -> PoissonSampler:
        return PoissonSampler(num_examples=self.rows.shape[0],
                              rate=self.batch / self.rows.shape[0],
                              max_batch=self.batch, seed=1)


def build_runtime(args) -> ServiceRuntime:
    # sigma is calibrated for the --calib-steps horizon (default --steps);
    # running past it is exactly what the budget gate is for
    build_args = argparse.Namespace(**vars(args))
    build_args.steps = getattr(args, "calib_steps", None) or args.steps
    (cfg, model, rows, _sampler, init_fn, step_fn, plan,
     mesh) = build_everything(build_args)
    return ServiceRuntime(cfg=cfg, model=model, rows=rows, init_fn=init_fn,
                          step=jit_step(step_fn, model, mesh), plan=plan,
                          batch=args.batch, seed=args.seed)


class TrainService:
    """One incarnation of the daemon over a --service-dir.

    Construction loads ledger + newest verified checkpoint (or initializes
    fresh state); `run()` trains until `target_steps` are committed or the
    budget is exhausted (raising BudgetExhausted after a final checkpoint).
    """

    def __init__(self, args, *, runtime: ServiceRuntime | None = None,
                 fault: FaultInjector | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.args = args
        self.fault = fault or FaultInjector.parse(
            getattr(args, "fault_at", None))
        self.sleep = sleep
        self.runtime = runtime or build_runtime(args)
        self.target_steps = args.steps
        self.delta = args.delta
        self.budget_eps = getattr(args, "budget_eps", None) or args.epsilon
        if self.budget_eps is None:
            raise ValueError("service needs a budget: --budget-eps (or "
                             "--epsilon for sigma calibration)")
        self.ckpt_every = max(1, getattr(args, "checkpoint_every", 10))

        os.makedirs(args.service_dir, exist_ok=True)
        self.ckpt_dir = os.path.join(args.service_dir, "ckpt")
        # adapter-only publishes for the serving side (launch.swap): only
        # written when the run actually trains adapters (trainable_key ==
        # "lora"), small (just the adapter subtree), and carrying the
        # epsilon spent so serving can display provenance
        self.publish_dir = os.path.join(args.service_dir, "publish")
        self.ledger = PrivacyLedger(
            os.path.join(args.service_dir, "ledger.jsonl"))

        rt = self.runtime
        plan = rt.plan
        self.q = float(plan.config.sampling_rate)
        self.sigma = float(plan.sigma)
        self.sampler = rt.make_sampler()
        self.key = jax.random.PRNGKey(rt.seed + 1)
        self._restore()

    # -- startup: ledger replay + checkpoint restore -----------------------

    def _restore(self) -> None:
        records = with_retries(self.ledger.replay, sleep=self.sleep,
                               describe="ledger replay")
        self.acct = accounting.RdpAccountant()
        for rec in records:
            if (abs(rec["q"] - self.q) > 1e-12
                    or abs(rec["sigma"] - self.sigma) > 1e-9):
                raise LedgerCorrupt(
                    f"ledger step {rec['step']} was spent at "
                    f"(q={rec['q']}, sigma={rec['sigma']}) but this service "
                    f"is configured for (q={self.q}, sigma={self.sigma}) — "
                    f"refusing to mix mechanisms in one ledger")
            self.acct.spend(rec["q"], rec["sigma"])
        self.ledgered_steps = len(records)

        rt = self.runtime
        params0 = init_params(rt.model.spec, jax.random.PRNGKey(rt.seed))
        opt0, dp0 = rt.init_fn(params0)
        template = {"params": params0, "opt_state": opt0, "dp_state": dp0}
        found = with_retries(
            lambda: load_latest_checkpoint(self.ckpt_dir, template),
            sleep=self.sleep, describe="checkpoint load")
        if found is None:
            self.committed = 0
            self.params, self.opt_state, self.dp_state = params0, opt0, dp0
        else:
            self.committed, tree, manifest = found
            self.params = tree["params"]
            self.opt_state = tree["opt_state"]
            self.dp_state = tree["dp_state"]
            meta = manifest.get("meta") or {}
            if "sampler" in meta:
                self.sampler.restore(meta["sampler"])
            if self.sampler.draws != self.committed:
                raise LedgerCorrupt(
                    f"checkpoint step {self.committed} carries a sampler at "
                    f"draw {self.sampler.draws} — sample stream and commit "
                    f"log disagree")
        # the privacy invariant: every committed step MUST be ledgered
        # (the converse — ledgered but uncommitted — is the safe crash gap
        # that deterministic re-execution closes)
        if self.ledgered_steps < self.committed:
            raise LedgerCorrupt(
                f"ledger covers {self.ledgered_steps} steps but "
                f"{self.committed} steps are committed — the ledger "
                f"under-counts; refusing to continue")

    # -- the step loop -----------------------------------------------------

    def epsilon(self) -> float:
        return self.acct.epsilon(self.delta)

    def _fetch_batch(self) -> dict:
        def fetch():
            idx = self.sampler.next_indices()
            return make_lm_batch(self.runtime.rows, idx, self.runtime.batch)
        return with_retries(fetch, sleep=self.sleep, describe="batch fetch")

    def _admit(self, step: int) -> None:
        """The budget gate + the ledger-before-commit append for `step`."""
        if step < self.ledgered_steps:
            # Re-executing a step that was ledgered but not committed when
            # the previous incarnation died. The resume is bitwise
            # deterministic (same params, same sampler stream, same
            # fold_in(key, step) noise), so this re-release is the SAME
            # mechanism output the ledger already paid for — spending it
            # again would double-count.
            return
        projected = self.acct.peek(self.q, self.sigma, self.delta)
        if projected > self.budget_eps + 1e-9:
            raise BudgetExhausted(
                f"step {step} projects epsilon {projected:.4f} > budget "
                f"{self.budget_eps} (delta={self.delta}); spent so far: "
                f"{self.epsilon():.4f} over {self.acct.steps} steps")
        self.fault.fire("pre-ledger-append", step)
        record = {"step": step, "q": self.q, "sigma": self.sigma,
                  "orders_crc": _ORDERS_CRC}
        with_retries(lambda: self.ledger.append(record), sleep=self.sleep,
                     describe="ledger append")
        self.acct.spend(self.q, self.sigma)
        self.ledgered_steps += 1
        self.fault.fire("post-ledger-append", step)

    def _checkpoint(self) -> str:
        step = self.committed
        meta = {
            "sampler": self.sampler.state(),
            "ledger_records": self.ledgered_steps,
            "epsilon": self.epsilon(),
            "quantile": export_quantile_state(self.dp_state.qstate),
            "mechanism": {"q": self.q, "sigma": self.sigma,
                          "delta": self.delta},
        }
        tree = {"params": self.params, "opt_state": self.opt_state,
                "dp_state": self.dp_state}

        def hook(stage):  # the mid-publish kill of the fault matrix
            if stage == "pre-rename":
                self.fault.fire("pre-ckpt-rename", step)

        path = with_retries(
            lambda: save_checkpoint(self.ckpt_dir, step, tree, meta=meta,
                                    fault_hook=hook),
            sleep=self.sleep, describe="checkpoint save")
        self._publish_adapter(step)
        return path

    def _publish_adapter(self, step: int) -> None:
        """Adapter-only publish for live serving (`launch.swap` watches
        `<service_dir>/publish`). Published AFTER the full checkpoint so
        a publish never refers to training state that could be lost; the
        tree is ``{"lora": ...}`` to match the watcher's template."""
        if (getattr(self.runtime.model, "trainable_key", None) != "lora"
                or "lora" not in self.params):
            return
        meta = {"epsilon": self.epsilon(), "delta": self.delta,
                "source_step": step}
        with_retries(
            lambda: save_checkpoint(self.publish_dir, step,
                                    {"lora": self.params["lora"]},
                                    meta=meta),
            sleep=self.sleep, describe="adapter publish")

    def run(self) -> dict:
        """Train until target_steps are committed or the budget runs out.

        Returns a status dict; raises BudgetExhausted (after writing a
        final checkpoint) when the gate refuses the next step — callers
        treat that as a CLEAN shutdown. SimulatedCrash/os._exit from the
        fault injector propagate uncaught, by design.
        """
        log_every = max(1, getattr(self.args, "log_every", 10))
        while self.committed < self.target_steps:
            step = self.committed
            try:
                self._admit(step)
            except BudgetExhausted:
                self._checkpoint()  # make the refusal cheap to resume from
                self.ledger.close()
                raise
            batch = self._fetch_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, self.dp_state, met = \
                self.runtime.step(self.params, self.opt_state, self.dp_state,
                                  batch, self.key)
            loss = float(met.loss)  # blocks: the update is now materialized
            self.committed += 1
            self.fault.fire("post-step-commit", step)
            if step % log_every == 0 or self.committed == self.target_steps:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"eps {self.epsilon():.4f}/{self.budget_eps} "
                      f"thr {float(met.mean_threshold):.4f}", flush=True)
            if (self.committed % self.ckpt_every == 0
                    or self.committed == self.target_steps):
                self._checkpoint()
        self.ledger.close()
        return {"status": "complete", "committed": self.committed,
                "epsilon": self.epsilon(), "budget_eps": self.budget_eps}


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------


def build_service_parser() -> argparse.ArgumentParser:
    ap = build_arg_parser(
        description="crash-safe online DP training service")
    ap.add_argument("--service-dir", required=True,
                    help="durable state root: ledger.jsonl + ckpt/")
    ap.add_argument("--budget-eps", type=float, default=None,
                    help="hard epsilon budget enforced by the admission "
                         "gate (default: --epsilon)")
    ap.add_argument("--calib-steps", type=int, default=None,
                    help="horizon used to calibrate sigma from --epsilon "
                         "(default: --steps); set it below --steps to "
                         "drive the run into budget exhaustion")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--fault-at", default=None, metavar="POINT:STEP",
                    help=f"die (os._exit {EXIT_FAULT}) at an injection "
                         f"point; POINT one of {', '.join(FAULT_POINTS)}")
    return ap


def main(argv=None) -> int:
    args = build_service_parser().parse_args(argv)
    # warm start: reuse persisted compiled step programs + the measured
    # autotune table (the daemon restarts on every crash/resume cycle, so
    # cold retrace+compile would otherwise be paid per incarnation)
    from repro.launch.train import record_cache_program, setup_caches
    setup_caches(args)
    svc = TrainService(args)
    record_cache_program(args, entry="service", arch=svc.runtime.cfg.name)
    print(f"# service dir={args.service_dir} arch={svc.runtime.cfg.name} "
          f"mode={svc.runtime.plan.config.mode} q={svc.q:.5f} "
          f"sigma={svc.sigma:.4f} budget_eps={svc.budget_eps} "
          f"resume_at={svc.committed} ledgered={svc.ledgered_steps} "
          f"eps_spent={svc.epsilon():.4f}", flush=True)
    try:
        status = svc.run()
    except BudgetExhausted as e:
        print(f"# service: status=budget_exhausted step={svc.committed} "
              f"epsilon={svc.epsilon():.4f} budget={svc.budget_eps}")
        print(f"# {e}")
        return 0
    print(f"# service: status={status['status']} step={status['committed']} "
          f"epsilon={status['epsilon']:.4f} budget={status['budget_eps']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
