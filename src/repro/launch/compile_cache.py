"""Persistent compile/startup cache for the jitted train/serve programs.

PR 3 measured ~19s of retrace+compile for ONE production dryrun — and every
train/serve/service worker re-pays that cold at startup. This module wires
jax's persistent compilation cache to a repo-local directory so compiled
executables survive the process: the second (and every later) startup
deserializes instead of recompiling. Fleet economics: bake the populated
cache directory into the worker image and thousands of workers skip both
autotuning (repro.kernels.autotune) and compilation.

What jax's cache keys on already subsumes our semantic key — the post-
optimization HLO module, compile options, jax/jaxlib version, and the
accelerator config all hash into the entry name — so a change to the model
config, mesh, ghost backend, BK execution, clipping mode, or jax version
produces a different module hash and therefore a CLEAN MISS (recompile),
never a stale hit. On top of that this module adds:

  * an integrity sweep with the crc32 discipline from the PR 6 checkpoint
    store: ``manifest.json`` records a checksum per cache entry; at
    `enable()` time corrupt/truncated entries are silently deleted (jax
    would only warn-and-recompile, but a torn file would otherwise warn on
    EVERY startup forever) and new entries from previous runs are adopted.
    A jax-version change wipes the dead entries wholesale. The manifest
    itself is checksummed and rebuilt from the files if torn.
  * a ``programs.json`` index mapping our SEMANTIC key — (entry point,
    model config, mesh, backend, execution, clipping mode, jax version) —
    to run counts, so an operator can see which programs a cache warm-up
    actually covered (`warmed_programs()`).

Entry points call `enable()` under their ``--cache`` knob (train, serve,
service, dryrun) and `record_program()` after building their step; the
cache directory defaults to ``<repo>/.cache/compile`` (``REPRO_CACHE_DIR``
or ``--cache-dir`` override). Everything here is best-effort: cache
trouble degrades to cold compiles, never to a crashed worker.
"""
from __future__ import annotations

import json
import os
import warnings
import zlib

import jax

MANIFEST_VERSION = 1
_MANIFEST = "manifest.json"
_PROGRAMS = "programs.json"

_ENABLED_DIR: str | None = None


def cache_root(override: str | None = None) -> str:
    from repro.kernels.autotune import repo_cache_root
    return repo_cache_root(override)


def compile_dir(root: str | None = None) -> str:
    return os.path.join(cache_root(root), "compile")


def program_key(**parts) -> str:
    """Stable id for one compiled program's semantic coordinates."""
    blob = json.dumps({k: str(v) for k, v in sorted(parts.items())},
                      sort_keys=True)
    return f"{zlib.crc32(blob.encode()):08x}"


# ---------------------------------------------------------------------------
# Integrity sweep (crc32 manifest over the serialized executables).
# ---------------------------------------------------------------------------


def _file_crc(path: str) -> int:
    crc = 0
    with open(path, "rb") as fh:
        for chunk in iter(lambda: fh.read(1 << 20), b""):
            crc = zlib.crc32(chunk, crc)
    return crc


def _entry_decodes(path: str) -> bool:
    """Can jax's cache layer decode this entry's compressed payload?

    jax writes cache entries with a plain (NON-atomic) write_bytes, so a
    process killed mid-write — exactly what the service's fault injection
    does — leaves a truncated compressed stream on disk. XLA's C++
    executable deserializer can ABORT the whole process on such bytes
    (heap corruption, not a catchable error), so a torn entry must never
    be adopted into the manifest. The compression checksum (zstd frame /
    zlib adler32) reliably rejects any truncation."""
    try:
        with open(path, "rb") as fh:
            raw = fh.read()
    except OSError:
        return False
    try:
        from jax._src import compilation_cache as jcc
        jcc.extract_executable_and_time(jcc.decompress_executable(raw))
        return True
    except ImportError:  # internals moved: cannot validate, keep the entry
        return True
    except Exception:  # noqa: BLE001 - torn/garbage payload
        return False


def _atomic_json(path: str, doc: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh, indent=1)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def _load_manifest(dirpath: str) -> dict | None:
    """The entries dict, or None if the manifest is missing/torn/stale
    (caller rebuilds from the files)."""
    path = os.path.join(dirpath, _MANIFEST)
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError):
        return None
    if not isinstance(doc, dict) or doc.get("version") != MANIFEST_VERSION:
        return None
    payload = {"version": doc.get("version"),
               "jax_version": doc.get("jax_version"),
               "entries": doc.get("entries")}
    blob = json.dumps(payload, sort_keys=True)
    if zlib.crc32(blob.encode()) != doc.get("crc32"):
        return None
    if doc.get("jax_version") != jax.__version__:
        # serialized executables from another jax are dead weight: report
        # stale so the sweep wipes them (jax's key gives the clean miss
        # anyway; this keeps the directory from growing forever)
        return {"__stale_jax__": True}
    if not isinstance(doc.get("entries"), dict):
        return None
    return doc["entries"]


def _save_manifest(dirpath: str, entries: dict) -> None:
    payload = {"version": MANIFEST_VERSION, "jax_version": jax.__version__,
               "entries": entries}
    blob = json.dumps(payload, sort_keys=True)
    _atomic_json(os.path.join(dirpath, _MANIFEST),
                 {"crc32": zlib.crc32(blob.encode()), **payload})


def sweep(dirpath: str) -> dict:
    """Verify every cache entry against the manifest; delete corrupt or
    truncated files (they rebuild warm on next use), adopt entries written
    by previous processes, drop records for files that vanished. Returns
    {kept, adopted, dropped_corrupt, dropped_missing, wiped_stale_jax}."""
    os.makedirs(dirpath, exist_ok=True)
    manifest = _load_manifest(dirpath)
    stats = {"kept": 0, "adopted": 0, "dropped_corrupt": 0,
             "dropped_missing": 0, "wiped_stale_jax": 0}
    if manifest is not None and manifest.get("__stale_jax__"):
        # another jax wrote these executables: clean miss by construction,
        # so reclaim the space rather than verifying dead entries
        for name in os.listdir(dirpath):
            if name.endswith("-cache") or name.endswith("-atime"):
                try:
                    os.unlink(os.path.join(dirpath, name))
                    stats["wiped_stale_jax"] += 1
                except OSError:
                    pass
        manifest = {}
    if manifest is None:
        manifest = {}  # torn/missing manifest: rebuild by adoption below
    entries = {}
    corrupt: set[str] = set()
    for name in sorted(os.listdir(dirpath)):
        if not name.endswith("-cache"):
            continue
        path = os.path.join(dirpath, name)
        try:
            crc = _file_crc(path)
        except OSError:
            stats["dropped_missing"] += 1
            continue
        known = manifest.get(name)
        if known is None:
            # adoption is the integrity gate: entries already in the
            # manifest passed it once (crc covers bit rot thereafter)
            if _entry_decodes(path):
                entries[name] = crc
                stats["adopted"] += 1
            else:
                try:
                    os.unlink(path)
                except OSError:
                    pass
                atime = path[:-len("-cache")] + "-atime"
                if os.path.exists(atime):
                    try:
                        os.unlink(atime)
                    except OSError:
                        pass
                stats["dropped_corrupt"] += 1
                corrupt.add(name)
        elif known == crc:
            entries[name] = crc
            stats["kept"] += 1
        else:
            # bit rot / torn write: delete so jax recompiles warm instead
            # of warning about the undecodable entry on every startup
            try:
                os.unlink(path)
            except OSError:
                pass
            atime = path[:-len("-cache")] + "-atime"
            if os.path.exists(atime):
                try:
                    os.unlink(atime)
                except OSError:
                    pass
            stats["dropped_corrupt"] += 1
            corrupt.add(name)
    stats["dropped_missing"] += sum(1 for n in manifest
                                    if n.endswith("-cache")
                                    and n not in entries
                                    and n not in corrupt)
    _save_manifest(dirpath, entries)
    return stats


# ---------------------------------------------------------------------------
# Enable / disable.
# ---------------------------------------------------------------------------


def enable(root: str | None = None, *, min_compile_secs: float = 0.0,
           quiet: bool = True) -> str | None:
    """Sweep + point jax's persistent compilation cache at the repo-local
    dir. Idempotent; best-effort (returns None and leaves compilation
    uncached on any failure — a worker never dies over cache trouble)."""
    global _ENABLED_DIR
    try:
        dirpath = compile_dir(root)
        sweep(dirpath)
        jax.config.update("jax_compilation_cache_dir", dirpath)
        # jax memoizes "is the cache used" at the FIRST compilation of the
        # process; a long-lived process (tests, notebooks) that compiled
        # anything before enable() has latched False — reset to pristine so
        # the new directory takes effect
        _reset_jax_cache_state()
        # default thresholds skip sub-second / small programs — the exact
        # programs a CPU test fleet compiles; cache everything
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          float(min_compile_secs))
        try:
            jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
        except AttributeError:  # older jax: size threshold didn't exist
            pass
        _ENABLED_DIR = dirpath
        return dirpath
    except Exception as e:  # noqa: BLE001 - degrade to cold compiles
        if not quiet:
            warnings.warn(f"compile cache disabled: {type(e).__name__}: {e}")
        _ENABLED_DIR = None
        return None


def disable() -> None:
    """Stop caching new compilations (tests; already-compiled programs are
    unaffected)."""
    global _ENABLED_DIR
    jax.config.update("jax_compilation_cache_dir", None)
    _reset_jax_cache_state()
    _ENABLED_DIR = None


def _reset_jax_cache_state() -> None:
    try:
        from jax.experimental.compilation_cache import (
            compilation_cache as jcc)
        jcc.reset_cache()
    except Exception:  # noqa: BLE001 - older jax: no latch to reset
        pass


def enabled_dir() -> str | None:
    return _ENABLED_DIR


# ---------------------------------------------------------------------------
# Semantic program index.
# ---------------------------------------------------------------------------


def record_program(parts: dict, *, root: str | None = None) -> str | None:
    """Note that a program with these semantic coordinates compiled (or
    re-dispatched) under the cache; returns its key. Best-effort."""
    try:
        dirpath = _ENABLED_DIR or compile_dir(root)
        os.makedirs(dirpath, exist_ok=True)
        key = program_key(**parts)
        path = os.path.join(dirpath, _PROGRAMS)
        try:
            with open(path) as fh:
                doc = json.load(fh)
            if not isinstance(doc, dict):
                doc = {}
        except (OSError, ValueError):
            doc = {}
        row = doc.get(key) or {"parts": {k: str(v) for k, v in
                                         sorted(parts.items())}, "runs": 0}
        row["runs"] = int(row.get("runs", 0)) + 1
        doc[key] = row
        _atomic_json(path, doc)
        return key
    except Exception:  # noqa: BLE001
        return None


def warmed_programs(root: str | None = None) -> dict:
    """The semantic index: which (entry, config, mesh, backend, ...)
    programs this cache has seen, and how often."""
    try:
        with open(os.path.join(compile_dir(root), _PROGRAMS)) as fh:
            doc = json.load(fh)
        return doc if isinstance(doc, dict) else {}
    except (OSError, ValueError):
        return {}
