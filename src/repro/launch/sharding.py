"""Sharding rules: spec paths -> PartitionSpec (Megatron-style TP + DP).

Rules are name-based over the parameter spec tree (the same canonical paths
that name clipping groups), so every architecture gets coherent tensor
parallelism from one table:

  column-parallel (output dim -> model): qkv / gate_up / in_proj / rwkv
      r,k,v,g / lora b / mla q_b,kv_b / cross kv / head
  row-parallel   (input dim -> model): o / down / out_proj / rwkv o / cm v
  expert-parallel: moe w_gu / w_down shard the EXPERT dim over model
  replicated: norms, small vectors, routers, embed-adjacent gains

Non-divisible dims fall back to replication (uneven GSPMD sharding is legal
but wasteful for weights; we prefer predictable layouts — recorded per arch
in EXPERIMENTS.md)."""
from __future__ import annotations

import fnmatch
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core.spec import P, SpecTree
from repro.launch.mesh import data_axes

# pattern -> (axis_from_end, kind); kind: 'dim' shard that axis on model,
# 'replicate'
_RULES: list[tuple[str, Any]] = [
    ("embed/w", ("dim", 0)),            # vocab -> model
    ("head/w", ("dim", -1)),            # vocab -> model
    ("*moe/w_gu", ("expert", -1)),    # fallback: column-parallel in-expert
    ("*moe/w_down", ("expert", -2)),  # fallback: row-parallel in-expert
    ("*moe/router/w", ("replicate", None)),
    ("*moe/shared/gate_up/w", ("dim", -1)),
    ("*moe/shared/down/w", ("dim", -2)),
    ("*attn/qkv/w", ("dim", -1)),
    ("*attn/qkv/b", ("dim", -1)),
    ("*attn/kv/w", ("dim", -1)),
    ("*attn/kv/b", ("dim", -1)),
    ("*attn/o/w", ("dim", -2)),
    ("*attn/q/w", ("dim", -1)),
    ("*attn/q_a/w", ("replicate", None)),
    ("*attn/q_b/w", ("dim", -1)),
    ("*attn/kv_a/w", ("replicate", None)),
    ("*attn/kv_b/w", ("dim", -1)),
    ("*cross/qkv/w", ("dim", -1)),
    ("*cross/kv/w", ("dim", -1)),
    ("*cross/o/w", ("dim", -2)),
    ("*mlp/gate_up/w", ("dim", -1)),
    ("*mlp/down/w", ("dim", -2)),
    ("*in_proj/w", ("dim", -1)),
    ("*out_proj/w", ("dim", -2)),
    ("*tm/r/w", ("dim", -1)),
    ("*tm/k/w", ("dim", -1)),
    ("*tm/v/w", ("dim", -1)),
    ("*tm/g/w", ("dim", -1)),
    ("*tm/o/w", ("dim", -2)),
    ("*cm/k/w", ("dim", -1)),
    ("*cm/v/w", ("dim", -2)),
    ("*cm/r/w", ("dim", -1)),
    ("lora/*/b", ("dim", -1)),          # adapter B column-parallel
    ("lora/*/a", ("replicate", None)),
    ("mtp/proj/w", ("dim", -1)),
]


def _spec_for(path: str, p: P, model_size: int) -> PS:
    ndim = len(p.shape)
    for pattern, (kind, axis) in _RULES:
        if fnmatch.fnmatch(path, pattern):
            if kind == "replicate":
                return PS()
            if kind == "expert":
                # shape (..., E, d, f): expert dim is -3; when E doesn't
                # divide the model axis (e.g. granite's 40 experts on 16
                # shards) fall back to intra-expert tensor parallelism so
                # expert compute never replicates.
                e_axis = ndim - 3
                if p.shape[e_axis] % model_size == 0:
                    out = [None] * ndim
                    out[e_axis] = "model"
                    return PS(*out)
                ax = axis % ndim
                if p.shape[ax] % model_size == 0:
                    out = [None] * ndim
                    out[ax] = "model"
                    return PS(*out)
                return PS()
            ax = axis % ndim
            if p.shape[ax] % model_size == 0:
                out = [None] * ndim
                out[ax] = "model"
                return PS(*out)
            return PS()
    return PS()  # default: replicate (norm scales, small vectors)


def params_shardings(spec: SpecTree, mesh, *, serving: bool = False) -> Any:
    """Pytree of NamedSharding parallel to the params.

    serving=True additionally shards the largest unsharded dim of every
    sizable weight over the DATA plane (weight-FSDP for inference): a
    training step needs params replicated across data for the gradient
    psum, but a serve step has no gradients and a 671B MoE simply does not
    fit 16 GB/chip at model-axis-only sharding (84 GB/device measured);
    fully-sharded weights are all-gathered per layer by XLA instead."""
    model_size = mesh.shape["model"]
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def walk(node, prefix):
        if isinstance(node, P):
            ps = _spec_for("/".join(prefix), node, model_size)
            if serving and int(np.prod(node.shape)) >= (1 << 20):
                axes = list(ps) + [None] * (len(node.shape) - len(ps))
                # largest still-unsharded dim -> data plane
                cands = [(node.shape[i], i) for i in range(len(axes))
                         if axes[i] is None and node.shape[i] % dp_size == 0]
                if cands:
                    _, i = max(cands)
                    axes[i] = dp
                    ps = PS(*axes)
            return NamedSharding(mesh, ps)
        return {k: walk(v, prefix + (k,)) for k, v in node.items()}

    return walk(spec, ())


def batch_shardings(batch_abstract: Any, mesh) -> Any:
    """Batch leaves shard dim 0 over the data(+pod) plane."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def one(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % dp_size == 0:
            return NamedSharding(mesh, PS(dp))
        return NamedSharding(mesh, PS())

    return jax.tree_util.tree_map(one, batch_abstract)


def cache_shardings(cache_abstract: Any, mesh) -> Any:
    """Decode caches: (L, B, S, heads, hd)-style leaves.

    dim 1 (batch) -> data plane when divisible; otherwise the SEQUENCE dim
    (2) shards over data (long-context, batch=1). Head/expert dims shard
    over model when divisible."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    model_size = mesh.shape["model"]

    def one(leaf):
        nd = leaf.ndim
        if nd <= 1:
            return NamedSharding(mesh, PS())
        ax = [None] * nd
        if leaf.shape[1] % dp_size == 0:
            ax[1] = dp
        elif nd >= 3 and leaf.shape[2] % dp_size == 0:
            ax[2] = dp
        # try a model axis on one of the trailing dims (prefer heads)
        for cand in range(nd - 2, 1, -1):
            if ax[cand] is None and leaf.shape[cand] % model_size == 0 \
                    and leaf.shape[cand] >= model_size:
                ax[cand] = "model"
                break
        return NamedSharding(mesh, PS(*ax))

    return jax.tree_util.tree_map(one, cache_abstract)


def replicated(tree: Any, mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, PS()), tree)


def opt_state_shardings(opt_state_abstract: Any, pshard: Any, mesh) -> Any:
    """Optimizer state: moment leaves shard like their parameter; scalars
    replicate. Matches by shape against the param shardings tree."""
    pshard_leaves = {}
    for path, s in jax.tree_util.tree_flatten_with_path(pshard)[0]:
        pshard_leaves.setdefault(None, []).append(s)

    # mu/nu have the same treedef as params: map by structure when possible
    params_treedef = jax.tree_util.tree_structure(pshard)

    def assign(node):
        if jax.tree_util.tree_structure(node) == params_treedef:
            return pshard
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, PS()), node)

    # opt states are NamedTuples whose fields are either scalars or
    # param-shaped pytrees
    if hasattr(opt_state_abstract, "_fields"):
        return type(opt_state_abstract)(*[
            assign(getattr(opt_state_abstract, f))
            for f in opt_state_abstract._fields])
    return assign(opt_state_abstract)
