"""Sharding rules: spec paths -> PartitionSpec (Megatron-style TP + DP).

Rules are name-based over the parameter spec tree (the same canonical paths
that name clipping groups), so every architecture gets coherent tensor
parallelism from one table:

  column-parallel (output dim -> model): qkv / gate_up / in_proj / rwkv
      r,k,v,g / lora b / mla q_b,kv_b / cross kv / head
  row-parallel   (input dim -> model): o / down / out_proj / rwkv o / cm v
  expert-parallel: moe w_gu / w_down shard the EXPERT dim over model
  replicated: norms, small vectors, routers, embed-adjacent gains

Non-divisible dims fall back to replication (uneven GSPMD sharding is legal
but wasteful for weights; we prefer predictable layouts — recorded per arch
in EXPERIMENTS.md)."""
from __future__ import annotations

import fnmatch
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core.spec import P, SpecTree, _walk
from repro.launch.mesh import data_axes

# pattern -> (axis_from_end, kind); kind: 'dim' shard that axis on model,
# 'replicate'
_RULES: list[tuple[str, Any]] = [
    ("embed/w", ("dim", 0)),            # vocab -> model
    ("head/w", ("dim", -1)),            # vocab -> model
    ("*moe/w_gu", ("expert", -1)),    # fallback: column-parallel in-expert
    ("*moe/w_down", ("expert", -2)),  # fallback: row-parallel in-expert
    ("*moe/router/w", ("replicate", None)),
    ("*moe/shared/gate_up/w", ("dim", -1)),
    ("*moe/shared/down/w", ("dim", -2)),
    ("*attn/qkv/w", ("dim", -1)),
    ("*attn/qkv/b", ("dim", -1)),
    ("*attn/kv/w", ("dim", -1)),
    ("*attn/kv/b", ("dim", -1)),
    ("*attn/o/w", ("dim", -2)),
    ("*attn/q/w", ("dim", -1)),
    ("*attn/q_a/w", ("replicate", None)),
    ("*attn/q_b/w", ("dim", -1)),
    ("*attn/kv_a/w", ("replicate", None)),
    ("*attn/kv_b/w", ("dim", -1)),
    ("*cross/qkv/w", ("dim", -1)),
    ("*cross/kv/w", ("dim", -1)),
    ("*cross/o/w", ("dim", -2)),
    ("*mlp/gate_up/w", ("dim", -1)),
    ("*mlp/down/w", ("dim", -2)),
    ("*in_proj/w", ("dim", -1)),
    ("*out_proj/w", ("dim", -2)),
    ("*tm/r/w", ("dim", -1)),
    ("*tm/k/w", ("dim", -1)),
    ("*tm/v/w", ("dim", -1)),
    ("*tm/g/w", ("dim", -1)),
    ("*tm/o/w", ("dim", -2)),
    ("*cm/k/w", ("dim", -1)),
    ("*cm/v/w", ("dim", -2)),
    ("*cm/r/w", ("dim", -1)),
    ("lora/*/b", ("dim", -1)),          # adapter B column-parallel
    ("lora/*/a", ("replicate", None)),
    ("mtp/proj/w", ("dim", -1)),
]


def _spec_for(path: str, p: P, model_size: int) -> PS:
    ndim = len(p.shape)
    for pattern, (kind, axis) in _RULES:
        if fnmatch.fnmatch(path, pattern):
            if kind == "replicate":
                return PS()
            if kind == "expert":
                # shape (..., E, d, f): expert dim is -3; when E doesn't
                # divide the model axis (e.g. granite's 40 experts on 16
                # shards) fall back to intra-expert tensor parallelism so
                # expert compute never replicates.
                e_axis = ndim - 3
                if p.shape[e_axis] % model_size == 0:
                    out = [None] * ndim
                    out[e_axis] = "model"
                    return PS(*out)
                ax = axis % ndim
                if p.shape[ax] % model_size == 0:
                    out = [None] * ndim
                    out[ax] = "model"
                    return PS(*out)
                return PS()
            ax = axis % ndim
            if p.shape[ax] % model_size == 0:
                out = [None] * ndim
                out[ax] = "model"
                return PS(*out)
            return PS()
    return PS()  # default: replicate (norm scales, small vectors)


def group_shard_assignment(layout, model_size: int) -> tuple[int, ...]:
    """Map every flat clipping group to its owning model-axis shard.

    This is what makes `per_group` clipping mean PER-DEVICE clipping (paper
    Sec 4): supergroup s = "everything shard s owns", so each shard's norm
    reductions and clip factors close over shard-local groups only. Shared
    by `launch/train.py`, `launch/dryrun.py` and `benchmarks/bench_sharded`
    so the CLI, the lowering sweep and the executing sharded engine all
    agree on the partition. Ownership is derived from the SAME rule table
    that places the parameters (`_RULES`):

      * blocked groups (`P.blocks == model_size`) whose weight is
        column-parallel: block j lives on shard j — exact Megatron
        ownership, norm stays on the shard that holds the columns;
      * stacked groups (scanned layer runs): layer l -> shard
        l * model_size // L — contiguous pipeline-stage ownership (the
        paper's GPT-3 recipe partitions by pipeline stage);
      * singleton groups (embed / head / final norm / replicated scales):
        deterministic round-robin in sorted-name order, balancing the
        bookkeeping across shards.

    Returns a tuple of ints in [0, model_size) of length
    `layout.num_groups`, directly usable as `DPConfig.group_assignment`
    (with `num_supergroups=model_size`: a shard may own nothing).
    """
    spec = layout._spec
    leaves_by_group: dict[str, list] = {}
    for path, p in _walk(spec):
        leaves_by_group.setdefault(layout._leaf_group[path], []).append(
            (path, p))
    assign = np.zeros(layout.num_groups, dtype=np.int64)
    rr = 0  # round-robin counter for singleton groups
    for g in layout.groups:
        members = leaves_by_group.get(g.name, [])
        # primary leaf: the largest member (the weight, not the bias)
        path, p = max(members, key=lambda kv: int(
            np.prod(kv[1].shape, dtype=np.int64)))
        ps = _spec_for("/".join(path), p, model_size)
        axes = list(ps) + [None] * (len(p.shape) - len(ps))
        col_parallel = bool(axes) and axes[-1] == "model"
        ids = np.arange(g.count, dtype=np.int64)
        if g.count == 1:
            assign[g.offset] = rr % model_size
            rr += 1
            continue
        if p.blocks == model_size and p.blocks > 1 and col_parallel:
            # stack_shape ends in the block dim: element (.., j) -> shard j
            owners = ids % model_size
        else:
            first = g.stack_shape[0]
            owners = (ids // max(g.count // first, 1)) * model_size // first
        assign[g.offset: g.offset + g.count] = owners % model_size
    return tuple(int(a) for a in assign)


def params_shardings(spec: SpecTree, mesh, *, serving: bool = False) -> Any:
    """Pytree of NamedSharding parallel to the params.

    serving=True additionally shards the largest unsharded dim of every
    sizable weight over the DATA plane (weight-FSDP for inference): a
    training step needs params replicated across data for the gradient
    psum, but a serve step has no gradients and a 671B MoE simply does not
    fit 16 GB/chip at model-axis-only sharding (84 GB/device measured);
    fully-sharded weights are all-gathered per layer by XLA instead."""
    model_size = mesh.shape["model"]
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def walk(node, prefix):
        if isinstance(node, P):
            ps = _spec_for("/".join(prefix), node, model_size)
            if serving and int(np.prod(node.shape)) >= (1 << 20):
                axes = list(ps) + [None] * (len(node.shape) - len(ps))
                # largest still-unsharded dim -> data plane
                cands = [(node.shape[i], i) for i in range(len(axes))
                         if axes[i] is None and node.shape[i] % dp_size == 0]
                if cands:
                    _, i = max(cands)
                    axes[i] = dp
                    ps = PS(*axes)
            return NamedSharding(mesh, ps)
        return {k: walk(v, prefix + (k,)) for k, v in node.items()}

    return walk(spec, ())


def batch_shardings(batch_abstract: Any, mesh) -> Any:
    """Batch leaves shard dim 0 over the data(+pod) plane."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def one(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % dp_size == 0:
            return NamedSharding(mesh, PS(dp))
        return NamedSharding(mesh, PS())

    return jax.tree_util.tree_map(one, batch_abstract)


def cache_shardings(cache_abstract: Any, mesh) -> Any:
    """Decode caches: (L, B, S, heads, hd)-style leaves.

    dim 1 (batch) -> data plane when divisible; otherwise the SEQUENCE dim
    (2) shards over data (long-context, batch=1). Head/expert dims shard
    over model when divisible."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    model_size = mesh.shape["model"]

    def one(leaf):
        nd = leaf.ndim
        if nd <= 1:
            return NamedSharding(mesh, PS())
        ax = [None] * nd
        if leaf.shape[1] % dp_size == 0:
            ax[1] = dp
        elif nd >= 3 and leaf.shape[2] % dp_size == 0:
            ax[2] = dp
        # try a model axis on one of the trailing dims (prefer heads)
        for cand in range(nd - 2, 1, -1):
            if ax[cand] is None and leaf.shape[cand] % model_size == 0 \
                    and leaf.shape[cand] >= model_size:
                ax[cand] = "model"
                break
        return NamedSharding(mesh, PS(*ax))

    return jax.tree_util.tree_map(one, cache_abstract)


def replicated(tree: Any, mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, PS()), tree)


def opt_state_shardings(opt_state_abstract: Any, pshard: Any, mesh) -> Any:
    """Optimizer state: moment leaves shard like their parameter; scalars
    replicate. Matches by shape against the param shardings tree."""
    pshard_leaves = {}
    for path, s in jax.tree_util.tree_flatten_with_path(pshard)[0]:
        pshard_leaves.setdefault(None, []).append(s)

    # mu/nu have the same treedef as params: map by structure when possible
    params_treedef = jax.tree_util.tree_structure(pshard)

    def assign(node):
        if jax.tree_util.tree_structure(node) == params_treedef:
            return pshard
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, PS()), node)

    # opt states are NamedTuples whose fields are either scalars or
    # param-shaped pytrees
    if hasattr(opt_state_abstract, "_fields"):
        return type(opt_state_abstract)(*[
            assign(getattr(opt_state_abstract, f))
            for f in opt_state_abstract._fields])
    return assign(opt_state_abstract)
