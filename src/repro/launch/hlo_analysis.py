"""Backward-compat shim: this module moved to `repro.analysis.hlo`.

The trip-count-aware HLO cost analysis grew a rules engine and a jaxpr
taint pass (repro.analysis), so the parser now lives with them. Existing
imports (dryrun, sharded_checks, benchmarks, tests) keep working through
this re-export; new code should import `repro.analysis.hlo` directly.
"""
from repro.analysis.hlo import *  # noqa: F401,F403
from repro.analysis.hlo import (_axes_of_groups, _parse_instr_line,  # noqa: F401
                                _parse_replica_groups, _reachable,
                                HloAnalyzer, Totals, analyze_hlo,
                                backward_passes, classify_collectives,
                                collective_axis_summary,
                                collective_breakdown, entry_aliases,
                                entry_param_count, dynamic_shape_instrs,
                                filter_model_norm_rows, mesh_device_coords,
                                model_axis_norm_collectives, parse_module,
                                summarize_axis_rows)
