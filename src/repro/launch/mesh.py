"""Production meshes.

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes forming the data-parallel plane."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def make_debug_mesh(data: int = 2, model: int = 2, *, pod: int = 0):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def named_shard_map(f, mesh, *, in_specs, out_specs):
    """`shard_map` across jax versions (manual SPMD, no replication check).

    The sharded DP train step relies on values that ARE replicated but that
    the checker cannot prove so (masked per-shard contributions joined by a
    psum), hence check_rep/check_vma off."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
