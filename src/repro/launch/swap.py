"""Hot adapter swap: feed freshly published DP-LoRA checkpoints into a
LIVE multi-tenant engine.

The training side (`launch.service.TrainService`) publishes adapter-only
checkpoints — tree ``{"lora": <adapter subtree>}`` — to its ``publish/``
directory as fine-tuning progresses, each with the standard per-leaf
crc32 manifest (`checkpoint.store`). The serving side runs an
`AdapterWatcher` per (tenant, publish directory): between engine
dispatches it

  1. polls `latest_verified_step` (torn or bit-rotted publishes are
     invisible — only a step whose every shard passes checksum counts);
  2. diffs the step's manifest against what the tenant is running —
     same step, or same per-leaf crcs (a re-publish of identical
     weights), means no swap;
  3. loads the tree with ``verify=True`` against the engine's
     `adapter_template()` and calls `DecodeEngine.update_adapter`,
     which `jax.device_put`s the leaves into the tenant's slot of the
     stacked adapter buffer — pure data, ZERO recompilation, blue/green
     versioned so requests already decoding on the old version drain on
     it before the slot remaps;
  4. reads the installed slot back off the device and compares
     `adapter_crcs` with the manifest: the swap is confirmed BITWISE
     equal to the published checkpoint, not merely "a load happened".

`poll()` is deliberately synchronous and cheap when idle (one directory
listing + one manifest read on a new step); drive it from the serving
loop between `engine.step()` calls or on a timer thread. See
docs/serving.md for the tenant-onboarding walkthrough and
examples/multi_tenant_serve.py for the full train -> publish -> swap
loop in one process.
"""
from __future__ import annotations

import dataclasses

from repro.checkpoint.store import (latest_verified_step, load_checkpoint,
                                    manifest_crcs)

__all__ = ["AdapterWatcher", "SwapResult"]


@dataclasses.dataclass(frozen=True)
class SwapResult:
    """Outcome of one detected publish: the checkpoint step installed,
    the tenant's new adapter version, and `verified` = the on-device
    slot read back bitwise-equal to the manifest (always True on a
    successful poll; a mismatch raises instead)."""

    step: int
    tenant: int
    version: int
    verified: bool


class AdapterWatcher:
    """Poll one publish directory and hot-swap one tenant's adapter.

    engine: a multi-tenant `DecodeEngine`. tenant: the tenant id whose
    adapter tracks this directory. directory: the training service's
    publish dir (``<service_dir>/publish``). subtree: key of the adapter
    subtree inside the published tree (the service publishes
    ``{"lora": ...}``).

    The watcher owns no thread: call `poll()` whenever convenient (the
    serve CLI's ``--watch`` does it between pool steps). Each poll costs
    a directory scan; a new verified step additionally costs one
    checkpoint load + one device round-trip for the bitwise check.
    """

    def __init__(self, engine, tenant: int, directory: str, *,
                 subtree: str = "lora"):
        self.engine = engine
        self.tenant = tenant
        self.directory = directory
        self.subtree = subtree
        self.installed_step: int | None = None
        self._installed_crcs: list[int] | None = None
        self.stats = {"polls": 0, "swaps": 0, "skipped_unchanged": 0}

    def poll(self) -> SwapResult | None:
        """Install the newest verified publish if it differs from what
        the tenant runs. Returns a `SwapResult` on a swap, None when
        nothing new. Raises RuntimeError if the installed slot reads
        back different from the manifest (a failed device write — the
        engine keeps serving the PREVIOUS version in that case only if
        the blue/green path was taken; treat it as fatal)."""
        self.stats["polls"] += 1
        step = latest_verified_step(self.directory)
        if step is None or step == self.installed_step:
            return None
        crcs = manifest_crcs(self.directory, step)
        if crcs is not None and crcs == self._installed_crcs:
            # re-publish of bitwise-identical weights: record the step so
            # the manifest read isn't repeated, but don't burn an adapter
            # slot on a no-op blue/green rotation
            self.installed_step = step
            self.stats["skipped_unchanged"] += 1
            return None
        template = {self.subtree: self.engine.adapter_template()}
        tree = load_checkpoint(self.directory, step, template, verify=True)
        self.engine.update_adapter(self.tenant, tree[self.subtree])
        live = self.engine.adapter_crcs(self.tenant)
        if crcs is not None and live != crcs:
            raise RuntimeError(
                f"hot swap of tenant {self.tenant} to step {step} is not "
                f"bitwise equal to the published checkpoint "
                f"({self.directory}): device readback crc mismatch")
        self.installed_step = step
        self._installed_crcs = crcs if crcs is not None else live
        self.stats["swaps"] += 1
        return SwapResult(step=step, tenant=self.tenant,
                          version=self.engine.tenant_stats(
                              self.tenant)["version"],
                          verified=True)
