from repro.optim.optimizers import (
    Optimizer, adam, adamw, chain_clip_by_global_norm, sgd,
)
from repro.optim.schedules import (
    constant, cosine_decay, linear_decay, wsd, Schedule,
)

__all__ = [
    "Optimizer", "adam", "adamw", "sgd", "chain_clip_by_global_norm",
    "constant", "cosine_decay", "linear_decay", "wsd", "Schedule",
]
