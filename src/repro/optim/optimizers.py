"""First-order optimizers with an optax-like (init, update) interface.

Implemented from scratch (no optax offline): SGD(+momentum), Adam, AdamW.
`update` returns the *delta* to add to params: params <- params + updates.
All states are pytrees, shard like their parameters, and are scan/jit-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.schedules import Schedule, constant

Updates = Any
Params = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Updates, Any, Params], tuple[Updates, Any]]


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


class SGDState(NamedTuple):
    step: jax.Array
    momentum: Any  # None-leaf pytree when momentum == 0


def sgd(lr: float | Schedule, momentum: float = 0.0,
        weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        mom = _tmap(jnp.zeros_like, params) if momentum else None
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(grads, state, params):
        step = state.step
        if weight_decay:
            grads = _tmap(lambda g, p: g + weight_decay * p, grads, params)
        if momentum:
            mom = _tmap(lambda m, g: momentum * m + g, state.momentum, grads)
            eff = (_tmap(lambda m, g: momentum * m + g, mom, grads)
                   if nesterov else mom)
        else:
            mom, eff = None, grads
        lr_t = sched(step)
        updates = _tmap(lambda g: (-lr_t * g).astype(g.dtype), eff)
        return updates, SGDState(step + 1, mom)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam(lr: float | Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         decoupled: bool = False) -> Optimizer:
    sched = lr if callable(lr) else constant(lr)

    def init(params):
        return AdamState(jnp.zeros((), jnp.int32),
                         _tmap(jnp.zeros_like, params),
                         _tmap(jnp.zeros_like, params))

    def update(grads, state, params):
        step = state.step + 1
        if weight_decay and not decoupled:
            grads = _tmap(lambda g, p: g + weight_decay * p, grads, params)
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = _tmap(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)).astype(v.dtype),
                   state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = sched(state.step)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = -lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and decoupled:
                u = u - lr_t * weight_decay * p
            return u.astype(p.dtype)

        updates = _tmap(upd, mu, nu, params)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(lr: float | Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay, decoupled=True)


def chain_clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Non-private global-norm clipping wrapper (for non_private baselines)."""

    def update(grads, state, params):
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
        grads = _tmap(lambda g: (g * scale).astype(g.dtype), grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)
