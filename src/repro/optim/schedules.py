"""Learning-rate schedules (pure functions of the step).

Includes WSD (Warmup-Stable-Decay) from MiniCPM (arXiv:2404.06395), the
schedule of the assigned minicpm-2b architecture.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.float32(lr)


def _warmup(step, warmup_steps):
    return jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup_steps, 1))


def linear_decay(lr: float, total_steps: int, warmup_steps: int = 0,
                 end_fraction: float = 0.0) -> Schedule:
    def f(step):
        w = _warmup(step, warmup_steps)
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        return jnp.float32(lr) * w * (1.0 - (1.0 - end_fraction) * frac)

    return f


def cosine_decay(lr: float, total_steps: int, warmup_steps: int = 0,
                 end_fraction: float = 0.1) -> Schedule:
    def f(step):
        w = _warmup(step, warmup_steps)
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.float32(lr) * w * (end_fraction + (1 - end_fraction) * cos)

    return f


def wsd(lr: float, total_steps: int, warmup_steps: int,
        decay_fraction: float = 0.1, end_fraction: float = 0.01) -> Schedule:
    """Warmup-Stable-Decay (MiniCPM): warmup, long plateau, sharp exp decay."""
    decay_steps = max(int(total_steps * decay_fraction), 1)
    stable_end = total_steps - decay_steps

    def f(step):
        w = _warmup(step, warmup_steps)
        in_decay = step > stable_end
        frac = jnp.clip((step - stable_end) / decay_steps, 0.0, 1.0)
        decay = jnp.exp(jnp.log(jnp.float32(end_fraction)) * frac)
        return jnp.float32(lr) * w * jnp.where(in_decay, decay, 1.0)

    return f
