from repro.checkpoint.store import (
    CheckpointCorrupt, all_steps, latest_step, latest_verified_step,
    load_checkpoint, load_latest_checkpoint, load_manifest, save_checkpoint,
    verify_checkpoint,
)

__all__ = [
    "CheckpointCorrupt", "all_steps", "latest_step", "latest_verified_step",
    "load_checkpoint", "load_latest_checkpoint", "load_manifest",
    "save_checkpoint", "verify_checkpoint",
]
