"""Checkpointing: msgpack + zstd sharded pytree store (no orbax offline).

Layout:  <dir>/step_<N>/manifest.msgpack   (treedef, shapes, dtypes, shards)
         <dir>/step_<N>/shard_<i>.bin.zst  (concatenated raw leaf bytes;
         .bin.zz when zstandard is unavailable and zlib is used — the
         manifest's "codec" field is authoritative)

Leaves are written in tree_flatten order, split into ~`shard_bytes` shards so
very large checkpoints stream instead of materializing one blob. Restore
reconstructs on host then (optionally) device_puts with a target sharding
tree — on the production mesh each process would pass its addressable
shardings; on CPU it's a plain load.

Crash safety: `save_checkpoint` stages everything in a `tmp-` sibling
directory, fsyncs each file and the parent directory, then `os.replace`s it
into place — a kill at any point leaves either the complete old state or the
complete new state, never a half-written step directory (`latest_step` only
matches `step_<N>` names, so orphaned `tmp-` stages are invisible). Every
leaf carries a crc32 in the manifest; `verify_checkpoint` /
`load_checkpoint(verify=True)` detect torn or bit-rotted shards instead of
deserializing them into garbage, and `load_latest_checkpoint` walks back to
the newest step that verifies.
"""
from __future__ import annotations

import os
import re
import struct

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # container lacks zstandard: fall back to stdlib zlib
    zstd = None
import zlib

_SHARD_BYTES = 256 * 1024 * 1024


def _compressor():
    if zstd is not None:
        return "zstd", zstd.ZstdCompressor(level=3).compress
    return "zlib", lambda raw: zlib.compress(raw, 6)


_SHARD_SUFFIX = {"zstd": ".bin.zst", "zlib": ".bin.zz"}


def _decompressor(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd but zstandard is not "
                "installed")
        return zstd.ZstdDecompressor().decompress
    if codec == "zlib":
        return zlib.decompress
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _leaf_meta(x) -> dict:
    arr = np.asarray(x)
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}


class CheckpointCorrupt(ValueError):
    """A checkpoint step failed checksum / structural verification."""


def _fsync_write(path: str, data: bytes) -> None:
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(directory: str, step: int, tree,
                    *, shard_bytes: int = _SHARD_BYTES, meta: dict | None = None,
                    fault_hook=None) -> str:
    """Atomically persist `tree` as `<directory>/step_<step>`.

    All files are staged under a `tmp-step_<step>-<pid>` sibling, fsynced,
    and published with a single `os.replace` — the step directory either
    exists complete or not at all. `meta` (msgpack-able dict) rides in the
    manifest; the training service stores sampler RNG state and the ledger
    offset there. `fault_hook(stage)` is a test seam: the fault-injection
    harness kills the process at "pre-stage" / "pre-rename" / "post-rename"
    to prove the atomicity claim.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    stage = os.path.join(directory, f"tmp-step_{step:08d}-{os.getpid()}")
    if fault_hook is not None:
        fault_hook("pre-stage")
    if os.path.isdir(stage):  # leftover from a crashed save: rebuild
        import shutil
        shutil.rmtree(stage)
    os.makedirs(stage)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas = []
    shards: list[list[bytes]] = [[]]
    cur = 0
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            raw = arr.view(np.uint16).tobytes()
            dtype = "bfloat16"
        else:
            raw = arr.tobytes()
            dtype = str(arr.dtype)
        if cur + len(raw) > shard_bytes and shards[-1]:
            shards.append([])
            cur = 0
        shards[-1].append(raw)
        cur += len(raw)
        metas.append({"shape": list(arr.shape), "dtype": dtype,
                      "shard": len(shards) - 1, "bytes": len(raw),
                      "crc32": zlib.crc32(raw)})
    codec, compress = _compressor()
    suffix = _SHARD_SUFFIX[codec]  # extension stays truthful to the codec
    for i, blobs in enumerate(shards):
        _fsync_write(os.path.join(stage, f"shard_{i:04d}{suffix}"),
                     compress(b"".join(blobs)))
    # treedef blob is advisory only (restore uses the caller's template);
    # proto serialization rejects user-defined nodes (NamedTuple states)
    try:
        treedef_blob = (jax.tree_util.tree_structure(tree)
                        .serialize_using_proto())
    except (AttributeError, ValueError):
        treedef_blob = None
    manifest = {
        "codec": codec,
        "treedef": treedef_blob,
        "num_shards": len(shards),
        "leaves": metas,
        "step": step,
        "meta": meta,
    }
    _fsync_write(os.path.join(stage, "manifest.msgpack"),
                 msgpack.packb(manifest))
    _fsync_dir(stage)
    if fault_hook is not None:
        fault_hook("pre-rename")
    if os.path.isdir(final):
        # re-publishing a step that already exists: shunt the old directory
        # aside atomically so `final` is free for the (atomic) replace, then
        # drop it — at every instant a complete version of the step exists
        import shutil
        trash = os.path.join(directory, f"tmp-old_{step:08d}-{os.getpid()}")
        if os.path.isdir(trash):
            shutil.rmtree(trash)
        os.replace(final, trash)
        os.replace(stage, final)
        shutil.rmtree(trash)
    else:
        os.replace(stage, final)
    _fsync_dir(directory)
    if fault_hook is not None:
        fault_hook("post-rename")
    return final


def load_manifest(directory: str, step: int) -> dict:
    """Read a step's manifest (shapes, codec, checksums, and `meta`)."""
    path = os.path.join(directory, f"step_{step:08d}", "manifest.msgpack")
    with open(path, "rb") as f:
        return msgpack.unpackb(f.read())


def verify_checkpoint(directory: str, step: int) -> bool:
    """True iff every shard decompresses and every leaf crc32 matches.

    Checkpoints written before checksums existed (no "crc32" in the leaf
    meta) verify structurally only (shards present, sizes consistent).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        codec = manifest.get("codec", "zstd")
        decompress = _decompressor(codec)
        suffix = _SHARD_SUFFIX[codec]
        shard_data = []
        for i in range(manifest["num_shards"]):
            with open(os.path.join(path, f"shard_{i:04d}{suffix}"), "rb") as f:
                shard_data.append(decompress(f.read()))
        offsets = [0] * manifest["num_shards"]
        for m in manifest["leaves"]:
            s, nbytes = m["shard"], m["bytes"]
            raw = shard_data[s][offsets[s]: offsets[s] + nbytes]
            offsets[s] += nbytes
            if len(raw) != nbytes:
                return False
            if "crc32" in m and zlib.crc32(raw) != m["crc32"]:
                return False
        return True
    except Exception:
        return False


def _owned_device_copy(arr: np.ndarray) -> jax.Array:
    """A runtime-OWNED device array with `arr`'s contents.

    `jnp.asarray` over a `np.frombuffer` view is zero-copy on CPU: the jax
    array aliases host memory the XLA runtime does not own. Restored state
    is fed straight into the DONATING train step (`jit_step`,
    donate_argnums=(0, 1, 2)), and donating an external, host-backed
    buffer into an executable that was DESERIALIZED from the persistent
    compilation cache corrupts memory on this jaxlib (garbage outputs,
    heap aborts — the resume leg of the service fault matrix hit all of
    them; freshly compiled executables handle the same donation fine).
    Routing the bytes through an explicit copy makes the leaf the output
    of an XLA execution, so the runtime owns its buffer and donation is
    safe regardless of how the step executable was obtained."""
    copied = jnp.copy(jnp.asarray(arr))
    assert copied.unsafe_buffer_pointer() != arr.ctypes.data
    return copied


def load_checkpoint(directory: str, step: int, template, *, shardings=None,
                    verify: bool = False):
    """Restore into the structure of `template` (shapes must match).

    shardings: optional pytree mirroring `template` leaf-for-leaf whose
    leaves are `jax.sharding.Sharding`s (or None to leave that leaf on the
    default device). Each restored leaf is `device_put` with its target
    sharding — the model-sharded-params resume path of
    `launch.train --mesh DxM`, asserted bitwise by
    tests/sharded_checks.py's checkpoint round-trip check. Build it with
    e.g. ``{"params": params_shardings(spec, mesh), "opt": tree of None}``
    (``jax.tree_util.tree_map(lambda _: None, subtree)``).

    verify: check every leaf's crc32 against the manifest before
    deserializing; a mismatch (torn shard, bit rot) raises
    `CheckpointCorrupt` instead of returning garbage arrays.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    codec = manifest.get("codec", "zstd")
    decompress = _decompressor(codec)
    suffix = _SHARD_SUFFIX[codec]
    shard_data = []
    for i in range(manifest["num_shards"]):
        with open(os.path.join(path, f"shard_{i:04d}{suffix}"), "rb") as f:
            try:
                shard_data.append(decompress(f.read()))
            except Exception as e:
                raise CheckpointCorrupt(
                    f"{path}: shard {i} failed to decompress: {e}") from e
    offsets = [0] * manifest["num_shards"]
    leaves = []
    for li, meta in enumerate(manifest["leaves"]):
        s, nbytes = meta["shard"], meta["bytes"]
        raw = shard_data[s][offsets[s]: offsets[s] + nbytes]
        offsets[s] += nbytes
        if len(raw) != nbytes:
            raise CheckpointCorrupt(
                f"{path}: shard {s} truncated at leaf {li} "
                f"(wanted {nbytes} bytes, got {len(raw)})")
        if verify and "crc32" in meta and zlib.crc32(raw) != meta["crc32"]:
            raise CheckpointCorrupt(
                f"{path}: leaf {li} crc mismatch (torn write?)")
        if meta["dtype"] == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(meta["shape"])
            leaves.append(_owned_device_copy(arr).view(jnp.bfloat16))
        else:
            arr = np.frombuffer(raw, np.dtype(meta["dtype"])).reshape(
                meta["shape"])
            leaves.append(_owned_device_copy(arr))
    treedef = jax.tree_util.tree_structure(template)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template expects "
            f"{treedef.num_leaves}")
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings,
            is_leaf=lambda x: x is None or isinstance(x, jax.sharding.Sharding))
        if len(sh_leaves) != len(leaves):
            raise ValueError(
                f"shardings tree has {len(sh_leaves)} leaves, checkpoint "
                f"has {len(leaves)} — mirror the template leaf-for-leaf")
        leaves = [l if s is None else jax.device_put(l, s)
                  for l, s in zip(leaves, sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def leaf_crc32(leaf) -> int:
    """crc32 over the exact raw bytes `save_checkpoint` checksums for a
    leaf (bfloat16 via its uint16 view, anything else via plain tobytes).
    Lets live state be compared bitwise against a manifest without
    re-serializing a checkpoint — the multi-tenant engine's hot-swap
    verification (`DecodeEngine.adapter_crcs` vs `manifest_crcs`)."""
    arr = np.asarray(jax.device_get(leaf))
    if arr.dtype == jnp.bfloat16:
        return zlib.crc32(arr.view(np.uint16).tobytes())
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def manifest_crcs(directory: str, step: int) -> list[int] | None:
    """Per-leaf crc32 list of a step's manifest (flatten order), or None
    when the checkpoint predates checksums."""
    leaves = load_manifest(directory, step)["leaves"]
    if any("crc32" not in m for m in leaves):
        return None
    return [m["crc32"] for m in leaves]


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def all_steps(directory: str) -> list[int]:
    """All step numbers present (complete or not), descending."""
    if not os.path.isdir(directory):
        return []
    return sorted((int(m.group(1)) for d in os.listdir(directory)
                   if (m := re.match(r"step_(\d+)$", d))), reverse=True)


def latest_verified_step(directory: str) -> int | None:
    """Newest step whose shards all pass checksum verification."""
    for step in all_steps(directory):
        if verify_checkpoint(directory, step):
            return step
    return None


def load_latest_checkpoint(directory: str, template, *, shardings=None):
    """Load the newest checkpoint that verifies; skip corrupt steps.

    Returns (step, tree, manifest) or None when no step verifies. A torn or
    bit-rotted newest step (detected by crc / decompress failure) falls back
    to the next older step rather than aborting the resume.
    """
    for step in all_steps(directory):
        if not verify_checkpoint(directory, step):
            continue
        tree = load_checkpoint(directory, step, template,
                               shardings=shardings, verify=True)
        return step, tree, load_manifest(directory, step)
    return None
