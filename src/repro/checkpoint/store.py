"""Checkpointing: msgpack + zstd sharded pytree store (no orbax offline).

Layout:  <dir>/step_<N>/manifest.msgpack   (treedef, shapes, dtypes, shards)
         <dir>/step_<N>/shard_<i>.bin.zst  (concatenated raw leaf bytes)

Leaves are written in tree_flatten order, split into ~`shard_bytes` shards so
very large checkpoints stream instead of materializing one blob. Restore
reconstructs on host then (optionally) device_puts with a target sharding
tree — on the production mesh each process would pass its addressable
shardings; on CPU it's a plain load.
"""
from __future__ import annotations

import os
import re
import struct

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard as zstd

_SHARD_BYTES = 256 * 1024 * 1024


def _leaf_meta(x) -> dict:
    arr = np.asarray(x)
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}


def save_checkpoint(directory: str, step: int, tree,
                    *, shard_bytes: int = _SHARD_BYTES) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas = []
    shards: list[list[bytes]] = [[]]
    cur = 0
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            raw = arr.view(np.uint16).tobytes()
            dtype = "bfloat16"
        else:
            raw = arr.tobytes()
            dtype = str(arr.dtype)
        if cur + len(raw) > shard_bytes and shards[-1]:
            shards.append([])
            cur = 0
        shards[-1].append(raw)
        cur += len(raw)
        metas.append({"shape": list(arr.shape), "dtype": dtype,
                      "shard": len(shards) - 1, "bytes": len(raw)})
    cctx = zstd.ZstdCompressor(level=3)
    for i, blobs in enumerate(shards):
        with open(os.path.join(path, f"shard_{i:04d}.bin.zst"), "wb") as f:
            f.write(cctx.compress(b"".join(blobs)))
    manifest = {
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto()
        if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto")
        else None,
        "num_shards": len(shards),
        "leaves": metas,
        "step": step,
    }
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return path


def load_checkpoint(directory: str, step: int, template):
    """Restore into the structure of `template` (shapes must match)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    dctx = zstd.ZstdDecompressor()
    shard_data = []
    for i in range(manifest["num_shards"]):
        with open(os.path.join(path, f"shard_{i:04d}.bin.zst"), "rb") as f:
            shard_data.append(dctx.decompress(f.read()))
    offsets = [0] * manifest["num_shards"]
    leaves = []
    for meta in manifest["leaves"]:
        s, nbytes = meta["shard"], meta["bytes"]
        raw = shard_data[s][offsets[s]: offsets[s] + nbytes]
        offsets[s] += nbytes
        if meta["dtype"] == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(meta["shape"])
            leaves.append(jnp.asarray(arr).view(jnp.bfloat16))
        else:
            arr = np.frombuffer(raw, np.dtype(meta["dtype"])).reshape(
                meta["shape"])
            leaves.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(template)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template expects "
            f"{treedef.num_leaves}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None
