"""Checkpointing: msgpack + zstd sharded pytree store (no orbax offline).

Layout:  <dir>/step_<N>/manifest.msgpack   (treedef, shapes, dtypes, shards)
         <dir>/step_<N>/shard_<i>.bin.zst  (concatenated raw leaf bytes;
         .bin.zz when zstandard is unavailable and zlib is used — the
         manifest's "codec" field is authoritative)

Leaves are written in tree_flatten order, split into ~`shard_bytes` shards so
very large checkpoints stream instead of materializing one blob. Restore
reconstructs on host then (optionally) device_puts with a target sharding
tree — on the production mesh each process would pass its addressable
shardings; on CPU it's a plain load.
"""
from __future__ import annotations

import os
import re
import struct

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # container lacks zstandard: fall back to stdlib zlib
    zstd = None
import zlib

_SHARD_BYTES = 256 * 1024 * 1024


def _compressor():
    if zstd is not None:
        return "zstd", zstd.ZstdCompressor(level=3).compress
    return "zlib", lambda raw: zlib.compress(raw, 6)


_SHARD_SUFFIX = {"zstd": ".bin.zst", "zlib": ".bin.zz"}


def _decompressor(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd but zstandard is not "
                "installed")
        return zstd.ZstdDecompressor().decompress
    if codec == "zlib":
        return zlib.decompress
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _leaf_meta(x) -> dict:
    arr = np.asarray(x)
    return {"shape": list(arr.shape), "dtype": str(arr.dtype)}


def save_checkpoint(directory: str, step: int, tree,
                    *, shard_bytes: int = _SHARD_BYTES) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas = []
    shards: list[list[bytes]] = [[]]
    cur = 0
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            raw = arr.view(np.uint16).tobytes()
            dtype = "bfloat16"
        else:
            raw = arr.tobytes()
            dtype = str(arr.dtype)
        if cur + len(raw) > shard_bytes and shards[-1]:
            shards.append([])
            cur = 0
        shards[-1].append(raw)
        cur += len(raw)
        metas.append({"shape": list(arr.shape), "dtype": dtype,
                      "shard": len(shards) - 1, "bytes": len(raw)})
    codec, compress = _compressor()
    suffix = _SHARD_SUFFIX[codec]  # extension stays truthful to the codec
    for i, blobs in enumerate(shards):
        with open(os.path.join(path, f"shard_{i:04d}{suffix}"), "wb") as f:
            f.write(compress(b"".join(blobs)))
    # treedef blob is advisory only (restore uses the caller's template);
    # proto serialization rejects user-defined nodes (NamedTuple states)
    try:
        treedef_blob = (jax.tree_util.tree_structure(tree)
                        .serialize_using_proto())
    except (AttributeError, ValueError):
        treedef_blob = None
    manifest = {
        "codec": codec,
        "treedef": treedef_blob,
        "num_shards": len(shards),
        "leaves": metas,
        "step": step,
    }
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return path


def load_checkpoint(directory: str, step: int, template, *, shardings=None):
    """Restore into the structure of `template` (shapes must match).

    shardings: optional pytree mirroring `template` leaf-for-leaf whose
    leaves are `jax.sharding.Sharding`s (or None to leave that leaf on the
    default device). Each restored leaf is `device_put` with its target
    sharding — the model-sharded-params resume path of
    `launch.train --mesh DxM`, asserted bitwise by
    tests/sharded_checks.py's checkpoint round-trip check. Build it with
    e.g. ``{"params": params_shardings(spec, mesh), "opt": tree of None}``
    (``jax.tree_util.tree_map(lambda _: None, subtree)``).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    codec = manifest.get("codec", "zstd")
    decompress = _decompressor(codec)
    suffix = _SHARD_SUFFIX[codec]
    shard_data = []
    for i in range(manifest["num_shards"]):
        with open(os.path.join(path, f"shard_{i:04d}{suffix}"), "rb") as f:
            shard_data.append(decompress(f.read()))
    offsets = [0] * manifest["num_shards"]
    leaves = []
    for meta in manifest["leaves"]:
        s, nbytes = meta["shard"], meta["bytes"]
        raw = shard_data[s][offsets[s]: offsets[s] + nbytes]
        offsets[s] += nbytes
        if meta["dtype"] == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(meta["shape"])
            leaves.append(jnp.asarray(arr).view(jnp.bfloat16))
        else:
            arr = np.frombuffer(raw, np.dtype(meta["dtype"])).reshape(
                meta["shape"])
            leaves.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(template)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template expects "
            f"{treedef.num_leaves}")
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings,
            is_leaf=lambda x: x is None or isinstance(x, jax.sharding.Sharding))
        if len(sh_leaves) != len(leaves):
            raise ValueError(
                f"shardings tree has {len(sh_leaves)} leaves, checkpoint "
                f"has {len(leaves)} — mirror the template leaf-for-leaf")
        leaves = [l if s is None else jax.device_put(l, s)
                  for l, s in zip(leaves, sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None
