"""qwen1.5-32b [dense]: 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064 — QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", arch_type="dense",
    num_layers=64, d_model=5120, d_ff=27_392, vocab_size=152_064,
    num_heads=40, num_kv_heads=40,
    qkv_bias=True,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="qwen1.5-32b-reduced", arch_type="dense",
    num_layers=2, d_model=256, d_ff=512, vocab_size=1_000,
    num_heads=4, num_kv_heads=4,
    qkv_bias=True,
)
