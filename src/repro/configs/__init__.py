"""Assigned-architecture registry.

Every architecture is selectable as ``--arch <id>``; each module defines
CONFIG (the exact assigned numbers, source cited) and REDUCED (a 2-layer,
d_model<=512, <=4-expert variant of the same family for CPU smoke tests).
"""
from __future__ import annotations

import importlib

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = [
    "qwen3-4b",
    "granite-moe-3b-a800m",
    "zamba2-7b",
    "deepseek-67b",
    "whisper-medium",
    "deepseek-v3-671b",
    "rwkv6-7b",
    "qwen1.5-32b",
    "qwen2-vl-72b",
    "minicpm-2b",
]


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str, *, reduced: bool = False,
               variant: str | None = None) -> ModelConfig:
    """Load an architecture config. variant='swa' selects the documented
    sliding-window flavor (long_500k support for dense archs)."""
    m = _module(arch_id)
    cfg = m.REDUCED if reduced else m.CONFIG
    if variant == "swa":
        import dataclasses
        cfg = dataclasses.replace(cfg, sliding_window=4096,
                                  name=cfg.name + "-swa")
    elif variant not in (None, "base"):
        raise ValueError(f"unknown variant {variant!r}")
    return cfg


def list_archs() -> list[str]:
    return list(ARCH_IDS)


__all__ = ["ARCH_IDS", "get_config", "list_archs", "INPUT_SHAPES",
           "InputShape", "ModelConfig"]
