"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
(per expert) vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", arch_type="moe",
    num_layers=32, d_model=1536, d_ff=512, vocab_size=49_155,
    num_heads=24, num_kv_heads=8,
    num_experts=40, num_experts_per_tok=8, moe_d_ff=512,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-reduced", arch_type="moe",
    num_layers=2, d_model=192, d_ff=128, vocab_size=1_000,
    num_heads=6, num_kv_heads=2,
    num_experts=4, num_experts_per_tok=2, moe_d_ff=128,
)
