"""whisper-medium [audio]: 24L (enc) + 24L (dec) d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865 — encoder-decoder; mel+conv frontend STUBBED:
input_specs provides precomputed frame embeddings (B, 1500, d_model).
long_500k skipped: full-attention decoder (DESIGN.md). [arXiv:2212.04356]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", arch_type="audio",
    num_layers=24, encoder_layers=24, encoder_seq_len=1500,
    d_model=1024, d_ff=4096, vocab_size=51_865,
    num_heads=16, num_kv_heads=16,
    max_seq_len=65_536,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="whisper-medium-reduced", arch_type="audio",
    num_layers=2, encoder_layers=2, encoder_seq_len=32,
    d_model=128, d_ff=256, vocab_size=1_000,
    num_heads=4, num_kv_heads=4,
    max_seq_len=4_096,
)
