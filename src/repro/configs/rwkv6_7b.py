"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536
— Finch: data-dependent decay time-mix. long_500k native (O(1) state).
[arXiv:2404.05892]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", arch_type="ssm",
    num_layers=32, d_model=4096, d_ff=14_336, vocab_size=65_536,
    num_heads=0, num_kv_heads=0, attention_kind="none",
    rwkv_head_dim=64,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="rwkv6-7b-reduced", arch_type="ssm",
    num_layers=2, d_model=256, d_ff=512, vocab_size=1_000,
    num_heads=0, num_kv_heads=0, attention_kind="none",
    rwkv_head_dim=64,
)
