"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama architecture. [arXiv:2401.02954]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", arch_type="dense",
    num_layers=95, d_model=8192, d_ff=22_016, vocab_size=102_400,
    num_heads=64, num_kv_heads=8,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="deepseek-67b-reduced", arch_type="dense",
    num_layers=2, d_model=256, d_ff=512, vocab_size=1_000,
    num_heads=4, num_kv_heads=2,
)
