"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention block
applied every 6 layers (parameter sharing; sensitivity_mult = #sites).
The shared attention uses a 4096-token sliding window so long_500k runs
natively (documented adaptation, DESIGN.md). [arXiv:2411.15242]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", arch_type="hybrid",
    num_layers=81, d_model=3584, d_ff=14_336, vocab_size=32_000,
    num_heads=32, num_kv_heads=32, head_dim=112,
    ssm_state=64, ssm_head_dim=64,
    shared_attention=True, shared_every=6,
    sliding_window=4096,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="zamba2-7b-reduced", arch_type="hybrid",
    num_layers=4, d_model=256, d_ff=512, vocab_size=1_000,
    num_heads=4, num_kv_heads=4, head_dim=64,
    ssm_state=16, ssm_head_dim=64, ssm_chunk=32,
    shared_attention=True, shared_every=2,
    sliding_window=64,
)
