"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE (t/h/w sections), dynamic resolution. Vision tower
STUBBED: input_specs provides precomputed patch embeddings.
long_500k skipped (full attention; DESIGN.md). [arXiv:2409.12191]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", arch_type="vlm",
    num_layers=80, d_model=8192, d_ff=29_568, vocab_size=152_064,
    num_heads=64, num_kv_heads=8,
    m_rope=True, m_rope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="qwen2-vl-72b-reduced", arch_type="vlm",
    num_layers=2, d_model=256, d_ff=512, vocab_size=1_000,
    num_heads=4, num_kv_heads=2,
    m_rope=True, m_rope_sections=(8, 12, 12),
    rope_theta=1_000_000.0,
)
