"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936,
qk_norm, explicit head_dim=128. [hf:Qwen/Qwen3-8B family]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", arch_type="dense",
    num_layers=36, d_model=2560, d_ff=9728, vocab_size=151_936,
    num_heads=32, num_kv_heads=8, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="qwen3-4b-reduced", arch_type="dense",
    num_layers=2, d_model=256, d_ff=512, vocab_size=1_000,
    num_heads=4, num_kv_heads=2, head_dim=64,
    qk_norm=True, rope_theta=1_000_000.0,
)
