"""Tiny dense config for unit tests and examples (not an assigned arch)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tiny", arch_type="dense",
    num_layers=2, d_model=64, d_ff=128, vocab_size=257,
    num_heads=4, num_kv_heads=2,
)
REDUCED = CONFIG
