"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048 (per routed
expert) vocab=129280 — MLA (q_lora=1536, kv_lora=512, nope=128, rope=64,
v=128), 1 shared + 256 routed experts top-8, first 3 layers dense
(d_ff=18432), MTP. [arXiv:2412.19437]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", arch_type="moe",
    num_layers=61, d_model=7168, d_ff=18_432, vocab_size=129_280,
    num_heads=128, num_kv_heads=128,
    attention_kind="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=256, num_experts_per_tok=8, num_shared_experts=1,
    moe_d_ff=2048, first_k_dense=3,
    mtp_depth=1,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="deepseek-v3-671b-reduced", arch_type="moe",
    num_layers=2, d_model=256, d_ff=512, vocab_size=1_000,
    num_heads=4, num_kv_heads=4,
    attention_kind="mla",
    q_lora_rank=64, kv_lora_rank=32,
    qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
    num_experts=4, num_experts_per_tok=2, num_shared_experts=1,
    moe_d_ff=128, first_k_dense=1,
    mtp_depth=1,
)
