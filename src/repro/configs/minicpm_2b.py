"""minicpm-2b [dense]: 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753 — llama-like; trained with the WSD schedule (repro.optim.wsd).
[arXiv:2404.06395]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", arch_type="dense",
    num_layers=40, d_model=2304, d_ff=5760, vocab_size=122_753,
    num_heads=36, num_kv_heads=36,
    dtype=jnp.bfloat16,
)

REDUCED = ModelConfig(
    name="minicpm-2b-reduced", arch_type="dense",
    num_layers=2, d_model=256, d_ff=512, vocab_size=1_000,
    num_heads=4, num_kv_heads=4,
)
