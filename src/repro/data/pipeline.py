"""Data pipeline: sources, packing, and the DP Poisson sampler.

DP-SGD's privacy accounting assumes POISSON subsampling: each example joins
the minibatch independently with probability rho = B/N (Abadi et al. 2016).
`PoissonSampler` implements exactly that (variable-size batches padded /
truncated to a fixed shape with a validity mask so jit shapes stay static —
padding examples are real examples with zero loss weight is NOT acceptable
for DP, so padding rows carry target=-1 everywhere and a zero clip
contribution by construction: their per-example gradient is exactly 0).

Sources (offline container => synthetic + byte-level):
  * SyntheticLM — Zipf-ish Markov token stream with planted bigram structure
    (a model can actually learn it; used by the utility benchmarks).
  * ByteCorpus — byte-level tokenizer over any text blob / file.
  * SyntheticClassification — separable-cluster classification (the WRN16-4
    CIFAR analogue for Table 1 / Fig. 3 style experiments).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np


# ---------------------------------------------------------------------------
# Sources.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SyntheticLM:
    """Markov chain with Zipf marginals; next-token structure is learnable."""

    vocab_size: int
    num_docs: int = 1024
    doc_len: int = 512
    seed: int = 0
    order_mix: float = 0.8  # prob of following the planted bigram table

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._marginal = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._succ = rng.integers(0, v, size=(v,))  # planted bigram successor
        self._rng = rng

    def documents(self) -> list[np.ndarray]:
        v = self.vocab_size
        docs = []
        for _ in range(self.num_docs):
            toks = np.empty(self.doc_len, np.int32)
            toks[0] = self._rng.choice(v, p=self._marginal)
            follow = self._rng.random(self.doc_len) < self.order_mix
            rand = self._rng.choice(v, size=self.doc_len, p=self._marginal)
            for t in range(1, self.doc_len):
                toks[t] = self._succ[toks[t - 1]] if follow[t] else rand[t]
            docs.append(toks)
        return docs


@dataclasses.dataclass
class ByteCorpus:
    """Byte-level 'tokenizer' over a text blob (vocab 256 + BOS=256)."""

    text: str
    doc_sep: str = "\n\n"

    @property
    def vocab_size(self) -> int:
        return 257

    def documents(self) -> list[np.ndarray]:
        return [np.frombuffer(d.encode("utf-8", "ignore"), dtype=np.uint8)
                .astype(np.int32)
                for d in self.text.split(self.doc_sep) if d]


@dataclasses.dataclass
class SyntheticClassification:
    """Gaussian clusters with margin; per-example DP utility experiments."""

    num_classes: int = 10
    dim: int = 32
    num_examples: int = 2048
    noise: float = 0.8
    seed: int = 0

    def arrays(self) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed)
        centers = rng.normal(size=(self.num_classes, self.dim))
        centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
        y = rng.integers(0, self.num_classes, size=self.num_examples)
        x = centers[y] + self.noise * rng.normal(
            size=(self.num_examples, self.dim))
        return x.astype(np.float32), y.astype(np.int32)


# ---------------------------------------------------------------------------
# Packing.
# ---------------------------------------------------------------------------


def pack_documents(docs: list[np.ndarray], seq_len: int, *,
                   bos: int | None = None) -> np.ndarray:
    """Concatenate docs (optionally BOS-separated) into (N, seq_len) rows."""
    parts = []
    for d in docs:
        if bos is not None:
            parts.append(np.array([bos], np.int32))
        parts.append(d.astype(np.int32))
    stream = np.concatenate(parts)
    n = len(stream) // seq_len
    return stream[: n * seq_len].reshape(n, seq_len)


def make_lm_batch(rows: np.ndarray, idx: np.ndarray, pad_to: int
                  ) -> dict[str, np.ndarray]:
    """Gather rows -> {'tokens', 'targets'} padded to `pad_to` examples.

    Padding rows get tokens=0 and targets=-1 everywhere: their per-example
    loss and gradient are identically zero, so they add nothing to the
    clipped sum and do not consume sensitivity."""
    take = rows[idx[:pad_to]]
    b = take.shape[0]
    tokens = np.zeros((pad_to, rows.shape[1]), np.int32)
    targets = np.full((pad_to, rows.shape[1]), -1, np.int32)
    tokens[:b] = take
    targets[:b, :-1] = take[:, 1:]
    return {"tokens": tokens, "targets": targets}


# ---------------------------------------------------------------------------
# The DP sampler.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PoissonSampler:
    """Poisson subsampling: every example independently with prob `rate`.

    Batches have random size ~ Binomial(N, rate); `max_batch` fixes the jit
    shape (overflowing examples are dropped — with rate*N << max_batch this
    is vanishingly rare; the event is counted so callers can assert on it)."""

    num_examples: int
    rate: float
    max_batch: int
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.overflow_count = 0
        self.draws = 0  # batches drawn so far (position in the stream)

    def next_indices(self) -> np.ndarray:
        mask = self._rng.random(self.num_examples) < self.rate
        idx = np.nonzero(mask)[0]
        self._rng.shuffle(idx)
        if len(idx) > self.max_batch:
            self.overflow_count += 1
            idx = idx[: self.max_batch]
        self.draws += 1
        return idx.astype(np.int64)

    def expected_batch(self) -> float:
        return self.num_examples * self.rate

    def state(self) -> dict:
        """Serializable snapshot: resuming from it continues the EXACT
        subsample stream (amplification accounting assumes the stream is
        drawn once — silently restarting it on resume is wrong). The RNG
        bit-generator state is JSON-encoded because its 128-bit PCG64
        integers overflow msgpack's int64."""
        return {
            "rng": json.dumps(self._rng.bit_generator.state),
            "draws": self.draws,
            "overflow_count": self.overflow_count,
            "num_examples": self.num_examples,
            "rate": self.rate,
            "max_batch": self.max_batch,
        }

    def restore(self, state: dict) -> None:
        """Inverse of `state()`. Refuses a snapshot from a sampler over a
        different corpus/rate — that would silently change q mid-ledger."""
        for field in ("num_examples", "max_batch"):
            if int(state[field]) != getattr(self, field):
                raise ValueError(
                    f"sampler state mismatch: {field} was {state[field]}, "
                    f"this sampler has {getattr(self, field)}")
        if abs(float(state["rate"]) - self.rate) > 1e-12:
            raise ValueError(
                f"sampler state mismatch: rate was {state['rate']}, "
                f"this sampler has {self.rate}")
        self._rng.bit_generator.state = json.loads(state["rng"])
        self.draws = int(state["draws"])
        self.overflow_count = int(state["overflow_count"])
