from repro.data.pipeline import (
    ByteCorpus, PoissonSampler, SyntheticLM, SyntheticClassification,
    make_lm_batch, pack_documents,
)

__all__ = [
    "ByteCorpus", "PoissonSampler", "SyntheticLM", "SyntheticClassification",
    "make_lm_batch", "pack_documents",
]
