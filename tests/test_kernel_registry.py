"""Kernel-registry completeness: every autotuned op has an oracle + test.

The registry contract (ISSUE 9 satellite): each op in
`kernels.autotune.OPS` must have (a) a pure-jnp ground truth in
`kernels.ref.ORACLES`, (b) a parity test somewhere under tests/ that
calls that oracle by name, and (c) a dispatch site in
`kernels/backend.py`. A new kernel cannot land half-wired.
"""
import inspect
import os

from repro.kernels import autotune, backend, ref

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def _tests_source() -> str:
    chunks = []
    for fn in sorted(os.listdir(TESTS_DIR)):
        if fn.endswith(".py") and fn != os.path.basename(__file__):
            with open(os.path.join(TESTS_DIR, fn)) as fh:
                chunks.append(fh.read())
    return "\n".join(chunks)


def test_ops_oracles_bijection():
    assert set(autotune.OPS) == set(ref.ORACLES), (
        f"autotune.OPS {sorted(autotune.OPS)} and ref.ORACLES "
        f"{sorted(ref.ORACLES)} must list the same ops")


def test_every_oracle_is_a_ref_function():
    for op, fn in ref.ORACLES.items():
        assert callable(fn), op
        assert fn.__module__ == "repro.kernels.ref", (
            f"{op}: oracle must live in kernels/ref.py, "
            f"got {fn.__module__}")


def test_every_oracle_has_a_parity_test():
    src = _tests_source()
    for op, fn in ref.ORACLES.items():
        assert fn.__name__ in src, (
            f"op {op!r}: no test under tests/ references its oracle "
            f"{fn.__name__!r} — add a parity test before registering "
            f"the kernel")


def test_every_op_is_dispatched_by_backend():
    src = inspect.getsource(backend)
    for op in autotune.OPS:
        assert f'"{op}"' in src or f"'{op}'" in src, (
            f"op {op!r} is autotuned but never dispatched in "
            f"kernels/backend.py")
