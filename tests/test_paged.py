"""Paged KV-cache data plane.

Four layers of guarantees, bottom-up:
  * launch.pages unit behavior — allocator refcounts, LIFO reuse, the
    full-page prefix registry (longest-hit probe, dedupe, LRU eviction,
    host spill / readmit key movement);
  * kernels — the Pallas paged-gather decode kernel matches the XLA
    gather reference over ragged page tables and partially filled last
    pages (through the backend engine registration, both families);
  * the per-family slot-axis spec the engine's recycle program is built
    from (a wrong axis would cross-contaminate slots silently);
  * engine-level bitwise invariants — prefix sharing (including a
    request admitted mid-flight against a live slot's registered
    prefix), evict -> host-spill -> readmit token roundtrip, skew-capped
    admission, and page-reservation deferral — all token-for-token
    against the per-request `greedy_decode(prefill="loop")` oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec import init_params
from repro.kernels import backend
from repro.launch.engine import DecodeEngine
from repro.launch.pages import PagePool, PrefixStore, pages_needed
from repro.launch.serve import greedy_decode
from repro.models.transformer import build_model, cache_slot_axes


def _build(arch):
    cfg = get_config(arch, reduced=(arch != "tiny"))
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, prompt, gen, cache_len):
    return np.asarray(greedy_decode(
        model, params, jnp.asarray(prompt)[None], gen, cache_len,
        prefill="loop"))[0].tolist()


# ---------------------------------------------------------------------------
# launch.pages units (pure host state, no model).
# ---------------------------------------------------------------------------


def test_pages_needed():
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2
    assert pages_needed(0, 16) == 0


def test_pool_alloc_refcount_free():
    pool = PagePool(4, 16)
    a = pool.alloc(3)
    assert len(a) == 3 and pool.num_free == 1 and pool.num_used == 3
    assert pool.alloc(2) is None  # over-alloc is atomic: nothing taken
    assert pool.num_free == 1
    pool.incref([a[0]])
    assert pool.decref([a[0]]) == []      # rc 2 -> 1: not freed
    assert pool.decref(a) == a            # rc 1 -> 0: all freed
    assert pool.num_free == 4
    with pytest.raises(ValueError):
        pool.decref([a[0]])               # double free
    with pytest.raises(ValueError):
        pool.incref([a[0]])               # incref of free page


def test_pool_lifo_reuse():
    pool = PagePool(4, 8)
    a = pool.alloc(2)
    pool.decref(a)
    b = pool.alloc(2)
    assert b == a[::-1]  # most recently freed first


def test_prefix_probe_longest_and_tail_token_rule():
    pool = PagePool(8, 4)
    store = PrefixStore(pool)
    toks = np.arange(100, 112, dtype=np.int32)  # 3 full pages of 4
    pages = pool.alloc(3)
    assert store.register(toks, pages)
    # longest full-page prefix wins
    probe = store.probe(np.concatenate([toks, [7, 8]]))
    assert probe is not None and probe[1] == 3 and probe[2] == "device"
    # shorter prompts hit their page-truncated subkey
    assert store.probe(toks[:9])[1] == 2
    # the LAST prompt token is never covered by a hit (it must be
    # prefilled to produce the true-last-token logits): an exact-page
    # prompt hits j = pages - 1, not pages
    assert store.probe(toks)[1] == 2
    assert store.probe(toks[:4]) is None  # one page = its own tail token
    # no match at all
    assert store.probe(np.asarray([1, 2, 3], np.int32)) is None
    # registering the same full key again dedupes without increfs
    rc_before = [pool.refcount(p) for p in pages]
    assert not store.register(toks, pages)
    assert [pool.refcount(p) for p in pages] == rc_before


def test_prefix_evict_spill_readmit_key_movement():
    pool = PagePool(8, 4)
    store = PrefixStore(pool)
    t1 = np.arange(0, 8, dtype=np.int32)
    t2 = np.arange(50, 58, dtype=np.int32)
    p1, p2 = pool.alloc(2), pool.alloc(2)
    store.register(t1, p1)
    store.register(t2, p2)
    pool.decref(p1), pool.decref(p2)  # slots retire: registry refs remain
    store.probe(np.concatenate([t1, [9]]))  # touch t1 -> t2 is LRU
    entry = store.evict_lru()
    assert entry.tokens.tolist() == t2.tolist()
    freed = store.spill(entry, {"k": np.zeros((1, 2, 4, 3))})
    assert sorted(freed) == sorted(p2)
    assert entry.tier == "host" and entry.n_pages == 2
    # host-tier hit, then readmission moves the keys back to device
    assert store.probe(np.concatenate([t2, [9]]))[2] == "host"
    np_pages = pool.alloc(2)
    store.readmit(entry, np_pages)
    assert store.probe(np.concatenate([t2, [9]]))[2] == "device"
    assert store.num_host_entries == 0 and store.num_device_entries == 2


def test_prefix_evictable_pages_counts_registry_only_refs():
    pool = PagePool(8, 4)
    store = PrefixStore(pool)
    toks = np.arange(0, 8, dtype=np.int32)
    pages = pool.alloc(2)
    store.register(toks, pages)
    pool.decref(pages)  # registering slot retires
    pool.incref([pages[0]])  # page 0 re-shared by a live slot
    assert store.evictable_pages() == 1


# ---------------------------------------------------------------------------
# Kernel: pallas paged-gather == xla gather reference (backend engines).
# ---------------------------------------------------------------------------

# b, kv, g, dq, dv, pool pages, page_len, table pages
PAGED_SHAPES = [
    (3, 2, 2, 16, None, 7, 8, 3),
    (2, 1, 4, 24, 16, 5, 4, 4),    # MLA-style: aliased pool, dv truncation
    (1, 2, 1, 8, None, 3, 16, 2),
    (4, 1, 1, 4, None, 9, 2, 5),
]


@pytest.mark.parametrize("b,kv,g,dq,dv,n,L,P", PAGED_SHAPES)
def test_paged_attn_backend_parity(b, kv, g, dq, dv, n, L, P):
    key = jax.random.PRNGKey(b * 7 + dq)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (b, kv, g, dq))
    kpool = jax.random.normal(k2, (n, L, kv, dq))
    vpool = kpool if dv is not None else jax.random.normal(k3, (n, L, kv, dq))
    pt = jax.random.randint(k4, (b, P), 0, n)
    # ragged validity incl. the edge cases: a single valid token, a
    # partially filled last page, and a completely full table
    pos = np.full((b,), P * L // 2, np.int32)
    pos[0] = 0
    pos[-1] = P * L - 1
    pos = jnp.asarray(pos)
    scale = 1.0 / np.sqrt(dq)

    xla = backend.make_engine("xla")
    pls = backend.make_engine("pallas", interpret=True)
    assert xla.paged_impl() == "xla" and pls.paged_impl() == "pallas"
    ref = xla.paged_attn(q, kpool, vpool, pt, pos, scale=scale, dv=dv)
    ker = pls.paged_attn(q, kpool, vpool, pt, pos, scale=scale, dv=dv)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-4, atol=1e-6)
    # the registry oracle (kernels.ref.paged_attn_ref) IS the xla path;
    # pin that identity so the oracle stays the allclose ground truth
    from repro.kernels.ref import paged_attn_ref
    oracle = paged_attn_ref(q, kpool, vpool, pt, pos, scale=scale, dv=dv)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(oracle))


def test_paged_impl_auto_routing():
    # off-TPU the auto engine stays on the bitwise xla gather path unless
    # interpret-mode kernels are forced
    auto = backend.make_engine("auto")
    on_tpu = jax.default_backend() == "tpu"
    assert auto.paged_impl() == ("pallas" if on_tpu else "xla")
    assert backend.make_engine("auto", interpret=True).paged_impl() == \
        "pallas"


# ---------------------------------------------------------------------------
# Slot-axis spec across cache families.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["tiny", "deepseek-v3-671b", "zamba2-7b",
                                  "rwkv6-7b", "whisper-medium"])
def test_cache_slot_axes_families(arch):
    """Every contiguous-cache tensor's declared slot axis really is the
    slot axis: its extent equals the slot count."""
    cfg = get_config(arch, reduced=(arch != "tiny"))
    model = build_model(cfg)
    cache = model.init_cache(3, 16)
    axes = model.cache_slot_axes(cache)
    assert set(axes) == set(cache)
    assert axes["pos"] == 0
    for k, v in cache.items():
        assert axes[k] is not None, (arch, k)
        assert v.shape[axes[k]] == 3, (arch, k)


def test_cache_slot_axes_paged_and_unknown():
    cfg, model, _ = _build("tiny")
    cache = model.init_paged_cache(3, 32, num_pages=6, page_len=16)
    axes = model.cache_slot_axes(cache)
    assert axes["pos"] == 0 and axes["pt"] == 0
    pools = [k for k in cache if k.endswith(("_kpool", "_vpool",
                                             "_latpool"))]
    assert pools and all(axes[k] is None for k in pools)
    with pytest.raises(KeyError, match="slot-axis"):
        cache_slot_axes({"mystery": jnp.zeros((2, 2))})


def test_paged_cache_unsupported_families():
    cfg, model, params = _build("rwkv6-7b")
    assert model.init_paged_cache is None
    eng = DecodeEngine(model, params, num_slots=2, cache_len=32)
    assert not eng.paged  # auto falls back to the contiguous plane
    with pytest.raises(ValueError, match="paging"):
        DecodeEngine(model, params, num_slots=2, cache_len=32, paging="on")
    # divisibility is part of the bitwise guarantee: auto declines too
    cfg2, model2, params2 = _build("tiny")
    eng2 = DecodeEngine(model2, params2, num_slots=2, cache_len=30,
                        page_len=16)
    assert not eng2.paged


# ---------------------------------------------------------------------------
# Engine-level bitwise invariants.
# ---------------------------------------------------------------------------


def test_engine_prefix_sharing_bitwise_incl_midflight():
    """Requests sharing a system prompt map the same physical pages —
    including one admitted mid-flight against a slot that is still
    decoding — and stay token-for-token with the unshared oracle."""
    cfg, model, params = _build("tiny")
    rng = np.random.RandomState(7)
    sys_p = rng.randint(1, cfg.vocab_size, 37).astype(np.int32)  # 2 pages

    def req(n):
        return np.concatenate(
            [sys_p, rng.randint(1, cfg.vocab_size, n).astype(np.int32)])

    eng = DecodeEngine(model, params, num_slots=2, cache_len=64,
                       page_len=16)
    assert eng.paged
    r0, r1, r2 = req(3), req(11), req(6)
    rid0 = eng.submit(r0, max_new_tokens=16)
    for _ in range(4):  # r0 admitted (prefix registered) and mid-decode
        eng.step()
    rid1 = eng.submit(r1, max_new_tokens=8)
    rid2 = eng.submit(r2, max_new_tokens=8)
    done = eng.run()
    assert eng.stats["prefix_hits"] >= 2
    assert eng.stats["shared_pages"] >= 4
    for rid, r, g in [(rid0, r0, 16), (rid1, r1, 8), (rid2, r2, 8)]:
        assert done[rid].tokens == _oracle(model, params, r, g, 64), rid


def test_engine_evict_spill_readmit_roundtrip():
    """A prefix evicted to the host tier re-admits bitwise: the resumed
    request decodes token-for-token as if its pages never left."""
    cfg, model, params = _build("tiny")
    rng = np.random.RandomState(1)
    sys_p = rng.randint(1, cfg.vocab_size, 35).astype(np.int32)

    def req(n):
        return np.concatenate(
            [sys_p, rng.randint(1, cfg.vocab_size, n).astype(np.int32)])

    eng = DecodeEngine(model, params, num_slots=1, cache_len=64,
                       page_len=16, num_pages=4)
    r1 = req(5)                                                   # 40 tok
    r2 = rng.randint(1, cfg.vocab_size, 30).astype(np.int32)      # 4 pages
    r3 = req(9)                                                   # 44 tok
    rid1 = eng.submit(r1, max_new_tokens=8)
    eng.run()
    # r2 needs the whole pool -> the registered sys prefix spills to host
    rid2 = eng.submit(r2, max_new_tokens=26)
    eng.run()
    assert eng.stats["evicted_pages"] >= 2
    # r3 hits the host tier -> pages re-uploaded and re-shared
    rid3 = eng.submit(r3, max_new_tokens=8)
    done = eng.run()
    assert eng.stats["readmitted_pages"] >= 2
    assert eng.stats["prefix_hits"] >= 1
    for rid, r, g in [(rid1, r1, 8), (rid2, r2, 26), (rid3, r3, 8)]:
        assert done[rid].tokens == _oracle(model, params, r, g, 64), rid


def test_engine_spill_disabled_drops_prefix():
    cfg, model, params = _build("tiny")
    rng = np.random.RandomState(3)
    r1 = rng.randint(1, cfg.vocab_size, 20).astype(np.int32)
    r2 = rng.randint(1, cfg.vocab_size, 30).astype(np.int32)
    eng = DecodeEngine(model, params, num_slots=1, cache_len=64,
                       page_len=16, num_pages=4, host_spill=False)
    rid1 = eng.submit(r1, max_new_tokens=8)
    eng.run()
    rid2 = eng.submit(r2, max_new_tokens=26)  # forces eviction (drop)
    done = eng.run()
    assert eng.stats["evicted_pages"] >= 1
    assert eng.stats["readmitted_pages"] == 0
    for rid, r, g in [(rid1, r1, 8), (rid2, r2, 26)]:
        assert done[rid].tokens == _oracle(model, params, r, g, 64), rid


def test_engine_admission_skew_bucketing():
    """A short prompt is no longer dragged through a long co-admission's
    padded chunk grid; outputs stay oracle-exact either way."""
    cfg, model, params = _build("tiny")
    rng = np.random.RandomState(9)
    short = rng.randint(1, cfg.vocab_size, 4).astype(np.int32)
    long = rng.randint(1, cfg.vocab_size, 40).astype(np.int32)

    def serve(**kw):
        eng = DecodeEngine(model, params, num_slots=2, cache_len=64,
                           prefill_chunk=4, **kw)
        rids = [eng.submit(short, max_new_tokens=4),
                eng.submit(long, max_new_tokens=4)]
        done = eng.run()
        return eng, [done[r].tokens for r in rids]

    eng, toks = serve()
    assert eng.stats["prefill_pad_chunks_saved"] > 0
    # effectively-unbounded skew co-admits everything (the old behavior)
    eng_all, toks_all = serve(prefill_skew_chunks=10 ** 6)
    assert eng_all.stats["prefill_pad_chunks_saved"] == 0
    assert toks == toks_all
    oracle = [_oracle(model, params, short, 4, 64),
              _oracle(model, params, long, 4, 64)]
    assert toks == oracle


def test_engine_page_reservation_deferral_fifo():
    """Admission reserves every page a request will touch; when the pool
    can't cover the next queued request it defers (FIFO preserved) and
    admits once pages free up — tokens unaffected."""
    cfg, model, params = _build("tiny")
    rng = np.random.RandomState(11)
    r1 = rng.randint(1, cfg.vocab_size, 20).astype(np.int32)
    r2 = rng.randint(1, cfg.vocab_size, 20).astype(np.int32)
    eng = DecodeEngine(model, params, num_slots=2, cache_len=32,
                       page_len=16, num_pages=3)
    rid1 = eng.submit(r1, max_new_tokens=8)
    rid2 = eng.submit(r2, max_new_tokens=8)
    done = eng.run()
    assert eng.stats["admission_deferrals"] >= 1
    assert eng.stats["requests_done"] == 2
    for rid, r in [(rid1, r1), (rid2, r2)]:
        assert done[rid].tokens == _oracle(model, params, r, 8, 32), rid
    # a request the pool could never cover is rejected at submit (the
    # pool here is smaller than the slots' logical capacity)
    small = DecodeEngine(model, params, num_slots=1, cache_len=64,
                         page_len=16, num_pages=2)
    with pytest.raises(ValueError, match="pages"):
        small.submit(rng.randint(1, cfg.vocab_size, 30).astype(np.int32),
                     max_new_tokens=8)  # 38 tokens -> 3 pages > pool of 2


def test_engine_paged_stats_and_cache_bytes():
    cfg, model, params = _build("tiny")
    rng = np.random.RandomState(13)
    reqs = [rng.randint(1, cfg.vocab_size, n).astype(np.int32)
            for n in (5, 9, 13)]
    paged = DecodeEngine(model, params, num_slots=3, cache_len=64,
                         page_len=16)
    contig = DecodeEngine(model, params, num_slots=3, cache_len=64,
                          paging="off")
    for r in reqs:
        paged.submit(r, max_new_tokens=6)
        contig.submit(r, max_new_tokens=6)
    paged.run(), contig.run()
    assert paged.stats["peak_live_slots"] == 3
    assert paged.stats["live_slot_steps"] >= 3
    assert paged.stats["peak_pages_in_use"] >= 3
    assert paged.cache_bytes() > 0 and contig.cache_bytes() > 0
