"""DP-SGD integration: modes, microbatching, LoRA freezing, noise stats."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core.dp_sgd import DPConfig, build_plan, make_dp_train_step
from repro.core.spec import init_params
from repro.launch.inputs import concrete_train_batch
from repro.models.transformer import build_model

B, T = 8, 16


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny")
    m = build_model(cfg)
    params = init_params(m.spec, jax.random.PRNGKey(0))
    batch = concrete_train_batch(cfg, B, T, jax.random.PRNGKey(1))
    return cfg, m, params, batch


@pytest.mark.parametrize("mode", ["per_layer", "ghost_flat", "per_group",
                                  "non_private"])
def test_modes_run_and_update(mode, tiny):
    cfg, m, params, batch = tiny
    assign = tuple(i % 2 for i in range(m.layout.num_groups)) \
        if mode == "per_group" else None
    dpc = DPConfig(mode=mode, sigma=1.0, sampling_rate=0.1, steps=10,
                   adaptive=(mode != "non_private"),
                   group_assignment=assign)
    init_fn, step_fn, plan = make_dp_train_step(
        m.loss_fn, m.spec, m.layout, optim.adam(1e-3), dpc, batch_size=B)
    opt_state, dp_state = init_fn(params)
    p2, _, _, met = jax.jit(step_fn)(params, opt_state, dp_state, batch,
                                     jax.random.PRNGKey(5))
    assert np.isfinite(float(met.loss))
    moved = any(not np.allclose(a, b) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)))
    assert moved


def test_microbatching_is_exact(tiny):
    cfg, m, params, batch = tiny
    outs = []
    for nmb in (1, 4):
        dpc = DPConfig(mode="per_layer", sigma=1.0, sampling_rate=0.1,
                       steps=10, adaptive=True, microbatches=nmb)
        init_fn, step_fn, _ = make_dp_train_step(
            m.loss_fn, m.spec, m.layout, optim.sgd(0.1), dpc, batch_size=B)
        opt_state, dp_state = init_fn(params)
        p2, _, _, met = jax.jit(step_fn)(params, opt_state, dp_state, batch,
                                         jax.random.PRNGKey(5))
        outs.append(p2)
    for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                    jax.tree_util.tree_leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


def test_noise_magnitude_statistics(tiny):
    """With huge thresholds (no clipping) and fixed grads, the update noise
    std must match sigma_new * S per coordinate (global strategy)."""
    cfg, m, params, batch = tiny
    dpc = DPConfig(mode="per_layer", sigma=2.0, sampling_rate=0.1, steps=10,
                   adaptive=False, init_threshold=1e-6)  # clip ~everything
    init_fn, step_fn, plan = make_dp_train_step(
        m.loss_fn, m.spec, m.layout, optim.sgd(1.0), dpc, batch_size=B)
    # with C tiny, grads ~ 0 and update ~ -lr * noise / B
    opt_state, dp_state = init_fn(params)
    p2, _, _, _ = jax.jit(step_fn)(params, opt_state, dp_state, batch,
                                   jax.random.PRNGKey(7))
    diffs = jnp.concatenate([
        (a - b).reshape(-1) for a, b in zip(
            jax.tree_util.tree_leaves(p2), jax.tree_util.tree_leaves(params))
    ])
    k = m.layout.num_groups
    expected_std = plan.sigma_new * jnp.sqrt(k * 1e-12) / B  # S=sqrt(K)*C
    got = float(jnp.std(diffs))
    assert abs(got - float(expected_std)) / float(expected_std) < 0.05


def test_lora_freezes_base():
    cfg = dataclasses.replace(get_config("qwen3-4b", reduced=True),
                              lora_rank=4)
    m = build_model(cfg)
    assert m.trainable_key == "lora"
    params = init_params(m.spec, jax.random.PRNGKey(0))
    batch = concrete_train_batch(cfg, 4, T, jax.random.PRNGKey(1))
    dpc = DPConfig(mode="per_layer", sigma=1.0, sampling_rate=0.1, steps=10)
    init_fn, step_fn, _ = make_dp_train_step(
        m.loss_fn, m.dp_spec, m.layout, optim.adam(1e-3), dpc, batch_size=4,
        trainable_key="lora")
    opt_state, dp_state = init_fn(params)
    p2, _, _, _ = jax.jit(step_fn)(params, opt_state, dp_state, batch,
                                   jax.random.PRNGKey(2))
    for k in params:
        for a, b in zip(jax.tree_util.tree_leaves(params[k]),
                        jax.tree_util.tree_leaves(p2[k])):
            if k == "lora":
                continue
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    moved = any(not np.allclose(a, b) for a, b in zip(
        jax.tree_util.tree_leaves(params["lora"]),
        jax.tree_util.tree_leaves(p2["lora"])))
    assert moved


def test_plan_accounting_consistency(tiny):
    cfg, m, params, batch = tiny
    dpc = DPConfig(mode="per_layer", epsilon=4.0, delta=1e-5,
                   sampling_rate=0.05, steps=200, adaptive=True,
                   quantile_budget_fraction=0.05)
    plan = build_plan(dpc, m.layout)
    assert plan.sigma_new > plan.sigma  # quantile budget costs noise
    from repro.core.accounting import compute_epsilon
    eps = compute_epsilon(sigma=plan.sigma, sampling_rate=0.05, steps=200,
                          delta=1e-5)
    assert eps <= 4.0 * 1.001


def test_fixed_vs_adaptive_threshold_state(tiny):
    cfg, m, params, batch = tiny
    for adaptive in (True, False):
        dpc = DPConfig(mode="per_layer", sigma=1.0, sampling_rate=0.1,
                       steps=10, adaptive=adaptive, init_threshold=0.5)
        init_fn, step_fn, _ = make_dp_train_step(
            m.loss_fn, m.spec, m.layout, optim.sgd(0.1), dpc, batch_size=B)
        opt_state, dp_state = init_fn(params)
        _, _, dp2, _ = jax.jit(step_fn)(params, opt_state, dp_state, batch,
                                        jax.random.PRNGKey(9))
        changed = not np.allclose(np.asarray(dp2.qstate.thresholds), 0.5)
        assert changed == adaptive


def test_shared_param_sensitivity_mult():
    cfg = get_config("zamba2-7b", reduced=True)
    m = build_model(cfg)
    mults = m.layout.sens_mults
    assert mults.max() > 1.0  # shared attention sites
    dpc = DPConfig(mode="per_layer", sigma=1.0, sampling_rate=0.1, steps=10)
    plan = build_plan(dpc, m.layout)
    assert plan.sens_mults.max() > 1.0
