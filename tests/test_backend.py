"""Ghost-op backend engine: registry mechanics + pallas ≡ xla parity for
every registered op, including ragged shapes that exercise the kernels'
padding paths, and an end-to-end DP train step on the tiny config."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import backend

# ragged on purpose: T not a multiple of bt=32, din < dk, dout < bj,
# plus one tile-aligned case
SHAPES = [
    (2, 8, 16, 24),
    (3, 70, 48, 40),
    (1, 33, 7, 130),
    (4, 64, 32, 32),
]


def _data(shape, seed=0):
    b, t, din, dout = shape
    key = jax.random.PRNGKey(seed + (hash(shape) & 0xFFFF))
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
    f = jax.random.uniform(jax.random.fold_in(key, 2), (b,))
    return a, g, f


def _engines():
    xla_eng = backend.make_engine("xla", bt=32, dk=32, bi=32, bj=32)
    pal_eng = backend.make_engine("pallas", bt=32, dk=32, bi=32, bj=32)
    return xla_eng, pal_eng


# ---------------------------------------------------------------------------
# Registry / scoping mechanics.
# ---------------------------------------------------------------------------


def test_registry_contents():
    assert set(backend.backends()) >= {"xla", "pallas", "auto"}


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown ghost backend"):
        backend.make_engine("tensorcore9000")


def test_scoped_nesting_and_inheritance():
    assert backend.active().name == "xla"  # default
    with backend.scoped("pallas", bt=64):
        assert backend.active().name == "pallas"
        assert backend.active().config.bt == 64
        # inner scope inherits unspecified fields from the enclosing one
        with backend.scoped(outer_max_elems=123):
            cfg = backend.active().config
            assert cfg.backend == "pallas"
            assert cfg.bt == 64
            assert cfg.outer_max_elems == 123
        assert backend.active().config.outer_max_elems != 123
    assert backend.active().name == "xla"


def test_scoped_restores_on_error():
    with pytest.raises(RuntimeError):
        with backend.scoped("pallas"):
            raise RuntimeError("boom")
    assert backend.active().name == "xla"


def test_choose_linear_path_cost_model():
    cfg = backend.EngineConfig(bt=256, dk=512)
    # off-TPU without forced interpret: always xla
    assert backend.choose_linear_path(4096, 1024, 1024, cfg,
                                      on_tpu=False) == "xla"
    # small weight, outer path cheaper -> xla even on TPU
    assert backend.choose_linear_path(4096, 16, 16, cfg, on_tpu=True) == "xla"
    # gram regime on TPU (outer transient over the cap) -> pallas
    assert backend.choose_linear_path(4096, 4096, 4096, cfg,
                                      on_tpu=True) == "pallas"
    # sub-tile sequence -> xla
    assert backend.choose_linear_path(64, 4096, 4096, cfg,
                                      on_tpu=True) == "xla"
    # interpret forced on CPU (tests): kernels selectable
    cfg_i = backend.EngineConfig(bt=256, dk=512, interpret=True)
    assert backend.choose_linear_path(4096, 4096, 4096, cfg_i,
                                      on_tpu=False) == "pallas"


# ---------------------------------------------------------------------------
# Op-level parity: pallas (interpret) ≡ xla for the full op surface.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
def test_parity_linear_norms_sq(shape):
    xla_eng, pal_eng = _engines()
    a, g, _ = _data(shape)
    np.testing.assert_allclose(pal_eng.linear_norms_sq(a, g),
                               xla_eng.linear_norms_sq(a, g), rtol=1e-4)


@pytest.mark.parametrize("shape", [(2, 8, 16, 24), (3, 70, 48, 40)])
@pytest.mark.parametrize("axis,m", [("out", 4), ("in", 8)])
def test_parity_linear_norms_sq_blocked(shape, axis, m):
    xla_eng, pal_eng = _engines()
    a, g, _ = _data(shape)
    got = pal_eng.linear_norms_sq_blocked(a, g, m, block_axis=axis)
    want = xla_eng.linear_norms_sq_blocked(a, g, m, block_axis=axis)
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("shape", SHAPES)
def test_parity_clipped_sum_linear(shape):
    xla_eng, pal_eng = _engines()
    a, g, f = _data(shape)
    np.testing.assert_allclose(pal_eng.clipped_sum_linear(a, g, f),
                               xla_eng.clipped_sum_linear(a, g, f),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("axis,m", [("out", 4), ("in", 8)])
def test_parity_clipped_sum_linear_blocked(axis, m):
    xla_eng, pal_eng = _engines()
    a, g, _ = _data((3, 70, 48, 40))
    fb = jax.random.uniform(jax.random.PRNGKey(7), (3, m))
    got = pal_eng.clipped_sum_linear_blocked(a, g, fb, block_axis=axis)
    want = xla_eng.clipped_sum_linear_blocked(a, g, fb, block_axis=axis)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("with_extra", [False, True])
def test_parity_linear_clip(shape, with_extra):
    """The fused norm+clip+reduce op — inc. the composed fallback path."""
    xla_eng, pal_eng = _engines()
    a, g, _ = _data(shape)
    b = shape[0]
    c = jnp.array(([0.2, jnp.inf, -0.5, 0.01] * b)[:b])
    extra = (jax.random.uniform(jax.random.PRNGKey(3), (b,))
             if with_extra else None)
    n_x, f_x, dw_x = xla_eng.linear_clip(a, g, c, extra)
    n_p, f_p, dw_p = pal_eng.linear_clip(a, g, c, extra)
    np.testing.assert_allclose(n_p, n_x, rtol=1e-4)
    np.testing.assert_allclose(f_p, f_x, rtol=1e-4)
    np.testing.assert_allclose(dw_p, dw_x, rtol=1e-4, atol=1e-5)


def test_parity_linear_clip_vmem_fallback():
    """Over the VMEM guard the pallas engine composes two kernels — same
    answer as the fused kernel / xla."""
    xla_eng, _ = _engines()
    small = backend.make_engine("pallas", bt=32, dk=32, bi=32, bj=32,
                                vmem_limit_bytes=1024)
    assert not small._fused_fits(48, 40)
    a, g, _ = _data((3, 70, 48, 40))
    c = jnp.array([0.2, jnp.inf, 0.05])
    n_x, _, dw_x = xla_eng.linear_clip(a, g, c)
    n_p, _, dw_p = small.linear_clip(a, g, c)
    np.testing.assert_allclose(n_p, n_x, rtol=1e-4)
    np.testing.assert_allclose(dw_p, dw_x, rtol=1e-4, atol=1e-5)


def test_parity_linear_clip_prefer_fused_off():
    """prefer_fused=False (the two-pass drivers' norms-only scope) composes
    norm + reduce kernels — same answer as the fused kernel."""
    xla_eng, _ = _engines()
    composed = backend.make_engine("pallas", bt=32, dk=32, bi=32, bj=32,
                                   prefer_fused=False)
    a, g, _ = _data((3, 70, 48, 40))
    c = jnp.array([0.2, jnp.inf, 0.05])
    n_x, _, dw_x = xla_eng.linear_clip(a, g, c)
    n_p, _, dw_p = composed.linear_clip(a, g, c)
    np.testing.assert_allclose(n_p, n_x, rtol=1e-4)
    np.testing.assert_allclose(dw_p, dw_x, rtol=1e-4, atol=1e-5)


def test_parity_fallback_ops():
    """Ops with no kernel fall back to the xla implementations — identical
    answers by construction, but the dispatch must still resolve."""
    xla_eng, pal_eng = _engines()
    key = jax.random.PRNGKey(11)
    g = jax.random.normal(key, (4, 9, 7))
    ids = jax.random.randint(jax.random.fold_in(key, 1), (4, 9), 0, 30)
    xhat = jax.random.normal(jax.random.fold_in(key, 2), (4, 9, 7))
    f = jax.random.uniform(jax.random.fold_in(key, 3), (4,))
    for op, args in [
        ("bias_norms_sq", (g,)),
        ("embed_norms_sq", (ids, g)),
        ("scale_norms_sq", (xhat, g)),
        ("vector_norms_sq", (g,)),
        ("clipped_sum_bias", (g, f)),
        ("clipped_sum_embed", (ids, g, f, 30)),
        ("clipped_sum_scale", (xhat, g, f)),
    ]:
        np.testing.assert_allclose(getattr(pal_eng, op)(*args),
                                   getattr(xla_eng, op)(*args), rtol=1e-5)


def test_auto_backend_dispatch_runs():
    """auto resolves (to xla off-TPU) and matches the reference."""
    with backend.scoped("auto") as auto_eng:
        a, g, f = _data((2, 8, 16, 24))
        xla_eng, _ = _engines()
        np.testing.assert_allclose(auto_eng.linear_norms_sq(a, g),
                                   xla_eng.linear_norms_sq(a, g), rtol=1e-5)
        n, fac, dw = auto_eng.linear_clip(a, g, jnp.full((2,), 0.3))
        assert n.shape == (2,) and dw.shape == (16, 24)


# ---------------------------------------------------------------------------
# End-to-end: DP train step on configs/tiny under backend="pallas".
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_config
    from repro.core.spec import init_params
    from repro.launch.inputs import concrete_train_batch
    from repro.models.transformer import build_model
    cfg = get_config("tiny")
    m = build_model(cfg)
    params = init_params(m.spec, jax.random.PRNGKey(0))
    batch = concrete_train_batch(cfg, 4, 16, jax.random.PRNGKey(1))
    return m, params, batch


def test_e2e_norms_parity_tiny(tiny_model):
    """Acceptance: per-group norms² under pallas match xla to <=1e-4 rel."""
    from repro.core.clipping import dp_clipped_gradients
    m, params, batch = tiny_model
    th = jnp.full((m.layout.num_groups,), 0.05)

    def run():
        return dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                    mode="per_layer", batch_size=4,
                                    thresholds=th)

    with backend.scoped("xla"):
        res_x = jax.jit(run)()
    with backend.scoped("pallas"):
        res_p = jax.jit(run)()
    np.testing.assert_allclose(np.asarray(res_p.norms_sq),
                               np.asarray(res_x.norms_sq), rtol=1e-4)
    for gx, gp in zip(jax.tree_util.tree_leaves(res_x.grads),
                      jax.tree_util.tree_leaves(res_p.grads)):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gx),
                                   rtol=2e-3, atol=1e-6)


def test_e2e_train_step_backends_match(tiny_model):
    """make_dp_train_step(backend='pallas') runs a full DP step on tiny and
    lands on the same state as backend='xla' (same noise key)."""
    from repro import optim
    from repro.core.dp_sgd import DPConfig, make_dp_train_step
    m, params, batch = tiny_model
    outs = []
    for be in ("xla", "pallas"):
        dpc = DPConfig(mode="per_layer", sigma=1.0, sampling_rate=0.1,
                       steps=10, adaptive=True, backend=be)
        init_fn, step_fn, _ = make_dp_train_step(
            m.loss_fn, m.spec, m.layout, optim.sgd(0.1), dpc, batch_size=4)
        opt_state, dp_state = init_fn(params)
        p2, _, dp2, met = jax.jit(step_fn)(params, opt_state, dp_state,
                                           batch, jax.random.PRNGKey(5))
        assert np.isfinite(float(met.loss))
        outs.append((p2, dp2, met))
    (p_x, dp_x, met_x), (p_p, dp_p, met_p) = outs
    # norms² drive clip_fraction and the threshold update — must agree
    np.testing.assert_allclose(float(met_p.clip_fraction),
                               float(met_x.clip_fraction), atol=1e-6)
    np.testing.assert_allclose(np.asarray(dp_p.qstate.thresholds),
                               np.asarray(dp_x.qstate.thresholds), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p_x),
                    jax.tree_util.tree_leaves(p_p)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=1e-6)
