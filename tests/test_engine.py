"""DecodeEngine contract: for any ragged request stream — including
requests admitted mid-flight into recycled slots — the engine's output is
token-for-token identical to running each request ALONE, unpadded,
through `greedy_decode(prefill="loop")` (the reference oracle), while the
pool advances every live slot in one dispatch per step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec import init_params
from repro.launch.engine import DecodeEngine
from repro.launch.inputs import synthetic_requests
from repro.launch.serve import greedy_decode
from repro.models.transformer import build_model


def _build(arch):
    cfg = get_config(arch, reduced=(arch != "tiny"))
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, prompt, gen, cache_len):
    return np.asarray(greedy_decode(
        model, params, jnp.asarray(prompt)[None], gen, cache_len,
        prefill="loop"))[0].tolist()


def test_engine_burst_parity_and_single_dispatch_per_step():
    cfg, model, params = _build("tiny")
    reqs = synthetic_requests(cfg.vocab_size, 4, min_len=1, max_len=9,
                              seed=2)
    eng = DecodeEngine(model, params, num_slots=4, cache_len=64,
                       prefill_chunk=4)
    rids = [eng.submit(r, max_new_tokens=8) for r in reqs]
    done = eng.run()
    for rid, r in zip(rids, reqs):
        assert done[rid].tokens == _oracle(model, params, r, 8, 64)
        assert done[rid].finish_reason == "length"
        assert done[rid].prompt_len == len(r)
    # pool-wide decode: one dispatch advances all live slots, so the
    # dispatch count tracks the LONGEST request, not the token total
    assert eng.stats["decode_dispatches"] < eng.stats["tokens_out"]


def test_engine_mid_flight_admission_and_slot_recycling():
    """More requests than slots; half submitted while the pool is already
    decoding. Slots are recycled (reset) between occupants."""
    cfg, model, params = _build("tiny")
    reqs = synthetic_requests(cfg.vocab_size, 5, min_len=1, max_len=7,
                              seed=3)
    eng = DecodeEngine(model, params, num_slots=2, cache_len=64,
                       prefill_chunk=4)
    gens = [8, 8, 8, 12, 8]
    rids = [eng.submit(r, max_new_tokens=g)
            for r, g in zip(reqs[:2], gens[:2])]
    for _ in range(3):  # pool mid-decode when the rest arrive
        eng.step()
    rids += [eng.submit(r, max_new_tokens=g)
             for r, g in zip(reqs[2:], gens[2:])]
    done = eng.run()
    for rid, r, g in zip(rids, reqs, gens):
        assert done[rid].tokens == _oracle(model, params, r, g, 64), rid
    assert eng.stats["requests_done"] == 5


def test_engine_eos_retirement():
    cfg, model, params = _build("tiny")
    r = synthetic_requests(cfg.vocab_size, 1, min_len=3, max_len=3,
                           seed=4)[0]
    full = _oracle(model, params, r, 8, 64)
    eos = full[3]  # retire after the 4th token
    cut = full.index(eos) + 1
    eng = DecodeEngine(model, params, num_slots=2, cache_len=64, eos_id=eos)
    rid = eng.submit(r, max_new_tokens=8)
    done = eng.run()
    assert done[rid].tokens == full[:cut]
    assert done[rid].finish_reason == "eos"


@pytest.mark.parametrize("arch,cache_len",
                         [("rwkv6-7b", 32), ("zamba2-7b", 12)])
def test_engine_recurrent_and_ring_cache_families(arch, cache_len):
    """Per-slot write/retire masking holds for recurrent state (RWKV) and
    the sliding-window ring cache incl. a ring wrap (Zamba2 hybrid)."""
    cfg, model, params = _build(arch)
    reqs = synthetic_requests(cfg.vocab_size, 3, min_len=2, max_len=7,
                              seed=1)
    eng = DecodeEngine(model, params, num_slots=2, cache_len=cache_len,
                       prefill_chunk=4)
    rids = [eng.submit(r, max_new_tokens=8) for r in reqs]
    done = eng.run()
    for rid, r in zip(rids, reqs):
        assert done[rid].tokens == _oracle(model, params, r, 8, cache_len), \
            (arch, rid)


@pytest.mark.parametrize("arch", ["tiny", "deepseek-v3-671b"])
def test_engine_paged_parity_vs_contiguous_and_oracle(arch):
    """At matching logical capacity the paged data plane (block pool +
    page tables + paged-gather attention) is BITWISE the contiguous
    engine — and both match the loop oracle — including mid-flight
    admission into recycled slots. Covers gqa and mla cache layouts."""
    cfg, model, params = _build(arch)
    reqs = synthetic_requests(cfg.vocab_size, 5, min_len=1, max_len=20,
                              seed=5)
    gens = [8, 8, 12, 8, 8]

    def serve(paging):
        eng = DecodeEngine(model, params, num_slots=2, cache_len=64,
                           prefill_chunk=4, paging=paging, page_len=16)
        rids = [eng.submit(r, max_new_tokens=g)
                for r, g in zip(reqs[:2], gens[:2])]
        for _ in range(3):  # pool mid-decode when the rest arrive
            eng.step()
        rids += [eng.submit(r, max_new_tokens=g)
                 for r, g in zip(reqs[2:], gens[2:])]
        done = eng.run()
        return eng, [done[rid].tokens for rid in rids]

    paged_eng, paged = serve("on")
    assert paged_eng.paged
    contig_eng, contig = serve("off")
    assert not contig_eng.paged
    assert paged == contig
    for toks, r, g in zip(paged, reqs, gens):
        assert toks == _oracle(model, params, r, g, 64)


def test_engine_submit_validation():
    cfg, model, params = _build("tiny")
    eng = DecodeEngine(model, params, num_slots=2, cache_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], max_new_tokens=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], max_new_tokens=0)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(list(range(10)), max_new_tokens=10)  # full KV cache
