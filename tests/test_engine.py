"""DecodeEngine contract: for any ragged request stream — including
requests admitted mid-flight into recycled slots — the engine's output is
token-for-token identical to running each request ALONE, unpadded,
through `greedy_decode(prefill="loop")` (the reference oracle), while the
pool advances every live slot in one dispatch per step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec import init_params
from repro.launch.engine import DecodeEngine
from repro.launch.inputs import synthetic_requests
from repro.launch.serve import greedy_decode
from repro.models.transformer import build_model


def _build(arch):
    cfg = get_config(arch, reduced=(arch != "tiny"))
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, prompt, gen, cache_len):
    return np.asarray(greedy_decode(
        model, params, jnp.asarray(prompt)[None], gen, cache_len,
        prefill="loop"))[0].tolist()


def test_engine_burst_parity_and_single_dispatch_per_step():
    cfg, model, params = _build("tiny")
    reqs = synthetic_requests(cfg.vocab_size, 4, min_len=1, max_len=9,
                              seed=2)
    eng = DecodeEngine(model, params, num_slots=4, cache_len=64,
                       prefill_chunk=4)
    rids = [eng.submit(r, max_new_tokens=8) for r in reqs]
    done = eng.run()
    for rid, r in zip(rids, reqs):
        assert done[rid].tokens == _oracle(model, params, r, 8, 64)
        assert done[rid].finish_reason == "length"
        assert done[rid].prompt_len == len(r)
    # pool-wide decode: one dispatch advances all live slots, so the
    # dispatch count tracks the LONGEST request, not the token total
    assert eng.stats["decode_dispatches"] < eng.stats["tokens_out"]


def test_engine_mid_flight_admission_and_slot_recycling():
    """More requests than slots; half submitted while the pool is already
    decoding. Slots are recycled (reset) between occupants."""
    cfg, model, params = _build("tiny")
    reqs = synthetic_requests(cfg.vocab_size, 5, min_len=1, max_len=7,
                              seed=3)
    eng = DecodeEngine(model, params, num_slots=2, cache_len=64,
                       prefill_chunk=4)
    gens = [8, 8, 8, 12, 8]
    rids = [eng.submit(r, max_new_tokens=g)
            for r, g in zip(reqs[:2], gens[:2])]
    for _ in range(3):  # pool mid-decode when the rest arrive
        eng.step()
    rids += [eng.submit(r, max_new_tokens=g)
             for r, g in zip(reqs[2:], gens[2:])]
    done = eng.run()
    for rid, r, g in zip(rids, reqs, gens):
        assert done[rid].tokens == _oracle(model, params, r, g, 64), rid
    assert eng.stats["requests_done"] == 5


def test_engine_eos_retirement():
    cfg, model, params = _build("tiny")
    r = synthetic_requests(cfg.vocab_size, 1, min_len=3, max_len=3,
                           seed=4)[0]
    full = _oracle(model, params, r, 8, 64)
    eos = full[3]  # retire after the 4th token
    cut = full.index(eos) + 1
    eng = DecodeEngine(model, params, num_slots=2, cache_len=64, eos_id=eos)
    rid = eng.submit(r, max_new_tokens=8)
    done = eng.run()
    assert done[rid].tokens == full[:cut]
    assert done[rid].finish_reason == "eos"


@pytest.mark.parametrize("arch,cache_len",
                         [("rwkv6-7b", 32), ("zamba2-7b", 12)])
def test_engine_recurrent_and_ring_cache_families(arch, cache_len):
    """Per-slot write/retire masking holds for recurrent state (RWKV) and
    the sliding-window ring cache incl. a ring wrap (Zamba2 hybrid)."""
    cfg, model, params = _build(arch)
    reqs = synthetic_requests(cfg.vocab_size, 3, min_len=2, max_len=7,
                              seed=1)
    eng = DecodeEngine(model, params, num_slots=2, cache_len=cache_len,
                       prefill_chunk=4)
    rids = [eng.submit(r, max_new_tokens=8) for r in reqs]
    done = eng.run()
    for rid, r in zip(rids, reqs):
        assert done[rid].tokens == _oracle(model, params, r, 8, cache_len), \
            (arch, rid)


@pytest.mark.parametrize("arch", ["tiny", "deepseek-v3-671b"])
def test_engine_paged_parity_vs_contiguous_and_oracle(arch):
    """At matching logical capacity the paged data plane (block pool +
    page tables + paged-gather attention) is BITWISE the contiguous
    engine — and both match the loop oracle — including mid-flight
    admission into recycled slots. Covers gqa and mla cache layouts."""
    cfg, model, params = _build(arch)
    reqs = synthetic_requests(cfg.vocab_size, 5, min_len=1, max_len=20,
                              seed=5)
    gens = [8, 8, 12, 8, 8]

    def serve(paging):
        eng = DecodeEngine(model, params, num_slots=2, cache_len=64,
                           prefill_chunk=4, paging=paging, page_len=16)
        rids = [eng.submit(r, max_new_tokens=g)
                for r, g in zip(reqs[:2], gens[:2])]
        for _ in range(3):  # pool mid-decode when the rest arrive
            eng.step()
        rids += [eng.submit(r, max_new_tokens=g)
                 for r, g in zip(reqs[2:], gens[2:])]
        done = eng.run()
        return eng, [done[rid].tokens for rid in rids]

    paged_eng, paged = serve("on")
    assert paged_eng.paged
    contig_eng, contig = serve("off")
    assert not contig_eng.paged
    assert paged == contig
    for toks, r, g in zip(paged, reqs, gens):
        assert toks == _oracle(model, params, r, g, 64)


def test_engine_submit_validation():
    cfg, model, params = _build("tiny")
    eng = DecodeEngine(model, params, num_slots=2, cache_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], max_new_tokens=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], max_new_tokens=0)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(list(range(10)), max_new_tokens=10)  # full KV cache


# ---------------------------------------------------------------------------
# Multi-tenant serving: tenant-stacked adapters over one base model.
# ---------------------------------------------------------------------------


def _build_lora(arch, rank=4):
    import dataclasses
    cfg = dataclasses.replace(get_config(arch, reduced=(arch != "tiny")),
                              lora_rank=rank)
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    return cfg, model, params


def _rand_adapter(model, seed, scale=0.05):
    """A non-trivial adapter tree (both a AND b random, so the delta is
    nonzero) with leaves matching the model's lora spec."""
    flat, td = jax.tree_util.tree_flatten(
        model.spec["lora"], is_leaf=lambda v: hasattr(v, "init"))
    ks = jax.random.split(jax.random.PRNGKey(seed), len(flat))
    return jax.tree_util.tree_unflatten(
        td, [jax.random.normal(k, p.shape, jnp.float32) * scale
             for k, p in zip(ks, flat)])


@pytest.mark.parametrize("arch", ["tiny", "deepseek-v3-671b"])
def test_engine_multi_tenant_mixed_equals_each_tenant_alone(arch):
    """A pool mixing tenants A and B decodes token-for-token identically
    to serving each tenant ALONE — the per-row adapter gather is
    row-independent, so batch composition can never leak across tenants.
    The zero-adapter tenant additionally matches the plain single-model
    engine bitwise (covers gqa and mla adapter math, paged plane on)."""
    cfg, model, params = _build_lora(arch)
    adB = _rand_adapter(model, seed=7)
    reqs = synthetic_requests(cfg.vocab_size, 6, min_len=2, max_len=12,
                              seed=3)
    kw = dict(num_slots=4, cache_len=64, prefill_chunk=4)

    eng = DecodeEngine(model, params, max_tenants=2, **kw)
    ta, tb = eng.add_tenant(), eng.add_tenant(adB)
    rids = {eng.submit(r, max_new_tokens=6,
                       tenant=(ta if i % 2 == 0 else tb)): i
            for i, r in enumerate(reqs)}
    done = eng.run()

    def alone(adapters, idxs):
        e = DecodeEngine(model, params, max_tenants=1, **kw)
        t = e.add_tenant(adapters)
        rr = {e.submit(reqs[i], max_new_tokens=6, tenant=t): i
              for i in idxs}
        d = e.run()
        return {i: d[rid].tokens for rid, i in rr.items()}

    alone_a, alone_b = alone(None, [0, 2, 4]), alone(adB, [1, 3, 5])
    for rid, i in rids.items():
        want = (alone_a if i % 2 == 0 else alone_b)[i]
        assert done[rid].tokens == want, f"req {i} mixed != alone"
    # the adapter actually changes the output (B is non-trivial) ...
    single = DecodeEngine(model, params, **kw)
    srids = {single.submit(reqs[i], max_new_tokens=6): i for i in [0, 1]}
    sd = single.run()
    by_i = {i: sd[rid].tokens for rid, i in srids.items()}
    # ... and the zero-adapter tenant IS the single-model engine, bitwise
    assert done[[r for r, i in rids.items() if i == 0][0]].tokens == by_i[0]
    assert done[[r for r, i in rids.items() if i == 1][0]].tokens != by_i[1]


def test_engine_multi_tenant_zero_recompile_and_bitwise_swap():
    """Admitting a tenant and hot-swapping an adapter are pure buffer
    writes: the jitted prefill/decode/reset programs never retrace
    (trace-time counters assert it), and the installed slot reads back
    crc32-identical to the source adapter tree."""
    from repro.checkpoint.store import leaf_crc32
    cfg, model, params = _build_lora("tiny")
    reqs = synthetic_requests(cfg.vocab_size, 4, min_len=2, max_len=8,
                              seed=9)
    eng = DecodeEngine(model, params, num_slots=2, cache_len=64,
                       prefill_chunk=4, max_tenants=3)
    t0 = eng.add_tenant()
    eng.submit(reqs[0], max_new_tokens=4, tenant=t0)
    eng.run()  # warmup: traces all three programs exactly once
    assert eng.trace_counts == {"prefill": 1, "decode": 1, "reset": 1}

    adB = _rand_adapter(model, seed=11)
    t1 = eng.add_tenant(adB)           # new tenant: buffer write only
    eng.update_adapter(t0, adB)        # hot swap: buffer write only
    for i, t in ((1, t1), (2, t0), (3, t1)):
        eng.submit(reqs[i], max_new_tokens=4, tenant=t)
    eng.run()
    assert eng.trace_counts == {"prefill": 1, "decode": 1, "reset": 1}, \
        "tenant admission or hot swap recompiled a serving program"

    # bitwise: device readback of each installed slot == source tree
    want = [leaf_crc32(l) for l in jax.tree_util.tree_leaves(adB)]
    assert eng.adapter_crcs(t1) == want
    assert eng.adapter_crcs(t0) == want


def test_engine_multi_tenant_submit_validation():
    cfg, model, params = _build_lora("tiny")
    eng = DecodeEngine(model, params, num_slots=2, cache_len=32,
                       max_tenants=1)
    with pytest.raises(ValueError, match="unknown tenant"):
        eng.submit([1, 2], max_new_tokens=2, tenant=99)
    t = eng.add_tenant()
    with pytest.raises(ValueError, match="unknown tenant"):
        eng.submit([1, 2], max_new_tokens=2, tenant=None)
    # single-model engines refuse tenant routing ...
    plain = DecodeEngine(model, params, num_slots=2, cache_len=32)
    with pytest.raises(ValueError, match="multi-tenant"):
        plain.submit([1, 2], max_new_tokens=2, tenant=t)
    # ... and the multi-tenant surface refuses single-model engines
    with pytest.raises(ValueError, match="max_tenants"):
        plain.add_tenant()
    # a lora-less model cannot be multi-tenant
    _, m0, p0 = _build("tiny")
    with pytest.raises(ValueError, match="lora_rank"):
        DecodeEngine(m0, p0, num_slots=2, cache_len=32, max_tenants=1)
