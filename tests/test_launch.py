"""Launch-layer units: sharding rules, input specs, HLO analyzer, and the
end-to-end dry-run on a 4-device debug mesh (subprocess so the forced
device count never leaks into this process)."""
import json
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.inputs import serve_batch_specs, train_batch_specs
from repro.models.config import INPUT_SHAPES

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_hlo_analyzer_scan_trip_counts():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    t = analyze_hlo(txt)
    assert t.flops >= 10 * 2 * 128 * 256 * 256  # trip-count multiplied
    assert t.flops < 1.2 * 10 * 2 * 128 * 256 * 256 + 10 * 128 * 256 * 4


def test_hlo_analyzer_tuple_shapes_with_index_comments():
    from repro.launch.hlo_analysis import _parse_instr_line
    line = ('  %while.1 = (s32[], f32[36,64]{1,0}, /*index=5*/f32[2,3]) '
            'while(%tuple.1), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"7"}}')
    parsed = _parse_instr_line(line)
    assert parsed is not None
    name, shape, op, rest = parsed
    assert op == "while"
    assert "known_trip_count" in rest


def test_input_specs_cover_archs():
    for arch in ("qwen3-4b", "whisper-medium", "qwen2-vl-72b"):
        cfg = get_config(arch)
        specs = train_batch_specs(cfg, INPUT_SHAPES["train_4k"])
        assert specs["tokens"].shape == (256, 4096)
        if arch == "whisper-medium":
            assert "frames" in specs
        if arch == "qwen2-vl-72b":
            assert "vision_embeds" in specs
        s = serve_batch_specs(cfg, INPUT_SHAPES["decode_32k"])
        assert s["token"].shape == (128, 1)


def test_sharding_rules():
    """Rule table resolves to the expected Megatron layout (unit-level, no
    devices needed: we check the PartitionSpec assignment logic)."""
    from repro.core.spec import P
    from repro.launch.sharding import _spec_for
    from jax.sharding import PartitionSpec as PS
    # column parallel
    assert _spec_for("dense_blocks/attn/qkv/w", P((36, 2560, 6144), stack=1),
                     16) == PS(None, None, "model")
    # row parallel
    assert _spec_for("dense_blocks/attn/o/w", P((36, 4096, 2560), stack=1),
                     16) == PS(None, "model", None)
    # expert parallel
    assert _spec_for("moe_blocks/moe/w_gu", P((61, 256, 7168, 4096),
                                              stack=2), 16) == \
        PS(None, "model", None, None)
    # non-divisible -> replicate
    assert _spec_for("dense_blocks/attn/qkv/w", P((2, 30, 30), stack=1),
                     16) == PS()
    # norm scales replicate
    assert _spec_for("final_norm/s", P((2560,)), 16) == PS()


@pytest.mark.slow
def test_dryrun_debug_mesh_subprocess():
    """End-to-end: lower+compile a reduced arch on a 4-device mesh in a
    subprocess (train + decode), assert ok status and collective parse."""
    code = (
        "import sys, json\n"
        "from repro.launch.dryrun import run_one\n"
        "r1 = run_one('qwen3-4b', 'train_4k', 'debug', save=False, debug=True)\n"
        "r2 = run_one('rwkv6-7b', 'decode_32k', 'debug', save=False, debug=True)\n"
        "print('RESULT', json.dumps([{k: v for k, v in r.items()"
        " if k in ('status','flops','error')} for r in (r1, r2)]))\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    m = re.search(r"RESULT (.*)", out.stdout)
    rs = json.loads(m.group(1))
    for r in rs:
        assert r["status"] == "ok", r
        assert r["flops"] > 0


@pytest.mark.parametrize("arch", ["tiny", "rwkv6-7b"])
def test_fused_prefill_matches_loop_prefill(arch):
    """serve.py's single-jitted-scan prefill must generate EXACTLY what the
    token-at-a-time reference path does (same cache, same logits), for both
    KV-cache attention and recurrent-state archs."""
    from repro.core.spec import init_params
    from repro.launch.serve import greedy_decode
    from repro.models.transformer import build_model
    cfg = get_config(arch, reduced=(arch != "tiny"))
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0,
                                 cfg.vocab_size)
    want = greedy_decode(model, params, prompts, 6, 24, prefill="loop")
    got = greedy_decode(model, params, prompts, 6, 24, prefill="fused")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
