"""Private quantile tracker (Andrew et al. 2019 geometric update)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core.quantile import clip_counts, init_quantile_state, update_thresholds


def _run_tracker(norm_stream, target, steps, lr=0.3, sigma_b=0.0, k=1):
    state = init_quantile_state(np.ones(k), target_quantile=target, lr=lr,
                                sigma_b=sigma_b)
    key = jax.random.PRNGKey(0)
    for i in range(steps):
        norms_sq = jnp.asarray(norm_stream(i)) ** 2  # (K, B)
        counts = clip_counts(norms_sq, state.thresholds)
        state = update_thresholds(state, counts, norms_sq.shape[-1],
                                  jax.random.fold_in(key, i))
    return state


def test_converges_to_quantile():
    rng = np.random.default_rng(0)
    data = rng.lognormal(0.0, 0.5, size=(1, 256))
    state = _run_tracker(lambda i: data, target=0.7, steps=300)
    got = float(state.thresholds[0])
    want = float(np.quantile(data, 0.7))
    assert abs(got - want) / want < 0.15


def test_tracks_drift():
    rng = np.random.default_rng(1)
    base = rng.lognormal(0.0, 0.3, size=(1, 128))

    def stream(i):
        return base * (1.0 + 0.01 * i)  # norms grow 1%/step

    state = _run_tracker(stream, target=0.5, steps=400)
    want = float(np.quantile(base * (1.0 + 0.01 * 399), 0.5))
    got = float(state.thresholds[0])
    assert abs(got - want) / want < 0.3  # tracks within lag


def test_private_noise_unbiased_direction():
    # with sigma_b > 0 the update is noisy but still converges on average
    rng = np.random.default_rng(2)
    data = rng.lognormal(0.0, 0.4, size=(1, 512))
    state = _run_tracker(lambda i: data, target=0.5, steps=400,
                         sigma_b=5.0)
    want = float(np.quantile(data, 0.5))
    got = float(state.thresholds[0])
    assert abs(got - want) / want < 0.35


@settings(max_examples=30, deadline=None)
@given(st.floats(0.1, 0.9), st.floats(0.2, 5.0))
def test_update_direction(q, c0):
    """If every norm is below C, C must shrink (too many clipped... i.e.
    b/B = 1 > q); if all above, C must grow."""
    state = init_quantile_state(np.array([c0]), target_quantile=q, lr=0.3,
                                sigma_b=0.0)
    below = jnp.full((1, 64), (c0 * 0.5) ** 2)
    counts = clip_counts(below, state.thresholds)
    s2 = update_thresholds(state, counts, 64, jax.random.PRNGKey(0))
    assert float(s2.thresholds[0]) < c0
    above = jnp.full((1, 64), (c0 * 2.0) ** 2)
    counts = clip_counts(above, state.thresholds)
    s3 = update_thresholds(state, counts, 64, jax.random.PRNGKey(0))
    assert float(s3.thresholds[0]) > c0


def test_multi_group_independent():
    state = init_quantile_state(np.ones(3), target_quantile=0.5, lr=0.3,
                                sigma_b=0.0)
    norms_sq = jnp.stack([jnp.full((8,), 0.01),   # all below -> shrink
                          jnp.full((8,), 100.0),  # all above -> grow
                          jnp.full((8,), 1.0)])   # boundary
    counts = clip_counts(norms_sq, state.thresholds)
    s2 = update_thresholds(state, counts, 8, jax.random.PRNGKey(0))
    assert float(s2.thresholds[0]) < 1.0
    assert float(s2.thresholds[1]) > 1.0


def test_export_restore_state_roundtrip():
    """The manifest-meta snapshot round-trips the tracker exactly (float32
    values survive the python-float detour bit-for-bit)."""
    import msgpack

    from repro.core.quantile import export_state, restore_state

    state = init_quantile_state(np.array([0.25, 1.7, 3.3], np.float32),
                                target_quantile=0.55, lr=0.3, sigma_b=12.5)
    counts = clip_counts(jnp.full((3, 16), 0.04), state.thresholds)
    state = update_thresholds(state, counts, 16, jax.random.PRNGKey(7))
    snap = export_state(state)
    msgpack.packb(snap)  # must be manifest-meta safe
    back = restore_state(snap)
    for a, b in zip(state, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype
