"""Data pipeline, optimizers, schedules, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro import optim
from repro.checkpoint import (CheckpointCorrupt, latest_step,
                              latest_verified_step, load_checkpoint,
                              load_latest_checkpoint, load_manifest,
                              save_checkpoint, verify_checkpoint)
from repro.data import (ByteCorpus, PoissonSampler, SyntheticLM,
                        make_lm_batch, pack_documents)


def test_poisson_sampler_statistics():
    ps = PoissonSampler(num_examples=10_000, rate=0.01, max_batch=200,
                        seed=0)
    sizes = [len(ps.next_indices()) for _ in range(200)]
    assert abs(np.mean(sizes) - 100) < 10  # E = N * rate = 100
    assert np.std(sizes) > 5  # genuinely random sizes (not fixed-size)
    assert ps.overflow_count == 0


def test_poisson_sampler_state_resumes_exact_stream():
    ps = PoissonSampler(num_examples=500, rate=0.05, max_batch=60, seed=3)
    for _ in range(4):
        ps.next_indices()
    snap = ps.state()
    msgpack.packb(snap)  # must ride in a checkpoint manifest as-is
    expected = [ps.next_indices() for _ in range(5)]
    fresh = PoissonSampler(num_examples=500, rate=0.05, max_batch=60, seed=3)
    fresh.restore(snap)
    assert fresh.draws == 4
    got = [fresh.next_indices() for _ in range(5)]
    for a, b in zip(expected, got):
        np.testing.assert_array_equal(a, b)
    # a restart WITHOUT restore would restart the stream — the bug resume
    # used to have; prove the streams actually differ so the test has teeth
    restarted = PoissonSampler(num_examples=500, rate=0.05, max_batch=60,
                               seed=3)
    assert any(not np.array_equal(a, restarted.next_indices())
               for a in expected)


def test_poisson_sampler_restore_refuses_mismatched_corpus():
    ps = PoissonSampler(num_examples=500, rate=0.05, max_batch=60, seed=3)
    snap = ps.state()
    other = PoissonSampler(num_examples=400, rate=0.05, max_batch=60, seed=3)
    with pytest.raises(ValueError):
        other.restore(snap)
    other2 = PoissonSampler(num_examples=500, rate=0.04, max_batch=60, seed=3)
    with pytest.raises(ValueError):
        other2.restore(snap)


def test_padding_rows_are_inert():
    rows = np.arange(4 * 8, dtype=np.int32).reshape(4, 8)
    batch = make_lm_batch(rows, np.array([1, 3]), pad_to=5)
    assert batch["tokens"].shape == (5, 8)
    assert (batch["targets"][2:] == -1).all()  # padding: all targets ignored


def test_packing():
    docs = [np.arange(10, dtype=np.int32), np.arange(7, dtype=np.int32)]
    rows = pack_documents(docs, 5, bos=99)
    assert rows.shape[1] == 5
    assert rows[0, 0] == 99


def test_byte_corpus():
    c = ByteCorpus("hello world\n\nsecond doc")
    docs = c.documents()
    assert len(docs) == 2
    assert docs[0][0] == ord("h")


def test_adam_quadratic_convergence():
    opt = optim.adam(0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}  # d/dx x^2
        upd, state = opt.update(grads, state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_sgd_momentum_direction():
    opt = optim.sgd(0.1, momentum=0.9)
    params = {"x": jnp.array(1.0)}
    state = opt.init(params)
    upd, state = opt.update({"x": jnp.array(1.0)}, state, params)
    assert float(upd["x"]) < 0


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 1000), st.integers(0, 50))
def test_wsd_schedule_shape(total, warmup):
    sched = optim.wsd(1.0, total, warmup)
    lrs = np.array([float(sched(jnp.asarray(s))) for s in
                    range(0, total, max(total // 50, 1))])
    assert lrs.max() <= 1.0 + 1e-6
    assert lrs[-1] <= lrs.max()  # decays at the end
    assert (lrs >= 0).all()


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": jnp.array(5, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree)
        save_checkpoint(d, 9, tree)
        assert latest_step(d) == 9
        out = load_checkpoint(d, 7, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_leaves_are_runtime_owned():
    """Restored leaves must be XLA-runtime-owned buffers, never zero-copy
    views over the decompressed shard bytes. They are fed straight into
    the donating train step (jit_step, donate_argnums=(0, 1, 2)), and
    donating a host-backed buffer into an executable deserialized from
    the persistent compile cache corrupts memory on this jaxlib — the
    service fault matrix caught it as non-bitwise resumes, NaNs, and heap
    aborts. Deterministic proxy: a zero-copy jax array aliases the numpy
    view's memory (unsafe_buffer_pointer == ctypes.data); owned copies
    must not."""
    from repro.checkpoint.store import _owned_device_copy
    # zero-copy only engages for 64-byte-aligned host pointers (which is
    # why the corruption was intermittent: it tracked where malloc placed
    # the decompressed shard bytes) — build an aligned view so the hazard
    # precondition holds deterministically
    buf = np.ones(64 * 64 + 16, np.float32)
    off = ((-buf.ctypes.data) % 64) // 4
    view = buf[off:off + 64 * 64].reshape(64, 64)
    assert view.ctypes.data % 64 == 0
    assert jnp.asarray(view).unsafe_buffer_pointer() == view.ctypes.data, \
        "zero-copy aliasing gone on this jaxlib; hazard may have moved"
    assert (_owned_device_copy(view).unsafe_buffer_pointer()
            != view.ctypes.data)
    tree = {"w": jnp.linspace(0, 1, 256).astype(jnp.float32),
            "h": jnp.ones((4, 4), jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        out = load_checkpoint(d, 1, tree)
        donated = jax.jit(lambda t: jax.tree_util.tree_map(
            lambda x: x * 2, t), donate_argnums=0)(out)
        np.testing.assert_array_equal(
            np.asarray(donated["w"]), np.asarray(tree["w"]) * 2)


def test_checkpoint_sharded_blobs():
    big = {"w": jnp.ones((1024, 256), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, big, shard_bytes=128 * 1024)
        out = load_checkpoint(d, 1, big)
        np.testing.assert_array_equal(out["w"], big["w"])


def test_checkpoint_zlib_fallback_roundtrip():
    """Force the stdlib-zlib codec path (container without zstandard) and
    assert the manifest + suffix stay truthful and the bytes round-trip."""
    import msgpack

    from repro.checkpoint import store as store_mod

    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((2, 2), jnp.bfloat16)}
    had = store_mod.zstd
    try:
        store_mod.zstd = None
        with tempfile.TemporaryDirectory() as d:
            path = save_checkpoint(d, 3, tree)
            with open(f"{path}/manifest.msgpack", "rb") as fh:
                assert msgpack.unpackb(fh.read())["codec"] == "zlib"
            import os
            assert any(f.endswith(".bin.zz") for f in os.listdir(path))
            out = load_checkpoint(d, 3, tree)
            for x, y in zip(jax.tree_util.tree_leaves(tree),
                            jax.tree_util.tree_leaves(out)):
                np.testing.assert_array_equal(np.asarray(x, np.float32),
                                              np.asarray(y, np.float32))
    finally:
        store_mod.zstd = had


def test_checkpoint_load_with_shardings_validates_and_places():
    """`shardings=` must mirror the template leaf-for-leaf; matching trees
    device_put each restored leaf onto its target."""
    import pytest

    tree = {"a": jnp.arange(8, dtype=jnp.float32),
            "b": jnp.ones((2,), jnp.float32)}
    dev = jax.devices()[0]
    sharding = jax.sharding.SingleDeviceSharding(dev)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        out = load_checkpoint(d, 1, tree,
                              shardings={"a": sharding, "b": None})
        assert out["a"].sharding == sharding
        np.testing.assert_array_equal(out["a"], tree["a"])
        with pytest.raises(ValueError, match="leaf-for-leaf"):
            load_checkpoint(d, 1, tree, shardings={"a": sharding})


# ---------------------------------------------------------------------------
# Crash-safe checkpointing: atomicity, checksums, fallback.
# ---------------------------------------------------------------------------


def _tree():
    return {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.full((8,), 2.5, jnp.float32)}


def test_checkpoint_atomic_no_partial_step_on_crash():
    """A kill before the rename leaves NO step directory — only an inert
    tmp- stage that latest_step/load never see, and that a re-save of the
    same step cleans up."""
    class Boom(RuntimeError):
        pass

    def hook(stage):
        if stage == "pre-rename":
            raise Boom()

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        with pytest.raises(Boom):
            save_checkpoint(d, 2, _tree(), fault_hook=hook)
        assert latest_step(d) == 1  # the torn publish is invisible
        assert any(f.startswith("tmp-") for f in os.listdir(d))
        save_checkpoint(d, 2, _tree())  # retry reuses/clears the stage
        assert latest_step(d) == 2
        assert verify_checkpoint(d, 2)
        assert not any(f.startswith("tmp-") for f in os.listdir(d))


def test_checkpoint_resave_same_step_stays_complete():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, _tree())
        tree2 = {"w": -jnp.ones((8, 8), jnp.float32),
                 "b": jnp.zeros((8,), jnp.float32)}
        save_checkpoint(d, 5, tree2)
        out = load_checkpoint(d, 5, tree2, verify=True)
        np.testing.assert_array_equal(out["w"], tree2["w"])
        assert not any(f.startswith("tmp-") for f in os.listdir(d))


def test_checkpoint_checksums_detect_torn_write():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        step_dir = os.path.join(d, "step_00000001")
        shard = next(os.path.join(step_dir, f)
                     for f in sorted(os.listdir(step_dir))
                     if f.startswith("shard_"))
        # flip one byte mid-shard: decompression may still "succeed", the
        # per-leaf crc32 is what must catch it
        with open(shard, "r+b") as f:
            f.seek(os.path.getsize(shard) // 2)
            byte = f.read(1)
            f.seek(-1, os.SEEK_CUR)
            f.write(bytes([byte[0] ^ 0xFF]))
        assert not verify_checkpoint(d, 1)
        with pytest.raises(CheckpointCorrupt):
            load_checkpoint(d, 1, _tree(), verify=True)


def test_checkpoint_truncated_shard_detected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        step_dir = os.path.join(d, "step_00000001")
        shard = next(os.path.join(step_dir, f)
                     for f in sorted(os.listdir(step_dir))
                     if f.startswith("shard_"))
        with open(shard, "r+b") as f:
            f.truncate(os.path.getsize(shard) // 2)
        assert not verify_checkpoint(d, 1)
        with pytest.raises(CheckpointCorrupt):
            load_checkpoint(d, 1, _tree(), verify=True)


def test_load_latest_falls_back_past_corrupt_step():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        save_checkpoint(d, 2, _tree(), meta={"tag": "newest"})
        step_dir = os.path.join(d, "step_00000002")
        shard = next(os.path.join(step_dir, f)
                     for f in sorted(os.listdir(step_dir))
                     if f.startswith("shard_"))
        with open(shard, "r+b") as f:
            f.truncate(1)
        assert latest_step(d) == 2          # present...
        assert latest_verified_step(d) == 1  # ...but not trustworthy
        found = load_latest_checkpoint(d, _tree())
        assert found is not None
        step, out, manifest = found
        assert step == 1
        np.testing.assert_array_equal(out["w"], _tree()["w"])
        assert load_latest_checkpoint(tempfile.mkdtemp(), _tree()) is None


def test_checkpoint_meta_roundtrip():
    meta = {"sampler": {"rng": "{...}", "draws": 7}, "epsilon": 1.25,
            "ledger_records": 9}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, _tree(), meta=meta)
        manifest = load_manifest(d, 3)
        assert manifest["meta"] == meta
        assert manifest["step"] == 3
        assert all("crc32" in leaf for leaf in manifest["leaves"])
