"""Data pipeline, optimizers, schedules, checkpointing."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro import optim
from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import (ByteCorpus, PoissonSampler, SyntheticLM,
                        make_lm_batch, pack_documents)


def test_poisson_sampler_statistics():
    ps = PoissonSampler(num_examples=10_000, rate=0.01, max_batch=200,
                        seed=0)
    sizes = [len(ps.next_indices()) for _ in range(200)]
    assert abs(np.mean(sizes) - 100) < 10  # E = N * rate = 100
    assert np.std(sizes) > 5  # genuinely random sizes (not fixed-size)
    assert ps.overflow_count == 0


def test_padding_rows_are_inert():
    rows = np.arange(4 * 8, dtype=np.int32).reshape(4, 8)
    batch = make_lm_batch(rows, np.array([1, 3]), pad_to=5)
    assert batch["tokens"].shape == (5, 8)
    assert (batch["targets"][2:] == -1).all()  # padding: all targets ignored


def test_packing():
    docs = [np.arange(10, dtype=np.int32), np.arange(7, dtype=np.int32)]
    rows = pack_documents(docs, 5, bos=99)
    assert rows.shape[1] == 5
    assert rows[0, 0] == 99


def test_byte_corpus():
    c = ByteCorpus("hello world\n\nsecond doc")
    docs = c.documents()
    assert len(docs) == 2
    assert docs[0][0] == ord("h")


def test_adam_quadratic_convergence():
    opt = optim.adam(0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}  # d/dx x^2
        upd, state = opt.update(grads, state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_sgd_momentum_direction():
    opt = optim.sgd(0.1, momentum=0.9)
    params = {"x": jnp.array(1.0)}
    state = opt.init(params)
    upd, state = opt.update({"x": jnp.array(1.0)}, state, params)
    assert float(upd["x"]) < 0


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 1000), st.integers(0, 50))
def test_wsd_schedule_shape(total, warmup):
    sched = optim.wsd(1.0, total, warmup)
    lrs = np.array([float(sched(jnp.asarray(s))) for s in
                    range(0, total, max(total // 50, 1))])
    assert lrs.max() <= 1.0 + 1e-6
    assert lrs[-1] <= lrs.max()  # decays at the end
    assert (lrs >= 0).all()


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": jnp.array(5, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree)
        save_checkpoint(d, 9, tree)
        assert latest_step(d) == 9
        out = load_checkpoint(d, 7, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_sharded_blobs():
    big = {"w": jnp.ones((1024, 256), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, big, shard_bytes=128 * 1024)
        out = load_checkpoint(d, 1, big)
        np.testing.assert_array_equal(out["w"], big["w"])


def test_checkpoint_zlib_fallback_roundtrip():
    """Force the stdlib-zlib codec path (container without zstandard) and
    assert the manifest + suffix stay truthful and the bytes round-trip."""
    import msgpack

    from repro.checkpoint import store as store_mod

    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((2, 2), jnp.bfloat16)}
    had = store_mod.zstd
    try:
        store_mod.zstd = None
        with tempfile.TemporaryDirectory() as d:
            path = save_checkpoint(d, 3, tree)
            with open(f"{path}/manifest.msgpack", "rb") as fh:
                assert msgpack.unpackb(fh.read())["codec"] == "zlib"
            import os
            assert any(f.endswith(".bin.zz") for f in os.listdir(path))
            out = load_checkpoint(d, 3, tree)
            for x, y in zip(jax.tree_util.tree_leaves(tree),
                            jax.tree_util.tree_leaves(out)):
                np.testing.assert_array_equal(np.asarray(x, np.float32),
                                              np.asarray(y, np.float32))
    finally:
        store_mod.zstd = had


def test_checkpoint_load_with_shardings_validates_and_places():
    """`shardings=` must mirror the template leaf-for-leaf; matching trees
    device_put each restored leaf onto its target."""
    import pytest

    tree = {"a": jnp.arange(8, dtype=jnp.float32),
            "b": jnp.ones((2,), jnp.float32)}
    dev = jax.devices()[0]
    sharding = jax.sharding.SingleDeviceSharding(dev)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        out = load_checkpoint(d, 1, tree,
                              shardings={"a": sharding, "b": None})
        assert out["a"].sharding == sharding
        np.testing.assert_array_equal(out["a"], tree["a"])
        with pytest.raises(ValueError, match="leaf-for-leaf"):
            load_checkpoint(d, 1, tree, shardings={"a": sharding})
