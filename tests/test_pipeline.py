"""Algorithm 2 (private pipeline parallelism with per-device clipping):
the shard_map pipeline must match the single-device reference exactly —
loss, gradients, and per-stage clipped gradients — and its per-example
norm computations must stay stage-local (run in a 2-device subprocess)."""
import json
import os
import re
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np, json
from repro.core.pipeline import (PipelineConfig, pipeline_spec,
                                 make_pipeline_loss, reference_loss)
from repro.core.spec import GroupLayout, init_params
from repro.core.clipping import dp_clipped_gradients

cfg = PipelineConfig(n_stages=2, layers_per_stage=3, d_model=16, d_in=8,
                     n_classes=4)
spec = pipeline_spec(cfg)
layout = GroupLayout(spec)
params = init_params(spec, jax.random.PRNGKey(0))
mesh = jax.make_mesh((2,), ("pod",))
loss_pipe = make_pipeline_loss(cfg, mesh)

B = 8
x = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_in))
y = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, cfg.n_classes)
batch = (x, y)
inf = layout.pack_value(jnp.inf, B)

lp = jax.jit(lambda p: loss_pipe(p, batch, inf))(params)
lr = reference_loss(cfg, params, batch, inf)
np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-4)

gp = jax.jit(jax.grad(lambda p: loss_pipe(p, batch, inf).sum()))(params)
gr = jax.grad(lambda p: reference_loss(cfg, p, batch, inf).sum())(params)
for a, b in zip(jax.tree_util.tree_leaves(gp), jax.tree_util.tree_leaves(gr)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=1e-5)

# per-DEVICE clipping: groups = stages (+ embed, head); two-pass driver
names = [g.name for g in layout.groups]
stage_g = layout.group("stage")
assign = np.zeros(layout.num_groups, np.int32)
nxt = 1
for g in layout.groups:
    if g.name == "stage":
        for i in range(g.count):
            assign[g.offset + i] = nxt + i
    else:
        assign[g.offset] = 0
n_super = int(assign.max()) + 1
cg = jnp.full((n_super,), 0.05)
res_p = dp_clipped_gradients(
    lambda p, b, t: loss_pipe(p, b, t), params, batch, layout,
    mode="per_group", batch_size=B, group_assignment=jnp.asarray(assign),
    group_thresholds=cg)
res_r = dp_clipped_gradients(
    lambda p, b, t: reference_loss(cfg, p, b, t), params, batch, layout,
    mode="per_group", batch_size=B, group_assignment=jnp.asarray(assign),
    group_thresholds=cg)
for a, b in zip(jax.tree_util.tree_leaves(res_p.grads),
                jax.tree_util.tree_leaves(res_r.grads)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3,
                               atol=1e-5)
np.testing.assert_allclose(np.asarray(res_p.norms_sq),
                           np.asarray(res_r.norms_sq), rtol=2e-3)

# structural check: per-example norm values never cross the stage axis —
# the (S, B) norms come back per stage with no norm-valued collective.
# (activation ppermutes ARE expected; we check that the number of
# collectives does not grow with the number of stage groups' norms.)
hlo = jax.jit(lambda p, t: dp_clipped_gradients(
    lambda pp, bb, tt: loss_pipe(pp, bb, tt), p, batch, layout,
    mode="per_group", batch_size=B, group_assignment=jnp.asarray(assign),
    group_thresholds=t).norms_sq).lower(params, cg).compile().as_text()
n_perm = hlo.count(" collective-permute(")
print(json.dumps({"ok": True, "n_ppermute": n_perm}))
"""


@pytest.mark.slow
def test_pipeline_matches_reference_and_clips_per_stage():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    m = re.search(r'\{.*\}', out.stdout)
    r = json.loads(m.group(0))
    assert r["ok"]
    assert r["n_ppermute"] >= 1  # the pipeline really communicates
