"""Sharded execution engine: ownership assignment, the axis-classifying
collectives parser (pure units), and the full 8-virtual-device check suite
(subprocess, so the forced device count never leaks into this process).

The executable contract under test is the paper's Sec 4: per-device
(`per_group`) clipping crosses the model axis with ZERO norm collectives
while `ghost_flat` pays exactly its (B,) total-norm psum — asserted from
compiled HLO by `tests/sharded_checks.py`, alongside sharded == single-
device parity of grads, norms² and quantile state.
"""
import json
import os
import re
import subprocess
import sys

import numpy as np
import pytest

from repro.core.spec import GroupLayout, P
from repro.launch.hlo_analysis import (_axes_of_groups,
                                       _parse_replica_groups)
from repro.launch.sharding import group_shard_assignment

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Ownership assignment (layout groups -> owning model shard).
# ---------------------------------------------------------------------------


def test_assignment_blocked_column_parallel_tracks_blocks():
    """blocks == model_size on a column-parallel weight: block j -> shard j
    (exact Megatron ownership)."""
    spec = {"dense_blocks": {"mlp": {"gate_up": {
        "w": P((3, 16, 32), stack=1, blocks=4)}}}}
    layout = GroupLayout(spec)
    assign = group_shard_assignment(layout, 4)
    assert len(assign) == layout.num_groups == 12
    assert assign == tuple([0, 1, 2, 3] * 3)  # (layer, block) row-major


def test_assignment_stacked_contiguous_pipeline_split():
    spec = {"blocks": {"mlp": {"down": {"w": P((8, 16, 16), stack=1)}}}}
    layout = GroupLayout(spec)
    assign = group_shard_assignment(layout, 4)
    assert assign == (0, 0, 1, 1, 2, 2, 3, 3)


def test_assignment_singletons_round_robin_and_range():
    spec = {"embed": {"w": P((64, 8))},
            "head": {"w": P((8, 64))},
            "final_norm": {"s": P((8,), init="ones")}}
    layout = GroupLayout(spec)
    assign = group_shard_assignment(layout, 4)
    assert len(assign) == 3
    assert len(set(assign)) == 3  # balanced, not all on shard 0
    assert all(0 <= a < 4 for a in assign)


def test_assignment_matches_layout_length_on_real_model():
    from repro.configs import get_config
    from repro.models.transformer import build_model
    m = build_model(get_config("tiny"))
    for msize in (2, 4, 16):
        assign = group_shard_assignment(m.layout, msize)
        assert len(assign) == m.layout.num_groups
        assert max(assign) < msize


# ---------------------------------------------------------------------------
# replica_groups parsing + axis classification.
# ---------------------------------------------------------------------------


def _coords_2x4():
    # (data=2, model=4) row-major: id = d*4 + m
    return {d * 4 + m: (d, m) for d in range(2) for m in range(4)}


def test_parse_replica_groups_literal_and_iota():
    assert _parse_replica_groups("{{0,1,2,3},{4,5,6,7}}", 8) == \
        [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert _parse_replica_groups("{}", 4) == [[0, 1, 2, 3]]
    # iota v2: [2,4]<=[8] -> two consecutive groups of 4
    assert _parse_replica_groups("[2,4]<=[8]", 8) == \
        [[0, 1, 2, 3], [4, 5, 6, 7]]
    # transposed iota: [4,2]<=[2,4]T(1,0) -> stride-4 pairs
    assert _parse_replica_groups("[4,2]<=[2,4]T(1,0)", 8) == \
        [[0, 4], [1, 5], [2, 6], [3, 7]]
    assert _parse_replica_groups("[1,1]<=[totally-bogus]", 8) is None


def test_axes_of_groups_classification():
    coords, axes = _coords_2x4(), ("data", "model")
    assert _axes_of_groups([[0, 1, 2, 3], [4, 5, 6, 7]], coords, axes) == \
        ("model",)
    assert _axes_of_groups([[0, 4], [1, 5], [2, 6], [3, 7]], coords, axes) == \
        ("data",)
    assert _axes_of_groups([[0, 1, 2, 3, 4, 5, 6, 7]], coords, axes) == \
        ("data", "model")
    # degenerate singleton groups span nothing
    assert _axes_of_groups([[i] for i in range(8)], coords, axes) == ()


def test_classify_collectives_from_synthetic_hlo():
    from repro.launch.hlo_analysis import classify_collectives

    class FakeDev:
        def __init__(self, i):
            self.id = i

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.array([[FakeDev(d * 4 + m) for m in range(4)]
                            for d in range(2)])

    hlo = """\
HloModule test

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  %ar0 = f32[8]{0} all-reduce(f32[8]{0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add, metadata={op_name="jit(f)/flat_norm_psum/psum"}
  ROOT %ar1 = f32[8]{0} all-reduce(f32[8]{0} %ar0), replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add, metadata={op_name="jit(f)/grad_psum/psum"}
}
"""
    rows = classify_collectives(hlo, FakeMesh())
    by_site = {r["site"].split("/")[-2]: r for r in rows}
    assert by_site["flat_norm_psum"]["axes"] == ("model",)
    assert by_site["grad_psum"]["axes"] == ("data",)
    assert by_site["flat_norm_psum"]["bytes"] == 32.0


# ---------------------------------------------------------------------------
# The full engine contract on a real 8-device debug mesh (subprocess).
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_engine_checks_subprocess():
    """Parity (grads / norms² / quantile state, incl. microbatches and the
    LoRA trainable_key path) and the zero-model-axis-norm-collectives
    assertion — see tests/sharded_checks.py for the check list."""
    env = dict(os.environ, PYTHONPATH=SRC)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        flags + ["--xla_force_host_platform_device_count=8"])
    out = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "sharded_checks.py")],
        capture_output=True, text=True, env=env, timeout=1500)
    m = re.search(r"RESULT (.*)", out.stdout)
    assert m, (out.stdout[-2000:], out.stderr[-3000:])
    results = json.loads(m.group(1))
    bad = {k: v for k, v in results.items()
           if k != "hlo_axis_counts" and v != "ok"}
    assert not bad, bad
    assert out.returncode == 0, out.stderr[-3000:]
    # the Sec-4 contract, restated here so the numbers are visible in CI
    assert results["hlo_axis_counts"]["per_group"] == 0
    assert results["hlo_axis_counts"]["ghost_flat"] >= 1
