"""Mutation tests for the static DP-safety auditor (ISSUE 9 tentpole).

The auditor is only worth its CI gate if seeded violations actually trip
it: each mutation here surgically breaks ONE invariant in the real
engine (drop the clip multiply, double/drop the noise add, collapse the
key fold, strip donation) and must be flagged by EXACTLY its expected
rule — no more, no less. The green configs prove the unmutated tree
passes, so a firing rule is signal, not noise.

The sharded half of the matrix needs 8 devices and runs in CI via
`python -m repro.launch.audit --matrix` (the CLI sets
--xla_force_host_platform_device_count before jax loads); here the
collective-leak rule is exercised hermetically with a stub mesh over a
synthetic HLO module instead.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.analysis import hlo as hlo_mod
from repro.analysis.findings import (ERROR, INFO, SEVERITIES, WARNING,
                                     Finding, errors, worst_severity)
from repro.analysis.rules import (RULES, StepExpectation,
                                  rule_collective_leak, run_hlo_rules)
from repro.launch import audit as audit_mod


def _error_rules(rec: dict) -> list[str]:
    return sorted({f["rule"] for f in rec["findings"]
                   if f["severity"] == ERROR})


# ---------------------------------------------------------------------------
# Green baselines: the unmutated engine passes both static passes.
# ---------------------------------------------------------------------------


def test_green_ghost_flat_full_audit():
    rec = audit_mod.audit_config("ghost_flat", "bk", False)
    assert rec["status"] == "ok", rec["findings"]
    assert rec["num_errors"] == 0
    rules_seen = {f["rule"] for f in rec["findings"]}
    # the positive evidence is recorded, not silently skipped
    assert {"HLO-BWD-COUNT", "HLO-DONATION",
            "HLO-SHAPE-STABLE"} <= rules_seen


@pytest.mark.parametrize("mode,execution",
                         [("per_layer", "bk"), ("ghost_flat", "twopass"),
                          ("naive_flat", "bk")])
def test_green_jaxpr_pass(mode, execution):
    rec = audit_mod.audit_config(mode, execution, False, jaxpr_only=True)
    assert rec["status"] == "ok", rec["findings"]


# ---------------------------------------------------------------------------
# The teeth: every seeded violation trips exactly its expected rule.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(audit_mod.MUTATIONS))
def test_mutation_trips_exactly_expected_rule(name):
    want = audit_mod.MUTATIONS[name]
    donate = name != "strip_donation"
    jaxpr_only = name != "strip_donation"
    with audit_mod.seeded_violation(name):
        rec = audit_mod.audit_config("ghost_flat", "bk", False,
                                     donate=donate, jaxpr_only=jaxpr_only)
    assert rec["status"] == "error"
    assert _error_rules(rec) == [want], rec["findings"]


def test_double_noise_message_counts_draws():
    with audit_mod.seeded_violation("double_noise"):
        rec = audit_mod.audit_config("ghost_flat", "bk", False,
                                     jaxpr_only=True)
    assert any("2 noise draws" in f["message"] for f in rec["findings"])


def test_reuse_key_names_colliding_leaves():
    with audit_mod.seeded_violation("reuse_key"):
        rec = audit_mod.audit_config("ghost_flat", "bk", False,
                                     jaxpr_only=True)
    errs = [f for f in rec["findings"] if f["severity"] == ERROR]
    assert errs and all(f["rule"] == "JAXPR-KEY-LINEAGE" for f in errs)
    # each finding names a PAIR of distinct leaves sharing a signature
    assert all(" ~ " in f["location"] and " and " in f["message"]
               for f in errs)


def test_backward_count_catches_execution_lie():
    # compile the REAL twopass program, then audit it under the CLAIM that
    # it is bk: the rules engine must count 2 transposed layer loops and
    # refuse the claim (this is the measured half of tests/test_bk.py
    # turned into a gate)
    step_fn, args, mesh, expect = audit_mod.build_case(
        "ghost_flat", "twopass", False)
    hlo = (jax.jit(step_fn, donate_argnums=(0, 1, 2))
           .lower(*args).compile().as_text())
    assert not errors(run_hlo_rules(hlo, expect, mesh))
    lied = dataclasses.replace(expect, execution="bk")
    errs = errors(run_hlo_rules(hlo, lied, mesh))
    assert [f.rule for f in errs] == ["HLO-BWD-COUNT"]
    assert "2 backward" in errs[0].message


# ---------------------------------------------------------------------------
# Collective-leak rule, hermetic: stub 2x4 mesh + synthetic HLO.
# ---------------------------------------------------------------------------


class _StubDev:
    def __init__(self, i):
        self.id = i


class _StubMesh:
    """Just enough mesh surface for mesh_device_coords: a (2, 4) device
    array with row-major ids and (data, model) axis names."""

    axis_names = ("data", "model")
    devices = np.array([[_StubDev(d * 4 + m) for m in range(4)]
                        for d in range(2)], dtype=object)


def _synth_hlo(site: str) -> str:
    # all-reduce over {0,1,2,3}/{4,5,6,7}: membership varies only along
    # the model axis of the 2x4 stub mesh
    return f"""HloModule synth

%add (a: f32[], b: f32[]) -> f32[] {{
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}}

ENTRY %main (p0: f32[8]) -> f32[8] {{
  %p0 = f32[8] parameter(0)
  ROOT %ar = f32[8] all-reduce(%p0), replica_groups={{{{0,1,2,3}},{{4,5,6,7}}}}, to_apply=%add, metadata={{op_name="jit(step_fn)/{site}/all-reduce"}}
}}
"""


def test_coll_leak_flags_per_device_mode():
    # per_layer promises ZERO model-axis norm traffic; any norm psum
    # crossing the model axis is a leak of per-example norm data
    expect = StepExpectation(mode="per_layer", sharded=True)
    fs = rule_collective_leak(_synth_hlo("per_example_norm_psum"),
                              expect, _StubMesh())
    errs = errors(fs)
    assert len(errs) == 1 and errs[0].rule == "HLO-COLL-LEAK"
    assert "model" in errs[0].message


def test_coll_leak_whitelists_ghost_flat_norm_psum():
    expect = StepExpectation(mode="ghost_flat", sharded=True)
    fs = rule_collective_leak(_synth_hlo("flat_norm_psum"),
                              expect, _StubMesh())
    assert not errors(fs)
    assert any(f.severity == INFO and "whitelisted" in f.message
               for f in fs)


def test_coll_leak_rejects_unwhitelisted_site_even_for_ghost_flat():
    expect = StepExpectation(mode="ghost_flat", sharded=True)
    fs = rule_collective_leak(_synth_hlo("per_example_norm_psum"),
                              expect, _StubMesh())
    assert any(f.severity == ERROR for f in fs)
    # and the missing whitelisted psum is itself called out
    assert any(f.severity == WARNING and "flat_norm_psum" in f.message
               for f in fs)


def test_coll_leak_ignores_data_axis_norm_psum():
    # {0,4}-style groups vary only the DATA coordinate: per-device modes
    # are allowed to reduce norms across data shards
    text = _synth_hlo("per_example_norm_psum").replace(
        "{{0,1,2,3},{4,5,6,7}}", "{{0,4},{1,5},{2,6},{3,7}}")
    expect = StepExpectation(mode="per_layer", sharded=True)
    fs = rule_collective_leak(text, expect, _StubMesh())
    assert not errors(fs)


# ---------------------------------------------------------------------------
# HLO header parsing + findings plumbing.
# ---------------------------------------------------------------------------


def test_entry_aliases_parse():
    text = ('HloModule jit_step, input_output_alias={ {0}: (0, {}, '
            'may-alias), {1}: (2, {}, must-alias) }, '
            'entry_computation_layout={(f32[4])->f32[4]}\n')
    assert hlo_mod.entry_aliases(text) == [
        {"output_index": (0,), "param": 0, "kind": "may-alias"},
        {"output_index": (1,), "param": 2, "kind": "must-alias"},
    ]
    assert hlo_mod.entry_aliases("HloModule bare\n") == []


def test_dynamic_shape_instrs_ignores_iota_attrs():
    stable = ('ENTRY %e (p: f32[4]) -> f32[4] {\n'
              '  %p = f32[4] parameter(0)\n'
              '  ROOT %g = f32[4] all-gather(%p), dimensions={0}, '
              'replica_groups=[2,2]<=[4]\n}\n')
    assert hlo_mod.dynamic_shape_instrs(stable) == []
    dyn = stable.replace("f32[4] all-gather", "f32[<=4] all-gather")
    assert [n for n, _ in hlo_mod.dynamic_shape_instrs(dyn)] == ["g"]


def test_findings_helpers():
    f1 = Finding("HLO-BWD-COUNT", INFO, "fine")
    f2 = Finding("HLO-DONATION", ERROR, "bad", "entry")
    assert errors([f1, f2]) == [f2]
    assert worst_severity([f1]) == INFO
    assert worst_severity([f1, f2]) == ERROR
    assert SEVERITIES.index(ERROR) < SEVERITIES.index(INFO)
    d = f2.to_dict()
    assert d == {"rule": "HLO-DONATION", "severity": ERROR,
                 "message": "bad", "location": "entry"}


def test_rule_catalog_is_closed():
    # every rule id the passes can emit is documented in the catalog
    assert set(RULES) == {
        "JAXPR-CLIP-PATH", "JAXPR-NOISE-ONCE", "JAXPR-KEY-LINEAGE",
        "HLO-COLL-LEAK", "HLO-BWD-COUNT", "HLO-DONATION",
        "HLO-SHAPE-STABLE"}
    for rid, (sev, invariant) in RULES.items():
        assert sev in SEVERITIES and invariant
    assert set(audit_mod.MUTATIONS.values()) <= set(RULES)


def test_hlo_analysis_shim_reexports():
    # satellite (a): launch.hlo_analysis moved to analysis.hlo; the shim
    # must keep every public name importable for older callers
    from repro.launch import hlo_analysis as shim
    for name in ("analyze_hlo", "backward_passes", "classify_collectives",
                 "filter_model_norm_rows", "entry_aliases",
                 "dynamic_shape_instrs", "HloAnalyzer", "Totals"):
        assert getattr(shim, name) is getattr(hlo_mod, name), name
