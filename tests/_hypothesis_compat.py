"""Deterministic fallback for `hypothesis` when it is not installed.

The container image lacks hypothesis; `pytest.importorskip` at module level
would skip entire files including their deterministic tests. Instead each
test module guards its import:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

and this shim keeps the property-style tests running: `given` draws a fixed
number of pseudo-random examples from a seed derived from the test name
(stable across runs and processes — no PYTHONHASHSEED dependence). Only the
strategy surface this repo uses is implemented (integers, floats). With
real hypothesis installed the shim is never imported.
"""
from __future__ import annotations

import functools
import random
import zlib

# keep example counts CI-friendly: shrinking/replay don't exist here, so
# large example counts only cost time without buying minimization
_MAX_EXAMPLES_CAP = 8


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


st = _Strategies()


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed = zlib.crc32(fn.__qualname__.encode())
            rnd = random.Random(seed)
            n = min(getattr(wrapper, "_max_examples", 10), _MAX_EXAMPLES_CAP)
            for _ in range(n):
                drawn = [s.draw(rnd) for s in strategies]
                fn(*args, *drawn, **kwargs)

        # pytest resolves fixtures from the (followed) signature; without
        # this it would treat the drawn parameters as fixture requests
        del wrapper.__wrapped__
        wrapper._max_examples = 10
        return wrapper

    return deco
