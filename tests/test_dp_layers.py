"""Clip-in-backprop primitives vs per-example jacrev oracles — the core
correctness contract of the paper's fused per-layer clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dp_layers as dpl
from repro.core import lora
from repro.core.clipping import dp_clipped_gradients
from repro.core.spec import GroupLayout, P, init_params

B, T = 6, 5


def _model():
    spec = {
        "emb": {"w": P((50, 8), init="embed")},
        "l1": {"w": P((8, 16)), "b": P((16,), init="zeros")},
        "norm": {"s": P((16,), init="ones")},
        "l2": {"w": P((16, 4))},
    }
    layout = GroupLayout(spec)
    params = init_params(spec, jax.random.PRNGKey(0))

    def loss_fn(p, batch, th):
        ids, y = batch
        x = dpl.dp_embed(p["emb"]["w"], ids, th["emb"])
        x = dpl.dp_linear(p["l1"]["w"], p["l1"]["b"], x, th["l1"])
        x = jnp.tanh(x)
        mu = jnp.mean(x * x, -1, keepdims=True)
        x = dpl.dp_scale(p["norm"]["s"], x * jax.lax.rsqrt(mu + 1e-6),
                         th["norm"])
        x = dpl.dp_linear(p["l2"]["w"], None, x, th["l2"])
        logits = jnp.mean(x, axis=1)
        return -jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y]

    ids = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, 50)
    y = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, 4)
    return spec, layout, params, loss_fn, (ids, y)


@pytest.fixture(scope="module")
def model():
    spec, layout, params, loss_fn, batch = _model()
    inf = layout.pack_value(jnp.inf, B)
    jac = jax.jacrev(lambda p: loss_fn(p, batch, inf))(params)
    return spec, layout, params, loss_fn, batch, jac


PATHS = {"emb": [("emb", "w")], "l1": [("l1", "w"), ("l1", "b")],
         "l2": [("l2", "w")], "norm": [("norm", "s")]}


def _leaf(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def _oracle_norms(jac):
    out = {}
    for g, plist in PATHS.items():
        n = jnp.zeros(B)
        for pth in plist:
            gg = _leaf(jac, pth).reshape(B, -1)
            n = n + jnp.sum(gg * gg, -1)
        out[g] = n
    return out


def test_per_layer_matches_oracle(model):
    spec, layout, params, loss_fn, batch, jac = model
    oracle = _oracle_norms(jac)
    C = jnp.array([0.05, 0.02, 0.03, 0.01])
    res = dp_clipped_gradients(loss_fn, params, batch, layout,
                               mode="per_layer", batch_size=B, thresholds=C)
    for i, g in enumerate(layout.groups):
        np.testing.assert_allclose(res.norms_sq[i], oracle[g.name], rtol=2e-4)
        f = jnp.minimum(1.0, C[i] / jnp.sqrt(oracle[g.name] + 1e-12))
        for pth in PATHS[g.name]:
            per_ex = _leaf(jac, pth)
            want = jnp.tensordot(f, per_ex.reshape(B, -1), 1).reshape(
                per_ex.shape[1:])
            np.testing.assert_allclose(_leaf(res.grads, pth), want,
                                       rtol=2e-3, atol=1e-6)


def test_ghost_flat_equals_naive_flat(model):
    spec, layout, params, loss_fn, batch, jac = model
    r1 = dp_clipped_gradients(loss_fn, params, batch, layout,
                              mode="ghost_flat", batch_size=B,
                              flat_threshold=0.05)
    r2 = dp_clipped_gradients(loss_fn, params, batch, layout,
                              mode="naive_flat", batch_size=B,
                              flat_threshold=0.05)
    for a, b in zip(jax.tree_util.tree_leaves(r1.grads),
                    jax.tree_util.tree_leaves(r2.grads)):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-6)


def test_per_group_matches_oracle(model):
    spec, layout, params, loss_fn, batch, jac = model
    oracle = _oracle_norms(jac)
    names = [g.name for g in layout.groups]
    assign = jnp.array([0, 0, 1, 1])
    cg = jnp.array([0.04, 0.03])
    res = dp_clipped_gradients(loss_fn, params, batch, layout,
                               mode="per_group", batch_size=B,
                               group_assignment=assign, group_thresholds=cg)
    gn = [oracle[names[0]] + oracle[names[1]],
          oracle[names[2]] + oracle[names[3]]]
    for i, g in enumerate(layout.groups):
        f = jnp.minimum(1.0, cg[assign[i]] / jnp.sqrt(gn[int(assign[i])]
                                                      + 1e-12))
        for pth in PATHS[g.name]:
            per_ex = _leaf(jac, pth)
            want = jnp.tensordot(f, per_ex.reshape(B, -1), 1).reshape(
                per_ex.shape[1:])
            np.testing.assert_allclose(_leaf(res.grads, pth), want,
                                       rtol=2e-3, atol=1e-6)


def test_clipped_norms_bounded(model):
    """Post-clipping invariant: every per-example per-group contribution has
    norm <= C_k (the DP sensitivity bound)."""
    spec, layout, params, loss_fn, batch, jac = model
    oracle = _oracle_norms(jac)
    C = jnp.array([0.01, 0.01, 0.01, 0.01])
    for i, g in enumerate(layout.groups):
        f = jnp.minimum(1.0, C[i] / jnp.sqrt(oracle[g.name] + 1e-12))
        clipped_norm = f * jnp.sqrt(oracle[g.name])
        assert bool(jnp.all(clipped_norm <= C[i] * (1 + 1e-4)))


def test_unclipped_input_cotangent(model):
    """Algorithm 1 line 11: the INPUT cotangent must be the unclipped one —
    per_layer grads at C=inf equal plain grads."""
    spec, layout, params, loss_fn, batch, jac = model
    inf_th = jnp.full((layout.num_groups,), jnp.inf)
    res = dp_clipped_gradients(loss_fn, params, batch, layout,
                               mode="per_layer", batch_size=B,
                               thresholds=inf_th)
    plain = jax.grad(lambda p: jnp.sum(loss_fn(
        p, batch, layout.pack_value(jnp.inf, B))))(params)
    for a, b in zip(jax.tree_util.tree_leaves(res.grads),
                    jax.tree_util.tree_leaves(plain)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)


def test_expert_linear_vs_oracle():
    """Exact per-example clipping through MoE token mixing."""
    E, C, din, dout, bsz = 3, 8, 5, 4, 4
    key = jax.random.PRNGKey(7)
    w = jax.random.normal(key, (E, din, dout)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (E, C, din))
    exids = jax.random.randint(jax.random.fold_in(key, 2), (E, C), -1, bsz)
    x = x * (exids >= 0)[..., None]  # empty slots carry zeros
    cth = jnp.full((E, bsz), 0.4)

    def loss(w_, c_):
        y = dpl.dp_expert_linear(w_, x, exids, c_)
        return jnp.sum(y**2)

    grads, norms = jax.grad(loss, argnums=(0, 1))(w, cth)
    # oracle: per-example grad of expert e = sum over its slots with ex=i
    for e in range(E):
        for i in range(bsz):
            mask = (np.asarray(exids[e]) == i).astype(np.float32)
            ge = jax.grad(lambda w_: jnp.sum(
                (x[e] @ w_) ** 2 * mask[:, None]))(w[e])
            n_oracle = float(jnp.sum(ge**2))
            np.testing.assert_allclose(float(norms[e, i]), n_oracle,
                                       rtol=1e-3, atol=1e-5)
    # clipped sum
    for e in range(E):
        want = np.zeros((din, dout), np.float32)
        for i in range(bsz):
            mask = (np.asarray(exids[e]) == i).astype(np.float32)
            ge = jax.grad(lambda w_: jnp.sum(
                (x[e] @ w_) ** 2 * mask[:, None]))(w[e])
            f = min(1.0, 0.4 / float(jnp.sqrt(jnp.sum(ge**2) + 1e-12)))
            want += f * np.asarray(ge)
        np.testing.assert_allclose(np.asarray(grads[e]), want, rtol=2e-3,
                                   atol=1e-5)


def test_lora_pair_is_one_group():
    key = jax.random.PRNGKey(3)
    din, dout, r, alpha = 10, 6, 3, 8.0
    w = jax.random.normal(key, (din, dout)) * 0.3
    a = jax.random.normal(jax.random.fold_in(key, 1), (din, r)) * 0.2
    bmat = jax.random.normal(jax.random.fold_in(key, 2), (r, dout)) * 0.2
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, 7, din))

    def loss(ab, c):
        out = lora.dp_lora_linear(ab["a"], ab["b"], w, x, c, alpha)
        return jnp.sum(out**2, axis=(1, 2))

    cvec = jnp.full((4,), 0.5)
    grads, nrm = jax.grad(lambda ab, c: loss(ab, c).sum(),
                          argnums=(0, 1))({"a": a, "b": bmat}, cvec)

    def single(ab, xi):
        out = xi @ w + (xi @ ab["a"]) @ ab["b"] * (alpha / r)
        return jnp.sum(out**2)

    jac = jax.vmap(jax.grad(single), in_axes=(None, 0))({"a": a, "b": bmat}, x)
    n_o = (jnp.sum(jac["a"].reshape(4, -1) ** 2, -1)
           + jnp.sum(jac["b"].reshape(4, -1) ** 2, -1))
    np.testing.assert_allclose(nrm, n_o, rtol=1e-4)
    f = jnp.minimum(1, 0.5 / jnp.sqrt(n_o + 1e-12))
    np.testing.assert_allclose(
        grads["a"], jnp.tensordot(f, jac["a"].reshape(4, -1), 1).reshape(a.shape),
        rtol=1e-4)


def test_blocked_linear_blocks_sum_to_full():
    key = jax.random.PRNGKey(9)
    w = jax.random.normal(key, (6, 8)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, 5, 6))

    def loss_blk(w_, c_):
        y = dpl.dp_linear_blocked(w_, None, x, c_, "out")
        return jnp.sum(y**2)

    cth = jnp.full((3, 4), jnp.inf)  # 4 blocks, no clipping
    g_blk, n_blk = jax.grad(loss_blk, argnums=(0, 1))(w, cth)
    g_plain = jax.grad(lambda w_: jnp.sum((x @ w_) ** 2))(w)
    np.testing.assert_allclose(g_blk, g_plain, rtol=1e-4)
    # block norms sum to the full-layer norms
    def loss_full(w_, c_):
        y = dpl.dp_linear(w_, None, x, c_)
        return jnp.sum(y**2)
    _, n_full = jax.grad(loss_full, argnums=(0, 1))(
        w, jnp.full((3,), jnp.inf))
    np.testing.assert_allclose(jnp.sum(n_blk, -1), n_full, rtol=1e-4)
