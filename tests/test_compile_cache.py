"""Persistent compile cache (repro.launch.compile_cache): manifest/sweep
integrity discipline (corrupt entries deleted and rebuilt warm, never a
crash), jax-version staleness, the semantic program index, and the
acceptance bar — cached and uncached executions are BITWISE identical for
the DP train step and the decode engine."""
import hashlib
import json
import os
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import compile_cache as cc


@pytest.fixture
def cache_off():
    """Guarantee the process-global jax cache config is restored."""
    yield
    cc.disable()


def _valid_blob(data=b"fake executable"):
    """Bytes in jax's on-disk entry format (compressed, time-framed) —
    what a COMPLETE write leaves. Adoption decode-validates, so fakes
    must be decodable."""
    from jax._src import compilation_cache as jcc
    return jcc.compress_executable(jcc.combine_executable_and_time(data, 1))


def _fake_entry(dirpath, name, blob=None):
    os.makedirs(dirpath, exist_ok=True)
    path = os.path.join(dirpath, name + "-cache")
    with open(path, "wb") as fh:
        fh.write(_valid_blob(name.encode()) if blob is None else blob)
    return path


# ---------------------------------------------------------------------------
# Semantic program key / index.
# ---------------------------------------------------------------------------


def test_program_key_stable_and_order_independent():
    a = cc.program_key(entry="train", arch="tiny", mesh="none")
    b = cc.program_key(mesh="none", arch="tiny", entry="train")
    assert a == b
    assert cc.program_key(entry="serve", arch="tiny", mesh="none") != a
    assert cc.program_key(entry="train", arch="tiny", mesh="none",
                          jax_version="0.0.0") != a


def test_record_program_round_trip(tmp_path):
    root = str(tmp_path)
    key = cc.record_program({"entry": "train", "arch": "tiny"}, root=root)
    cc.record_program({"entry": "train", "arch": "tiny"}, root=root)
    cc.record_program({"entry": "serve", "arch": "tiny"}, root=root)
    progs = cc.warmed_programs(root)
    assert progs[key]["runs"] == 2
    assert progs[key]["parts"]["entry"] == "train"
    assert len(progs) == 2


def test_record_program_survives_torn_index(tmp_path):
    root = str(tmp_path)
    os.makedirs(cc.compile_dir(root), exist_ok=True)
    open(os.path.join(cc.compile_dir(root), "programs.json"),
         "w").write("{torn")
    assert cc.record_program({"entry": "train"}, root=root) is not None
    assert len(cc.warmed_programs(root)) == 1


# ---------------------------------------------------------------------------
# Sweep: adopt / keep / drop-corrupt / drop-missing / stale-jax wipe.
# ---------------------------------------------------------------------------


def test_sweep_adopts_then_keeps(tmp_path):
    d = str(tmp_path / "compile")
    _fake_entry(d, "aaa")
    _fake_entry(d, "bbb")
    stats = cc.sweep(d)
    assert stats == {"kept": 0, "adopted": 2, "dropped_corrupt": 0,
                     "dropped_missing": 0, "wiped_stale_jax": 0}
    stats = cc.sweep(d)  # idempotent second pass: everything known
    assert stats["kept"] == 2 and stats["adopted"] == 0


def test_sweep_deletes_corrupt_entry_for_warm_rebuild(tmp_path):
    d = str(tmp_path / "compile")
    good = _fake_entry(d, "good")
    bad = _fake_entry(d, "bad")
    open(bad[:-len("-cache")] + "-atime", "w").write("0")
    cc.sweep(d)
    with open(bad, "ab") as fh:  # bit rot after the manifest was written
        fh.write(b"XXXX")
    stats = cc.sweep(d)
    assert stats["dropped_corrupt"] == 1 and stats["kept"] == 1
    assert not os.path.exists(bad)  # jax recompiles warm, no per-start warn
    assert not os.path.exists(bad[:-len("-cache")] + "-atime")
    assert os.path.exists(good)
    # the corrupt entry is gone from the manifest too, not double-counted
    stats = cc.sweep(d)
    assert stats == {"kept": 1, "adopted": 0, "dropped_corrupt": 0,
                     "dropped_missing": 0, "wiped_stale_jax": 0}


def test_sweep_never_adopts_torn_entry(tmp_path):
    """A process killed mid-write (jax's entry write is NOT atomic — the
    service fault injection hits this for real) leaves a truncated
    compressed stream. Adopting it would hand XLA's C++ deserializer
    bytes that ABORT the process, so the sweep must delete it instead;
    the executable then rebuilds warm."""
    d = str(tmp_path / "compile")
    whole = _valid_blob(b"compiled program")
    _fake_entry(d, "ok")
    torn = _fake_entry(d, "torn", blob=whole[: len(whole) // 2])
    open(torn[:-len("-cache")] + "-atime", "wb").write(b"\0" * 8)
    stats = cc.sweep(d)
    assert stats["dropped_corrupt"] == 1 and stats["adopted"] == 1
    assert not os.path.exists(torn)
    assert not os.path.exists(torn[:-len("-cache")] + "-atime")
    stats = cc.sweep(d)  # gone from the manifest, not double-counted
    assert stats == {"kept": 1, "adopted": 0, "dropped_corrupt": 0,
                     "dropped_missing": 0, "wiped_stale_jax": 0}


def test_sweep_drops_vanished_entries(tmp_path):
    d = str(tmp_path / "compile")
    keep = _fake_entry(d, "keep")
    gone = _fake_entry(d, "gone")
    cc.sweep(d)
    os.unlink(gone)
    stats = cc.sweep(d)
    assert stats["dropped_missing"] == 1 and stats["kept"] == 1
    assert os.path.exists(keep)


def test_sweep_rebuilds_torn_manifest_by_adoption(tmp_path):
    d = str(tmp_path / "compile")
    _fake_entry(d, "aaa")
    cc.sweep(d)
    open(os.path.join(d, "manifest.json"), "w").write("{torn json")
    stats = cc.sweep(d)  # never a crash; files re-adopted
    assert stats["adopted"] == 1 and stats["kept"] == 0
    stats = cc.sweep(d)
    assert stats["kept"] == 1


def test_sweep_wipes_entries_from_another_jax(tmp_path):
    d = str(tmp_path / "compile")
    _fake_entry(d, "old")
    open(os.path.join(d, "old-atime"), "w").write("0")
    # a manifest legitimately written (crc OK) by a different jax version
    payload = {"version": cc.MANIFEST_VERSION, "jax_version": "0.0.0",
               "entries": {"old-cache": 123}}
    blob = json.dumps(payload, sort_keys=True)
    json.dump({"crc32": zlib.crc32(blob.encode()), **payload},
              open(os.path.join(d, "manifest.json"), "w"))
    stats = cc.sweep(d)
    assert stats["wiped_stale_jax"] == 2  # entry + its atime companion
    assert not os.path.exists(os.path.join(d, "old-cache"))
    # fresh entries written under THIS jax adopt cleanly afterwards
    _fake_entry(d, "new")
    assert cc.sweep(d)["adopted"] == 1


# ---------------------------------------------------------------------------
# enable(): end-to-end against the real jax cache, corruption included.
# ---------------------------------------------------------------------------


def test_enable_populates_and_survives_corruption(tmp_path, cache_off):
    root = str(tmp_path)
    assert cc.enable(root) == cc.compile_dir(root)
    assert cc.enabled_dir() == cc.compile_dir(root)

    def f(x):
        return jnp.tanh(x @ x.T).sum()

    x = jnp.ones((64, 64))
    first = jax.jit(f)(x)
    entries = [n for n in os.listdir(cc.compile_dir(root))
               if n.endswith("-cache")]
    assert entries, "persistent cache wrote no entries"
    assert cc.sweep(cc.compile_dir(root))["adopted"] == len(entries)
    # corrupt every entry; re-enable must sweep them out and a fresh trace
    # must still produce the right answer (warm rebuild, no crash)
    for name in entries:
        with open(os.path.join(cc.compile_dir(root), name), "r+b") as fh:
            fh.seek(0)
            fh.write(b"\x00" * 16)
    stats = cc.sweep(cc.compile_dir(root))
    assert stats["dropped_corrupt"] == len(entries)
    assert cc.enable(root) is not None
    again = jax.jit(lambda x: jnp.tanh(x @ x.T).sum())(x)
    np.testing.assert_array_equal(np.asarray(first), np.asarray(again))


def test_enable_is_best_effort_on_unwritable_root(tmp_path, cache_off):
    blocker = tmp_path / "flat"
    blocker.write_text("not a directory")
    assert cc.enable(str(blocker)) is None  # degraded, not raised
    assert cc.enabled_dir() is None


# ---------------------------------------------------------------------------
# Acceptance: cached vs uncached executions are BITWISE identical.
# ---------------------------------------------------------------------------


def _digest(tree) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _train_step_digest():
    """Trace a FRESH tiny DP train step (new closures -> new trace; with
    the cache enabled the compile deserializes from disk) and digest the
    updated params + metrics."""
    from repro import optim
    from repro.configs import get_config
    from repro.core.dp_sgd import DPConfig, make_dp_train_step
    from repro.core.spec import init_params
    from repro.launch.inputs import concrete_train_batch
    from repro.models.transformer import build_model

    cfg = get_config("tiny")
    m = build_model(cfg)
    params = init_params(m.spec, jax.random.PRNGKey(0))
    batch = concrete_train_batch(cfg, 4, 16, jax.random.PRNGKey(1))
    dpc = DPConfig(mode="per_layer", sigma=1.0, sampling_rate=0.1, steps=10,
                   adaptive=True)
    init_fn, step_fn, _ = make_dp_train_step(
        m.loss_fn, m.spec, m.layout, optim.adam(1e-3), dpc, batch_size=4)
    opt_state, dp_state = init_fn(params)
    p2, _, _, met = jax.jit(step_fn)(params, opt_state, dp_state, batch,
                                     jax.random.PRNGKey(5))
    return _digest((p2, met.loss))


def _engine_tokens():
    from repro.configs import get_config
    from repro.core.spec import init_params
    from repro.launch.engine import DecodeEngine
    from repro.launch.inputs import synthetic_requests
    from repro.models.transformer import build_model

    cfg = get_config("tiny")
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    reqs = synthetic_requests(cfg.vocab_size, 2, min_len=1, max_len=6,
                              seed=7)
    eng = DecodeEngine(model, params, num_slots=2, cache_len=32,
                       prefill_chunk=4)
    rids = [eng.submit(r, max_new_tokens=4) for r in reqs]
    done = eng.run()
    return [done[r].tokens for r in rids]


def test_train_step_bitwise_identical_cached_vs_uncached(tmp_path,
                                                         cache_off):
    cold = _train_step_digest()  # uncached baseline
    assert cc.enable(str(tmp_path)) is not None
    compiling = _train_step_digest()  # populates the cache
    warm = _train_step_digest()  # deserializes from it
    assert cold == compiling == warm


def test_engine_decode_bitwise_identical_cached_vs_uncached(tmp_path,
                                                            cache_off):
    cold = _engine_tokens()
    assert cc.enable(str(tmp_path)) is not None
    compiling = _engine_tokens()
    warm = _engine_tokens()
    assert cold == compiling == warm
