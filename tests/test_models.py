"""Model-component correctness: attention blocking, SWA, SSD vs recurrence,
RWKV chunked vs scan, MLA prefill/decode consistency, MoE combine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A
from repro.models import mamba2 as M2
from repro.models import rwkv6 as R6
from repro.models.config import ModelConfig
from repro.models import moe as MOE
from repro.core.spec import GroupLayout, init_params


def test_attend_blocked_matches_single_shot(monkeypatch):
    key = jax.random.PRNGKey(0)
    b, t, h, kv, hd = 2, 300, 4, 2, 16
    q = jax.random.normal(key, (b, t, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    ref = A.attend(q, k, v, pos, pos, causal=True)
    monkeypatch.setattr(A, "_SINGLE_SHOT_MAX", 0)  # force blocked
    monkeypatch.setattr(A, "_QB", 64)
    monkeypatch.setattr(A, "_KB", 128)
    blocked = A.attend(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(blocked, ref, rtol=2e-4, atol=2e-5)


def test_sliding_window_masks_old_tokens():
    key = jax.random.PRNGKey(1)
    b, t, h, hd = 1, 32, 2, 8
    q = jax.random.normal(key, (b, t, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, h, hd))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    win = A.attend(q, k, v, pos, pos, causal=True, window=4)
    # manual: last query attends only to last 4 keys
    scores = jnp.einsum("bhd,bshd->bhs", q[:, -1] / jnp.sqrt(hd), k)
    scores = scores.at[:, :, :-4].set(-1e30)
    want = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(win[:, -1], want, rtol=2e-4, atol=1e-5)


def test_gqa_decode_matches_prefill():
    """Stepping tokens one-by-one through the cache must equal the causal
    prefill attention output at the last position."""
    cfg = get_config("qwen3-4b", reduced=True)
    from repro.models.attention import gqa_spec
    spec = gqa_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(0))
    layout = GroupLayout(spec)
    b, t = 2, 7
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model)) * 0.3
    inf_b = jnp.full((b,), jnp.inf)
    th = {k.name: inf_b for k in layout.groups}
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    full = A.gqa_attention(cfg, params, x, th, pos)
    hd, kvh = cfg.resolved_head_dim, cfg.num_kv_heads
    ck = jnp.zeros((b, 16, kvh, hd))
    cv = jnp.zeros((b, 16, kvh, hd))
    for i in range(t):
        out, ck, cv = A.gqa_decode(cfg, params, x[:, i:i + 1], th, ck, cv,
                                   jnp.full((b,), i, jnp.int32))
    np.testing.assert_allclose(out[:, 0], full[:, -1], rtol=3e-3, atol=3e-4)


def test_mla_decode_matches_prefill():
    cfg = get_config("deepseek-v3-671b", reduced=True)
    spec = A.mla_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(0))
    layout = GroupLayout(spec)
    b, t = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model)) * 0.3
    inf_b = jnp.full((b,), jnp.inf)
    th = {g.name: inf_b for g in layout.groups}
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    full = A.mla_attention(cfg, params, x, th, pos)
    ckv = jnp.zeros((b, 16, cfg.kv_lora_rank))
    krope = jnp.zeros((b, 16, cfg.qk_rope_head_dim))
    for i in range(t):
        out, ckv, krope = A.mla_decode(cfg, params, x[:, i:i + 1], th, ckv,
                                       krope, jnp.full((b,), i, jnp.int32))
    np.testing.assert_allclose(out[:, 0], full[:, -1], rtol=3e-3, atol=3e-4)


def test_ssd_chunked_matches_recurrence():
    """Chunked SSD == naive per-step SSM recurrence."""
    key = jax.random.PRNGKey(2)
    b, t, h, p, n = 2, 37, 3, 4, 5
    xh = jax.random.normal(key, (b, t, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, t, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (b, h)) * 0.3)
    B_ = jax.random.normal(jax.random.fold_in(key, 3), (b, t, n)) * 0.5
    C_ = jax.random.normal(jax.random.fold_in(key, 4), (b, t, n)) * 0.5
    y, sT = M2._ssd_chunked(xh, dt, a, B_, C_, chunk=8)
    # naive recurrence
    s = np.zeros((b, h, p, n), np.float32)
    ys = []
    for step in range(t):
        decay = np.exp(np.asarray(a) * np.asarray(dt[:, step]))  # (b, h)
        upd = np.einsum("bhp,bn,bh->bhpn", np.asarray(xh[:, step]),
                        np.asarray(B_[:, step]), np.asarray(dt[:, step]))
        s = s * decay[:, :, None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", s, np.asarray(C_[:, step])))
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sT), s, rtol=2e-3, atol=2e-4)


def test_rwkv_chunked_matches_scan():
    key = jax.random.PRNGKey(3)
    b, t, h, d = 2, 45, 2, 8
    r = jax.random.normal(key, (b, t, h, d)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, h, d)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, h, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 3),
                                         (b, t, h, d)) + 2.0) * 0.4 + 0.6
    u = jax.random.normal(jax.random.fold_in(key, 4), (b, h, d)) * 0.3
    s0 = jnp.zeros((b, h, d, d))
    o_scan, s_scan = R6._wkv_scan(r, k, v, w, u, s0)
    o_chunk, s_chunk = R6._wkv_chunked(r, k, v, w, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_scan),
                               rtol=3e-3, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_scan),
                               rtol=3e-3, atol=3e-4)


def test_moe_combine_matches_dense_at_high_capacity():
    """With capacity >= tokens, dropping never occurs and the MoE output
    equals the dense gather-free reference."""
    cfg = get_config("granite-moe-3b-a800m", reduced=True)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0, router_aux_coef=0.0)
    spec = MOE.moe_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(0))
    layout = GroupLayout(spec)
    b, t = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model)) * 0.5
    inf = jnp.full((b,), jnp.inf)
    th = {"router": inf,
          "w_gu": jnp.full((cfg.num_experts, b), jnp.inf),
          "w_down": jnp.full((cfg.num_experts, b), jnp.inf)}
    y, aux = MOE.moe_block(cfg, params, x, th)
    # dense reference
    logits = x @ params["router"]["w"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gv, gi = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gv = gv / jnp.sum(gv, -1, keepdims=True)
    f = cfg.moe_d_ff
    want = jnp.zeros_like(x)
    for kk in range(cfg.num_experts_per_tok):
        for e in range(cfg.num_experts):
            mask = (gi[..., kk] == e).astype(x.dtype) * gv[..., kk].astype(x.dtype)
            hgu = x @ params["w_gu"][e]
            act = jax.nn.silu(hgu[..., :f].astype(jnp.float32)).astype(x.dtype) * hgu[..., f:]
            want = want + mask[..., None] * (act @ params["w_down"][e])
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=3e-3,
                               atol=3e-4)


def test_m_rope_sections():
    from repro.models.layers import apply_m_rope, apply_rope
    key = jax.random.PRNGKey(5)
    b, t, h, hd = 2, 9, 2, 16
    x = jax.random.normal(key, (b, t, h, hd))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    pos3 = jnp.broadcast_to(pos[None], (3, b, t))
    # identical position streams across sections == plain rope
    out = apply_m_rope(x, pos3, 10_000.0, (4, 2, 2))
    want = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


def test_moe_grouped_matches_flat_dispatch():
    """§Perf optimization: grouped dispatch == flat dispatch when capacity
    never binds (same routing, same experts, block-diagonal DP norms)."""
    cfg = get_config("granite-moe-3b-a800m", reduced=True)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0, router_aux_coef=0.0)
    spec = MOE.moe_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(0))
    b, t = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model)) * 0.5
    inf = jnp.full((b,), jnp.inf)
    e = cfg.num_experts
    th = {"router": inf, "w_gu": jnp.full((e, b), jnp.inf),
          "w_down": jnp.full((e, b), jnp.inf)}
    y1, _ = MOE.moe_block(cfg, params, x, th)
    y2, _ = MOE.moe_block_grouped(cfg, params, x, th)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-3,
                               atol=3e-4)


def test_grouped_expert_dp_norms_oracle():
    from repro.core import dp_layers as dpl
    e2, c, din, dout, b = 3, 4, 5, 6, 4
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (e2, din, dout)) * 0.3
    xx = jax.random.normal(jax.random.fold_in(key, 1), (b, e2, c, din))
    cth = jnp.full((e2, b), 0.3)

    def loss(w_, c_):
        return jnp.sum(dpl.dp_expert_linear_grouped(w_, xx, c_) ** 2)

    grads, norms = jax.grad(loss, argnums=(0, 1))(w, cth)
    want = np.zeros_like(np.asarray(w))
    for e in range(e2):
        for i in range(b):
            ge = jax.grad(lambda w_: jnp.sum((xx[i, e] @ w_) ** 2))(w[e])
            n_o = float(jnp.sum(ge**2))
            np.testing.assert_allclose(float(norms[e, i]), n_o, rtol=1e-3)
            f = min(1.0, 0.3 / np.sqrt(n_o + 1e-12))
            want[e] += f * np.asarray(ge)
    np.testing.assert_allclose(np.asarray(grads), want, rtol=2e-3, atol=1e-5)
