"""Fault-injection harness for the crash-safe DP training service.

Drives `repro.launch.service.TrainService` through deterministic crashes at
each named injection point and exposes the comparisons the acceptance
criteria need:

  * run a reference (uninterrupted) service to completion,
  * run a faulted service that dies at (point, step) via SimulatedCrash —
    the in-process stand-in for `kill -9`; nothing is cleaned up, the
    on-disk state is exactly what the kill would have left,
  * resume it to completion,
  * digest the durable state (final checkpoint leaf bytes + ledger bytes)
    for bitwise comparison.

tests/test_service.py runs the matrix in tier-1; scripts/ci.sh runs the
same points as real `os._exit` kills through the service CLI (--fault-at).
A single jitted runtime is shared across all runs (the model, corpus, and
compiled step are deterministic and state-free), so the matrix pays one
compile.
"""
from __future__ import annotations

import hashlib
import os

import jax
import numpy as np

from repro.checkpoint.store import (
    latest_verified_step, load_latest_checkpoint, load_manifest)
from repro.core.spec import init_params
from repro.launch import service as svc_mod
from repro.launch.service import (
    FaultInjector, PrivacyLedger, ServiceRuntime, SimulatedCrash,
    TrainService, build_service_parser)

TINY_ARGV = [
    "--arch", "tiny", "--steps", "8", "--batch", "8", "--seq", "32",
    "--docs", "64", "--sigma", "0.8", "--checkpoint-every", "3",
    "--log-every", "100",
]


def make_args(service_dir: str, **overrides):
    """Service args over tiny defaults; overrides are flag names with
    underscores (steps=12, budget_eps=3.5, ...)."""
    argv = ["--service-dir", service_dir] + list(TINY_ARGV)
    for k, v in overrides.items():
        argv += [f"--{k.replace('_', '-')}", str(v)]
    return build_service_parser().parse_args(argv)


def shared_runtime(args) -> ServiceRuntime:
    return svc_mod.build_runtime(args)


def run_service(args, runtime, *, fault: FaultInjector | None = None):
    """One service incarnation. Returns ("complete", status) or
    ("crashed", point@step) or ("budget_exhausted", msg)."""
    svc = TrainService(args, runtime=runtime, fault=fault, sleep=lambda _: None)
    try:
        status = svc.run()
    except SimulatedCrash as e:
        return "crashed", str(e)
    except svc_mod.BudgetExhausted as e:
        return "budget_exhausted", str(e)
    return "complete", status


def run_with_crash_and_resume(args, runtime, point: str, step: int):
    """Crash at (point, step), then resume to completion. Returns the crash
    tag so callers can assert the fault actually fired."""
    outcome, tag = run_service(
        args, runtime, fault=FaultInjector(point=point, step=step,
                                           mode="raise"))
    assert outcome == "crashed", f"fault {point}@{step} never fired: {outcome}"
    outcome2, status = run_service(args, runtime)
    assert outcome2 == "complete", f"resume failed: {status}"
    return tag, status


def state_digest(service_dir: str) -> dict:
    """Bitwise fingerprint of the durable state: every leaf of the newest
    verified checkpoint, the sampler snapshot, and the raw ledger bytes."""
    ckpt_dir = os.path.join(service_dir, "ckpt")
    step = latest_verified_step(ckpt_dir)
    assert step is not None, f"no verified checkpoint under {ckpt_dir}"
    manifest = load_manifest(ckpt_dir, step)
    h = hashlib.sha256()
    codec = manifest["codec"]
    suffix = {"zstd": ".bin.zst", "zlib": ".bin.zz"}[codec]
    for i in range(manifest["num_shards"]):
        with open(os.path.join(ckpt_dir, f"step_{step:08d}",
                               f"shard_{i:04d}{suffix}"), "rb") as f:
            h.update(f.read())
    with open(os.path.join(service_dir, "ledger.jsonl"), "rb") as f:
        ledger_bytes = f.read()
    return {
        "step": step,
        "shards_sha": h.hexdigest(),
        "sampler": manifest["meta"]["sampler"],
        "epsilon": manifest["meta"]["epsilon"],
        "ledger_sha": hashlib.sha256(ledger_bytes).hexdigest(),
        "ledger_records": len([l for l in ledger_bytes.splitlines() if l]),
    }


def load_final_tree(args, runtime, service_dir: str):
    """The newest verified checkpoint's pytree (for leaf-level diffs)."""
    params0 = init_params(runtime.model.spec, jax.random.PRNGKey(runtime.seed))
    opt0, dp0 = runtime.init_fn(params0)
    found = load_latest_checkpoint(
        os.path.join(service_dir, "ckpt"),
        {"params": params0, "opt_state": opt0, "dp_state": dp0})
    assert found is not None
    return found


def assert_trees_bitwise_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype and xa.shape == ya.shape
        assert xa.tobytes() == ya.tobytes(), "leaf differs bitwise"


def ledger_records(service_dir: str) -> list[dict]:
    return PrivacyLedger(os.path.join(service_dir, "ledger.jsonl")).replay()


def committed_steps(service_dir: str) -> int:
    step = latest_verified_step(os.path.join(service_dir, "ckpt"))
    return 0 if step is None else step
