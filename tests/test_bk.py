"""Book-keeping (BK) engine: single-backprop flat/group clipping.

Contract under test (repro.core.bk + kernels/bk.py):
  * bk ≡ twopass — clipped grads AND per-group norms² identical for
    ghost_flat and per_group, including microbatch accumulation and the
    DP-LoRA trainable_key path;
  * the scale_contract Pallas kernel matches its jnp oracle;
  * the compiled HLO really contains ONE backward pass under execution=bk
    and TWO under twopass (launch.hlo_analysis.backward_passes);
  * unsupported layouts (shared-site params) fall back to twopass;
  * naive_flat reports real per-layout-group norms².
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config
from repro.core import bk
from repro.core.clipping import dp_clipped_gradients
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import abstract_params, init_params
from repro.launch.inputs import concrete_train_batch
from repro.models.transformer import build_model

B, T = 8, 16


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny")
    m = build_model(cfg)
    params = init_params(m.spec, jax.random.PRNGKey(0))
    batch = concrete_train_batch(cfg, B, T, jax.random.PRNGKey(1))
    return cfg, m, params, batch


def _assert_trees_close(a, b, rtol=2e-4, atol=2e-6):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                                   atol=atol)


# ---------------------------------------------------------------------------
# bk ≡ twopass on the tiny transformer (scanned stacks, embed, head, norms).
# ---------------------------------------------------------------------------


def test_probe_captures_tiny_layout(tiny):
    cfg, m, params, batch = tiny
    rec = bk.probe_recipes(m.loss_fn, params, batch, m.layout, B)
    assert rec is not None
    kinds = {r.kind for r in rec.values()}
    assert {"linear", "embed", "scale"} <= kinds
    assert all(r.count == 1 for r in rec.values())


def test_ghost_flat_bk_equals_twopass(tiny):
    cfg, m, params, batch = tiny
    r_bk = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                mode="ghost_flat", batch_size=B,
                                flat_threshold=0.5, execution="bk")
    r_tp = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                mode="ghost_flat_twopass", batch_size=B,
                                flat_threshold=0.5)
    np.testing.assert_allclose(np.asarray(r_bk.norms_sq),
                               np.asarray(r_tp.norms_sq), rtol=1e-5,
                               atol=1e-8)
    _assert_trees_close(r_bk.grads, r_tp.grads)


def test_per_group_bk_equals_twopass(tiny):
    cfg, m, params, batch = tiny
    assign = jnp.array([i % 2 for i in range(m.layout.num_groups)])
    cg = jnp.array([0.3, 0.4])
    kw = dict(mode="per_group", batch_size=B, group_assignment=assign,
              group_thresholds=cg)
    r_bk = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                execution="bk", **kw)
    r_tp = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                execution="twopass", **kw)
    np.testing.assert_allclose(np.asarray(r_bk.norms_sq),
                               np.asarray(r_tp.norms_sq), rtol=1e-5,
                               atol=1e-8)
    _assert_trees_close(r_bk.grads, r_tp.grads)


def test_bk_microbatched_step_equals_twopass(tiny):
    """Full jitted train step, microbatches > 1: same key -> same noise, so
    any parameter difference comes from the clipped grads."""
    cfg, m, params, batch = tiny
    outs = []
    for execution in ("bk", "twopass"):
        dpc = DPConfig(mode="ghost_flat", sigma=1.0, sampling_rate=0.1,
                       steps=10, adaptive=True, microbatches=4,
                       execution=execution)
        init_fn, step_fn, _ = make_dp_train_step(
            m.loss_fn, m.spec, m.layout, optim.sgd(0.1), dpc, batch_size=B)
        opt_state, dp_state = init_fn(params)
        p2, _, _, met = jax.jit(step_fn)(params, opt_state, dp_state, batch,
                                         jax.random.PRNGKey(5))
        assert np.isfinite(float(met.loss))
        outs.append(p2)
    _assert_trees_close(outs[0], outs[1], rtol=2e-4, atol=2e-6)


def test_bk_lora_trainable_key_equals_twopass():
    cfg = dataclasses.replace(get_config("qwen3-4b", reduced=True),
                              lora_rank=4)
    m = build_model(cfg)
    params = init_params(m.spec, jax.random.PRNGKey(0))
    batch = concrete_train_batch(cfg, 4, T, jax.random.PRNGKey(1))
    kw = dict(mode="ghost_flat", batch_size=4, flat_threshold=0.5,
              trainable_key="lora")
    r_bk = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                execution="bk", **kw)
    r_tp = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                execution="twopass", **kw)
    np.testing.assert_allclose(np.asarray(r_bk.norms_sq),
                               np.asarray(r_tp.norms_sq), rtol=1e-5,
                               atol=1e-8)
    assert set(r_bk.grads) == {"lora"}
    _assert_trees_close(r_bk.grads, r_tp.grads)


def test_bk_falls_back_on_shared_site_params():
    """Zamba2's shared attention block (sensitivity_mult > 1) cannot be
    captured — one threshold leaf, many runtime sites — so the probe must
    refuse and the driver must still produce twopass-correct results."""
    cfg = get_config("zamba2-7b", reduced=True)
    m = build_model(cfg)
    params = init_params(m.spec, jax.random.PRNGKey(0))
    batch = concrete_train_batch(cfg, 2, 8, jax.random.PRNGKey(1))
    assert bk.probe_recipes(m.loss_fn, params, batch, m.layout, 2) is None
    r_bk = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                mode="ghost_flat", batch_size=2,
                                flat_threshold=0.5, execution="bk")
    r_tp = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                mode="ghost_flat_twopass", batch_size=2,
                                flat_threshold=0.5)
    _assert_trees_close(r_bk.grads, r_tp.grads)


# ---------------------------------------------------------------------------
# The epilogue kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(2, 4, 300, 65, 130), (1, 3, 17, 8, 5),
                                   (3, 2, 256, 130, 64)])
def test_scale_contract_kernel_matches_ref(shape):
    from repro.kernels.bk import scale_contract
    from repro.kernels.ref import scale_contract_ref
    s, b, t, di, do = shape
    k = jax.random.PRNGKey(0)
    a = jax.random.normal(jax.random.fold_in(k, 1), (s, b, t, di))
    g = jax.random.normal(jax.random.fold_in(k, 2), (s, b, t, do))
    f = jax.random.uniform(jax.random.fold_in(k, 3), (s, b))
    got = scale_contract(a, g, f, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(scale_contract_ref(a, g, f)),
                               rtol=2e-5, atol=1e-4)


def test_scale_contract_backend_op_parity():
    from repro.kernels import backend
    k = jax.random.PRNGKey(7)
    a = jax.random.normal(jax.random.fold_in(k, 1), (2, 3, 40, 20))
    g = jax.random.normal(jax.random.fold_in(k, 2), (2, 3, 40, 9))
    f = jax.random.uniform(jax.random.fold_in(k, 3), (2, 3))
    with backend.scoped("pallas", interpret=True):
        got = backend.active().scale_contract(a, g, f)
    with backend.scoped("xla"):
        want = backend.active().scale_contract(a, g, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=1e-4)
    # unstacked 3-D form routes through clipped_sum_linear semantics
    with backend.scoped("xla"):
        got3 = backend.active().scale_contract(a[0], g[0], f[0])
    np.testing.assert_allclose(np.asarray(got3), np.asarray(want[0]),
                               rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# The win is asserted from the compiled HLO, not assumed.
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_hlo_reports_single_backward_pass_under_bk():
    from repro.launch.hlo_analysis import backward_passes
    cfg = dataclasses.replace(get_config("tiny"), num_layers=4)
    m = build_model(cfg)
    params = abstract_params(m.spec)
    batch = jax.eval_shape(
        lambda k: concrete_train_batch(cfg, B, T, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    counts = {}
    for execution in ("bk", "twopass"):
        dpc = DPConfig(mode="ghost_flat", sigma=1.0, sampling_rate=0.1,
                       steps=10, execution=execution, backend="xla")
        init_fn, step_fn, _ = make_dp_train_step(
            m.loss_fn, m.spec, m.layout, optim.adam(1e-3), dpc,
            batch_size=B)
        opt_abs, dp_abs = jax.eval_shape(init_fn, params)
        hlo = jax.jit(step_fn).lower(params, opt_abs, dp_abs, batch,
                                     key).compile().as_text()
        counts[execution] = backward_passes(hlo, 4)
    assert counts == {"bk": 1, "twopass": 2}


# ---------------------------------------------------------------------------
# naive_flat now reports real per-layout-group norms².
# ---------------------------------------------------------------------------


def test_naive_flat_reports_per_group_norms(tiny):
    cfg, m, params, batch = tiny
    r_naive = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                   mode="naive_flat", batch_size=B,
                                   flat_threshold=0.5)
    r_ghost = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                   mode="ghost_flat", batch_size=B,
                                   flat_threshold=0.5)
    assert r_naive.norms_sq.shape == (m.layout.num_groups, B)
    np.testing.assert_allclose(np.asarray(r_naive.norms_sq),
                               np.asarray(r_ghost.norms_sq), rtol=2e-3,
                               atol=1e-6)
