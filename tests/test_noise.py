"""Noise allocation strategies (paper Sec 3.3 + Appendix E)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import noise as N


def test_global_strategy_total_norm():
    # V_G ∝ (sum C_k^2) * (sum d_k)
    c = jnp.array([1.0, 2.0, 3.0])
    d = jnp.array([10.0, 20.0, 30.0])
    v = N.total_noise_sq_norm("global", c, d, sigma_new=1.0)
    want = float(jnp.sum(c**2) * jnp.sum(d))
    assert abs(float(v) - want) / want < 1e-6


def test_equal_budget_total_norm():
    # V_E ∝ K * sum d_k C_k^2
    c = jnp.array([1.0, 2.0, 3.0])
    d = jnp.array([10.0, 20.0, 30.0])
    v = N.total_noise_sq_norm("equal_budget", c, d, sigma_new=1.0)
    want = float(len(c) * jnp.sum(d * c**2))
    assert abs(float(v) - want) / want < 1e-6


def test_weighted_total_norm():
    c = jnp.array([1.0, 2.0])
    d = jnp.array([4.0, 9.0])
    v = N.total_noise_sq_norm("weighted", c, d, sigma_new=1.0)
    want = float(jnp.sum(d) * jnp.sum(c**2))
    assert abs(float(v) - want) / want < 1e-6


def test_equal_budget_is_communication_free():
    """Per-device clipping property: each group's std depends only on its
    OWN threshold (and K), never on other groups' thresholds."""
    d = jnp.array([10.0, 20.0, 30.0])
    c1 = jnp.array([1.0, 2.0, 3.0])
    c2 = jnp.array([1.0, 99.0, 3.0])  # perturb group 1 only
    s1 = N.group_noise_stds("equal_budget", c1, d, 1.0)
    s2 = N.group_noise_stds("equal_budget", c2, d, 1.0)
    np.testing.assert_allclose(s1[0], s2[0], rtol=1e-6)
    np.testing.assert_allclose(s1[2], s2[2], rtol=1e-6)
    # global strategy does NOT have this property
    g1 = N.group_noise_stds("global", c1, d, 1.0)
    g2 = N.group_noise_stds("global", c2, d, 1.0)
    assert not np.allclose(g1[0], g2[0])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.floats(0.5, 2.0))
def test_sensitivity_identity(k, scale):
    """S*gamma_k inequality: global noise std = sigma * sqrt(sum C^2)."""
    c = jnp.arange(1.0, k + 1) * scale
    d = jnp.ones(k) * 7
    stds = N.group_noise_stds("global", c, d, 2.0)
    want = 2.0 * float(jnp.sqrt(jnp.sum(c**2)))
    np.testing.assert_allclose(np.asarray(stds), want, rtol=1e-5)


def test_add_gaussian_noise_stat():
    grads = {"a": {"w": jnp.zeros((200, 50))}, "b": {"w": jnp.zeros((100,))}}
    gids = {"a": {"w": 0}, "b": {"w": 1}}
    stds = jnp.array([2.0, 0.5])
    out = N.add_gaussian_noise(grads, gids, stds, jax.random.PRNGKey(0))
    sa = float(jnp.std(out["a"]["w"]))
    sb = float(jnp.std(out["b"]["w"]))
    assert abs(sa - 2.0) < 0.1
    assert abs(sb - 0.5) < 0.1


# ---------------------------------------------------------------------------
# PRNG leaf-key collision gate (ISSUE 9 satellite): two parameter paths
# folding to the same 31-bit key hash would draw IDENTICAL noise, which
# silently voids the Gaussian mechanism. The pair below is a REAL crc32
# collision (found by search): stable_hash('brjcykot') ==
# stable_hash('nbpitdgr'), so both the '/'-joined plan-build hash and the
# per-segment polynomial used for fold constants collide.
# ---------------------------------------------------------------------------

_COLLIDING = ("g/brjcykot/w", "g/nbpitdgr/w")


def test_collision_pair_is_real():
    from repro.core.spec import stable_hash
    assert stable_hash("brjcykot") == stable_hash("nbpitdgr") == 475959702
    a, b = _COLLIDING
    assert stable_hash(a) == stable_hash(b) == 1816530066
    assert N._leaf_key_hash_str(a) == N._leaf_key_hash_str(b)
    # control: the gate is not trigger-happy on ordinary distinct names
    assert stable_hash("g/attn/w") != stable_hash("g/mlp/w")


def test_check_leaf_key_collisions_names_both_paths():
    import pytest
    with pytest.raises(ValueError) as exc:
        N.check_leaf_key_collisions(list(_COLLIDING))
    msg = str(exc.value)
    assert _COLLIDING[0] in msg and _COLLIDING[1] in msg
    assert "collision" in msg
    # the same path twice is dedup, not a collision
    table = N.check_leaf_key_collisions(["g/attn/w", "g/attn/w", "g/mlp/w"])
    assert len(table) == 2


def test_add_gaussian_noise_rejects_colliding_leaves():
    import pytest
    grads = {"g": {"brjcykot": {"w": jnp.zeros((4,))},
                   "nbpitdgr": {"w": jnp.zeros((4,))}}}
    gids = {"g": {"brjcykot": {"w": 0}, "nbpitdgr": {"w": 0}}}
    with pytest.raises(ValueError, match="collision"):
        N.add_gaussian_noise(grads, gids, jnp.ones((1,)),
                             jax.random.PRNGKey(0))


def test_plan_build_rejects_colliding_spec():
    import pytest

    from repro import optim
    from repro.core.dp_sgd import DPConfig, make_dp_train_step
    from repro.core.spec import GroupLayout, P

    spec = {"g": {"brjcykot": {"w": P((4, 4))},
                  "nbpitdgr": {"w": P((4, 4))}}}
    layout = GroupLayout(spec)
    loss = lambda params, batch: 0.0  # noqa: E731 - never traced: gate fires first
    with pytest.raises(ValueError) as exc:
        make_dp_train_step(loss, spec, layout, optim.adam(1e-3),
                           DPConfig(mode="per_layer", sigma=1.0),
                           batch_size=8)
    assert "g/brjcykot/w" in str(exc.value)
    assert "g/nbpitdgr/w" in str(exc.value)
    # non-private training never draws noise: the gate must not block it
    make_dp_train_step(loss, spec, layout, optim.adam(1e-3),
                       DPConfig(mode="non_private", epsilon=None,
                                adaptive=False), batch_size=8)
