"""Noise allocation strategies (paper Sec 3.3 + Appendix E)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import noise as N


def test_global_strategy_total_norm():
    # V_G ∝ (sum C_k^2) * (sum d_k)
    c = jnp.array([1.0, 2.0, 3.0])
    d = jnp.array([10.0, 20.0, 30.0])
    v = N.total_noise_sq_norm("global", c, d, sigma_new=1.0)
    want = float(jnp.sum(c**2) * jnp.sum(d))
    assert abs(float(v) - want) / want < 1e-6


def test_equal_budget_total_norm():
    # V_E ∝ K * sum d_k C_k^2
    c = jnp.array([1.0, 2.0, 3.0])
    d = jnp.array([10.0, 20.0, 30.0])
    v = N.total_noise_sq_norm("equal_budget", c, d, sigma_new=1.0)
    want = float(len(c) * jnp.sum(d * c**2))
    assert abs(float(v) - want) / want < 1e-6


def test_weighted_total_norm():
    c = jnp.array([1.0, 2.0])
    d = jnp.array([4.0, 9.0])
    v = N.total_noise_sq_norm("weighted", c, d, sigma_new=1.0)
    want = float(jnp.sum(d) * jnp.sum(c**2))
    assert abs(float(v) - want) / want < 1e-6


def test_equal_budget_is_communication_free():
    """Per-device clipping property: each group's std depends only on its
    OWN threshold (and K), never on other groups' thresholds."""
    d = jnp.array([10.0, 20.0, 30.0])
    c1 = jnp.array([1.0, 2.0, 3.0])
    c2 = jnp.array([1.0, 99.0, 3.0])  # perturb group 1 only
    s1 = N.group_noise_stds("equal_budget", c1, d, 1.0)
    s2 = N.group_noise_stds("equal_budget", c2, d, 1.0)
    np.testing.assert_allclose(s1[0], s2[0], rtol=1e-6)
    np.testing.assert_allclose(s1[2], s2[2], rtol=1e-6)
    # global strategy does NOT have this property
    g1 = N.group_noise_stds("global", c1, d, 1.0)
    g2 = N.group_noise_stds("global", c2, d, 1.0)
    assert not np.allclose(g1[0], g2[0])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.floats(0.5, 2.0))
def test_sensitivity_identity(k, scale):
    """S*gamma_k inequality: global noise std = sigma * sqrt(sum C^2)."""
    c = jnp.arange(1.0, k + 1) * scale
    d = jnp.ones(k) * 7
    stds = N.group_noise_stds("global", c, d, 2.0)
    want = 2.0 * float(jnp.sqrt(jnp.sum(c**2)))
    np.testing.assert_allclose(np.asarray(stds), want, rtol=1e-5)


def test_add_gaussian_noise_stat():
    grads = {"a": {"w": jnp.zeros((200, 50))}, "b": {"w": jnp.zeros((100,))}}
    gids = {"a": {"w": 0}, "b": {"w": 1}}
    stds = jnp.array([2.0, 0.5])
    out = N.add_gaussian_noise(grads, gids, stds, jax.random.PRNGKey(0))
    sa = float(jnp.std(out["a"]["w"]))
    sb = float(jnp.std(out["b"]["w"]))
    assert abs(sa - 2.0) < 0.1
    assert abs(sb - 0.5) < 0.1
