"""Pallas kernels vs ref.py oracles: shape/dtype sweep in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.clip_reduce import clip_reduce
from repro.kernels.ghost_norm import ghost_norm

SHAPES = [
    (2, 8, 16, 24),
    (3, 300, 130, 70),
    (1, 513, 33, 1100),
    (4, 128, 128, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ghost_norm_kernel(shape, dtype):
    b, t, din, dout = shape
    key = jax.random.PRNGKey(hash(shape) & 0xFFFF)
    a = jax.random.normal(key, (b, t, din)).astype(dtype)
    g = (jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
         ).astype(dtype)
    got = ghost_norm(a, g, bt=128, dk=128)
    want = ref.ghost_norm_ref(a, g)
    rtol = 4e-3 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=rtol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_clip_reduce_kernel(shape, dtype):
    b, t, din, dout = shape
    key = jax.random.PRNGKey(hash(shape) & 0xFFF)
    a = jax.random.normal(key, (b, t, din)).astype(dtype)
    g = (jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
         ).astype(dtype)
    f = jax.random.uniform(jax.random.fold_in(key, 2), (b,))
    got = clip_reduce(a, g, f, bi=128, bj=128, bt=128)
    want = ref.clip_reduce_ref(a, g, f)
    rtol = 4e-3 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=rtol, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 80), st.integers(1, 50),
       st.integers(1, 50))
def test_ghost_norm_property(b, t, din, dout):
    key = jax.random.PRNGKey(b * 997 + t)
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout))
    got = ghost_norm(a, g, bt=32, dk=32)
    want = ref.ghost_norm_ref(a, g)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)
    assert bool(jnp.all(got >= -1e-5))  # norms² are nonnegative


def test_kernel_block_shape_sweep():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2, 200, 96))
    g = jax.random.normal(jax.random.fold_in(key, 1), (2, 200, 64))
    want = ref.ghost_norm_ref(a, g)
    for bt in (32, 64, 256):
        for dk in (32, 128):
            got = ghost_norm(a, g, bt=bt, dk=dk)
            np.testing.assert_allclose(got, want, rtol=2e-4)
