"""Pallas kernels vs ref.py oracles: shape/dtype sweep in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.clip_reduce import clip_reduce
from repro.kernels.fused_clip import fused_norm_clip
from repro.kernels.ghost_norm import ghost_norm, ghost_norm_blocked

SHAPES = [
    (2, 8, 16, 24),
    (3, 300, 130, 70),
    (1, 513, 33, 1100),
    (4, 128, 128, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ghost_norm_kernel(shape, dtype):
    b, t, din, dout = shape
    key = jax.random.PRNGKey(hash(shape) & 0xFFFF)
    a = jax.random.normal(key, (b, t, din)).astype(dtype)
    g = (jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
         ).astype(dtype)
    got = ghost_norm(a, g, bt=128, dk=128)
    want = ref.ghost_norm_ref(a, g)
    rtol = 4e-3 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=rtol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_clip_reduce_kernel(shape, dtype):
    b, t, din, dout = shape
    key = jax.random.PRNGKey(hash(shape) & 0xFFF)
    a = jax.random.normal(key, (b, t, din)).astype(dtype)
    g = (jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
         ).astype(dtype)
    f = jax.random.uniform(jax.random.fold_in(key, 2), (b,))
    got = clip_reduce(a, g, f, bi=128, bj=128, bt=128)
    want = ref.clip_reduce_ref(a, g, f)
    rtol = 4e-3 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=rtol, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 80), st.integers(1, 50),
       st.integers(1, 50))
def test_ghost_norm_property(b, t, din, dout):
    key = jax.random.PRNGKey(b * 997 + t)
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout))
    got = ghost_norm(a, g, bt=32, dk=32)
    want = ref.ghost_norm_ref(a, g)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)
    assert bool(jnp.all(got >= -1e-5))  # norms² are nonnegative


def test_kernel_block_shape_sweep():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2, 200, 96))
    g = jax.random.normal(jax.random.fold_in(key, 1), (2, 200, 64))
    want = ref.ghost_norm_ref(a, g)
    for bt in (32, 64, 256):
        for dk in (32, 128):
            got = ghost_norm(a, g, bt=bt, dk=dk)
            np.testing.assert_allclose(got, want, rtol=2e-4)


# ---------------------------------------------------------------------------
# Blocked ghost-norm kernel (per-shard clipping hot path).
# ---------------------------------------------------------------------------

BLOCKED_CASES = [
    # (B, T, din, dout, M, axis) — T not a multiple of bt, narrow blocks
    (2, 8, 16, 24, 4, "out"),
    (3, 70, 48, 40, 4, "out"),
    (3, 70, 48, 40, 6, "in"),
    (1, 130, 36, 128, 2, "out"),
]


@pytest.mark.parametrize("case", BLOCKED_CASES)
def test_ghost_norm_blocked_kernel(case):
    b, t, din, dout, m, axis = case
    # crc32, not hash(): case contains strings and str hashes are salted
    # per process — a CI failure must be reproducible locally
    import zlib
    key = jax.random.PRNGKey(zlib.crc32(repr(case).encode()) & 0xFFFF)
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
    got = ghost_norm_blocked(a, g, m, block_axis=axis, bt=32, dk=32)
    want = ref.ghost_norm_blocked_ref(a, g, m, block_axis=axis)
    np.testing.assert_allclose(got, want, rtol=1e-4)
    # per-block norms² must sum to the full-layer norm²
    np.testing.assert_allclose(jnp.sum(got, -1), ref.ghost_norm_ref(a, g),
                               rtol=1e-4)


def test_ghost_norm_blocked_bad_args():
    a = jnp.zeros((2, 8, 6))
    g = jnp.zeros((2, 8, 10))
    with pytest.raises(ValueError):
        ghost_norm_blocked(a, g, 3, block_axis="out")  # 10 % 3 != 0
    with pytest.raises(ValueError):
        ghost_norm_blocked(a, g, 2, block_axis="diag")


# ---------------------------------------------------------------------------
# Fused norm+clip kernel (one HBM pass over A, G).
# ---------------------------------------------------------------------------

FUSED_CASES = [
    (2, 8, 16, 24),
    (3, 70, 48, 40),    # ragged: T % bt != 0, din < dk
    (1, 130, 36, 140),  # dout > one 128 lane tile
]


@pytest.mark.parametrize("case", FUSED_CASES)
@pytest.mark.parametrize("with_extra", [False, True])
def test_fused_norm_clip_kernel(case, with_extra):
    b, t, din, dout = case
    key = jax.random.PRNGKey(hash(case) & 0xFFF)
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
    extra = (jax.random.uniform(jax.random.fold_in(key, 2), (b,))
             if with_extra else None)
    # exercise the whole threshold encoding: clip, pass-through, direct scale
    c = jnp.array(([0.5, jnp.inf, -0.7, 0.01] * b)[:b])
    got_n, got_dw = fused_norm_clip(a, g, c, extra, bt=32)
    want_n, want_dw = ref.fused_norm_clip_ref(a, g, c, extra)
    np.testing.assert_allclose(got_n, want_n, rtol=1e-4)
    np.testing.assert_allclose(got_dw, want_dw, rtol=1e-4, atol=1e-5)
