"""Ghost-norm identities vs naive per-example materialization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import ghost


def naive_linear_norms(a, g):
    a3 = a.reshape(a.shape[0], -1, a.shape[-1]).astype(jnp.float32)
    g3 = g.reshape(g.shape[0], -1, g.shape[-1]).astype(jnp.float32)
    pg = jnp.einsum("bti,bto->bio", a3, g3)
    return jnp.sum(pg**2, axis=(1, 2))


@pytest.mark.parametrize("path", ["gram", "gram_chunked", "outer"])
@pytest.mark.parametrize("shape", [(3, 17, 8, 12), (2, 1100, 6, 10),
                                   (1, 64, 40, 3)])
def test_linear_norms_paths(path, shape):
    b, t, din, dout = shape
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.3
    got = ghost.linear_norms_sq(a, g, force_path=path)
    want = naive_linear_norms(a, g)
    np.testing.assert_allclose(got, want, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 40), st.integers(1, 16),
       st.integers(1, 16))
def test_linear_norms_auto_path(b, t, din, dout):
    key = jax.random.PRNGKey(b * 1000 + t)
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout))
    got = ghost.linear_norms_sq(a, g)
    want = naive_linear_norms(a, g)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_bias_norms():
    key = jax.random.PRNGKey(2)
    g = jax.random.normal(key, (4, 9, 7))
    want = jnp.sum(jnp.sum(g, axis=1) ** 2, axis=-1)
    np.testing.assert_allclose(ghost.bias_norms_sq(g), want, rtol=1e-5)


def test_embed_norms_collision_exact():
    """Repeated tokens within an example must be summed BEFORE the norm."""
    ids = jnp.array([[1, 1, 2], [3, 4, 3]])
    g = jnp.arange(2 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 4)
    # naive: scatter into a (V, 4) table per example, then norm
    want = []
    for i in range(2):
        tab = np.zeros((8, 4), np.float32)
        for t in range(3):
            tab[int(ids[i, t])] += np.asarray(g[i, t])
        want.append(np.sum(tab**2))
    np.testing.assert_allclose(ghost.embed_norms_sq(ids, g), want, rtol=1e-5)


def test_embed_norms_chunked_matches():
    key = jax.random.PRNGKey(3)
    ids = jax.random.randint(key, (2, 1500), 0, 50)  # t > chunk -> chunked
    g = jax.random.normal(jax.random.fold_in(key, 1), (2, 1500, 6))
    got = ghost.embed_norms_sq(ids, g)
    # naive
    want = []
    for i in range(2):
        tab = np.zeros((50, 6), np.float32)
        np.add.at(tab, np.asarray(ids[i]), np.asarray(g[i]))
        want.append(np.sum(tab**2))
    np.testing.assert_allclose(got, want, rtol=1e-3)


def test_blocked_norms_sum_to_full():
    key = jax.random.PRNGKey(4)
    a = jax.random.normal(key, (3, 11, 8))
    g = jax.random.normal(jax.random.fold_in(key, 1), (3, 11, 12))
    full = ghost.linear_norms_sq(a, g)
    blocked = ghost.linear_norms_sq_blocked(a, g, 4, block_axis="out")
    np.testing.assert_allclose(jnp.sum(blocked, -1), full, rtol=1e-4)
    blocked_in = ghost.linear_norms_sq_blocked(a, g, 2, block_axis="in")
    np.testing.assert_allclose(jnp.sum(blocked_in, -1), full, rtol=1e-4)


def test_clipped_sums_match_naive():
    key = jax.random.PRNGKey(5)
    a = jax.random.normal(key, (4, 7, 5))
    g = jax.random.normal(jax.random.fold_in(key, 1), (4, 7, 6))
    f = jax.random.uniform(jax.random.fold_in(key, 2), (4,))
    want = sum(float(f[i]) * np.asarray(a[i]).T @ np.asarray(g[i])
               for i in range(4))
    np.testing.assert_allclose(ghost.clipped_sum_linear(a, g, f), want,
                               rtol=1e-4)
    blocked = ghost.clipped_sum_linear_blocked(
        a, g, jnp.broadcast_to(f[:, None], (4, 3)), block_axis="out")
    np.testing.assert_allclose(blocked, want, rtol=1e-4)
