"""Doc drift: the docs are checked against the code, mechanically.

Three contracts:

  * every CLI flag of the train / serve / service / audit parsers is
    documented somewhere in README.md or docs/, and every flag-looking
    token the docs mention for THOSE tools actually exists (a removed
    flag cannot linger in prose);
  * every relative markdown link resolves to a real file, and every
    `#anchor` to a real heading in its target;
  * the engine-stats table in docs/serving.md is byte-identical to what
    `DecodeEngine.STATS_DOC` renders — the field list cannot rot.
"""
import os
import re

import pytest

from repro.launch.audit import build_audit_parser
from repro.launch.engine import DecodeEngine
from repro.launch.serve import build_serve_parser
from repro.launch.service import build_service_parser
from repro.launch.train import build_arg_parser

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md", "docs/architecture.md", "docs/serving.md",
             "docs/operations.md"]

# flag-looking tokens the docs legitimately mention that belong to OTHER
# CLIs (autotune sweep, benchmarks, dryrun) or to env-var examples —
# anything else undocumented-in-a-parser is treated as stale
OTHER_CLI_FLAGS = {
    "--sweep", "--show", "--full",          # repro.kernels.autotune
    "--smoke",                              # benchmarks.* smoke modes
    "--shape", "--audit",                   # repro.launch.dryrun
}

PARSERS = {
    "train": build_arg_parser,
    "serve": build_serve_parser,
    "service": build_service_parser,
    "audit": build_audit_parser,
}


def _read(rel):
    with open(os.path.join(ROOT, rel), encoding="utf-8") as fh:
        return fh.read()


def _parser_flags():
    flags = set()
    for build in PARSERS.values():
        for action in build()._actions:
            flags.update(o for o in action.option_strings
                         if o.startswith("--"))
    flags.discard("--help")
    return flags


def _doc_flags(text):
    # a flag mention: --word at a non-word boundary; strips the
    # XLA_FLAGS=--xla_... env examples below
    toks = set(re.findall(r"(?<![-\w])--[a-z][a-z0-9-]+", text))
    return {t for t in toks if not t.startswith("--xla")}


def test_every_cli_flag_is_documented():
    docs = "\n".join(_read(f) for f in DOC_FILES)
    documented = _doc_flags(docs)
    missing = sorted(_parser_flags() - documented)
    assert not missing, (
        f"CLI flags absent from README.md/docs/: {missing} — document "
        f"them (serve CLI table in docs/serving.md, train/service/audit "
        f"tables in docs/operations.md)")


def test_no_stale_documented_flags():
    known = _parser_flags() | OTHER_CLI_FLAGS
    stale = {}
    for f in DOC_FILES:
        bad = sorted(_doc_flags(_read(f)) - known)
        if bad:
            stale[f] = bad
    assert not stale, (
        f"docs mention flags no parser defines (removed or renamed?): "
        f"{stale}")


# --------------------------------------------------------------------------
# markdown links
# --------------------------------------------------------------------------

_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.M)


def _anchor(heading):
    """GitHub heading -> anchor slug."""
    h = re.sub(r"`([^`]*)`", r"\1", heading).strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(text):
    return {_anchor(h) for h in _HEADING.findall(text)}


@pytest.mark.parametrize("doc", DOC_FILES)
def test_markdown_links_resolve(doc):
    text = _read(doc)
    base = os.path.dirname(os.path.join(ROOT, doc))
    problems = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, frag = target.partition("#")
        full = os.path.normpath(os.path.join(base, path)) if path \
            else os.path.join(ROOT, doc)
        if not os.path.exists(full):
            problems.append(f"{target}: file {path} not found")
            continue
        if frag:
            if not full.endswith(".md"):
                continue
            with open(full, encoding="utf-8") as fh:
                if frag not in _anchors(fh.read()):
                    problems.append(f"{target}: no heading for #{frag}")
    assert not problems, f"{doc}: broken links: {problems}"


# --------------------------------------------------------------------------
# engine-stats table
# --------------------------------------------------------------------------

def _render_stats_table():
    lines = ["| counter | meaning |", "|---|---|"]
    lines += [f"| `{k}` | {v} |" for k, v in DecodeEngine.STATS_DOC.items()]
    return "\n".join(lines)


def test_engine_stats_table_matches_stats_doc():
    text = _read("docs/serving.md")
    m = re.search(r"<!-- engine-stats:begin -->\n(.*?)\n"
                  r"<!-- engine-stats:end -->", text, re.S)
    assert m, "docs/serving.md lost its engine-stats block markers"
    assert m.group(1).strip() == _render_stats_table(), (
        "docs/serving.md engine-stats table is out of date — regenerate "
        "it from DecodeEngine.STATS_DOC (tests/test_docs.py"
        "::_render_stats_table)")


def test_stats_doc_covers_engine_stats():
    # the documented key set IS the runtime key set (STATS_DOC seeds
    # engine.stats, so a key added to one place only cannot hide)
    assert list(DecodeEngine.STATS_DOC), "STATS_DOC is empty?"
    src = _read("src/repro/launch/engine.py")
    assert "self.stats = {k: 0 for k in self.STATS_DOC}" in src, (
        "engine.stats no longer seeded from STATS_DOC — the docs table "
        "would silently diverge from the runtime counters")
