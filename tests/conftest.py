"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only the dry-run (subprocess) forces 512."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def make_lm_batch_for(cfg, b, t, key):
    from repro.launch.inputs import concrete_train_batch
    return concrete_train_batch(cfg, b, t, key)
