"""Measured kernel autotuning (repro.kernels.autotune): table persistence
discipline (versioned, topology-stamped, checksummed, never crashes on a
bad file), measured-beats-model precedence, and the acceptance bar — the
auto backend's choice equals the measured argmin on every measured bucket,
on any jax backend, with the static model only deciding unmeasured ones."""
import json
import os

import jax
import numpy as np
import pytest

from repro.kernels import autotune, backend


def _tab(path=None, topology=None):
    return autotune.AutotuneTable(
        topology=topology or autotune.topology_stamp(), path=path)


# ---------------------------------------------------------------------------
# Bucketing.
# ---------------------------------------------------------------------------


def test_bucket_dim_next_pow2():
    assert [autotune.bucket_dim(n) for n in (0, 1, 2, 3, 127, 128, 129)] \
        == [0, 1, 2, 4, 128, 128, 256]


def test_bucket_key_covers_the_bucket():
    # one measurement covers every shape in its power-of-two bucket
    assert autotune.bucket_key("norms", 100, 64, 48) \
        == autotune.bucket_key("norms", 128, 64, 64)
    assert autotune.bucket_key("norms", 129, 64, 64) \
        != autotune.bucket_key("norms", 128, 64, 64)
    assert autotune.bucket_key("norms", 128, 64, 64) \
        != autotune.bucket_key("clip_sum", 128, 64, 64)


# ---------------------------------------------------------------------------
# Record / best semantics.
# ---------------------------------------------------------------------------


def test_record_and_best_argmin():
    tab = _tab()
    assert tab.record("norms", 128, 64, 64, "xla", 100.0)
    assert tab.record("norms", 128, 64, 64, "pallas", 50.0)
    assert tab.best("norms", 128, 64, 64) == "pallas"
    assert tab.best("norms", 4096, 64, 64) is None  # unmeasured bucket
    # refreshing a measurement updates it
    tab.record("norms", 128, 64, 64, "pallas", 500.0)
    assert tab.best("norms", 128, 64, 64) == "xla"


def test_measured_beats_model_seed():
    tab = _tab()
    tab.record("clip_sum", 128, 64, 64, "xla", 100.0)
    # a model estimate must never overwrite a measurement...
    assert not tab.record("clip_sum", 128, 64, 64, "xla", 1.0,
                          source="model")
    assert tab.lookup("clip_sum", 128, 64, 64)["xla"]["us"] == 100.0
    # ...and a model-only row never outvotes a measured one in best()
    tab.record("clip_sum", 128, 64, 64, "pallas", 0.5, source="model")
    assert tab.best("clip_sum", 128, 64, 64) == "xla"
    # but a bucket with ONLY model rows still resolves
    tab.record("clip_sum", 512, 64, 64, "pallas", 2.0, source="model")
    assert tab.best("clip_sum", 512, 64, 64) == "pallas"
    # and a later measurement takes the bucket over
    tab.record("clip_sum", 128, 64, 64, "pallas", 10.0)
    assert tab.best("clip_sum", 128, 64, 64) == "pallas"


def test_record_rejects_garbage():
    tab = _tab()
    with pytest.raises(ValueError):
        tab.record("norms", 128, 64, 64, "cuda", 1.0)
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError):
            tab.record("norms", 128, 64, 64, "xla", bad)


# ---------------------------------------------------------------------------
# Persistence: round trip + every staleness mode loads EMPTY, never raises.
# ---------------------------------------------------------------------------


def test_save_load_round_trip(tmp_path):
    path = str(tmp_path / "tab.json")
    tab = _tab(path)
    tab.record("norms", 128, 64, 64, "pallas", 42.0)
    tab.record("paged_attn", 256, 64, 64, "xla", 7.0)
    tab.save()
    back = autotune.load(path)
    assert back.stale_reason is None
    assert back.entries == tab.entries
    assert back.best("norms", 100, 33, 64) == "pallas"  # same bucket


@pytest.mark.parametrize("breakage", [
    "missing", "not_json", "truncated", "crc", "version", "topology",
    "not_dict"])
def test_stale_or_corrupt_loads_empty(tmp_path, breakage):
    path = str(tmp_path / "tab.json")
    tab = _tab(path)
    tab.record("norms", 128, 64, 64, "pallas", 42.0)
    tab.save()
    if breakage == "missing":
        os.unlink(path)
    elif breakage == "not_json":
        open(path, "w").write("))) not json (((")
    elif breakage == "truncated":
        blob = open(path).read()
        open(path, "w").write(blob[: len(blob) // 2])
    elif breakage == "crc":
        doc = json.load(open(path))
        doc["entries"]["norms|t128|i64|o64"]["pallas"]["us"] = 1e-9
        json.dump(doc, open(path, "w"))  # edited without re-checksumming
    elif breakage == "version":
        doc = json.load(open(path))
        doc["version"] = autotune.TABLE_VERSION + 1
        json.dump(doc, open(path, "w"))
    elif breakage == "topology":
        pass  # broken via the load-side topology below
    elif breakage == "not_dict":
        json.dump([1, 2, 3], open(path, "w"))
    topo = autotune.topology_stamp()
    if breakage == "topology":
        topo = dict(topo, jax_version="0.0.0", device_count=8192)
    back = autotune.load(path, topology=topo)
    assert back.stale_reason is not None
    assert len(back) == 0
    assert back.best("norms", 128, 64, 64) is None  # clean miss
    # ...and the next sweep/save simply rebuilds the file
    back.record("norms", 128, 64, 64, "xla", 9.0)
    back.save()
    again = autotune.load(path, topology=topo)
    assert again.stale_reason is None and len(again) == 1


def test_save_is_atomic_no_tmp_left(tmp_path):
    path = str(tmp_path / "tab.json")
    tab = _tab(path)
    tab.record("norms", 128, 64, 64, "xla", 1.0)
    tab.save()
    assert not os.path.exists(path + ".tmp")


# ---------------------------------------------------------------------------
# choose_op: measured argmin wins on ANY backend; static model is the
# unmeasured fallback (the old non-TPU short-circuit lives there only).
# ---------------------------------------------------------------------------


def test_choose_op_equals_measured_argmin_everywhere():
    """Acceptance: over a synthetic table with KNOWN winners per bucket,
    auto's choice == the measured argmin on EVERY measured bucket —
    including pallas wins off-TPU, which the static model would never
    pick."""
    tab = _tab()
    want = {}
    rng = np.random.RandomState(0)
    for op in autotune.OPS:
        for t, d in ((128, 64), (256, 128), (1024, 512)):
            xla_us, pal_us = 1.0 + rng.rand(2) * 100
            tab.record(op, t, d, d, "xla", float(xla_us))
            tab.record(op, t, d, d, "pallas", float(pal_us))
            want[(op, t, d)] = "xla" if xla_us <= pal_us else "pallas"
    cfg = backend.EngineConfig(backend="auto")
    for (op, t, d), winner in want.items():
        for on_tpu in (False, True):
            assert backend.choose_op(op, t, d, d, cfg, on_tpu=on_tpu,
                                     table=tab) == winner, (op, t, d)
    assert any(w == "pallas" for w in want.values())  # exercised both ways
    assert any(w == "xla" for w in want.values())


def test_choose_op_unmeasured_falls_back_to_static_model():
    tab = _tab()
    tab.record("norms", 128, 64, 64, "pallas", 1.0)
    cfg = backend.EngineConfig(backend="auto")
    # unmeasured bucket off-TPU: the validation-only short-circuit applies
    assert backend.choose_op("norms", 4096, 1024, 1024, cfg, on_tpu=False,
                             table=tab) == "xla"
    assert backend.choose_op("paged_attn", 4096, 64, 64, cfg, on_tpu=False,
                             table=tab) == "xla"
    assert backend.choose_op("paged_attn", 4096, 64, 64, cfg, on_tpu=True,
                             table=tab) == "pallas"
    # ...but the MEASURED bucket honors the interpret-mode win off-TPU
    assert backend.choose_op("norms", 128, 64, 64, cfg, on_tpu=False,
                             table=tab) == "pallas"


def test_autotune_off_pins_static_model():
    tab = _tab()
    tab.record("norms", 128, 64, 64, "pallas", 1.0)
    cfg = backend.EngineConfig(backend="auto", autotune=False)
    assert backend.choose_op("norms", 128, 64, 64, cfg, on_tpu=False,
                             table=tab) == "xla"


def test_no_table_installed_matches_legacy_static_choice():
    assert autotune.installed_table() is None
    cfg = backend.EngineConfig(backend="auto")
    assert backend.choose_op("norms", 128, 64, 64, cfg, on_tpu=False) \
        == backend.choose_linear_path(128, 64, 64, cfg, on_tpu=False)


# ---------------------------------------------------------------------------
# Installation plumbing.
# ---------------------------------------------------------------------------


def test_install_and_use_table_scoping():
    base, override = _tab(), _tab()
    base.record("norms", 128, 64, 64, "xla", 1.0)
    override.record("norms", 128, 64, 64, "pallas", 1.0)
    try:
        autotune.install(base)
        assert autotune.installed_table() is base
        with autotune.use_table(override):
            assert autotune.installed_table() is override
        assert autotune.installed_table() is base
    finally:
        autotune.install(None)
    assert autotune.installed_table() is None


def test_install_default_survives_stale_file(tmp_path):
    root = str(tmp_path)
    # no file at all -> empty table installed, auto == static model
    try:
        tab = autotune.install_default(root)
        assert len(tab) == 0 and tab.stale_reason == "missing"
        # garbage on disk -> still an empty install, never a crash
        os.makedirs(os.path.dirname(tab.path), exist_ok=True)
        open(tab.path, "w").write("garbage")
        tab2 = autotune.install_default(root)
        assert len(tab2) == 0 and tab2.stale_reason is not None
    finally:
        autotune.install(None)


# ---------------------------------------------------------------------------
# The AutoBackend actually dispatches (and stays numerically right) on a
# table-driven choice.
# ---------------------------------------------------------------------------


def test_auto_backend_dispatch_and_value_parity_under_table():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (2, 128, 64))
    g = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 48)) * 0.1
    f = jax.random.uniform(jax.random.fold_in(key, 2), (2,))
    tab = _tab()
    tab.record("clip_sum", 128, 64, 64, "pallas", 1.0)  # dout 48 -> o64
    tab.record("clip_sum", 128, 64, 64, "xla", 2.0)
    with backend.scoped("auto"):
        eng = backend.active()
        assert eng._pick("clip_sum", a, g) is eng._xla  # static: off-TPU
        with autotune.use_table(tab):
            assert eng._pick("clip_sum", a, g) is eng._pallas
            got = eng.clipped_sum_linear(a, g, f)
    ref = backend.make_engine("xla").clipped_sum_linear(a, g, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_auto_paged_impl_hint_consults_table():
    args = autotune.paged_attn_data((2, 128, 64, 64))
    q, kp, vp, pt, pos = args
    t, din, dout = autotune.paged_attn_dims(q, pt, kp.shape[1], vp.shape[-1])
    tab = _tab()
    tab.record("paged_attn", t, din, dout, "pallas", 1.0)
    tab.record("paged_attn", t, din, dout, "xla", 2.0)
    eng = backend.make_engine("auto")
    assert eng.paged_impl() == "xla"  # no hints off-TPU: static rule
    assert eng.paged_impl(t=t, din=din, dout=dout) == "xla"  # no table
    with autotune.use_table(tab):
        assert eng.paged_impl(t=t, din=din, dout=dout) == "pallas"
        got = eng.paged_attn(q, kp, vp, pt, pos, scale=0.125)
    ref = backend.make_engine("xla").paged_attn(q, kp, vp, pt, pos,
                                                scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Seeding paths: bench records + the live sweep.
# ---------------------------------------------------------------------------


def test_seed_from_records_parses_bench_rows():
    records = [
        {"name": "kernel_clip_sum_pallas", "backend": "pallas",
         "t": 128, "din": 64, "dout": 64, "us_per_call": 5.0},
        {"name": "kernel_clip_sum_xla", "backend": "xla",
         "t": 128, "din": 64, "dout": 64, "us_per_call": 9.0},
        {"name": "kernel_norms_naive", "backend": "naive",
         "t": 128, "din": 64, "dout": 64, "us_per_call": 3.0},  # ignored
        {"name": "kernel_pallas_skipped", "backend": "pallas"},  # no timing
        {"name": "other_row", "backend": "xla", "t": 1, "din": 1,
         "dout": 1, "us_per_call": 1.0},  # not a kernel row
    ]
    tab = autotune.seed_from_records(records, _tab())
    assert len(tab) == 1
    assert tab.best("clip_sum", 128, 64, 64) == "pallas"


def test_sweep_measures_and_persists(tmp_path):
    path = str(tmp_path / "tab.json")
    tab = autotune.sweep(ops=("norms",), shapes=((2, 128, 64, 64),),
                         table=_tab(path))
    slot = tab.lookup("norms", 128, 64, 64)
    assert set(slot) == {"xla", "pallas"}
    assert all(v["us"] > 0 and v["source"] == "measured"
               for v in slot.values())
    back = autotune.load(path)
    assert back.entries == tab.entries
    assert back.best("norms", 128, 64, 64) in ("xla", "pallas")


# ---------------------------------------------------------------------------
# Topology stamp.
# ---------------------------------------------------------------------------


def test_topology_stamp_keys_and_crc_stability():
    stamp = autotune.topology_stamp()
    assert set(stamp) == {"jax_backend", "device_kind", "device_count",
                          "xla_flags", "jax_version"}
    assert stamp["jax_version"] == jax.__version__
    assert autotune.stamp_crc(stamp) == autotune.stamp_crc(stamp)
    assert autotune.stamp_crc(dict(stamp, device_count=8192)) \
        != autotune.stamp_crc(stamp)
    # a topology change moves the default table path: clean miss on disk too
    assert autotune.default_path("/x", stamp) \
        != autotune.default_path("/x", dict(stamp, jax_version="0.0.0"))
