"""RDP accountant + Proposition 3.1 budget split."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import accounting as acc


def test_epsilon_reference_point():
    # sanity region for the canonical (sigma=1, q=0.01, T=1000, 1e-5) point
    eps = acc.compute_epsilon(sigma=1.0, sampling_rate=0.01, steps=1000,
                              delta=1e-5)
    assert 1.5 < eps < 3.0


def test_no_subsampling_matches_gaussian_composition():
    # q=1: RDP alpha/(2 sigma^2) per step; eps should be near the analytic
    # optimum of T*alpha/(2 sigma^2) + log(1/delta)/(alpha-1)
    sigma, steps, delta = 5.0, 10, 1e-6
    eps = acc.compute_epsilon(sigma=sigma, sampling_rate=1.0, steps=steps,
                              delta=delta)
    alphas = np.linspace(1.01, 200, 5000)
    analytic = np.min(steps * alphas / (2 * sigma**2)
                      + np.log1p(-1 / alphas)
                      - (np.log(delta) + np.log(alphas)) / (alphas - 1))
    assert abs(eps - analytic) / analytic < 0.05


@settings(max_examples=20, deadline=None)
@given(st.floats(0.5, 4.0), st.floats(0.001, 0.05),
       st.integers(10, 2000))
def test_monotonicity(sigma, q, steps):
    e1 = acc.compute_epsilon(sigma=sigma, sampling_rate=q, steps=steps,
                             delta=1e-5)
    e2 = acc.compute_epsilon(sigma=sigma * 1.5, sampling_rate=q, steps=steps,
                             delta=1e-5)
    e3 = acc.compute_epsilon(sigma=sigma, sampling_rate=q, steps=steps * 2,
                             delta=1e-5)
    assert e2 <= e1 + 1e-9  # more noise, less eps
    assert e3 >= e1 - 1e-9  # more steps, more eps


def test_calibration_inverts():
    sigma = acc.calibrate_sigma(target_eps=3.0, sampling_rate=0.02,
                                steps=500, delta=1e-5)
    eps = acc.compute_epsilon(sigma=sigma, sampling_rate=0.02, steps=500,
                              delta=1e-5)
    assert eps <= 3.0
    assert eps > 3.0 * 0.98  # tight


def test_prop_3_1_exact():
    # sigma_new = (sigma^-2 - K/(2 sigma_b)^2)^(-1/2)
    split = acc.split_noise_multiplier(sigma=1.2, sigma_b=20.0, num_groups=50)
    lhs = split.sigma_new ** -2
    rhs = 1.2 ** -2 - 50 / (2 * 20.0) ** 2
    assert abs(lhs - rhs) < 1e-12


@settings(max_examples=25, deadline=None)
@given(st.floats(0.5, 3.0), st.integers(1, 300), st.floats(0.001, 0.5))
def test_remark_3_1_roundtrip(sigma, k, r):
    sigma_b = acc.sigma_b_for_fraction(sigma, k, r)
    split = acc.split_noise_multiplier(sigma, sigma_b, k)
    assert abs(split.r - r) < 1e-9
    assert split.sigma_new >= sigma  # paying for quantiles costs noise


def test_budget_exhaustion_raises():
    with pytest.raises(ValueError):
        acc.split_noise_multiplier(sigma=1.0, sigma_b=0.5, num_groups=10)
