"""RDP accountant + Proposition 3.1 budget split."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import accounting as acc


def test_epsilon_reference_point():
    # sanity region for the canonical (sigma=1, q=0.01, T=1000, 1e-5) point
    eps = acc.compute_epsilon(sigma=1.0, sampling_rate=0.01, steps=1000,
                              delta=1e-5)
    assert 1.5 < eps < 3.0


def test_no_subsampling_matches_gaussian_composition():
    # q=1: RDP alpha/(2 sigma^2) per step; eps should be near the analytic
    # optimum of T*alpha/(2 sigma^2) + log(1/delta)/(alpha-1)
    sigma, steps, delta = 5.0, 10, 1e-6
    eps = acc.compute_epsilon(sigma=sigma, sampling_rate=1.0, steps=steps,
                              delta=delta)
    alphas = np.linspace(1.01, 200, 5000)
    analytic = np.min(steps * alphas / (2 * sigma**2)
                      + np.log1p(-1 / alphas)
                      - (np.log(delta) + np.log(alphas)) / (alphas - 1))
    assert abs(eps - analytic) / analytic < 0.05


@settings(max_examples=20, deadline=None)
@given(st.floats(0.5, 4.0), st.floats(0.001, 0.05),
       st.integers(10, 2000))
def test_monotonicity(sigma, q, steps):
    e1 = acc.compute_epsilon(sigma=sigma, sampling_rate=q, steps=steps,
                             delta=1e-5)
    e2 = acc.compute_epsilon(sigma=sigma * 1.5, sampling_rate=q, steps=steps,
                             delta=1e-5)
    e3 = acc.compute_epsilon(sigma=sigma, sampling_rate=q, steps=steps * 2,
                             delta=1e-5)
    assert e2 <= e1 + 1e-9  # more noise, less eps
    assert e3 >= e1 - 1e-9  # more steps, more eps


def test_calibration_inverts():
    sigma = acc.calibrate_sigma(target_eps=3.0, sampling_rate=0.02,
                                steps=500, delta=1e-5)
    eps = acc.compute_epsilon(sigma=sigma, sampling_rate=0.02, steps=500,
                              delta=1e-5)
    assert eps <= 3.0
    assert eps > 3.0 * 0.98  # tight


def test_prop_3_1_exact():
    # sigma_new = (sigma^-2 - K/(2 sigma_b)^2)^(-1/2)
    split = acc.split_noise_multiplier(sigma=1.2, sigma_b=20.0, num_groups=50)
    lhs = split.sigma_new ** -2
    rhs = 1.2 ** -2 - 50 / (2 * 20.0) ** 2
    assert abs(lhs - rhs) < 1e-12


@settings(max_examples=25, deadline=None)
@given(st.floats(0.5, 3.0), st.integers(1, 300), st.floats(0.001, 0.5))
def test_remark_3_1_roundtrip(sigma, k, r):
    sigma_b = acc.sigma_b_for_fraction(sigma, k, r)
    split = acc.split_noise_multiplier(sigma, sigma_b, k)
    assert abs(split.r - r) < 1e-9
    assert split.sigma_new >= sigma  # paying for quantiles costs noise


def test_budget_exhaustion_raises():
    with pytest.raises(ValueError):
        acc.split_noise_multiplier(sigma=1.0, sigma_b=0.5, num_groups=10)


# ---------------------------------------------------------------------------
# Edge-case guards: explicit ValueErrors, not math-domain errors.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad_q", [-0.1, 1.0001, 2.0])
def test_rdp_rejects_bad_sampling_rate(bad_q):
    with pytest.raises(ValueError):
        acc.rdp_sampled_gaussian(bad_q, 1.0, 10)


@pytest.mark.parametrize("bad_sigma", [0.0, -1.0, math.inf, math.nan])
def test_rdp_rejects_bad_sigma(bad_sigma):
    with pytest.raises(ValueError):
        acc.rdp_sampled_gaussian(0.01, bad_sigma, 10)


def test_rdp_rejects_empty_or_invalid_order_grid():
    with pytest.raises(ValueError):
        acc.rdp_sampled_gaussian(0.01, 1.0, 10, orders=[])
    with pytest.raises(ValueError):
        acc.rdp_sampled_gaussian(0.01, 1.0, 10, orders=[0.5, 2.0])
    with pytest.raises(ValueError):
        acc.rdp_to_eps(np.zeros(0), 1e-5, orders=[])


def test_rdp_to_eps_rejects_bad_delta_and_shape_mismatch():
    rdp = acc.rdp_sampled_gaussian(0.01, 1.0, 10)
    for bad_delta in (0.0, 1.0, -1e-5, 2.0):
        with pytest.raises(ValueError):
            acc.rdp_to_eps(rdp, bad_delta)
    with pytest.raises(ValueError):
        acc.rdp_to_eps(rdp[:-1], 1e-5)


def test_calibrate_sigma_rejects_degenerate_inputs():
    # q=0 spends nothing (any sigma "works"); q>1 is not a probability;
    # both previously fell into cryptic log-domain failures
    for bad_q in (0.0, -0.01, 1.5):
        with pytest.raises(ValueError):
            acc.calibrate_sigma(target_eps=3.0, sampling_rate=bad_q,
                                steps=100, delta=1e-5)
    with pytest.raises(ValueError):
        acc.calibrate_sigma(target_eps=3.0, sampling_rate=0.01, steps=0,
                            delta=1e-5)
    with pytest.raises(ValueError):
        acc.calibrate_sigma(target_eps=3.0, sampling_rate=0.01, steps=100,
                            delta=0.0)


def test_q_edge_values_still_account():
    # the legal boundary values stay meaningful: q=0 spends nothing,
    # q=1 is plain (unsubsampled) Gaussian composition
    assert np.all(acc.rdp_sampled_gaussian(0.0, 1.0, 100) == 0)
    eps = acc.compute_epsilon(sigma=5.0, sampling_rate=1.0, steps=10,
                              delta=1e-6)
    assert eps > 0 and math.isfinite(eps)


# ---------------------------------------------------------------------------
# Incremental accountant (the service's ledger-replay API).
# ---------------------------------------------------------------------------


def test_accountant_matches_batch_composition():
    a = acc.RdpAccountant()
    for _ in range(25):
        a.spend(0.02, 1.1)
    batch = acc.compute_epsilon(sigma=1.1, sampling_rate=0.02, steps=25,
                                delta=1e-5)
    assert abs(a.epsilon(1e-5) - batch) < 1e-12
    assert a.steps == 25


def test_accountant_peek_prices_without_committing():
    a = acc.RdpAccountant()
    for _ in range(10):
        a.spend(0.01, 1.0)
    before = a.epsilon(1e-5)
    projected = a.peek(0.01, 1.0, 1e-5)
    assert a.epsilon(1e-5) == before  # peek did not commit
    a.spend(0.01, 1.0)
    assert abs(a.epsilon(1e-5) - projected) < 1e-12
    assert projected > before


def test_accountant_heterogeneous_mechanisms_compose():
    # RDP composes additively across different (q, sigma) — order must not
    # matter
    a1, a2 = acc.RdpAccountant(), acc.RdpAccountant()
    spends = [(0.01, 1.0)] * 5 + [(0.05, 2.0)] * 5
    for q, s in spends:
        a1.spend(q, s)
    for q, s in reversed(spends):
        a2.spend(q, s)
    assert abs(a1.epsilon(1e-5) - a2.epsilon(1e-5)) < 1e-12
    np.testing.assert_allclose(a1.rdp(), a2.rdp(), rtol=1e-12, atol=1e-12)


def test_replay_ledger_matches_manual_spends():
    recs = [{"step": i, "q": 0.01, "sigma": 0.9} for i in range(7)]
    acct, eps = acc.replay_ledger(recs, 1e-5)
    assert acct.steps == 7
    assert abs(eps - acc.compute_epsilon(sigma=0.9, sampling_rate=0.01,
                                         steps=7, delta=1e-5)) < 1e-12


def test_fresh_accountant_spends_nothing():
    a = acc.RdpAccountant()
    assert a.epsilon(1e-5) == 0.0
    with pytest.raises(ValueError):
        acc.RdpAccountant(orders=[])
