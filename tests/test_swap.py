"""Adapter-slot lifecycle edge cases and the hot-swap watcher.

The invariants under test:

  * blue/green version pinning — a request admitted before a hot swap
    decodes its WHOLE completion on the pre-swap adapter, even while
    later requests of the same tenant run the new one in the same pool;
  * retire-with-inflight — removing a tenant refuses new submits at
    once but drains queued + in-flight work before the adapter slot
    recycles;
  * slot exhaustion — a tenant beyond `max_tenants` waits FIFO (its
    requests defer admission, mirroring the paged plane's reservation
    semantics) and is admitted the moment a drain frees a slot;
  * the `AdapterWatcher` only ever installs VERIFIED publishes, skips
    bitwise-identical re-publishes, and its installs read back
    crc32-equal to the manifest.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import save_checkpoint
from repro.configs import get_config
from repro.core.spec import init_params
from repro.launch.engine import DecodeEngine
from repro.launch.inputs import synthetic_requests
from repro.launch.swap import AdapterWatcher
from repro.models.transformer import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("tiny"), lora_rank=4)
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    return cfg, model, params


def _adapter(model, seed, scale=0.05):
    flat, td = jax.tree_util.tree_flatten(
        model.spec["lora"], is_leaf=lambda v: hasattr(v, "init"))
    ks = jax.random.split(jax.random.PRNGKey(seed), len(flat))
    return jax.tree_util.tree_unflatten(
        td, [jax.random.normal(k, p.shape, jnp.float32) * scale
             for k, p in zip(ks, flat)])


def _engine(model, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("prefill_chunk", 4)
    return DecodeEngine(model, params, **kw)


def _alone(model, params, adapters, prompt, gen):
    e = _engine(model, params, max_tenants=1)
    t = e.add_tenant(adapters)
    rid = e.submit(prompt, max_new_tokens=gen, tenant=t)
    return e.run()[rid].tokens


def test_swap_mid_decode_pins_inflight_to_old_version(setup):
    cfg, model, params = setup
    ad1, ad2 = _adapter(model, 1), _adapter(model, 2)
    reqs = synthetic_requests(cfg.vocab_size, 2, min_len=4, max_len=6,
                              seed=4)
    eng = _engine(model, params, max_tenants=3)
    t = eng.add_tenant(ad1)
    r_old = eng.submit(reqs[0], max_new_tokens=10, tenant=t)
    for _ in range(3):
        eng.step()               # r_old is mid-decode on v1
    eng.update_adapter(t, ad2)   # blue/green: v1 keeps its slot, draining
    r_new = eng.submit(reqs[1], max_new_tokens=10, tenant=t)
    done = eng.run()
    assert done[r_old].tokens == _alone(model, params, ad1, reqs[0], 10), \
        "in-flight request leaked onto the post-swap adapter"
    assert done[r_new].tokens == _alone(model, params, ad2, reqs[1], 10)
    # the drained v1 slot was recycled: both versions' slots accounted for
    st = eng.tenant_stats(t)
    assert st["version"] == 1 and st["swaps"] == 1
    assert eng.num_free_adapter_slots == 2  # 3 slots, 1 live version


def test_swap_while_queued_routes_to_new_version(setup):
    """A request still in the queue (e.g. submitted just before a swap,
    not yet through chunked prefill) binds its adapter at ADMISSION, so
    it runs the new version — only already-admitted work drains on the
    old one."""
    cfg, model, params = setup
    ad1, ad2 = _adapter(model, 1), _adapter(model, 2)
    reqs = synthetic_requests(cfg.vocab_size, 1, min_len=9, max_len=12,
                              seed=6)
    eng = _engine(model, params, max_tenants=2)
    t = eng.add_tenant(ad1)
    rid = eng.submit(reqs[0], max_new_tokens=6, tenant=t)
    eng.update_adapter(t, ad2)   # lands before any dispatch
    done = eng.run()
    assert done[rid].tokens == _alone(model, params, ad2, reqs[0], 6)


def test_remove_tenant_drains_inflight_then_recycles_slot(setup):
    cfg, model, params = setup
    ad = _adapter(model, 3)
    reqs = synthetic_requests(cfg.vocab_size, 3, min_len=3, max_len=6,
                              seed=8)
    eng = _engine(model, params, max_tenants=1)
    t = eng.add_tenant(ad)
    r0 = eng.submit(reqs[0], max_new_tokens=8, tenant=t)
    r1 = eng.submit(reqs[1], max_new_tokens=8, tenant=t)  # queued behind
    eng.step()                   # r0 (and r1) admitted / in flight
    eng.remove_tenant(t)
    assert eng.tenant_stats(t)["state"] == "retiring"
    with pytest.raises(ValueError, match="retiring"):
        eng.submit(reqs[2], max_new_tokens=2, tenant=t)
    done = eng.run()             # drains BOTH on the tenant's adapter
    assert done[r0].tokens == _alone(model, params, ad, reqs[0], 8)
    assert done[r1].tokens == _alone(model, params, ad, reqs[1], 8)
    assert eng.tenant_stats(t)["state"] == "removed"
    assert eng.num_free_adapter_slots == 1
    assert eng.remove_tenant(t) is None  # idempotent


def test_adapter_slot_exhaustion_defers_fifo_until_drain(setup):
    cfg, model, params = setup
    ad = _adapter(model, 5)
    reqs = synthetic_requests(cfg.vocab_size, 2, min_len=3, max_len=6,
                              seed=10)
    eng = _engine(model, params, max_tenants=1)
    t0 = eng.add_tenant()
    t1 = eng.add_tenant(ad)      # no slot: waits
    assert eng.tenant_stats(t1)["state"] == "waiting"
    r0 = eng.submit(reqs[0], max_new_tokens=4, tenant=t0)
    r1 = eng.submit(reqs[1], max_new_tokens=4, tenant=t1)
    eng.run(max_steps=12)        # t0 completes; t1's request holds FIFO
    assert r0 in eng.completions() and r1 not in eng.completions()
    assert eng.stats["adapter_slot_deferrals"] > 0
    assert eng.num_pending == 1
    eng.remove_tenant(t0)        # idle retire -> slot frees -> t1 admitted
    done = eng.run()
    assert eng.tenant_stats(t1)["state"] == "active"
    assert done[r1].tokens == _alone(model, params, ad, reqs[1], 4)
    # an UNBOUNDED run with a permanently stuck head raises instead of
    # spinning (t1 now holds the only slot and nothing will free it)
    t2 = eng.add_tenant()
    eng.submit(reqs[0], max_new_tokens=2, tenant=t2)
    with pytest.raises(RuntimeError, match="stalled"):
        eng.run()


def test_watcher_installs_verified_publishes_bitwise(setup, tmp_path):
    cfg, model, params = setup
    d = str(tmp_path / "publish")
    eng = _engine(model, params, max_tenants=2)
    t = eng.add_tenant()
    w = AdapterWatcher(eng, t, d)
    assert w.poll() is None                    # nothing published yet

    ad1 = _adapter(model, 1)
    save_checkpoint(d, 4, {"lora": ad1})
    got = w.poll()
    assert got is not None and got.step == 4 and got.verified
    assert w.poll() is None                    # idempotent
    save_checkpoint(d, 8, {"lora": ad1})       # identical re-publish
    assert w.poll() is None and w.stats["skipped_unchanged"] == 1

    ad2 = _adapter(model, 2)
    save_checkpoint(d, 12, {"lora": ad2})
    got = w.poll()
    assert got.step == 12 and eng.tenant_stats(t)["version"] == 2
    # the tenant now decodes exactly as ad2 served directly
    reqs = synthetic_requests(cfg.vocab_size, 1, min_len=4, max_len=8,
                              seed=12)
    rid = eng.submit(reqs[0], max_new_tokens=5, tenant=t)
    assert eng.run()[rid].tokens == _alone(model, params, ad2, reqs[0], 5)


def test_watcher_ignores_torn_publish(setup, tmp_path):
    """A corrupted newest step (bit-rot, torn write) is invisible: the
    watcher keeps the tenant on the last verified version."""
    import os
    cfg, model, params = setup
    d = str(tmp_path / "publish")
    eng = _engine(model, params, max_tenants=1)
    t = eng.add_tenant()
    w = AdapterWatcher(eng, t, d)
    save_checkpoint(d, 4, {"lora": _adapter(model, 1)})
    assert w.poll().step == 4
    save_checkpoint(d, 8, {"lora": _adapter(model, 2)})
    shard = next(str(p) for p in sorted((tmp_path / "publish"
                                         / "step_00000008").iterdir())
                 if "shard" in p.name)
    with open(shard, "r+b") as f:              # flip bytes mid-shard
        f.seek(max(0, os.path.getsize(shard) // 2))
        f.write(b"\xff\xff\xff\xff")
    assert w.poll() is None                    # torn step never installs
    assert w.installed_step == 4
