"""Ragged-prompt serving regression: right-padded ragged batches must
decode EXACTLY like each prompt run alone unpadded (pad tokens masked out
of the cache, logits gathered at each sequence's true last token), for
both the fused-scan and the token-at-a-time reference prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.spec import init_params
from repro.launch.inputs import pad_ragged_prompts, synthetic_requests
from repro.launch.serve import greedy_decode
from repro.models.transformer import build_model


def _build(arch):
    cfg = get_config(arch, reduced=(arch != "tiny"))
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", ["tiny", "rwkv6-7b"])
def test_ragged_batch_matches_per_request_unpadded(arch):
    """THE bug this PR fixes: fused_prefill used to scan right-padded
    prompts straight into the cache and take logits[-1]."""
    cfg, model, params = _build(arch)
    reqs = synthetic_requests(cfg.vocab_size, 4, min_len=1, max_len=7,
                              seed=2)
    prompts, lengths = pad_ragged_prompts(reqs)
    assert sorted(set(lengths)) != [lengths[0]]  # actually ragged
    got_fused = np.asarray(greedy_decode(
        model, params, jnp.asarray(prompts), 6, 32, prefill="fused",
        lengths=jnp.asarray(lengths)))
    got_loop = np.asarray(greedy_decode(
        model, params, jnp.asarray(prompts), 6, 32, prefill="loop",
        lengths=jnp.asarray(lengths)))
    for i, r in enumerate(reqs):
        alone = np.asarray(greedy_decode(
            model, params, jnp.asarray(r)[None], 6, 32, prefill="loop"))[0]
        np.testing.assert_array_equal(got_fused[i], alone)
        np.testing.assert_array_equal(got_loop[i], alone)


def test_ragged_batch_windowed_ring_cache():
    """Ring-buffer (sliding-window) caches keep the same guarantee, across
    a wrap of the ring."""
    cfg, model, params = _build("zamba2-7b")
    reqs = synthetic_requests(cfg.vocab_size, 3, min_len=2, max_len=6,
                              seed=5)
    prompts, lengths = pad_ragged_prompts(reqs)
    cache_len = 10  # < prompt+gen: cap = min(window, 10), ring wraps
    got = np.asarray(greedy_decode(
        model, params, jnp.asarray(prompts), 8, cache_len, prefill="fused",
        lengths=jnp.asarray(lengths)))
    for i, r in enumerate(reqs):
        alone = np.asarray(greedy_decode(
            model, params, jnp.asarray(r)[None], 8, cache_len,
            prefill="loop"))[0]
        np.testing.assert_array_equal(got[i], alone)


def test_equal_length_batch_unchanged_without_lengths():
    """lengths=None keeps the historical equal-length behavior."""
    cfg, model, params = _build("tiny")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0,
                                 cfg.vocab_size)
    a = greedy_decode(model, params, prompts, 5, 24, prefill="fused")
    b = greedy_decode(model, params, prompts, 5, 24, prefill="fused",
                      lengths=jnp.full((3,), 5, jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_empty_prompt_raises():
    cfg, model, params = _build("tiny")
    empty = jnp.zeros((2, 0), jnp.int32)
    for prefill in ("fused", "loop"):
        with pytest.raises(ValueError, match="empty prompt"):
            greedy_decode(model, params, empty, 4, 16, prefill=prefill)


def test_gen_zero_returns_empty_batch():
    cfg, model, params = _build("tiny")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 4), 0,
                                 cfg.vocab_size)
    for prefill in ("fused", "loop"):
        out = greedy_decode(model, params, prompts, 0, 16, prefill=prefill)
        assert out.shape == (3, 0)
        assert out.dtype == jnp.int32


def test_pad_ragged_prompts_validation():
    toks, lengths = pad_ragged_prompts([[1, 2, 3], [4], [5, 6]])
    assert toks.shape == (3, 3)
    np.testing.assert_array_equal(lengths, [3, 1, 2])
    np.testing.assert_array_equal(toks[1], [4, 0, 0])
    with pytest.raises(ValueError, match="empty prompt"):
        pad_ragged_prompts([[1], []])
    with pytest.raises(ValueError, match="empty request set"):
        pad_ragged_prompts([])
