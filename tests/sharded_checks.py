"""Executable sharded-engine checks (needs >= 8 devices BEFORE jax init).

Run directly (the CI 8-virtual-device stage does):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python tests/sharded_checks.py

or through `tests/test_sharded.py`, which spawns this module in a
subprocess so the forced device count never leaks into the main test
process. Prints one `RESULT {json}` line; exit code 0 iff every check
passed.

Checks (sharded == single-device, same math different communication):
  * clip-level parity — grads, per-group norms², clip counts — for
    per_layer / ghost_flat / per_group (bk AND the twopass fallback);
  * full-step parity after 2 steps (params, quantile thresholds, metrics)
    for all three modes, plus microbatches=2;
  * the DP-LoRA trainable_key path (ghost_flat on a reduced qwen3-4b);
  * the Sec-4 communication contract from compiled HLO: per-device
    (per_group) has ZERO model-axis collectives in norm computation,
    ghost_flat has >= 1 (launch.hlo_analysis.model_axis_norm_collectives);
  * the quantile contract: shard-local clip counts psum'd over the data
    plane (quantile.update_thresholds counts_axes=) reproduce the
    single-device geometric update bit-for-bit on every shard;
  * checkpoint round-trip of model-sharded params (the train.py --mesh
    resume path): save -> restore with target shardings (zlib fallback
    codec forced) -> one more step bitwise-equal to the uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS

from repro import optim
from repro.configs import get_config
from repro.core.clipping import dp_clipped_gradients, sharded_clipped_gradients
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import abstract_params, init_params
from repro.launch.hlo_analysis import model_axis_norm_collectives
from repro.launch.inputs import concrete_train_batch
from repro.launch.mesh import named_shard_map
from repro.launch.sharding import group_shard_assignment
from repro.models.transformer import build_model

B, T = 8, 16


def _close(a, b, rtol=2e-4, atol=2e-6):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                                   atol=atol)


def _sharded_clip(m, mesh, params, batch, bsz, mode, execution, assign_arr,
                  trainable_key=None, **mode_kw):
    """Run sharded_clipped_gradients inside shard_map; global outputs."""
    dax = tuple(a for a in mesh.axis_names if a != "model")
    d_size = int(np.prod([mesh.shape[a] for a in dax]))

    def body(params, batch):
        res = sharded_clipped_gradients(
            m.loss_fn, params, batch, m.layout, mode=mode,
            batch_size=bsz // d_size, data_size=d_size, data_axes=dax,
            model_axis="model", shard_assignment=assign_arr,
            trainable_key=trainable_key, execution=execution, **mode_kw)
        return tuple(res)  # plain tuple: out_specs prefix-match

    f = named_shard_map(body, mesh, in_specs=(PS(), PS(dax)),
                        out_specs=(PS(), PS(None, dax), PS(), PS()))
    from repro.core.clipping import ShardedClipResult
    return ShardedClipResult(*jax.jit(f)(params, batch))


def check_clip_parity(m, mesh, params, batch, assign, results):
    assign_arr = jnp.asarray(np.asarray(assign), jnp.int32)
    M = int(mesh.shape["model"])
    th = jnp.linspace(0.3, 0.6, m.layout.num_groups)
    gth = jnp.linspace(0.3, 0.6, M)
    cases = [
        ("per_layer", "bk", dict(thresholds=th), dict(thresholds=th)),
        ("ghost_flat", "bk", dict(flat_threshold=0.5),
         dict(flat_threshold=0.5)),
        ("ghost_flat", "twopass", dict(flat_threshold=0.5),
         dict(flat_threshold=0.5)),
        ("per_group", "bk", dict(group_thresholds=gth),
         dict(group_assignment=assign_arr, group_thresholds=gth)),
        ("per_group", "twopass", dict(group_thresholds=gth),
         dict(group_assignment=assign_arr, group_thresholds=gth)),
    ]
    for mode, execution, skw, rkw in cases:
        name = f"clip_parity_{mode}_{execution}"
        try:
            got = _sharded_clip(m, mesh, params, batch, B, mode, execution,
                                assign_arr, **skw)
            want = dp_clipped_gradients(m.loss_fn, params, batch, m.layout,
                                        mode=mode, batch_size=B,
                                        execution=execution, **rkw)
            np.testing.assert_allclose(np.asarray(got.norms_sq),
                                       np.asarray(want.norms_sq),
                                       rtol=1e-4, atol=1e-7)
            _close(got.grads, want.grads)
            results[name] = "ok"
        except Exception as e:  # noqa: BLE001
            results[name] = f"{type(e).__name__}: {e}"


def _two_steps(m, dpc, params, batch, mesh=None):
    init_fn, step_fn, _ = make_dp_train_step(
        m.loss_fn, m.spec, m.layout, optim.sgd(0.1), dpc, batch_size=B,
        mesh=mesh)
    opt_state, dp_state = init_fn(params)
    step = jax.jit(step_fn)
    p, o, d = params, opt_state, dp_state
    for _ in range(2):
        p, o, d, met = step(p, o, d, batch, jax.random.PRNGKey(5))
    return p, d, met


def check_step_parity(m, mesh, params, batch, assign, results):
    M = int(mesh.shape["model"])
    for mode, nmb in (("per_layer", 1), ("ghost_flat", 1), ("per_group", 1),
                      ("ghost_flat", 2), ("per_group", 2)):
        name = f"step_parity_{mode}" + (f"_mb{nmb}" if nmb > 1 else "")
        try:
            kw = dict(mode=mode, sigma=1.0, sampling_rate=0.1, steps=10,
                      adaptive=True, microbatches=nmb)
            if mode == "per_group":
                kw.update(group_assignment=assign, num_supergroups=M)
            dpc = DPConfig(**kw)
            p1, d1, met1 = _two_steps(m, dpc, params, batch)
            p2, d2, met2 = _two_steps(m, dpc, params, batch, mesh=mesh)
            _close(p1, p2)
            _close(d1.qstate.thresholds, d2.qstate.thresholds)
            np.testing.assert_allclose(float(met1.clip_fraction),
                                       float(met2.clip_fraction), atol=1e-5)
            np.testing.assert_allclose(float(met1.loss), float(met2.loss),
                                       rtol=1e-5)
            results[name] = "ok"
        except Exception as e:  # noqa: BLE001
            results[name] = f"{type(e).__name__}: {e}"


def check_lora(mesh4, results):
    """DP-LoRA trainable_key path on a (2, 2) mesh."""
    name = "clip_parity_lora_ghost_flat"
    try:
        cfg = dataclasses.replace(get_config("qwen3-4b", reduced=True),
                                  lora_rank=4)
        m = build_model(cfg)
        params = init_params(m.spec, jax.random.PRNGKey(0))
        batch = concrete_train_batch(cfg, 4, T, jax.random.PRNGKey(1))
        lay = m.layout
        assign_arr = jnp.asarray(
            np.asarray(group_shard_assignment(lay, 2)), jnp.int32)

        def body(params, batch):
            return tuple(sharded_clipped_gradients(
                m.loss_fn, params, batch, lay, mode="ghost_flat",
                batch_size=2, data_size=2, data_axes=("data",),
                model_axis="model", shard_assignment=assign_arr,
                flat_threshold=0.5, trainable_key="lora"))

        f = named_shard_map(body, mesh4, in_specs=(PS(), PS("data")),
                            out_specs=(PS(), PS(None, "data"), PS(), PS()))
        from repro.core.clipping import ShardedClipResult
        got = ShardedClipResult(*jax.jit(f)(params, batch))
        want = dp_clipped_gradients(m.loss_fn, params, batch, lay,
                                    mode="ghost_flat", batch_size=4,
                                    flat_threshold=0.5, trainable_key="lora")
        assert set(got.grads) == {"lora"}
        np.testing.assert_allclose(np.asarray(got.norms_sq),
                                   np.asarray(want.norms_sq), rtol=1e-4,
                                   atol=1e-7)
        _close(got.grads, want.grads)
        results[name] = "ok"
    except Exception as e:  # noqa: BLE001
        results[name] = f"{type(e).__name__}: {e}"


def check_quantile_sharded(mesh, results):
    """One geometric update from GLOBAL counts: shard-local clip counts +
    the data-plane psum inside update_thresholds must reproduce the
    single-device quantile state exactly (replicated across every shard,
    asserted by the PS() out_spec)."""
    from repro.core.quantile import (clip_counts, init_quantile_state,
                                     update_thresholds)
    name = "quantile_sharded_parity"
    try:
        k = 5
        norms = jax.random.uniform(jax.random.PRNGKey(3), (k, B)) * 0.8
        state = init_quantile_state(np.linspace(0.2, 1.0, k), sigma_b=3.0)
        key = jax.random.PRNGKey(7)
        want = update_thresholds(
            state, clip_counts(norms, state.thresholds), B, key)
        dax = tuple(a for a in mesh.axis_names if a != "model")

        def body(norms_local):
            local = clip_counts(norms_local, state.thresholds)
            return update_thresholds(state, local, B, key,
                                     counts_axes=dax).thresholds

        f = named_shard_map(body, mesh, in_specs=(PS(None, dax),),
                            out_specs=PS())
        got = jax.jit(f)(norms)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(want.thresholds))
        results[name] = "ok"
    except Exception as e:  # noqa: BLE001
        results[name] = f"{type(e).__name__}: {e}"


def check_checkpoint_roundtrip(m, mesh, params, batch, results):
    """train.py --mesh resume path: 2 sharded steps -> save (params STORED
    model-sharded, zlib fallback codec) -> restore with target shardings
    -> step 3 bitwise-equal to the uninterrupted run."""
    import shutil
    import tempfile

    from repro.checkpoint import store as store_mod
    from repro.checkpoint.store import load_checkpoint, save_checkpoint
    from repro.launch.sharding import params_shardings

    name = "checkpoint_roundtrip_sharded"
    had_zstd = store_mod.zstd
    tmp = tempfile.mkdtemp(prefix="ckpt_roundtrip_")
    try:
        dpc = DPConfig(mode="ghost_flat", sigma=1.0, sampling_rate=0.1,
                       steps=10, adaptive=True)
        init_fn, step_fn, _ = make_dp_train_step(
            m.loss_fn, m.spec, m.layout, optim.adam(1e-3), dpc,
            batch_size=B, mesh=mesh)
        pshard = params_shardings(m.spec, mesh)
        step = jax.jit(step_fn,
                       in_shardings=(pshard, None, None, None, None),
                       out_shardings=(pshard, None, None, None))
        opt_state, dp_state = init_fn(params)
        p = jax.device_put(params, pshard)
        key = jax.random.PRNGKey(11)
        for _ in range(2):
            p, opt_state, dp_state, _ = step(p, opt_state, dp_state, batch,
                                             key)

        tree = {"params": p, "opt": opt_state, "dp": dp_state}
        store_mod.zstd = None  # force + cover the stdlib zlib fallback
        path = save_checkpoint(tmp, 2, tree)
        import msgpack
        with open(os.path.join(path, "manifest.msgpack"), "rb") as fh:
            assert msgpack.unpackb(fh.read())["codec"] == "zlib"
        nil = jax.tree_util.tree_map(lambda _: None,
                                     {"opt": opt_state, "dp": dp_state})
        restored = load_checkpoint(
            tmp, 2, tree, shardings={"params": pshard, **nil})
        for leaf, sh in zip(jax.tree_util.tree_leaves(restored["params"]),
                            jax.tree_util.tree_leaves(pshard)):
            assert leaf.sharding == sh, (leaf.sharding, sh)
        # resumed step == uninterrupted step, bitwise
        a = step(p, opt_state, dp_state, batch, key)
        b = step(restored["params"], restored["opt"], restored["dp"],
                 batch, key)
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        results[name] = "ok"
    except Exception as e:  # noqa: BLE001
        results[name] = f"{type(e).__name__}: {e}"
    finally:
        store_mod.zstd = had_zstd
        shutil.rmtree(tmp, ignore_errors=True)


def check_hlo_axis_contract(m, mesh, params, batch, assign, results):
    """Sec 4, asserted from compiled HLO: per-device clipping moves ZERO
    norm information across the model axis; flat clipping must."""
    M = int(mesh.shape["model"])
    params_abs = abstract_params(m.spec)
    batch_abs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
    counts = {}
    for mode in ("ghost_flat", "per_group"):
        name = f"hlo_axis_{mode}"
        try:
            kw = dict(mode=mode, sigma=1.0, sampling_rate=0.1, steps=10,
                      backend="xla")
            if mode == "per_group":
                kw.update(group_assignment=assign, num_supergroups=M)
            init_fn, step_fn, _ = make_dp_train_step(
                m.loss_fn, m.spec, m.layout, optim.adam(1e-3), DPConfig(**kw),
                batch_size=B, mesh=mesh)
            opt_abs, dp_abs = jax.eval_shape(init_fn, params_abs)
            hlo = jax.jit(step_fn).lower(params_abs, opt_abs, dp_abs,
                                         batch_abs,
                                         key_abs).compile().as_text()
            n = sum(r["count"] for r in model_axis_norm_collectives(hlo, mesh))
            counts[mode] = n
            ok = (n == 0) if mode == "per_group" else (n >= 1)
            results[name] = ("ok" if ok else
                             f"model-axis norm collectives = {n}")
        except Exception as e:  # noqa: BLE001
            results[name] = f"{type(e).__name__}: {e}"
    results["hlo_axis_counts"] = counts


def main() -> int:
    assert jax.device_count() >= 8, (
        f"need 8 devices, got {jax.device_count()}; run with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    results: dict = {}
    try:
        cfg = get_config("tiny")
        m = build_model(cfg)
        params = init_params(m.spec, jax.random.PRNGKey(0))
        batch = concrete_train_batch(cfg, B, T, jax.random.PRNGKey(1))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        mesh4 = jax.sharding.Mesh(
            np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
        assign = group_shard_assignment(m.layout, 4)

        check_clip_parity(m, mesh, params, batch, assign, results)
        check_step_parity(m, mesh, params, batch, assign, results)
        check_lora(mesh4, results)
        check_quantile_sharded(mesh, results)
        check_checkpoint_roundtrip(m, mesh, params, batch, results)
        check_hlo_axis_contract(m, mesh, params, batch, assign, results)
    except Exception:  # noqa: BLE001
        results["fatal"] = traceback.format_exc()[-2000:]
    print("RESULT " + json.dumps(results), flush=True)
    failed = [k for k, v in results.items()
              if k != "hlo_axis_counts" and v != "ok"]
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
