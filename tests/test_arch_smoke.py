"""Per-architecture smoke: REDUCED variant forward/train/decode on CPU.

One test per assigned architecture (task requirement): instantiate the
reduced config, run one forward + one DP train step, assert output shapes
and finiteness; plus a two-token decode against the cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_config
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import init_params
from repro.launch.inputs import concrete_train_batch
from repro.models.transformer import build_model

B, T = 2, 16


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, reduced=True)
            m = build_model(cfg)
            params = init_params(m.spec, jax.random.PRNGKey(0))
            cache[arch] = (cfg, m, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, built):
    cfg, m, params = built(arch)
    batch = concrete_train_batch(cfg, B, T, jax.random.PRNGKey(1))
    th = m.layout.pack_value(jnp.inf, B)
    losses = m.loss_fn(params, batch, th)
    assert losses.shape == (B,)
    assert np.isfinite(np.asarray(losses)).all()
    assert m.layout.num_groups > 0
    assert m.num_params > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_dp_train_step(arch, built):
    cfg, m, params = built(arch)
    batch = concrete_train_batch(cfg, 4, T, jax.random.PRNGKey(2))
    dpc = DPConfig(mode="per_layer", sigma=0.8, sampling_rate=0.1, steps=10,
                   adaptive=True, init_threshold=1.0)
    init_fn, step_fn, plan = make_dp_train_step(
        m.loss_fn, getattr(m, "dp_spec", m.spec), m.layout,
        optim.adam(1e-3), dpc, batch_size=4,
        trainable_key=getattr(m, "trainable_key", None))
    opt_state, dp_state = init_fn(params)
    p2, _, dp2, met = jax.jit(step_fn)(params, opt_state, dp_state, batch,
                                       jax.random.PRNGKey(3))
    assert np.isfinite(float(met.loss))
    for leaf in jax.tree_util.tree_leaves(p2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)))
    assert moved
    assert int(dp2.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_two_steps(arch, built):
    cfg, m, params = built(arch)
    cache = m.init_cache(B, 64)
    tok = jax.random.randint(jax.random.PRNGKey(4), (B, 1), 0,
                             cfg.vocab_size)
    step = jax.jit(m.serve_step)
    logits, cache = step(params, cache, {"token": tok})
    logits2, cache = step(params, cache, {"token": tok})
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache["pos"][0]) == 2
