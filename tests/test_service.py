"""The crash-safe training service: fault matrix, ledger semantics, budget
enforcement, retry/backoff. The kill -9 (os._exit) variant of the same
matrix runs in scripts/ci.sh through the service CLI; here the crashes are
in-process (FaultInjector mode="raise") so tier-1 pays one compile."""
import json
import os
import zlib

import numpy as np
import pytest

import faults
from repro.core import accounting
from repro.launch import service as svc_mod
from repro.launch.service import (
    BudgetExhausted, FaultInjector, LedgerCorrupt, PrivacyLedger,
    SimulatedCrash, with_retries)


@pytest.fixture(scope="module")
def runtime(tmp_path_factory):
    args = faults.make_args(str(tmp_path_factory.mktemp("rt")))
    return faults.shared_runtime(args)


@pytest.fixture(scope="module")
def reference(runtime, tmp_path_factory):
    """Uninterrupted 8-step run: the oracle every faulted run must match."""
    d = str(tmp_path_factory.mktemp("ref"))
    outcome, status = faults.run_service(faults.make_args(d), runtime)
    assert outcome == "complete" and status["committed"] == 8
    return d


# ---------------------------------------------------------------------------
# The fault matrix: kill at each injection point, resume, demand bitwise
# equality with the uninterrupted run.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("point,step", [
    ("pre-ledger-append", 4),    # before the step's spend is durable
    ("post-ledger-append", 4),   # spend durable, update NOT committed
    ("post-ledger-append", 5),   # ditto, off the checkpoint boundary
    ("post-step-commit", 4),     # update done, checkpoint may lag
    ("pre-ckpt-rename", 6),      # mid checkpoint publish (staged, unrenamed)
])
def test_fault_matrix_bitwise_resume(runtime, reference, tmp_path, point,
                                     step):
    d = str(tmp_path)
    args = faults.make_args(d)
    tag, _ = faults.run_with_crash_and_resume(args, runtime, point, step)
    assert tag == f"{point}@{step}"
    # durable state identical to the run that never crashed: params, opt
    # state, thresholds, sampler stream (manifest meta), ledger bytes
    assert faults.state_digest(d) == faults.state_digest(reference)
    _, tree_f, _ = faults.load_final_tree(args, runtime, d)
    _, tree_r, _ = faults.load_final_tree(args, runtime, reference)
    faults.assert_trees_bitwise_equal(tree_f, tree_r)


def test_ledger_never_undercounts_at_crash(runtime, tmp_path):
    """At the instant of ANY crash, ledger records >= committed steps: the
    ledger may over-count by the in-flight step, never under-count."""
    for point, step in [("pre-ledger-append", 4), ("post-ledger-append", 4),
                        ("post-step-commit", 4), ("pre-ckpt-rename", 6)]:
        d = str(tmp_path / f"{point}-{step}")
        outcome, _ = faults.run_service(
            faults.make_args(d), runtime,
            fault=FaultInjector(point=point, step=step, mode="raise"))
        assert outcome == "crashed"
        records = faults.ledger_records(d)
        committed = faults.committed_steps(d)
        assert len(records) >= committed
        # post-append pre-commit is the over-count gap the resume closes
        if point == "post-ledger-append":
            assert len(records) == step + 1 and committed < step + 1


def test_replayed_epsilon_is_monotone(reference):
    recs = faults.ledger_records(reference)
    assert [r["step"] for r in recs] == list(range(8))
    acct = accounting.RdpAccountant()
    eps_seq = []
    for r in recs:
        acct.spend(r["q"], r["sigma"])
        eps_seq.append(acct.epsilon(1e-5))
    assert all(b >= a for a, b in zip(eps_seq, eps_seq[1:]))
    assert eps_seq[0] > 0


def test_budget_exhaustion_refuses_cleanly(runtime, tmp_path):
    """A budget between the 5- and 6-step spend stops the run at exactly 5
    committed steps, with a checkpoint written and the refusal durable
    across a restart (no over-spend, no crash)."""
    acct = accounting.RdpAccountant()
    q, sigma = runtime.plan.config.sampling_rate, runtime.plan.sigma
    eps_at = []
    for _ in range(6):
        acct.spend(q, sigma)
        eps_at.append(acct.epsilon(1e-5))
    budget = (eps_at[4] + eps_at[5]) / 2.0
    d = str(tmp_path)
    args = faults.make_args(d, budget_eps=budget)
    outcome, msg = faults.run_service(args, runtime)
    assert outcome == "budget_exhausted", msg
    assert faults.committed_steps(d) == 5
    records = faults.ledger_records(d)
    assert len(records) == 5  # the refused 6th step was never ledgered
    _, eps_spent = accounting.replay_ledger(records, 1e-5)
    assert eps_spent <= budget
    # enforcement survives the restart: resume refuses immediately
    outcome2, _ = faults.run_service(args, runtime)
    assert outcome2 == "budget_exhausted"
    assert faults.committed_steps(d) == 5


def test_resume_after_budget_raise_with_higher_budget(runtime, tmp_path):
    """Raising the budget lets the same ledger continue spending."""
    d = str(tmp_path)
    acct = accounting.RdpAccountant()
    q, sigma = runtime.plan.config.sampling_rate, runtime.plan.sigma
    for _ in range(4):
        acct.spend(q, sigma)
    budget = acct.epsilon(1e-5) + 1e-6
    outcome, _ = faults.run_service(
        faults.make_args(d, budget_eps=budget), runtime)
    assert outcome == "budget_exhausted"
    committed_before = faults.committed_steps(d)
    outcome2, status = faults.run_service(
        faults.make_args(d, budget_eps=8.0), runtime)
    assert outcome2 == "complete" and status["committed"] == 8
    assert faults.committed_steps(d) == 8 > committed_before


# ---------------------------------------------------------------------------
# Torn files and graceful degradation.
# ---------------------------------------------------------------------------


def _corrupt_one_byte(path):
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))


def test_torn_checkpoint_falls_back_and_recovers(runtime, reference,
                                                 tmp_path):
    """Corrupting the newest checkpoint's shard is DETECTED (crc) and the
    service falls back to the previous verified step, then re-trains the
    gap deterministically — final state still bitwise equals the oracle."""
    d = str(tmp_path)
    args = faults.make_args(d)
    outcome, _ = faults.run_service(args, runtime)
    assert outcome == "complete"
    ckpt = os.path.join(d, "ckpt", "step_00000008")
    shard = next(os.path.join(ckpt, f) for f in sorted(os.listdir(ckpt))
                 if f.startswith("shard_"))
    _corrupt_one_byte(shard)
    assert faults.committed_steps(d) == 6  # fallback target
    outcome2, status = faults.run_service(args, runtime)
    assert outcome2 == "complete" and status["committed"] == 8
    assert faults.state_digest(d) == faults.state_digest(reference)


def test_ledger_torn_tail_is_discarded(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    led = PrivacyLedger(path)
    recs = [{"step": i, "q": 0.01, "sigma": 1.0,
             "orders_crc": svc_mod._ORDERS_CRC} for i in range(3)]
    for r in recs:
        led.append(r)
    led.close()
    with open(path, "ab") as f:  # a half-written append, as a crash leaves
        f.write(b'{"step":3,"q":0.0')
    out = PrivacyLedger(path).replay()
    assert [r["step"] for r in out] == [0, 1, 2]
    # the torn tail was truncated away so the NEXT append starts clean
    led2 = PrivacyLedger(path)
    led2.append({"step": 3, "q": 0.01, "sigma": 1.0,
                 "orders_crc": svc_mod._ORDERS_CRC})
    led2.close()
    assert [r["step"] for r in PrivacyLedger(path).replay()] == [0, 1, 2, 3]


def test_ledger_midfile_corruption_refuses(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    led = PrivacyLedger(path)
    for i in range(4):
        led.append({"step": i, "q": 0.01, "sigma": 1.0,
                    "orders_crc": svc_mod._ORDERS_CRC})
    led.close()
    with open(path, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    lines[1] = b'{"step":1,"q":0.999,"sigma":0.0} deadbeef\n'  # bad crc
    with open(path, "wb") as f:
        f.writelines(lines)
    with pytest.raises(LedgerCorrupt):
        PrivacyLedger(path).replay()


def test_ledger_step_gap_refuses(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    led = PrivacyLedger(path)
    for step in (0, 2):  # gap at 1
        led.append({"step": step, "q": 0.01, "sigma": 1.0,
                    "orders_crc": svc_mod._ORDERS_CRC})
    led.close()
    with pytest.raises(LedgerCorrupt):
        PrivacyLedger(path).replay()


def test_retry_backoff_caps_and_gives_up():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 3:
            raise OSError("transient")
        return "ok"

    out = with_retries(flaky, retries=4, base_delay=0.05, max_delay=0.15,
                       sleep=sleeps.append)
    assert out == "ok" and calls["n"] == 4
    assert sleeps == [0.05, 0.1, 0.15]  # exponential, capped

    with pytest.raises(OSError):
        with_retries(lambda: (_ for _ in ()).throw(OSError("hard")),
                     retries=2, base_delay=0.01, sleep=sleeps.append)


def test_fault_injector_validation():
    with pytest.raises(ValueError):
        FaultInjector(point="no-such-point", step=1)
    inj = FaultInjector.parse("post-ledger-append:7", mode="raise")
    with pytest.raises(SimulatedCrash):
        inj.fire("post-ledger-append", 7)
    inj.fire("post-ledger-append", 6)  # wrong step: no-op
    inj.fire("pre-ledger-append", 7)  # wrong point: no-op
    assert FaultInjector.parse(None).point is None


def test_mechanism_mismatch_refuses(runtime, tmp_path):
    """A ledger spent at a different (q, sigma) must not silently continue
    under this service's mechanism."""
    d = str(tmp_path)
    os.makedirs(d, exist_ok=True)
    led = PrivacyLedger(os.path.join(d, "ledger.jsonl"))
    led.append({"step": 0, "q": 0.5, "sigma": 2.0,
                "orders_crc": svc_mod._ORDERS_CRC})
    led.close()
    with pytest.raises(LedgerCorrupt):
        svc_mod.TrainService(faults.make_args(d), runtime=runtime,
                             sleep=lambda _: None)
