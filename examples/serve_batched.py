"""Continuous-batching serving demo: a ragged request stream through the
slot-pool DecodeEngine, across architecture families (GQA KV cache, MLA
latent cache, SSM O(1) recurrent state). Four requests share three slots,
so the last one is admitted MID-FLIGHT into a recycled slot; every output
is token-for-token what the request would produce alone, unpadded.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.spec import init_params
from repro.launch.engine import DecodeEngine
from repro.launch.inputs import synthetic_requests

from repro.models.transformer import build_model

for arch in ("qwen3-4b", "deepseek-v3-671b", "rwkv6-7b"):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    reqs = synthetic_requests(cfg.vocab_size, 4, min_len=2, max_len=8,
                              seed=1)
    t0 = time.time()
    engine = DecodeEngine(model, params, num_slots=3, cache_len=64)
    rids = [engine.submit(r, max_new_tokens=24) for r in reqs]
    done = engine.run()
    dt = time.time() - t0
    kind = {"gqa": "KV cache", "mla": "MLA latent cache",
            "none": "recurrent state"}[cfg.attention_kind]
    stats = engine.stats
    print(f"{arch:20s} [{kind:16s}] lens={[len(r) for r in reqs]} "
          f"4x24 tokens over 3 slots in {dt:5.2f}s "
          f"({stats['decode_dispatches']} decode dispatches)  "
          f"sample: {done[rids[0]].tokens[:8]}")
