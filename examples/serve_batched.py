"""Batched serving demo: greedy decode with the KV/state cache across
architecture families (GQA cache, MLA latent cache, SSM O(1) state).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.spec import init_params
from repro.launch.serve import greedy_decode
from repro.models.transformer import build_model

for arch in ("qwen3-4b", "deepseek-v3-671b", "rwkv6-7b"):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = greedy_decode(model, params, prompts, gen=24, cache_len=64)
    dt = time.time() - t0
    kind = {"gqa": "KV cache", "mla": "MLA latent cache",
            "none": "recurrent state"}[cfg.attention_kind]
    print(f"{arch:20s} [{kind:16s}] 4x24 tokens in {dt:5.2f}s  "
          f"sample: {np.asarray(toks)[0, :8].tolist()}")
