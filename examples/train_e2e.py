"""End-to-end driver: train a LM for a few hundred DP steps with adaptive
per-layer clipping, checkpoint, and report the spent privacy budget.

Defaults run a ~1.7M-param qwen3-family reduced model for 200 steps on CPU
(a few minutes); pass --arch/--steps/--batch to scale up — the same driver
runs any assigned architecture.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    defaults = ["--arch", "qwen3-4b", "--reduced", "--steps", "200",
                "--batch", "16", "--seq", "64", "--microbatches", "2",
                "--checkpoint-dir", "/tmp/repro_e2e_ckpt",
                "--log-every", "20"]
    # user args win
    sys.argv = [sys.argv[0]] + defaults + argv
    raise SystemExit(main())
