"""Multi-tenant DP-LoRA serving, end to end in one process:

  1. fine-tune TWO tiny adapters through the crash-safe training service
     at DIFFERENT privacy budgets (epsilon 2 and epsilon 8) — each run
     publishes adapter-only checkpoints to its <service_dir>/publish;
  2. serve both tenants CONCURRENTLY from one engine: one base model, one
     tenant-stacked adapter buffer, per-slot tenant ids routing each
     request through its own adapter inside a single pooled dispatch;
  3. keep training tenant B a little longer and hot-swap its freshly
     published adapter into the LIVE engine mid-traffic — requests
     already decoding finish on the old version, new requests pick up
     the new one, and the installed weights are verified bitwise
     (crc32) against the published checkpoint. Zero recompilations
     throughout (the script asserts it).

    PYTHONPATH=src python examples/multi_tenant_serve.py

Walkthrough: docs/serving.md ("Tenant onboarding").
"""
import tempfile

import numpy as np

from repro.launch.engine import DecodeEngine
from repro.launch.inputs import synthetic_requests
from repro.launch.service import TrainService, build_service_parser
from repro.launch.swap import AdapterWatcher

# ---------------------------------------------------------------------------
# 1. Two private fine-tunes at different budgets, publishing adapters.
# ---------------------------------------------------------------------------

ARGV = ["--arch", "tiny", "--lora-rank", "4", "--batch", "8", "--seq", "32",
        "--docs", "64", "--checkpoint-every", "4", "--log-every", "100"]


def service(dirname: str, *, epsilon: float, steps: int, seed: int,
            calib_steps: int | None = None, runtime=None) -> TrainService:
    argv = ARGV + ["--service-dir", dirname, "--epsilon", str(epsilon),
                   "--steps", str(steps), "--seed", str(seed)]
    if calib_steps is not None:
        # sigma sized for the FULL horizon so the run can be continued
        # later without blowing the budget
        argv += ["--calib-steps", str(calib_steps)]
    args = build_service_parser().parse_args(argv)
    return TrainService(args, runtime=runtime, sleep=lambda _: None)


root = tempfile.mkdtemp(prefix="mt-serve-")
dir_a, dir_b = f"{root}/tenant-a", f"{root}/tenant-b"

svc_a = service(dir_a, epsilon=2.0, steps=8, seed=0)
svc_a.run()
print(f"tenant A trained: epsilon {svc_a.epsilon():.2f} / 2.0")

svc_b = service(dir_b, epsilon=8.0, steps=8, seed=1, calib_steps=12)
svc_b.run()
print(f"tenant B trained: epsilon {svc_b.epsilon():.2f} / 8.0")

# ---------------------------------------------------------------------------
# 2. One engine, both tenants. The serving model is the TRAINING model's
#    config (same lora_rank) — the stacked adapter buffer's leaves must
#    match the published trees.
# ---------------------------------------------------------------------------

model, params = svc_a.runtime.model, svc_a.params
base_params = {k: v for k, v in params.items() if k != "lora"}
cfg = svc_a.runtime.cfg

eng = DecodeEngine(model, base_params, num_slots=4, cache_len=64,
                   prefill_chunk=8, max_tenants=3)
ten_a = eng.add_tenant(name="tenant-a")
ten_b = eng.add_tenant(name="tenant-b")
watch_a = AdapterWatcher(eng, ten_a, f"{dir_a}/publish")
watch_b = AdapterWatcher(eng, ten_b, f"{dir_b}/publish")
for w, t in ((watch_a, "A"), (watch_b, "B")):
    got = w.poll()
    print(f"tenant {t}: installed published step {got.step} "
          f"(bitwise verified: {got.verified})")

reqs = synthetic_requests(cfg.vocab_size, 8, min_len=4, max_len=12, seed=7)
rids = {eng.submit(r, max_new_tokens=8,
                   tenant=(ten_a if i % 2 == 0 else ten_b)): i
        for i, r in enumerate(reqs[:4])}
done = eng.run()
print(f"served {len(done)} requests across 2 tenants in "
      f"{eng.stats['decode_dispatches']} pooled decode dispatches")
traces0 = dict(eng.trace_counts)  # warmup done: nothing below may retrace

# ---------------------------------------------------------------------------
# 3. Train tenant B further, then hot-swap mid-traffic.
# ---------------------------------------------------------------------------

svc_b2 = service(dir_b, epsilon=8.0, steps=12, seed=1, calib_steps=12,
                 runtime=svc_b.runtime)      # resumes from its checkpoint
svc_b2.run()
print(f"tenant B continued: epsilon {svc_b2.epsilon():.2f} / 8.0")

# traffic in flight while the swap lands: submit, pump a few steps, poll
for i, r in enumerate(reqs[4:]):
    rids[eng.submit(r, max_new_tokens=8,
                    tenant=(ten_a if i % 2 == 0 else ten_b))] = 4 + i
eng.run(max_steps=2)                         # old-version decode under way
swap = watch_b.poll()
print(f"hot swap: tenant B -> step {swap.step} v{swap.version} "
      f"(bitwise verified: {swap.verified}); in-flight requests drain "
      f"on the old version")
eng.run()

assert dict(eng.trace_counts) == traces0, "serving retraced!"
sa, sb = eng.tenant_stats(ten_a), eng.tenant_stats(ten_b)
print(f"tenant A: v{sa['version']} done={sa['requests_done']} "
      f"tokens={sa['tokens_out']}")
print(f"tenant B: v{sb['version']} done={sb['requests_done']} "
      f"tokens={sb['tokens_out']} swaps={sb['swaps']}")
print(f"engine: admits={eng.stats['tenants_admitted']} "
      f"swaps={eng.stats['adapter_swaps']} "
      f"traces={sum(eng.trace_counts.values())} (all from warmup)")

toks = np.full((len(reqs), 8), -1, np.int32)
for rid, i in rids.items():
    c = eng.completions()[rid]
    toks[i, :len(c.tokens)] = c.tokens
print(toks)
